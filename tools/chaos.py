"""Chaos smoke: a serving session under a random-but-seeded fault plan.

Two phases, each gated (DESIGN.md §11); any gate failure exits nonzero:

  A. ENGINE LADDER — a gemm dispatch stream on a fresh Engine under
     precompile/aot_launch faults.  Gates: every output allclose to the
     no-fault reference, and at least one degradation rung exercised
     (quarantined retry or XLA fallback).
  B. SERVING ISOLATION — the gpt2 smoke server driven through the
     continuous scheduler under pool_lease/scheduler_step faults, against
     a no-fault serial reference.  Gates: every submitted request
     resolves (tokens or a typed RequestError — nothing lost, nothing
     hung), non-faulted requests' tokens are identical to serial, and
     the kv pool's ``leases_active`` returns to 0 after drain + close.

Usage:  PYTHONPATH=src python tools/chaos.py [--seed N]

The plan is deterministic in the seed (CI runs seeds 0..2), so a failing
seed reproduces locally bit-for-bit.
"""
from __future__ import annotations

import argparse
import sys

sys.path.insert(0, "src")

import numpy as np  # noqa: E402

from repro.runtime import faults  # noqa: E402

_FAILURES: list[str] = []


def _gate(ok: bool, label: str) -> None:
    print(f"  [{'PASS' if ok else 'FAIL'}] {label}")
    if not ok:
        _FAILURES.append(label)


def phase_a_engine(seed: int) -> None:
    """Kernel degradation ladder under compile/launch faults."""
    import jax.numpy as jnp

    from repro.vortex import Engine

    print(f"phase A: engine ladder (seed={seed})")
    rng = np.random.default_rng(seed)
    extents = [int(m) for m in rng.integers(17, 300, size=6)]
    w = jnp.asarray(rng.normal(size=(64, 64)), jnp.float32)
    xs = [
        jnp.asarray(rng.normal(size=(m, 64)), jnp.float32) for m in extents
    ]

    def run_stream(eng):
        return [np.asarray(eng.dispatch("gemm", x, w)) for x in xs]

    # No-fault reference (denylist off: each phase must be hermetic).
    ref_eng = Engine("host_cpu", empirical_levels=(), denylist_persist=False)
    ref = run_stream(ref_eng)

    plan = faults.FaultPlan.random(
        seed, sites=("precompile", "aot_launch"), rate=0.3, horizon=40
    )
    eng = Engine("host_cpu", empirical_levels=(), denylist_persist=False)
    with faults.installed(plan):
        try:
            got = run_stream(eng)
        except Exception as exc:  # ladder must absorb every injection
            _gate(False, f"no unhandled exception from dispatch ({exc!r})")
            return
    stats = eng.stats()["gemm"]
    rungs = stats["quarantined"] + stats["fallbacks"]
    print(
        f"  plan fired {len(plan.fired)} fault(s) {plan.fired}; "
        f"quarantined={stats['quarantined']} fallbacks={stats['fallbacks']}"
    )
    _gate(len(plan.fired) >= 1, "fault plan fired at least once")
    _gate(rungs >= 1, "at least one degradation rung exercised")
    _gate(
        all(np.allclose(g, r, atol=1e-5) for g, r in zip(got, ref)),
        "faulted outputs allclose to no-fault reference",
    )


def phase_b_serving(seed: int) -> None:
    """Per-request isolation under pool/scheduler faults."""
    import jax
    from jax.sharding import Mesh

    from repro.launch.scheduler import ContinuousScheduler
    from repro.launch.serve import Request, RequestError, VortexServer
    from repro.models.registry import get_smoke_config

    print(f"phase B: serving isolation (seed={seed})")
    cfg = get_smoke_config("paper-gpt2-124m")
    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1), ("data", "model"))
    server = VortexServer(cfg, mesh, max_cache=256)
    rng = np.random.default_rng(seed)
    reqs = [
        Request(
            tokens=rng.integers(0, cfg.vocab, (1, int(s))).astype(np.int32),
            max_new=6,
        )
        for s in rng.integers(24, 48, size=6)
    ]

    # Serial no-fault reference, and a warm pass so the faulted run's
    # executables are compiled (faults target serving sites, not XLA).
    serial = [server.generate(r) for r in reqs]

    plan = faults.FaultPlan.random(
        seed, sites=("pool_lease", "scheduler_step"), rate=0.04, horizon=60
    )
    # The random draw can land only on occurrences the short smoke run
    # never reaches; guarantee one early scheduler fault (deterministic in
    # the seed) so the isolation gates are never vacuous.
    spec = {site: set(occs) for site, occs in plan.spec.items()}
    spec.setdefault("scheduler_step", set()).add(
        2 + int(np.random.default_rng(seed + 1).integers(0, 4))
    )
    plan = faults.FaultPlan(spec)
    sched = ContinuousScheduler(server, batch_rows=8)
    with faults.installed(plan):
        rids = [sched.submit(r) for r in reqs]
        try:
            results = sched.drain()
        except Exception as exc:
            _gate(False, f"no unhandled exception from drain ({exc!r})")
            sched.close()
            return
    sched.close()
    pool = server.kv_pool.stats()
    errors = {
        rid for rid, out in results.items() if isinstance(out, RequestError)
    }
    matched = sum(
        1
        for rid, r in zip(rids, serial)
        if rid not in errors and np.array_equal(results[rid], r)
    )
    print(
        f"  plan fired {len(plan.fired)} fault(s) {plan.fired}; "
        f"{len(results)} resolved, {len(errors)} typed error(s), "
        f"{matched} token-identical to serial; "
        f"leases_active={pool['leases_active']}"
    )
    _gate(len(plan.fired) >= 1, "fault plan fired at least once")
    _gate(
        set(rids) == set(results),
        "every submitted request resolved (tokens or RequestError)",
    )
    _gate(
        matched == len(rids) - len(errors),
        "non-faulted requests token-identical to serial",
    )
    _gate(pool["leases_active"] == 0, "leases_active == 0 after drain+close")


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    phase_a_engine(args.seed)
    phase_b_serving(args.seed)
    if _FAILURES:
        print(f"chaos: {len(_FAILURES)} gate(s) FAILED: {_FAILURES}")
        return 1
    print("chaos: all gates passed")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
