"""Micro-harness: lower grad(chunked_attention) on the 512-dev production
mesh with deepseek-like shapes and rank collectives, for rapid sharding
iteration without recompiling the whole model."""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import re, sys
sys.path.insert(0, "src")

import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.launch.mesh import make_production_mesh
from repro.roofline import hlo_parse as hp
from repro.kernels.ref import chunked_attention

variant = sys.argv[1] if len(sys.argv) > 1 else "v0"

mesh = make_production_mesh()
b, H, S, d, dv = 16, 128, 4096, 192, 128

def loss(q, k, v):
    out = chunked_attention(q, k, v, causal=True, chunk=1024)
    return jnp.sum(out.astype(jnp.float32) ** 2)

qkv_spec = P("data", "model", None, None)
sh = NamedSharding(mesh, qkv_spec)

def run(fn):
    g = jax.grad(fn, argnums=(0, 1, 2))
    specs = (jax.ShapeDtypeStruct((b, H, S, d), jnp.bfloat16),
             jax.ShapeDtypeStruct((b, H, S, d), jnp.bfloat16),
             jax.ShapeDtypeStruct((b, H, S, dv), jnp.bfloat16))
    comp = jax.jit(g, in_shardings=(sh, sh, sh),
                   out_shardings=(sh, sh, sh)).lower(*specs).compile()
    costs = hp.parse_hlo_costs(comp.as_text())
    print(f"{variant}: coll {costs.collective_bytes/1e9:.1f} GB/dev  "
          f"flops {costs.flops/1e12:.2f} TF/dev  mem {costs.memory_bytes/1e9:.1f} GB/dev")
    for k2, v2 in sorted(costs.collective_by_kind.items(), key=lambda x:-x[1]):
        print(f"   {k2:20s} {v2/1e9:10.1f} GB")

if variant == "v0":
    run(loss)
elif variant == "v1":
    # remat the whole attention (recompute in bwd instead of saving/psum)
    run(lambda q, k, v: jnp.sum(
        jax.checkpoint(
            lambda q_, k_, v_: chunked_attention(q_, k_, v_, causal=True, chunk=1024)
        )(q, k, v).astype(jnp.float32) ** 2))
elif variant == "v2":
    # constrain q/k/v inside before attention
    def f(q, k, v):
        c = lambda t: jax.lax.with_sharding_constraint(t, qkv_spec)
        out = chunked_attention(c(q), c(k), c(v), causal=True, chunk=1024)
        out = jax.lax.with_sharding_constraint(out, qkv_spec)
        return jnp.sum(out.astype(jnp.float32) ** 2)
    run(f)
