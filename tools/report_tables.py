"""Generate the EXPERIMENTS.md §Roofline markdown table from the dry-run
JSON and splice it over the <!-- ROOFLINE_TABLE --> marker.

    python tools/report_tables.py results/dryrun_final.json [--write]
"""
import json
import sys


def table(results: dict) -> str:
    rows = []
    head = (
        "| arch | shape | dominant | compute_s | memory_s | collective_s |"
        " useful | state GiB/dev | action |\n"
        "|---|---|---|---|---|---|---|---|---|\n"
    )
    actions = {
        ("collective", "train"): "overlap FSDP gathers / fewer microbatches",
        ("collective", "prefill"): "head-shard KV, pin chunk scan",
        ("collective", "decode"): "shard_map flash-decode",
        ("memory", "train"): "remat policy: save matmul outputs",
        ("memory", "prefill"): "fuse attention chunks (Pallas on TPU)",
        ("memory", "decode"): "at HBM floor (cache streaming)",
        ("compute", "train"): "near roofline — tune MXU tile via Vortex",
        ("compute", "prefill"): "near roofline",
        ("compute", "decode"): "near roofline",
    }
    for key in sorted(results):
        v = results[key]
        if v.get("mesh") != "pod16x16":
            continue
        if "skipped" in v:
            rows.append(
                f"| {v['arch']} | {v['shape']} | — | — | — | — | — | — | "
                f"skipped: sub-quadratic rule |"
            )
            continue
        if "roofline" not in v:
            continue
        r = v["roofline"]
        kind = (
            "train" if v["shape"].startswith("train")
            else "prefill" if "prefill" in v["shape"] else "decode"
        )
        act = actions.get((r["dominant"], kind), "")
        rows.append(
            f"| {v['arch']} | {v['shape']} | **{r['dominant']}** | "
            f"{r['compute_s']:.4g} | {r['memory_s']:.4g} | "
            f"{r['collective_s']:.4g} | {r['useful_ratio']:.3f} | "
            f"{v['state_gib_per_device']:.2f} | {act} |"
        )
    return head + "\n".join(rows)


def main():
    path = sys.argv[1] if len(sys.argv) > 1 else "results/dryrun_final.json"
    with open(path) as f:
        results = json.load(f)
    md = table(results)
    if "--write" in sys.argv:
        with open("EXPERIMENTS.md") as f:
            doc = f.read()
        marker = "<!-- ROOFLINE_TABLE -->"
        assert marker in doc
        doc = doc.replace(marker, marker + "\n\n" + md)
        with open("EXPERIMENTS.md", "w") as f:
            f.write(doc)
        print("EXPERIMENTS.md updated")
    else:
        print(md)


if __name__ == "__main__":
    main()
