"""Debug tool: compile one dry-run cell and rank its collectives by
(bytes x trip multiplier). Usage:
   python tools/collective_topk.py <arch> <shape> [topk]
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import re
import sys

sys.path.insert(0, "src")

from repro.launch.dryrun import lower_cell  # noqa: E402
import repro.launch.dryrun as dr  # noqa: E402
from repro.roofline import hlo_parse as hp  # noqa: E402


def main():
    arch, shape = sys.argv[1], sys.argv[2]
    topk = int(sys.argv[3]) if len(sys.argv) > 3 else 15
    # reuse lower_cell internals but keep the compiled text
    import repro.launch.dryrun as d
    from repro.models.registry import get_config
    cfg = get_config(arch)

    # monkeypatch roofline_report to capture hlo text
    captured = {}
    orig = d.roofline_report
    def wrap(**kw):
        captured["hlo"] = kw["hlo_text"]
        return orig(**kw)
    d.roofline_report = wrap
    d.lower_cell(arch, shape, multi_pod=(len(sys.argv)>4 and sys.argv[4]=="multi"))
    text = captured["hlo"]

    comps = hp._split_computations(text)
    entry = None
    for line in text.splitlines():
        m = re.match(r"^ENTRY\s+%?([\w\.\-]+)", line.strip())
        if m:
            entry = m.group(1)
            break
    rows = []

    def visit(name, mult, path):
        comp = comps.get(name)
        if comp is None:
            return
        for op in comp.ops:
            oc = op.opcode
            if any(oc.startswith(c) for c in hp._COLLECTIVES):
                nbytes = hp._shape_bytes(op.shape_str)
                rows.append((nbytes * mult, oc, op.shape_str[:60], mult,
                             "/".join(path[-2:])))
            if oc == "while":
                mc = dict(re.findall(r"(body|condition)=%?([\w\.\-]+)",
                                     op.rest))
                n = hp._trip_count(comps[mc["condition"]]) if mc.get(
                    "condition") in comps else 1
                if mc.get("body"):
                    visit(mc["body"], mult * n, path + [f"x{n}"])
            else:
                for m2 in hp._CALL_RE.finditer(op.rest):
                    if m2.group(1) != name:
                        visit(m2.group(1), mult, path)

    visit(entry, 1.0, ["entry"])
    rows.sort(reverse=True)
    total = sum(r[0] for r in rows)
    print(f"total collective bytes/dev: {total/1e9:.1f} GB over {len(rows)} op-instances")
    for b, oc, sh, mult, path in rows[:topk]:
        print(f"  {b/1e9:9.2f} GB  x{mult:6.0f}  {oc:20s} {sh:60s} [{path}]")


if __name__ == "__main__":
    main()
