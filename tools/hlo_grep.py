import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import re, sys
sys.path.insert(0, "src")
import repro.launch.dryrun as d

arch, shape, pat = sys.argv[1], sys.argv[2], sys.argv[3]
captured = {}
orig = d.roofline_report
def wrap(**kw):
    captured["hlo"] = kw["hlo_text"]
    return orig(**kw)
d.roofline_report = wrap
d.lower_cell(arch, shape, multi_pod=False)
text = captured["hlo"]
n = 0
for line in text.splitlines():
    if re.search(pat, line):
        print(line.strip()[:400])
        n += 1
        if n >= int(sys.argv[4]) if len(sys.argv) > 4 else n >= 6:
            break
