"""Shared timing utilities for the benchmark harness."""
from __future__ import annotations

import time

import jax

__all__ = ["time_call", "emit"]


def time_call(fn, *args, repeats: int = 5, warmup: int = 2) -> float:
    """Best-of-N wall-clock seconds for fn(*args) (block_until_ready)."""
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        best = min(best, time.perf_counter() - t0)
    return best


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    """One CSV line per benchmark result: name,us_per_call,derived."""
    print(f"{name},{us_per_call:.2f},{derived}")
