"""Paper Fig. 3 / Table 6 — off-sample degradation of sample-driven tuning.

The sample-driven compiler is tuned for M in [128, 256) (the paper's
Table 6 setup); runtime M sweeps [1, 384).  Vortex (sample-free) must show
a larger advantage on the ranges OUTSIDE the tuned window.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.core import GemmWorkload, HOST_CPU, VortexKernel
from repro.core.baselines import SampleDrivenCompiler
from benchmarks.util import emit, time_call

N, K = 768, 2304 // 2  # paper's BERT GEMM (K halved to stay CPU-friendly)


def main() -> None:
    wl = GemmWorkload(M=None, N=N, K=K)
    vortex = VortexKernel(HOST_CPU, wl)
    sampled = SampleDrivenCompiler(
        HOST_CPU, wl, samples=[128, 160, 192, 224, 255],
        search_budget=3, repeats=2,
    )
    rng = np.random.default_rng(1)
    ranges = {"in[128,256)": range(130, 256, 25),
              "out[0,128)": range(5, 128, 24),
              "out[256,384)": range(260, 384, 25)}
    for label, ms in ranges.items():
        sps, pads = [], []
        for m in ms:
            a = jnp.asarray(rng.normal(size=(m, K)), jnp.float32)
            b = jnp.asarray(rng.normal(size=(K, N)), jnp.float32)
            t_v = time_call(vortex, a, b, repeats=3)
            t_s = time_call(sampled, a, b, repeats=3)
            sps.append(t_s / t_v)
            pads.append(sampled.padded_m(m) / m)
        emit(
            f"offsample/{label}", 0.0,
            f"avg_speedup={np.mean(sps):.2f};"
            f"avg_pad_ratio_sampled={np.mean(pads):.2f}",
        )


if __name__ == "__main__":
    main()
