"""Paper Fig. 16 — dynamic hardware adaptation (Tensor Core vs CUDA core,
here MXU vs VPU).

For tiny M the MXU pads the sublane dim 16x and wastes the systolic array;
the VPU path has no contraction granularity.  The adaptive selector must
match the better of the two fixed settings for every (M, N) point.
Analytical costs on the TPU target spec (the decision function the runtime
uses); the paper reports up to 48%/54% gains over the fixed settings.
"""
from __future__ import annotations

import numpy as np

from repro.core import GemmWorkload, TPU_V5E, VortexKernel
from benchmarks.util import emit

K = 1024


def main() -> None:
    for N in (1024, 2048, 4096):
        wl = GemmWorkload(M=None, N=N, K=K)
        both = VortexKernel(TPU_V5E, wl, backends=("mxu", "vpu"))
        mxu = VortexKernel(TPU_V5E, wl, backends=("mxu",))
        vpu = VortexKernel(TPU_V5E, wl, backends=("vpu",))
        gains_mxu, gains_vpu, routed_vpu = [], [], 0
        for m in range(1, 17):
            c_a = both.select(m).predicted_cost
            c_m = mxu.select(m).predicted_cost
            c_v = vpu.select(m).predicted_cost
            assert c_a <= min(c_m, c_v) * 1.0001
            gains_mxu.append(c_m / c_a)
            gains_vpu.append(c_v / c_a)
            routed_vpu += both.select(m).backend == "vpu"
        emit(
            f"adaptive/N{N}", 0.0,
            f"max_gain_vs_mxu_only={max(gains_mxu):.2f};"
            f"max_gain_vs_vpu_only={max(gains_vpu):.2f};"
            f"vpu_routed={routed_vpu}/16",
        )


if __name__ == "__main__":
    main()
