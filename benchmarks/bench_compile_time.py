"""Paper §7.4 'Offline Overhead Analysis' — candidate counts and offline
compile seconds, Vortex vs sample-driven tuning (the 176x claim's shape).

Paper numbers for GEMM: 17731/392/2332 candidates and 29.3s/92.2s/529.6s
(CPU / TC / CUDA-core) vs 25 HOURS of DietCode tuning.  We reproduce the
structure: count our candidates and time our offline stage for (a) host-CPU
empirical-L0, (b) TPU-spec table-profiled L0+L1, (c) analytical-only, then
time the sample-driven tuner on a growing sample list.
"""
from __future__ import annotations

import time

from repro.core import GemmWorkload, HOST_CPU, TPU_V5E, VortexKernel
from repro.core.baselines import SampleDrivenCompiler
from benchmarks.util import emit

N, K = 768, 2304


def main() -> None:
    wl = GemmWorkload(M=None, N=N, K=K)

    modes = {
        "cpu_empirical_L0": dict(
            hw=HOST_CPU, empirical_levels=(0,), backends=("simd",)
        ),
        "tpu_table_L0L1": dict(
            hw=TPU_V5E, empirical_levels=(0, 1), backends=("mxu", "vpu")
        ),
        "tpu_analytical": dict(
            hw=TPU_V5E, empirical_levels=(), backends=("mxu",)
        ),
    }
    vortex_seconds = {}
    for name, kw in modes.items():
        hw = kw.pop("hw")
        t0 = time.perf_counter()
        eng = VortexKernel(hw, wl, **kw)
        dt = time.perf_counter() - t0
        vortex_seconds[name] = dt
        emit(
            f"compile_time/vortex/{name}", dt * 1e6,
            f"candidates={eng.offline_stats.num_candidates};"
            f"measured={eng.offline_stats.num_measured}",
        )

    for n_samples in (2, 4, 8):
        samples = [32 * (i + 1) for i in range(n_samples)]
        t0 = time.perf_counter()
        SampleDrivenCompiler(HOST_CPU, wl, samples, search_budget=4,
                             repeats=2)
        dt = time.perf_counter() - t0
        ratio = dt / max(vortex_seconds["cpu_empirical_L0"], 1e-9)
        emit(
            f"compile_time/sample_driven/{n_samples}samples", dt * 1e6,
            f"slowdown_vs_vortex={ratio:.1f}x",
        )


if __name__ == "__main__":
    main()
