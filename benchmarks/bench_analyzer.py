"""Paper Table 7 — hybrid-analyzer configuration study.

Offline overhead and selection quality for the analyzer configurations:
CPU default (E: L0) vs changed (E: L0,L1); TPU default (E: L0,L1 via the
calibrated table) vs changed (E: L0) vs analytical-only.  Quality is the
predicted-cost regret of the selected strategies vs the configuration's own
best (lower overhead usually costs selection quality — the paper's
trade-off).
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import GemmWorkload, HOST_CPU, TPU_V5E, VortexKernel
from benchmarks.util import emit

N, K = 768, 1152
MS = [7, 40, 128, 300, 777]


def main() -> None:
    wl = GemmWorkload(M=None, N=N, K=K)
    configs = [
        ("cpu/E_L0", HOST_CPU, (0,), ("simd",)),
        ("cpu/E_L0L1", HOST_CPU, (0, 1), ("simd",)),
        ("tpu/E_L0L1", TPU_V5E, (0, 1), ("mxu",)),
        ("tpu/E_L0", TPU_V5E, (0,), ("mxu",)),
        ("tpu/analytical", TPU_V5E, (), ("mxu",)),
    ]
    preds = {}
    for name, hw, levels, backends in configs:
        t0 = time.perf_counter()
        eng = VortexKernel(hw, wl, empirical_levels=levels, backends=backends)
        offline = time.perf_counter() - t0
        cost = float(np.mean([eng.select(m).predicted_cost for m in MS]))
        preds[name] = cost
        emit(
            f"analyzer/{name}", offline * 1e6,
            f"measured={eng.offline_stats.num_measured};"
            f"mean_predicted_cost={cost:.3e}",
        )
    # Relative quality of tpu configs vs the default (E: L0,L1).
    base = preds["tpu/E_L0L1"]
    for name in ("tpu/E_L0", "tpu/analytical"):
        emit(
            f"analyzer/{name}/regret", 0.0,
            f"predicted_cost_ratio={preds[name] / base:.3f}",
        )


if __name__ == "__main__":
    main()
