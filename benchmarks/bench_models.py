"""Paper Fig. 13 — model-level dynamic-shape performance.

End-to-end prefill latency of the GPT-2-class smoke model across dynamic
sequence lengths, comparing Vortex-bucketed serving (bounded executable
cache, lattice padding) against exact-shape compilation (a fresh executable
per distinct shape — the vendor-workflow stand-in).  Reported per shape:
steady-state latency and the one-time compile cost amortized over the shape
stream, which is where bucketing wins.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.launch.mesh import make_host_mesh
from repro.launch.serve import Request, VortexServer
from repro.models.registry import get_smoke_config
from repro.models.params import init_params
from repro.models.partitioning import make_rules
from repro.train.step import make_prefill_step
from benchmarks.util import emit

SEQ_LENS = [5, 17, 33, 52, 61, 77, 90, 101, 115, 120]  # "17 seq lens" style


def main() -> None:
    cfg = get_smoke_config("paper-gpt2-124m")
    mesh = make_host_mesh()
    server = VortexServer(cfg, mesh, max_cache=128)
    rng = np.random.default_rng(0)

    # --- Vortex-bucketed stream ---------------------------------------
    t0 = time.perf_counter()
    for s in SEQ_LENS:
        toks = rng.integers(0, cfg.vocab, (2, s)).astype(np.int32)
        server.generate(Request(tokens=toks, max_new=1))
    vortex_total = time.perf_counter() - t0

    # --- exact-shape workflow: one executable per distinct shape -------
    rules = make_rules(mesh, n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads)
    params = init_params(cfg, jax.random.PRNGKey(0))
    t0 = time.perf_counter()
    for s in SEQ_LENS:
        fn = jax.jit(make_prefill_step(cfg, rules, cache_len=128))
        toks = rng.integers(0, cfg.vocab, (2, s)).astype(np.int32)
        logits, cache = fn(params, {"tokens": jax.numpy.asarray(toks)})
        jax.block_until_ready(logits)
    exact_total = time.perf_counter() - t0

    emit(
        "models/gpt2_dynamic_stream",
        vortex_total / len(SEQ_LENS) * 1e6,
        f"speedup_vs_exact_shape={exact_total / vortex_total:.2f};"
        f"compiles_vortex={server.stats['prefill_compiles']};"
        f"compiles_exact={len(SEQ_LENS)}",
    )


if __name__ == "__main__":
    main()
