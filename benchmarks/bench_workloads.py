"""Workload-generic engine benchmark: dispatch overhead + cache behaviour.

The paper's runtime claim (Fig. 14) is that sample-free selection stays in
the microseconds regime and the executable cache stays bounded by the
lattice, not by the number of distinct runtime shapes.  This benchmark
drives GEMM, flash attention and Conv2D through ONE vortex Engine
session (repro.vortex) and
reports, per workload kind:

  * mean per-call dispatch overhead for UNSEEN shapes on the
    offline-materialized selection table vs the fused argmin path (the
    constant-time-dispatch speedup this repo tracks),
  * table/LRU/argmin serve counts over a repeated dynamic stream,
  * executable-cache entries vs calls served (bucket amortization),
  * steady-state wall-clock per call,
  * the padding-free hot path: steady-state wall-clock of UNALIGNED
    dispatch (staged masked-tail launch) vs ALIGNED dispatch (zero-copy
    launch) on the SAME bucket executable, plus copies/launches per call
    from the engine's DispatchStats — the Fig. 8 "padding confined to the
    outermost level" claim as a tracked ratio (CI gates it at 1.10x).

    PYTHONPATH=src:. python benchmarks/bench_workloads.py
    PYTHONPATH=src:. python benchmarks/bench_workloads.py \
        --smoke --json BENCH_dispatch.json   # CI smoke job

``--json`` writes BENCH_dispatch.json so the perf trajectory of the
serving hot path is tracked from run to run; ``benchmarks/run.py --json``
reuses :func:`serving_payload` to write the committed BENCH_serving.json
snapshot.
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import get_hardware
from repro.core.timing import interleaved_minima, retry_best
from repro.vortex import Engine
from repro.core.selector import RuntimeSelector
from benchmarks.util import emit

# Dynamic streams: every shape appears twice (second pass measures cache
# behaviour), sizes deliberately prime/non-tile-aligned.
GEMM_MS = [5, 33, 63, 128, 200, 381]
ATTN_SEQS = [31, 67, 127, 199, 257]
CONV_BATCHES = [1, 2, 3, 5]

# Unseen-shape dispatch stream: distinct extents a serving process has
# never selected before (the case an LRU keyed by raw M cannot help with).
DISPATCH_STREAM = 400
DISPATCH_M_MAX = 2048


def _bench(name: str, calls) -> float:
    t0 = time.perf_counter()
    for fn in calls:
        jax.block_until_ready(fn())
    return (time.perf_counter() - t0) / len(calls)


def _bench_dispatch(eng, hw, smoke: bool) -> dict[str, dict]:
    """Per kind: mean select overhead for unseen extents, table vs argmin.

    Fresh selectors over the SAME scored lattices the engine serves from,
    so both paths price the identical strategy space; every extent in the
    stream is unseen by construction (new selector, distinct extents).
    """
    stream_len = 60 if smoke else DISPATCH_STREAM
    rng = np.random.default_rng(42)
    ms = rng.permutation(np.arange(1, DISPATCH_M_MAX + 1))[:stream_len]
    ms = [int(m) for m in ms]

    results: dict[str, dict] = {}
    seen_kinds: set[str] = set()
    for kernel in eng._kernels.values():
        wl = kernel.workload
        if wl.kind in seen_kinds:
            continue
        seen_kinds.add(wl.kind)
        scored = kernel.selector.scored
        tabled = RuntimeSelector(hw, wl, scored, table_m_max=DISPATCH_M_MAX)
        argmin = RuntimeSelector(hw, wl, scored, table_m_max=0, cache_size=1)
        assert tabled.table is not None  # materialize offline, not in-loop

        # Best-of-N passes: the table loop's whole window is tens of us, so
        # a single scheduler preemption inside one pass would otherwise
        # dominate the (CI-gated) speedup ratio.
        repeats = 5

        def _best_of(select) -> float:
            best = float("inf")
            for _ in range(repeats):
                t0 = time.perf_counter()
                for m in ms:
                    select(m)
                best = min(best, time.perf_counter() - t0)
            return best / len(ms) * 1e6

        table_us = _best_of(tabled.select)
        argmin_us = _best_of(argmin.select)

        assert tabled.stats.table_hits == len(ms) * repeats
        results[wl.kind] = {
            "table_us": table_us,
            "argmin_us": argmin_us,
            "speedup": argmin_us / max(table_us, 1e-9),
            "table_entries": len(tabled.table),
            "table_build_s": tabled.stats.table_build_seconds,
            "stream_len": len(ms),
        }
    return results


def _attn_aligned_seq(kern, s0: int) -> int:
    """The first extent >= s0 whose attention bucket pads NEITHER seq dim
    (pq == s == pkv): the zero-copy aligned case.  Walk bucket starts, not
    every integer."""
    s = s0
    for _ in range(64):
        sel = kern.select(s)
        if sel.bucket[0] == s and sel.bucket[2] == s:
            return s
        s = max(sel.bucket[0], sel.bucket[2])
    raise RuntimeError("no both-dims-aligned attention extent found")


def _same_entry_unaligned(kern, aligned_m: int) -> int:
    """The largest extent below ``aligned_m`` that the selector serves with
    the SAME strategy and bucket (hence the same compiled executable).

    The aligned/unaligned comparison must time one program two ways; an
    extent one short of the bucket can fall in a different breakpoint
    interval with a different tile, which would time two different kernels.
    """
    ref = kern.select(aligned_m)
    for m in range(aligned_m - 1, max(aligned_m - 64, 0), -1):
        sel = kern.select(m)
        if (
            sel.bucket == ref.bucket
            and sel.strategy.l1 == ref.strategy.l1
            and sel.backend == ref.backend
        ):
            return m
    raise RuntimeError(
        f"no same-executable unaligned extent below {aligned_m}"
    )


def _bench_hot_path(smoke: bool) -> dict[str, dict]:
    """Aligned vs unaligned steady-state dispatch on the SAME bucket.

    Per kind: the unaligned extent is bucket-1 (staging + masked launch +
    output slice), the aligned extent the bucket itself (zero-copy launch)
    — same compiled program, so the ratio isolates exactly the cost the
    padding-free path adds at the boundary.  Conv uses a 1x1-kernel im2col
    view so the probe extents are exactly reachable; its im2col transform
    runs in BOTH variants.
    """
    eng = Engine("host_cpu", empirical_levels=())
    rng = np.random.default_rng(3)
    # Short interleaved windows + adaptive min-vs-min stop (the
    # throttling defense lives in repro.core.timing, shared with the
    # background calibrator): sample until BOTH variants' minima have
    # stopped improving, then gate min-vs-min.
    min_rounds = 20 if smoke else 30
    max_rounds = 80 if smoke else 120

    def paired_us(aligned_call, unaligned_call):
        """(aligned_us, unaligned_us, min-vs-min ratio, raw samples) —
        phase-robust minima for the gate, with the per-round samples kept
        so a flaky gate can be diagnosed from the committed JSON (was the
        distribution bimodal throttling or a real shift?)."""
        t = interleaved_minima(
            [aligned_call, unaligned_call],
            inner=2, min_rounds=min_rounds, max_rounds=max_rounds,
            patience=10,
        )
        return (
            t.best_s[0] * 1e6,
            t.best_s[1] * 1e6,
            t.ratio(1, 0),
            {
                "aligned_us": list(t.samples_us[0]),
                "unaligned_us": list(t.samples_us[1]),
            },
        )

    def f32(shape):
        return jnp.asarray(rng.normal(size=shape), jnp.float32)

    # Kernel compute must dominate the boundary copies for the ratio to
    # measure the contract rather than XLA's fixed per-launch overhead:
    # ratio-1 ~ c*(1/N + 1/K), so the static dims are sized in the
    # thousands (multi-ms kernels against sub-ms copies).
    cases: dict[str, tuple] = {}
    # gemm: any extent is reachable.
    gk = eng.op_kernel("gemm", (f32((8, 2304)), f32((2304, 2304))), {})
    gb = gk.select(381).padded_m
    gu = _same_entry_unaligned(gk, gb)
    wg = f32((2304, 2304))
    cases["gemm"] = (
        lambda a=f32((gb, 2304)): eng.dispatch("gemm", a, wg),
        lambda a=f32((gu, 2304)): eng.dispatch("gemm", a, wg),
    )
    # attention: aligned needs BOTH seq dims on their tile.
    q0 = (f32((2, 8, 8, 64)), f32((2, 4, 8, 64)), f32((2, 4, 8, 64)))
    ak = eng.op_kernel("attention", q0, {})
    sa = _attn_aligned_seq(ak, 199)
    su = _same_entry_unaligned(ak, sa)

    def attn_args(s):
        return (f32((2, 8, s, 64)), f32((2, 4, s, 64)), f32((2, 4, s, 64)))

    aa, au = attn_args(sa), attn_args(su)
    cases["attention"] = (
        lambda: eng.dispatch("attention", *aa),
        lambda: eng.dispatch("attention", *au),
    )
    # conv2d: 1x1 kernel -> im2col extent == the seq-like dim exactly.
    ck = eng.op_kernel(
        "conv2d", (f32((1, 1, 8, 1536)), f32((1, 1, 1536, 1536))), {}
    )
    cb = ck.select(500).padded_m
    cu = _same_entry_unaligned(ck, cb)
    wc = f32((1, 1, 1536, 1536))
    xa, xu = f32((1, 1, cb, 1536)), f32((1, 1, cu, 1536))
    cases["conv2d"] = (
        lambda: eng.dispatch("conv2d", xa, wc),
        lambda: eng.dispatch("conv2d", xu, wc),
    )

    results: dict[str, dict] = {}
    for kind, (aligned_call, unaligned_call) in cases.items():
        before = dict(eng.stats()[kind])
        # Up to 4 measurement attempts, keeping the best ratio: throttling
        # noise is strictly one-sided (it can only inflate a window), so
        # the min across attempts estimates the true boundary cost, while
        # a real regression fails every attempt.
        gate: dict = {}
        aligned_us, unaligned_us, ratio, samples = retry_best(
            lambda: paired_us(aligned_call, unaligned_call),
            attempts=4,
            accept=lambda r: r[2] <= 1.08,
            key=lambda r: r[2],
            stats=gate,
        )
        after = eng.stats()[kind]
        calls = after["calls"] - before["calls"]
        unaligned = after["unaligned_calls"] - before["unaligned_calls"]
        results[kind] = {
            "aligned_us": aligned_us,
            "unaligned_us": unaligned_us,
            "unaligned_over_aligned": ratio,
            # The gated attempt's raw per-round samples (same order the
            # minima were taken over) — the flake audit trail.
            "samples": samples,
            # Gate retry telemetry (DESIGN.md §11 robustness surface):
            # how many measurement attempts the gate burned, whether the
            # kept attempt passed, and which interleaved round each side's
            # min-vs-min winner came from.
            "gate_attempts": gate.get("attempts", 1),
            "gate_accepted": gate.get("accepted", True),
            "min_round": {
                side: int(np.argmin(vals)) for side, vals in samples.items()
            },
            # Zero-overhead guard: a no-fault bench must never touch the
            # degradation ladder.  CI asserts both stay 0.
            "fallbacks": after["fallbacks"] - before["fallbacks"],
            "quarantined": after["quarantined"] - before["quarantined"],
            "launches_per_call": (
                (after["launches"] - before["launches"]) / max(calls, 1)
            ),
            "copies_per_unaligned_call": (
                (
                    after["stage_copies"] + after["unstage_copies"]
                    - before["stage_copies"] - before["unstage_copies"]
                ) / max(unaligned, 1)
            ),
            "padded_calls": after["padded_calls"] - before["padded_calls"],
        }
    return results


def _bench_decode(smoke: bool) -> dict:
    """The serving decode section: drive VortexServer through a prompt
    whose generation crosses a kv-bucket boundary and report the per-token
    decode contract (one AOT launch per token, zero pad fallbacks, growth
    copies only at bucket transitions) plus steady-state wall-clock per
    token.  CI gates launches_per_token == 1 and padded_calls == 0."""
    from jax.sharding import Mesh
    from repro.launch.serve import Request, VortexServer
    from repro.models.registry import get_smoke_config

    cfg = get_smoke_config("paper-gpt2-124m")
    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1), ("data", "model"))
    server = VortexServer(cfg, mesh, max_cache=256)
    rng = np.random.default_rng(17)
    s = 120
    kvb0 = server.kv_bucket(server.seq_bucket(s))
    max_new = min(max(kvb0 - s + 4, 8), 24)
    reqs = [
        Request(
            tokens=rng.integers(0, cfg.vocab, (b, s)).astype(np.int32),
            max_new=max_new,
        )
        for b in (1, 2)
    ]
    # Warm EVERY (batch, seq) shape once: the timed window below must hold
    # decode steps only — a first-time jit trace + AOT compile (seconds)
    # inside it would make us_per_token track compile noise, not decode.
    for req in reqs:
        server.generate(req)
    tokens_before = server.decode_stats.calls
    t0 = time.perf_counter()
    for req in reqs:
        server.generate(req)
    wall = time.perf_counter() - t0
    d = server.decode_stats
    tokens = d.calls
    timed = max(tokens - tokens_before, 1)
    # Engine-side REAL observables from the decode lowerings: padded == 0
    # means no zero-pad was baked into any compiled decode step (every
    # traced dispatch hit the bucket-aligned path).
    eng_decode = server.engine_dispatch_stats()["decode_attention"]
    return {
        "tokens": tokens,
        "launches_per_token": d.launches / max(tokens, 1),
        "padded_calls": d.padded_calls,
        "growth_copies": d.stage_copies,
        "bucket_transitions": d.unaligned_calls,
        "decode_exec_buckets": len(server._decode_exec),
        "decode_compiles": server.stats["decode_compiles"],
        "engine_traced_calls": eng_decode["traced_calls"],
        "engine_padded_calls": eng_decode["padded_calls"],
        "decode_us_per_token": wall / timed * 1e6,
    }


def _bench_continuous_batching(smoke: bool) -> dict:
    """The continuous-batching serving section: the SAME 16 requests
    served (a) serially through ``generate()`` and (b) through the
    admission-queue scheduler at concurrency 1/4/16, reporting tokens/sec
    per mode plus the batched-step contract — exactly one AOT launch per
    batched decode step, zero padded calls.  CI gates
    launches_per_batched_step == 1, padded_calls == 0 and
    speedup_at_16 >= 1.5 (the batch-bucket dimension amortizes the
    per-launch cost serial decode pays per request)."""
    from jax.sharding import Mesh
    from repro.launch.scheduler import ContinuousScheduler
    from repro.launch.serve import Request, VortexServer
    from repro.models.registry import get_smoke_config

    cfg = get_smoke_config("paper-gpt2-124m")
    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1), ("data", "model"))
    server = VortexServer(cfg, mesh, max_cache=256)
    rng = np.random.default_rng(23)
    max_new = 8
    reqs = [
        Request(
            tokens=rng.integers(
                0, cfg.vocab, (1, int(s))
            ).astype(np.int32),
            max_new=max_new,
        )
        for s in rng.integers(30, 60, 16)
    ]
    total_tokens = len(reqs) * max_new

    def timed_serial() -> float:
        t0 = time.perf_counter()
        for req in reqs:
            server.generate(req)
        return time.perf_counter() - t0

    def timed_sched(batch_rows: int) -> tuple[float, dict]:
        sched = ContinuousScheduler(server, batch_rows=batch_rows)
        t0 = time.perf_counter()
        for req in reqs:
            sched.submit(req)
        res = sched.drain()
        wall = time.perf_counter() - t0
        assert len(res) == len(reqs)
        sched.close()
        return wall, sched.stats

    timed_serial()  # warm every prefill/decode executable
    serial_wall = timed_serial()
    out: dict = {
        "requests": len(reqs),
        "max_new": max_new,
        "serial_tokens_per_s": total_tokens / serial_wall,
        "concurrency": {},
    }
    worst_lps, padded = 0.0, 0
    for c in (1, 4, 16):
        timed_sched(c)  # warm the (c, kvb) mixed-progress programs
        wall, stats = timed_sched(c)
        lps = stats["launches"] / max(stats["steps"], 1)
        worst_lps = max(worst_lps, lps)
        padded += stats["padded_calls"]
        out["concurrency"][str(c)] = {
            "tokens_per_s": total_tokens / wall,
            "batched_steps": stats["steps"],
            "launches_per_batched_step": lps,
            "padded_calls": stats["padded_calls"],
        }
    out["launches_per_batched_step"] = worst_lps
    out["padded_calls"] = padded
    out["speedup_at_16"] = (
        out["concurrency"]["16"]["tokens_per_s"]
        / out["serial_tokens_per_s"]
    )
    pool = server.engine_dispatch_stats()["kv_pool"]
    out["kv_pool"] = pool
    assert pool["leases_active"] == 0, pool
    return out


def _bench_prefill_chain(smoke: bool) -> dict:
    """The chained-prefill serving section (DESIGN.md §8): whole-model
    prefills through launch/serve.py's lazy handle chain, reporting the
    boundary-copy contract — zero interior unstage+restage pairs at a
    chain-aligned bucket, every engine boundary forwarded — plus
    bit-identity vs the eager per-op reference (identical dispatch
    sequence on plain arrays).  CI gates boundary_copies_per_block <= 1,
    forwarded_per_prefill >= 1 and bit_identical_to_eager."""
    from jax.sharding import Mesh
    from repro.launch.serve import VortexServer
    from repro.models.registry import get_smoke_config

    cfg = get_smoke_config("paper-gpt2-124m")
    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1), ("data", "model"))
    server = VortexServer(cfg, mesh, max_cache=256, prefill="chained")
    rng = np.random.default_rng(29)
    bp, s = 1, 100
    sp = server.chain_seq_bucket(s, bp)
    tokens = rng.integers(0, cfg.vocab, (bp, s)).astype(np.int32)
    batch = server._make_batch(bp, sp, tokens)

    def chain_counters() -> dict:
        keys = (
            "stage_copies", "unstage_copies", "realize_slices", "forwarded",
        )
        out = dict.fromkeys(keys, 0)
        for kind, st in server.engine.stats().items():
            if kind == "calibration":  # engine-level section, not a kind
                continue
            for k in keys:
                out[k] += st[k]
        return out

    # Warm the per-bucket executables, then count over ONE prefill.
    last, cache = server.prefill_chained(bp, sp, batch)
    before = chain_counters()
    last, cache = server.prefill_chained(bp, sp, batch)
    after = chain_counters()
    copies = sum(
        after[k] - before[k]
        for k in ("stage_copies", "unstage_copies", "realize_slices")
    )
    forwarded = after["forwarded"] - before["forwarded"]
    blocks = cfg.n_layers

    last_e, cache_e = server.prefill_chained(bp, sp, batch, eager=True)
    max_abs = max(
        float(np.max(np.abs(
            np.asarray(a, np.float32) - np.asarray(b, np.float32)
        )))
        for a, b in zip(
            jax.tree_util.tree_leaves((last, cache)),
            jax.tree_util.tree_leaves((last_e, cache_e)),
        )
    )

    times = []
    for _ in range(3 if smoke else 10):
        t0 = time.perf_counter()
        jax.block_until_ready(server.prefill_chained(bp, sp, batch)[0])
        times.append(time.perf_counter() - t0)

    return {
        "seq_bucket": sp,
        "batch_bucket": bp,
        "blocks_per_prefill": blocks,
        "chain_aligned": server._chain_aligned(bp, sp),
        "boundary_copies_per_block": copies / max(blocks, 1),
        "forwarded_per_prefill": forwarded,
        "us_per_prefill": min(times) * 1e6,
        "max_abs_diff_vs_eager": max_abs,
        "bit_identical_to_eager": max_abs == 0.0,
    }


def _bench_moe(smoke: bool) -> dict:
    """The MoE serving section: a granite_moe-shaped expert-FFN layer
    served engine-vs-dense.  With a session installed, ``_expert_ffn``
    collapses its three dense ``(g,E,C,·)`` einsums into three grouped-GEMM
    dispatches — each is ONE bucketed masked-tail launch covering all E
    experts, with the per-expert token counts (a routing outcome, not an
    input length) riding in as the runtime extent vector.

    ``launches_per_moe_layer`` is normalized per projection (three
    projections — w_in, w_gate, w_out — per layer call): 1.0 means every
    projection ran as exactly ONE grouped launch for all experts, never E
    per-expert launches and never a pad fallback.  CI gates
    ``launches_per_moe_layer == 1 && padded_calls == 0`` plus bit-identity
    vs the dense-einsum fallback.
    """
    import dataclasses

    import repro.vortex as vortex
    from repro.configs.granite_moe_1b import CONFIG, SMOKE
    from repro.models import layers as Lyr
    from repro.models.partitioning import AxisRules

    rules = AxisRules(rules={}, mesh_axes=())
    if smoke:
        cfg = SMOKE
        b, s = 2, 33
    else:
        # granite_moe_1b's expert geometry (32 experts, top-8) at a width
        # a CPU runner can turn around; the launch accounting is what the
        # gate consumes, not the absolute wall-clock.
        cfg = dataclasses.replace(
            CONFIG, d_model=256,
            moe=dataclasses.replace(CONFIG.moe, d_ff_expert=128),
        )
        b, s = 2, 96
    m = cfg.moe
    rng = np.random.default_rng(41)
    mk = lambda *sh: jnp.asarray(rng.normal(size=sh) * 0.05, jnp.float32)
    p = {
        "router": mk(cfg.d_model, m.num_experts),
        "w_in": mk(m.num_experts, cfg.d_model, m.d_ff_expert),
        "w_gate": mk(m.num_experts, cfg.d_model, m.d_ff_expert),
        "w_out": mk(m.num_experts, m.d_ff_expert, cfg.d_model),
    }
    x = jnp.asarray(rng.normal(size=(b, s, cfg.d_model)), jnp.float32)

    layer_call = lambda: Lyr.moe_forward(p, x, cfg, rules)[0]
    y_dense = jax.block_until_ready(layer_call())
    rounds = dict(
        inner=1, min_rounds=3 if smoke else 10,
        max_rounds=10 if smoke else 40, patience=3,
    )
    # Dense timing OUTSIDE the session — with one installed, the same
    # layer call routes through the engine, so the two sides are the same
    # moe_forward with/without the grouped-GEMM dispatch path.
    dense_us = interleaved_minima([layer_call], **rounds).best_s[0] * 1e6

    eng = Engine("host_cpu", empirical_levels=(() if smoke else None))
    with vortex.use(eng):
        y_eng = jax.block_until_ready(layer_call())  # warm: compile + AOT
        before = {
            k: eng.stats()["grouped_gemm"][k]
            for k in ("launches", "padded_calls", "stage_copies")
        }
        layer_calls = 4 if smoke else 8
        for _ in range(layer_calls):
            jax.block_until_ready(layer_call())
        after = {
            k: eng.stats()["grouped_gemm"][k]
            for k in ("launches", "padded_calls", "stage_copies")
        }
        engine_us = interleaved_minima([layer_call], **rounds).best_s[0] * 1e6

    launches = after["launches"] - before["launches"]
    max_abs = float(np.max(np.abs(np.asarray(y_eng) - np.asarray(y_dense))))
    dropped = float(Lyr.moe_forward(p, x, cfg, rules)[2])
    return {
        "experts": m.num_experts,
        "top_k": m.top_k,
        "d_ff_expert": m.d_ff_expert,
        "tokens": b * s,
        "layer_calls": layer_calls,
        # per projection: 3 grouped-GEMM dispatches per layer call, each
        # must be exactly one launch for all experts.
        "launches_per_moe_layer": launches / (3 * layer_calls),
        "padded_calls": after["padded_calls"],
        "stage_copies": after["stage_copies"] - before["stage_copies"],
        "dropped_frac": dropped,
        "engine_us_per_layer": engine_us,
        "dense_us_per_layer": dense_us,
        "max_abs_diff_vs_dense": max_abs,
        "bit_identical_to_dense": max_abs == 0.0,
    }


def _bench_calibration(smoke: bool) -> dict:
    """Background-calibration quality section (BENCH_dispatch.json).

    A small gemm engine runs one full calibration pass (measure top-K
    candidates per bucket, fit/re-rank, atomic table swap), then reports
    measured-vs-analytical agreement and the calibrated pick's regret vs
    the measured-best candidate per bucket.  CI gates two invariants:

      * ``never_worse_on_measured`` — on every measured bucket the
        calibrated table's pick is at least as fast (by the measurements)
        as the analytical pick;
      * the persistence roundtrip — a FRESH engine loads the persisted
        tables by hardware fingerprint with ZERO re-measurements.
    """
    import dataclasses
    import tempfile

    cache_dir = tempfile.mkdtemp(prefix="vortex-bench-calib-")

    def fresh_engine() -> Engine:
        eng = Engine(
            "host_cpu", empirical_levels=(),
            calibration="on-idle",
            calibration_top_k=2 if smoke else 3,
            calibration_cache_dir=cache_dir,
        )
        rng = np.random.default_rng(11)
        eng.dispatch(
            "gemm",
            jnp.asarray(rng.normal(size=(33, 256)), jnp.float32),
            jnp.asarray(rng.normal(size=(256, 128)), jnp.float32),
        )
        return eng

    def tune(cal) -> None:
        # Bench-sized measurement plan; the policy only steers NEW
        # kernel-state planning, so set it before the first slice.
        cal.policy = dataclasses.replace(
            cal.policy,
            m_max=192 if smoke else 512,
            max_buckets=3 if smoke else 6,
            min_rounds=3 if smoke else 8,
            max_rounds=8 if smoke else 24,
            patience=2 if smoke else 4,
        )

    eng = fresh_engine()
    cal = eng.calibrator
    tune(cal)
    t0 = time.perf_counter()
    cal.run()
    calibrate_s = time.perf_counter() - t0
    report = cal.report()

    # Persistence roundtrip: fresh engine, same fingerprint -> the tables
    # load from disk and nothing is re-measured.
    eng2 = fresh_engine()
    cal2 = eng2.calibrator
    tune(cal2)
    loaded = cal2.load()
    roundtrip = {
        "loaded": loaded,
        "re_measurements": cal2.counters["measurements"],
        "pending_after_load": cal2.pending(),
        "table_swaps": cal2.counters["table_swaps"],
    }
    return {
        "kinds": report,
        "roundtrip": roundtrip,
        "calibrate_s": calibrate_s,
        "stats": cal.stats(),
    }


def serving_payload(smoke: bool) -> dict:
    """The BENCH_serving.json payload (benchmarks/run.py --json): dispatch
    overhead on unseen shapes, the aligned-vs-unaligned hot-path ratio and
    copies/launches per call (with raw per-round samples), the serving
    decode contract, the chained-prefill boundary-copy contract, and the
    MoE grouped-GEMM contract (one launch per projection for all
    experts)."""
    hardware = "host_cpu"
    eng = Engine(hardware, empirical_levels=(() if smoke else None))
    hw = get_hardware(hardware)
    rng = np.random.default_rng(0)
    # Touch one signature per kind so _bench_dispatch sees all three.
    eng.dispatch(
        "gemm",
        jnp.asarray(rng.normal(size=(33, 768)), jnp.float32),
        jnp.asarray(rng.normal(size=(768, 768)), jnp.float32),
    )
    q = jnp.asarray(rng.normal(size=(1, 4, 67, 64)), jnp.float32)
    kv = jnp.asarray(rng.normal(size=(1, 2, 67, 64)), jnp.float32)
    eng.dispatch("attention", q, kv, kv)
    eng.dispatch(
        "conv2d",
        jnp.asarray(rng.normal(size=(2, 28, 28, 16)), jnp.float32),
        jnp.asarray(rng.normal(size=(3, 3, 16, 32)), jnp.float32),
    )
    return {
        "mode": "smoke" if smoke else "full",
        "dispatch": _bench_dispatch(eng, hw, smoke),
        "hot_path": _bench_hot_path(smoke),
        "decode": _bench_decode(smoke),
        "prefill_chain": _bench_prefill_chain(smoke),
        "continuous_batching": _bench_continuous_batching(smoke),
        "moe": _bench_moe(smoke),
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument(
        "--smoke", action="store_true",
        help="reduced stream + analytical-only offline stage (CI)",
    )
    ap.add_argument(
        "--json", metavar="PATH", default=None,
        help="write per-kind dispatch-overhead results as JSON",
    )
    ap.add_argument(
        "--no-hot-path", action="store_true",
        help="skip the (minutes-long) aligned-vs-unaligned hot-path "
        "measurement — CI runs it separately via run.py --json and must "
        "not pay for it twice",
    )
    args = ap.parse_args()

    hardware = "host_cpu"
    eng = Engine(
        hardware, empirical_levels=(() if args.smoke else None)
    )
    hw = get_hardware(hardware)
    rng = np.random.default_rng(0)
    gemm_ms = GEMM_MS[:3] if args.smoke else GEMM_MS
    attn_seqs = ATTN_SEQS[:2] if args.smoke else ATTN_SEQS
    conv_batches = CONV_BATCHES[:2] if args.smoke else CONV_BATCHES

    # --- gemm ----------------------------------------------------------
    N, K = 768, 768
    b = jnp.asarray(rng.normal(size=(K, N)), jnp.float32)
    mats = {
        m: jnp.asarray(rng.normal(size=(m, K)), jnp.float32) for m in gemm_ms
    }
    gemm_calls = [
        (lambda a=mats[m]: eng.dispatch("gemm", a, b)) for m in gemm_ms * 2
    ]
    gemm_us = _bench("gemm", gemm_calls) * 1e6

    # --- attention -----------------------------------------------------
    qkv = {}
    for s in attn_seqs:
        qkv[s] = (
            jnp.asarray(rng.normal(size=(1, 8, s, 64)), jnp.float32),
            jnp.asarray(rng.normal(size=(1, 4, s, 64)), jnp.float32),
            jnp.asarray(rng.normal(size=(1, 4, s, 64)), jnp.float32),
        )
    attn_calls = [
        (lambda t=qkv[s]: eng.dispatch("attention", *t)) for s in attn_seqs * 2
    ]
    attn_us = _bench("attention", attn_calls) * 1e6

    # --- conv2d --------------------------------------------------------
    wconv = jnp.asarray(rng.normal(size=(3, 3, 16, 32)), jnp.float32)
    xs = {
        bs: jnp.asarray(rng.normal(size=(bs, 28, 28, 16)), jnp.float32)
        for bs in conv_batches
    }
    conv_calls = [
        (lambda x=xs[bs]: eng.dispatch("conv2d", x, wconv)) for bs in conv_batches * 2
    ]
    conv_us = _bench("conv2d", conv_calls) * 1e6

    # --- serving-path report -------------------------------------------
    wall = {"gemm": gemm_us, "attention": attn_us, "conv2d": conv_us}
    stats = eng.stats()
    stats.pop("calibration", None)  # engine-level section, not a kind
    for kind, s in stats.items():
        selects = max(s["selects"], 1)
        misses = s["select_argmin_misses"]
        # mean argmin-miss latency is only a measurement when misses exist
        # (with the table on, a typical stream never misses).
        miss_us = f"{s['select_us_sum'] / misses:.1f}" if misses else "n/a"
        emit(
            f"workloads/{kind}", wall[kind],
            f"argmin_miss_us={miss_us};"
            f"table_hit_rate={s['select_table_hits'] / selects:.2f};"
            f"lru_hits={s['select_lru_hits']};"
            f"argmin_misses={s['select_argmin_misses']};"
            f"table_entries={s['table_entries']};"
            f"exec_entries={s['exec_entries']};"
            f"exec_hits={s['exec_hits']};"
            f"compile_s={s['compile_seconds']:.2f}",
        )
    total_exec = sum(s["exec_entries"] for s in stats.values())
    total_calls = sum(s["exec_hits"] for s in stats.values())
    emit(
        "workloads/summary", 0.0,
        f"executables={total_exec};calls_served={total_calls};"
        f"amortization={total_calls / max(total_exec, 1):.1f}x",
    )

    # --- dispatch overhead: table vs argmin on unseen shapes ------------
    dispatch = _bench_dispatch(eng, hw, args.smoke)
    for kind, d in dispatch.items():
        emit(
            f"dispatch/{kind}", d["table_us"],
            f"argmin_us={d['argmin_us']:.1f};speedup={d['speedup']:.1f}x;"
            f"table_entries={d['table_entries']};"
            f"table_build_ms={d['table_build_s'] * 1e3:.1f}",
        )

    # --- padding-free hot path: aligned vs unaligned same-bucket --------
    hot = {} if args.no_hot_path else _bench_hot_path(args.smoke)
    for kind, h in hot.items():
        emit(
            f"hot_path/{kind}", h["unaligned_us"],
            f"aligned_us={h['aligned_us']:.1f};"
            f"ratio={h['unaligned_over_aligned']:.3f};"
            f"launches_per_call={h['launches_per_call']:.2f};"
            f"copies_per_unaligned_call={h['copies_per_unaligned_call']:.1f};"
            f"padded_calls={h['padded_calls']}",
        )

    # --- background calibration: measured vs analytical -----------------
    calibration = _bench_calibration(args.smoke)
    for kind, c in calibration["kinds"].items():
        emit(
            f"calibration/{kind}", c["mean_regret_vs_best"] * 1e2,
            f"mode={c['mode']};agreement={c['agreement_rate']:.2f};"
            f"pinned={c['pinned_buckets']}/{c['measured_buckets']};"
            f"never_worse={c['never_worse_on_measured']};"
            f"residual={c['residual']:.3f}",
        )
    rt = calibration["roundtrip"]
    emit(
        "calibration/roundtrip", calibration["calibrate_s"] * 1e6,
        f"loaded={rt['loaded']};re_measurements={rt['re_measurements']};"
        f"pending_after_load={rt['pending_after_load']}",
    )

    if args.json:
        payload = {
            "dispatch": dispatch,
            "hot_path": hot,
            "calibration": calibration,
            "serving": {
                kind: {
                    "selects": s["selects"],
                    "table_hit_rate": (
                        s["select_table_hits"] / max(s["selects"], 1)
                    ),
                    "argmin_misses": s["select_argmin_misses"],
                    "exec_entries": s["exec_entries"],
                    "launches": s["launches"],
                    "stage_copies": s["stage_copies"],
                    "unstage_copies": s["unstage_copies"],
                    "padded_calls": s["padded_calls"],
                    "wall_us_per_call": wall[kind],
                }
                for kind, s in stats.items()
            },
        }
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=2, sort_keys=True)
        print(f"wrote {args.json}")


if __name__ == "__main__":
    main()
