"""Workload-generic engine benchmark: select overhead + cache behaviour.

The paper's runtime claim (Fig. 14) is that sample-free selection stays in
the microseconds regime and the executable cache stays bounded by the
lattice, not by the number of distinct runtime shapes.  This benchmark
drives GEMM, flash attention and Conv2D through ONE VortexEngine and
reports, per workload kind:

  * mean selection overhead (us) for uncached shapes,
  * selection-cache hit rate over a repeated dynamic stream,
  * executable-cache entries vs calls served (bucket amortization),
  * steady-state wall-clock per call.

    PYTHONPATH=src python benchmarks/bench_workloads.py
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import VortexEngine
from benchmarks.util import emit

# Dynamic streams: every shape appears twice (second pass measures cache
# behaviour), sizes deliberately prime/non-tile-aligned.
GEMM_MS = [5, 33, 63, 128, 200, 381]
ATTN_SEQS = [31, 67, 127, 199, 257]
CONV_BATCHES = [1, 2, 3, 5]


def _bench(name: str, calls) -> float:
    t0 = time.perf_counter()
    for fn in calls:
        jax.block_until_ready(fn())
    return (time.perf_counter() - t0) / len(calls)


def main() -> None:
    eng = VortexEngine("host_cpu")
    rng = np.random.default_rng(0)

    # --- gemm ----------------------------------------------------------
    N, K = 768, 768
    b = jnp.asarray(rng.normal(size=(K, N)), jnp.float32)
    mats = {
        m: jnp.asarray(rng.normal(size=(m, K)), jnp.float32) for m in GEMM_MS
    }
    gemm_calls = [
        (lambda a=mats[m]: eng.gemm(a, b)) for m in GEMM_MS * 2
    ]
    gemm_us = _bench("gemm", gemm_calls) * 1e6

    # --- attention -----------------------------------------------------
    qkv = {}
    for s in ATTN_SEQS:
        qkv[s] = (
            jnp.asarray(rng.normal(size=(1, 8, s, 64)), jnp.float32),
            jnp.asarray(rng.normal(size=(1, 4, s, 64)), jnp.float32),
            jnp.asarray(rng.normal(size=(1, 4, s, 64)), jnp.float32),
        )
    attn_calls = [
        (lambda t=qkv[s]: eng.attention(*t)) for s in ATTN_SEQS * 2
    ]
    attn_us = _bench("attention", attn_calls) * 1e6

    # --- conv2d --------------------------------------------------------
    wconv = jnp.asarray(rng.normal(size=(3, 3, 16, 32)), jnp.float32)
    xs = {
        bs: jnp.asarray(rng.normal(size=(bs, 28, 28, 16)), jnp.float32)
        for bs in CONV_BATCHES
    }
    conv_calls = [
        (lambda x=xs[bs]: eng.conv2d(x, wconv)) for bs in CONV_BATCHES * 2
    ]
    conv_us = _bench("conv2d", conv_calls) * 1e6

    # --- report --------------------------------------------------------
    wall = {"gemm": gemm_us, "attention": attn_us, "conv2d": conv_us}
    for kind, s in eng.stats().items():
        selects = s["selects"]
        hits = s["select_cache_hits"]
        misses = max(selects - hits, 1)
        emit(
            f"workloads/{kind}", wall[kind],
            f"select_us={s['select_us_sum'] / misses:.1f};"
            f"select_hit_rate={hits / max(selects, 1):.2f};"
            f"exec_entries={s['exec_entries']};"
            f"exec_hits={s['exec_hits']};"
            f"compile_s={s['compile_seconds']:.2f}",
        )
    total_exec = sum(s["exec_entries"] for s in eng.stats().values())
    total_calls = sum(s["exec_hits"] for s in eng.stats().values())
    emit(
        "workloads/summary", 0.0,
        f"executables={total_exec};calls_served={total_calls};"
        f"amortization={total_calls / max(total_exec, 1):.1f}x",
    )


if __name__ == "__main__":
    main()
