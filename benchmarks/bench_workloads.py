"""Workload-generic engine benchmark: dispatch overhead + cache behaviour.

The paper's runtime claim (Fig. 14) is that sample-free selection stays in
the microseconds regime and the executable cache stays bounded by the
lattice, not by the number of distinct runtime shapes.  This benchmark
drives GEMM, flash attention and Conv2D through ONE vortex Engine
session (repro.vortex) and
reports, per workload kind:

  * mean per-call dispatch overhead for UNSEEN shapes on the
    offline-materialized selection table vs the fused argmin path (the
    constant-time-dispatch speedup this repo tracks),
  * table/LRU/argmin serve counts over a repeated dynamic stream,
  * executable-cache entries vs calls served (bucket amortization),
  * steady-state wall-clock per call.

    PYTHONPATH=src:. python benchmarks/bench_workloads.py
    PYTHONPATH=src:. python benchmarks/bench_workloads.py \
        --smoke --json BENCH_dispatch.json   # CI smoke job

``--json`` writes BENCH_dispatch.json so the perf trajectory of the
serving hot path is tracked from run to run.
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import get_hardware
from repro.vortex import Engine
from repro.core.selector import RuntimeSelector
from benchmarks.util import emit

# Dynamic streams: every shape appears twice (second pass measures cache
# behaviour), sizes deliberately prime/non-tile-aligned.
GEMM_MS = [5, 33, 63, 128, 200, 381]
ATTN_SEQS = [31, 67, 127, 199, 257]
CONV_BATCHES = [1, 2, 3, 5]

# Unseen-shape dispatch stream: distinct extents a serving process has
# never selected before (the case an LRU keyed by raw M cannot help with).
DISPATCH_STREAM = 400
DISPATCH_M_MAX = 2048


def _bench(name: str, calls) -> float:
    t0 = time.perf_counter()
    for fn in calls:
        jax.block_until_ready(fn())
    return (time.perf_counter() - t0) / len(calls)


def _bench_dispatch(eng, hw, smoke: bool) -> dict[str, dict]:
    """Per kind: mean select overhead for unseen extents, table vs argmin.

    Fresh selectors over the SAME scored lattices the engine serves from,
    so both paths price the identical strategy space; every extent in the
    stream is unseen by construction (new selector, distinct extents).
    """
    stream_len = 60 if smoke else DISPATCH_STREAM
    rng = np.random.default_rng(42)
    ms = rng.permutation(np.arange(1, DISPATCH_M_MAX + 1))[:stream_len]
    ms = [int(m) for m in ms]

    results: dict[str, dict] = {}
    seen_kinds: set[str] = set()
    for kernel in eng._kernels.values():
        wl = kernel.workload
        if wl.kind in seen_kinds:
            continue
        seen_kinds.add(wl.kind)
        scored = kernel.selector.scored
        tabled = RuntimeSelector(hw, wl, scored, table_m_max=DISPATCH_M_MAX)
        argmin = RuntimeSelector(hw, wl, scored, table_m_max=0, cache_size=1)
        assert tabled.table is not None  # materialize offline, not in-loop

        # Best-of-N passes: the table loop's whole window is tens of us, so
        # a single scheduler preemption inside one pass would otherwise
        # dominate the (CI-gated) speedup ratio.
        repeats = 5

        def _best_of(select) -> float:
            best = float("inf")
            for _ in range(repeats):
                t0 = time.perf_counter()
                for m in ms:
                    select(m)
                best = min(best, time.perf_counter() - t0)
            return best / len(ms) * 1e6

        table_us = _best_of(tabled.select)
        argmin_us = _best_of(argmin.select)

        assert tabled.stats.table_hits == len(ms) * repeats
        results[wl.kind] = {
            "table_us": table_us,
            "argmin_us": argmin_us,
            "speedup": argmin_us / max(table_us, 1e-9),
            "table_entries": len(tabled.table),
            "table_build_s": tabled.stats.table_build_seconds,
            "stream_len": len(ms),
        }
    return results


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument(
        "--smoke", action="store_true",
        help="reduced stream + analytical-only offline stage (CI)",
    )
    ap.add_argument(
        "--json", metavar="PATH", default=None,
        help="write per-kind dispatch-overhead results as JSON",
    )
    args = ap.parse_args()

    hardware = "host_cpu"
    eng = Engine(
        hardware, empirical_levels=(() if args.smoke else None)
    )
    hw = get_hardware(hardware)
    rng = np.random.default_rng(0)
    gemm_ms = GEMM_MS[:3] if args.smoke else GEMM_MS
    attn_seqs = ATTN_SEQS[:2] if args.smoke else ATTN_SEQS
    conv_batches = CONV_BATCHES[:2] if args.smoke else CONV_BATCHES

    # --- gemm ----------------------------------------------------------
    N, K = 768, 768
    b = jnp.asarray(rng.normal(size=(K, N)), jnp.float32)
    mats = {
        m: jnp.asarray(rng.normal(size=(m, K)), jnp.float32) for m in gemm_ms
    }
    gemm_calls = [
        (lambda a=mats[m]: eng.dispatch("gemm", a, b)) for m in gemm_ms * 2
    ]
    gemm_us = _bench("gemm", gemm_calls) * 1e6

    # --- attention -----------------------------------------------------
    qkv = {}
    for s in attn_seqs:
        qkv[s] = (
            jnp.asarray(rng.normal(size=(1, 8, s, 64)), jnp.float32),
            jnp.asarray(rng.normal(size=(1, 4, s, 64)), jnp.float32),
            jnp.asarray(rng.normal(size=(1, 4, s, 64)), jnp.float32),
        )
    attn_calls = [
        (lambda t=qkv[s]: eng.dispatch("attention", *t)) for s in attn_seqs * 2
    ]
    attn_us = _bench("attention", attn_calls) * 1e6

    # --- conv2d --------------------------------------------------------
    wconv = jnp.asarray(rng.normal(size=(3, 3, 16, 32)), jnp.float32)
    xs = {
        bs: jnp.asarray(rng.normal(size=(bs, 28, 28, 16)), jnp.float32)
        for bs in conv_batches
    }
    conv_calls = [
        (lambda x=xs[bs]: eng.dispatch("conv2d", x, wconv)) for bs in conv_batches * 2
    ]
    conv_us = _bench("conv2d", conv_calls) * 1e6

    # --- serving-path report -------------------------------------------
    wall = {"gemm": gemm_us, "attention": attn_us, "conv2d": conv_us}
    stats = eng.stats()
    for kind, s in stats.items():
        selects = max(s["selects"], 1)
        misses = s["select_argmin_misses"]
        # mean argmin-miss latency is only a measurement when misses exist
        # (with the table on, a typical stream never misses).
        miss_us = f"{s['select_us_sum'] / misses:.1f}" if misses else "n/a"
        emit(
            f"workloads/{kind}", wall[kind],
            f"argmin_miss_us={miss_us};"
            f"table_hit_rate={s['select_table_hits'] / selects:.2f};"
            f"lru_hits={s['select_lru_hits']};"
            f"argmin_misses={s['select_argmin_misses']};"
            f"table_entries={s['table_entries']};"
            f"exec_entries={s['exec_entries']};"
            f"exec_hits={s['exec_hits']};"
            f"compile_s={s['compile_seconds']:.2f}",
        )
    total_exec = sum(s["exec_entries"] for s in stats.values())
    total_calls = sum(s["exec_hits"] for s in stats.values())
    emit(
        "workloads/summary", 0.0,
        f"executables={total_exec};calls_served={total_calls};"
        f"amortization={total_calls / max(total_exec, 1):.1f}x",
    )

    # --- dispatch overhead: table vs argmin on unseen shapes ------------
    dispatch = _bench_dispatch(eng, hw, args.smoke)
    for kind, d in dispatch.items():
        emit(
            f"dispatch/{kind}", d["table_us"],
            f"argmin_us={d['argmin_us']:.1f};speedup={d['speedup']:.1f}x;"
            f"table_entries={d['table_entries']};"
            f"table_build_ms={d['table_build_s'] * 1e3:.1f}",
        )

    if args.json:
        payload = {
            "dispatch": dispatch,
            "serving": {
                kind: {
                    "selects": s["selects"],
                    "table_hit_rate": (
                        s["select_table_hits"] / max(s["selects"], 1)
                    ),
                    "argmin_misses": s["select_argmin_misses"],
                    "exec_entries": s["exec_entries"],
                    "wall_us_per_call": wall[kind],
                }
                for kind, s in stats.items()
            },
        }
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=2, sort_keys=True)
        print(f"wrote {args.json}")


if __name__ == "__main__":
    main()
