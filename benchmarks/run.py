"""Benchmark runner — one module per paper table/figure.

Each prints ``name,us_per_call,derived`` CSV lines (benchmarks/util.emit).

  bench_gemm             Fig. 12 / Table 5  operator-level speedups
  bench_offsample        Fig. 3  / Table 6  off-sample degradation
  bench_models           Fig. 13            model-level dynamic shapes
  bench_compile_time     §7.4               offline overhead
  bench_hierarchy        Fig. 15            static/dynamic ablation
  bench_analyzer         Table 7            hybrid analyzer configs
  bench_adaptive         Fig. 16            MXU/VPU adaptation
  bench_runtime_overhead Fig. 14            selection overhead
  bench_workloads        §4 generality      gemm/attention/conv one engine
"""
from __future__ import annotations

import importlib
import sys
import time
import traceback

MODULES = [
    "bench_compile_time",
    "bench_runtime_overhead",
    "bench_adaptive",
    "bench_analyzer",
    "bench_gemm",
    "bench_workloads",
    "bench_offsample",
    "bench_hierarchy",
    "bench_models",
]


def main() -> None:
    only = sys.argv[1] if len(sys.argv) > 1 else None
    failures = 0
    print("name,us_per_call,derived")
    for name in MODULES:
        if only and only not in name:
            continue
        t0 = time.perf_counter()
        print(f"# --- {name} ---", flush=True)
        try:
            importlib.import_module(f"benchmarks.{name}").main()
        except Exception:
            failures += 1
            traceback.print_exc()
        print(f"# {name} done in {time.perf_counter() - t0:.1f}s",
              flush=True)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
