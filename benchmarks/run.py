"""Benchmark runner — one module per paper table/figure.

Each prints ``name,us_per_call,derived`` CSV lines (benchmarks/util.emit).

  bench_gemm             Fig. 12 / Table 5  operator-level speedups
  bench_offsample        Fig. 3  / Table 6  off-sample degradation
  bench_models           Fig. 13            model-level dynamic shapes
  bench_compile_time     §7.4               offline overhead
  bench_hierarchy        Fig. 15            static/dynamic ablation
  bench_analyzer         Table 7            hybrid analyzer configs
  bench_adaptive         Fig. 16            MXU/VPU adaptation
  bench_runtime_overhead Fig. 14            selection overhead
  bench_workloads        §4 generality      gemm/attention/conv one engine

``--json PATH`` writes the serving-trajectory snapshot (BENCH_serving.json
at the repo root, committed once per PR): unseen-shape dispatch overhead
(table vs argmin), the aligned-vs-unaligned hot-path wall-clock ratio and
copies/launches per call.  With ``--json`` the module loop is SKIPPED
unless a module filter is also given — CI's bench-smoke job runs
``run.py --smoke --json BENCH_serving.json`` and gates on the ratio.
"""
from __future__ import annotations

import argparse
import importlib
import json
import sys
import time
import traceback

MODULES = [
    "bench_compile_time",
    "bench_runtime_overhead",
    "bench_adaptive",
    "bench_analyzer",
    "bench_gemm",
    "bench_workloads",
    "bench_offsample",
    "bench_hierarchy",
    "bench_models",
]


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("filter", nargs="?", default=None,
                    help="substring filter over benchmark module names")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced streams / analytical-only offline stage")
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="write the BENCH_serving.json payload")
    args, passthrough = ap.parse_known_args()
    if args.json and args.filter:
        # --json here means the SERVING payload; a module's own JSON flag
        # would be silently shadowed — force the unambiguous invocation.
        ap.error(
            "--json writes the serving payload and cannot be combined with "
            "a module filter; invoke the module directly for its own JSON "
            "(e.g. benchmarks/bench_workloads.py --json ...)"
        )

    failures = 0
    if args.filter is not None or args.json is None:
        # Module mains parse sys.argv themselves; strip the runner's own
        # arguments so they only see explicit passthrough flags (--smoke
        # is forwarded when a filter names the modules to run, since the
        # user is explicitly targeting modules that understand it).
        fwd = ["--smoke"] if args.smoke and args.filter else []
        sys.argv = [sys.argv[0]] + fwd + passthrough
        print("name,us_per_call,derived")
        for name in MODULES:
            if args.filter and args.filter not in name:
                continue
            t0 = time.perf_counter()
            print(f"# --- {name} ---", flush=True)
            try:
                importlib.import_module(f"benchmarks.{name}").main()
            except Exception:
                failures += 1
                traceback.print_exc()
            print(f"# {name} done in {time.perf_counter() - t0:.1f}s",
                  flush=True)

    if args.json:
        from benchmarks.bench_workloads import serving_payload

        payload = serving_payload(args.smoke)
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=2, sort_keys=True)
        print(f"wrote {args.json}")

    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
