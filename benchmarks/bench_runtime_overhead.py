"""Paper Fig. 14 — runtime overhead breakdown.

The Vortex runtime cost-model evaluation must be microseconds-scale and a
negligible fraction of kernel execution.  We time the selector in isolation
(cold = first evaluation of a new M, warm = cached) and compare against the
matmul execution time across M/N/K.
"""
from __future__ import annotations

import time

import numpy as np
import jax.numpy as jnp

from repro.core import GemmWorkload, HOST_CPU, VortexKernel
from benchmarks.util import emit, time_call


def main() -> None:
    for size in (64, 256, 1024):
        wl = GemmWorkload(M=None, N=size, K=size)
        eng = VortexKernel(HOST_CPU, wl)
        # cold selection: fresh M values
        t0 = time.perf_counter()
        n_cold = 200
        for m in range(1, n_cold + 1):
            eng.selector.select(m)
        cold_us = (time.perf_counter() - t0) / n_cold * 1e6
        # warm selection: cached M
        t0 = time.perf_counter()
        for _ in range(n_cold):
            eng.selector.select(7)
        warm_us = (time.perf_counter() - t0) / n_cold * 1e6
        # kernel execution at a representative M
        rng = np.random.default_rng(0)
        a = jnp.asarray(rng.normal(size=(size, size)), jnp.float32)
        b = jnp.asarray(rng.normal(size=(size, size)), jnp.float32)
        exec_us = time_call(eng, a, b) * 1e6
        emit(
            f"runtime_overhead/MNK{size}", exec_us,
            f"select_cold_us={cold_us:.1f};select_warm_us={warm_us:.2f};"
            f"overhead_frac={cold_us / max(exec_us, 1e-9):.3f}",
        )


if __name__ == "__main__":
    main()
