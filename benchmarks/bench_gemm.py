"""Paper Fig. 12 / Table 5 — operator-level dynamic-shape GEMM performance.

Two metrics per category, reflecting the two regimes that matter:

  * steady-state: best-of-N per-op wall-clock with warm executables.  On
    this host the "vendor" stand-in is exact-shape XLA — per-shape optimal
    once compiled, so Vortex's padding can only tie or lose slightly (the
    paper's cuBLAS/oneDNN baselines are NOT per-shape optimal, which is
    where its >1 steady-state speedups come from; recorded honestly in
    EXPERIMENTS.md).
  * dynamic stream: every M seen once, compile included.  This is the
    dynamic-shape serving regime the paper targets; Vortex's bounded bucket
    set amortizes compiles across shapes and wins.

Vortex latency always includes its runtime selection overhead (§7.2).
"""
from __future__ import annotations

import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import GemmWorkload, HOST_CPU, VortexKernel
from repro.core.baselines import SampleDrivenCompiler, VendorBaseline
from benchmarks.util import emit, time_call

# (category, N, K, M values) — scaled-down Table 3 rows that stay fast on CPU.
CASES = [
    ("transformer", 768, 768, [5, 33, 63, 128, 200, 381]),
    ("cnn", 512, 1152, [1, 7, 49, 96]),
    ("gnn", 64, 256, [500, 1111, 2708]),
]


def _stream_seconds(engine, mats) -> float:
    t0 = time.perf_counter()
    for a, b in mats:
        jax.block_until_ready(engine(a, b))
    return time.perf_counter() - t0


def main() -> None:
    steady_v, steady_s, stream_sp, n = 0.0, 0.0, [], 0
    for cat, N, K, ms in CASES:
        wl = GemmWorkload(M=None, N=N, K=K)
        rng = np.random.default_rng(0)
        mats = [
            (
                jnp.asarray(rng.normal(size=(m, K)), jnp.float32),
                jnp.asarray(rng.normal(size=(K, N)), jnp.float32),
            )
            for m in ms
        ]

        # --- steady state (warm executables) ---------------------------
        vortex = VortexKernel(HOST_CPU, wl)
        vendor = VendorBaseline(wl)
        sampled = SampleDrivenCompiler(
            HOST_CPU, wl, samples=[ms[len(ms) // 2]], search_budget=3,
            repeats=2,
        )
        for (a, b), m in zip(mats, ms):
            t_vortex = time_call(vortex, a, b)
            t_vendor = time_call(vendor, a, b)
            t_sampled = time_call(sampled, a, b)
            steady_v += t_vendor / t_vortex
            steady_s += t_sampled / t_vortex
            n += 1
            emit(
                f"gemm/{cat}/M{m}", t_vortex * 1e6,
                f"steady_speedup_vs_vendor={t_vendor / t_vortex:.2f};"
                f"steady_speedup_vs_sampled={t_sampled / t_vortex:.2f}",
            )

        # --- dynamic stream (fresh engines, compile included) ----------
        t_vx = _stream_seconds(VortexKernel(HOST_CPU, wl), mats)
        t_vd = _stream_seconds(VendorBaseline(wl), mats)
        stream_sp.append(t_vd / t_vx)
        emit(
            f"gemm/{cat}/dynamic_stream", t_vx / len(ms) * 1e6,
            f"stream_speedup_vs_exact_shape={t_vd / t_vx:.2f}",
        )

    emit(
        "gemm/average", 0.0,
        f"steady_speedup_vendor={steady_v / n:.2f};"
        f"steady_speedup_sampled={steady_s / n:.2f};"
        f"stream_speedup_vendor={float(np.mean(stream_sp)):.2f}",
    )


if __name__ == "__main__":
    main()
