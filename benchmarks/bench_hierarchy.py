"""Paper Fig. 15 — hierarchical kernel construction ablation.

Vortex (dynamic strategies at every level) vs:
  * Vortex-Static1: the L0 child is frozen to one tile; L1 stays dynamic
    (the lattice is re-scored with only that child available);
  * Vortex-Static2: L0 AND L1 frozen — one strategy for every shape;
  * Vortex-Oracle: per-shape exhaustive wall-clock search over the lattice
    buckets (Vortex run as a static-shape compiler with profiling).

Reported as fraction of Oracle wall-clock (paper: 94.7% / 60.7% / 49.5%).
All variants share one memoized executable factory so compile time never
contaminates the steady-state numbers.
"""
from __future__ import annotations

import collections
import functools
import math

import jax
import numpy as np
import jax.numpy as jnp

from repro.core import GemmWorkload, HOST_CPU, VortexKernel
from repro.core.analyzer import HybridAnalyzer, WallClockProfiler
from repro.core.candidates import CandidateLattice, generate_lattice
from repro.core.selector import RuntimeSelector
from benchmarks.util import emit, time_call

N, K = 512, 1024
MS = [3, 17, 40, 77, 128, 200, 311, 450]


@functools.lru_cache(maxsize=None)
def _exe(mp: int):
    fn = jax.jit(
        lambda a, b: jax.lax.dot_general(a, b, (((1,), (0,)), ((), ())))
    )
    a = jnp.zeros((mp, K), jnp.float32)
    b = jnp.zeros((K, N), jnp.float32)
    fn(a, b).block_until_ready()
    return fn


def _run_padded(mp: int, a, b):
    m = a.shape[0]
    if mp != m:
        a = jnp.pad(a, ((0, mp - m), (0, 0)))
    out = _exe(mp)(a, b)
    return out[:m] if mp != m else out


def _measure(tile_for, mats):
    out = {}
    for m, (a, b) in mats.items():
        tm = tile_for(m)
        mp = math.ceil(m / tm) * tm
        out[m] = time_call(lambda a_, b_: _run_padded(mp, a_, b_), a, b,
                           repeats=3)
    return out


def main() -> None:
    wl = GemmWorkload(M=None, N=N, K=K)
    vortex = VortexKernel(HOST_CPU, wl)
    backend = HOST_CPU.default_backend
    rng = np.random.default_rng(0)
    mats = {
        m: (
            jnp.asarray(rng.normal(size=(m, K)), jnp.float32),
            jnp.asarray(rng.normal(size=(K, N)), jnp.float32),
        )
        for m in MS
    }

    # Oracle: per-shape best wall-clock over the lattice's m-tile buckets.
    tiles = sorted({
        int(t[0]) for t in vortex.selector._scored[backend].l1_tiles
    })
    tiles = [t for t in tiles if t <= 1024][:10]
    oracle_t = {}
    for m in MS:
        a, b = mats[m]
        best = float("inf")
        for tm in tiles:
            mp = math.ceil(m / tm) * tm
            best = min(best, time_call(
                lambda a_, b_: _run_padded(mp, a_, b_), a, b, repeats=3
            ))
        oracle_t[m] = best

    # Vortex: dynamic at every level.
    vortex_t = _measure(
        lambda m: vortex.select(m).strategy.l1[0], mats
    )

    # Static1: freeze L0 to the globally most-chosen child; rescore the
    # lattice with only that child, keep runtime L1 selection dynamic.
    # "Most frequently optimal" is computed over the full workload range
    # (paper Table 3 includes training-scale M up to 1.9M), so the frozen
    # choice is biased to large shapes — exactly why it hurts small ones.
    sels = [vortex.select(m) for m in MS + [512, 1024, 2048, 4096, 8192]]
    l0_common = collections.Counter(
        s.strategy.tiles[0] for s in sels
    ).most_common(1)[0][0]
    full = generate_lattice(HOST_CPU, wl, backend)
    kept = {
        l1: (l0_common,)
        for l1 in full.l1
        if all(a % b == 0 for a, b in zip(l1, l0_common))
    }
    frozen = CandidateLattice(
        backend=backend,
        layers=((l0_common,), tuple(kept)),
        children=({}, kept),
    )
    scored1 = HybridAnalyzer(
        HOST_CPU, wl, profiler=WallClockProfiler(), empirical_levels=(0,)
    ).score(frozen)
    sel1 = RuntimeSelector(HOST_CPU, wl, {backend: scored1})
    static1_t = _measure(lambda m: sel1.select(m).strategy.l1[0], mats)

    # Static2: freeze L0 and L1 to the single most-chosen full strategy.
    l1_common = collections.Counter(
        s.strategy.l1 for s in sels
    ).most_common(1)[0][0]
    static2_t = _measure(lambda m: l1_common[0], mats)

    def frac(ts):
        return float(np.mean([oracle_t[m] / ts[m] for m in MS]))

    emit("hierarchy/vortex", 0.0, f"frac_of_oracle={frac(vortex_t):.3f}")
    emit("hierarchy/static1", 0.0, f"frac_of_oracle={frac(static1_t):.3f}")
    emit("hierarchy/static2", 0.0, f"frac_of_oracle={frac(static2_t):.3f}")


if __name__ == "__main__":
    main()
