"""End-to-end system behaviour: training convergence, microbatch
equivalence, paper-claim mechanisms (off-sample robustness, compile-time
gap), and the dynamic serving driver."""
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh

from repro.core import GemmWorkload, HOST_CPU, VortexKernel
from repro.core.baselines import SampleDrivenCompiler, VendorBaseline
from repro.data.pipeline import SyntheticLMDataset
from repro.models.params import init_params
from repro.models.partitioning import make_rules
from repro.models.registry import get_smoke_config
from repro.optim.adamw import adamw_init
from repro.train.step import TrainHParams, make_train_step


@pytest.fixture(scope="module")
def mesh():
    return Mesh(np.array(jax.devices()[:1]).reshape(1, 1), ("data", "model"))


def test_training_loss_decreases(mesh):
    """~40 steps on the GPT-2-smoke config must fit the synthetic stream."""
    cfg = get_smoke_config("paper-gpt2-124m")
    rules = make_rules(mesh, n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads)
    params = init_params(cfg, jax.random.PRNGKey(0))
    opt = adamw_init(params)
    hp = TrainHParams(base_lr=1e-2, warmup_steps=10, total_steps=60,
                      num_microbatches=1)
    step = jax.jit(make_train_step(cfg, rules, hp))
    data = SyntheticLMDataset(cfg.vocab, seq_len=32, global_batch=16)
    losses = []
    for i in range(60):
        batch = {k: jnp.asarray(v) for k, v in data.batch_at(i).items()}
        params, opt, metrics = step(params, opt, batch)
        losses.append(float(metrics["loss"]))
    assert np.isfinite(losses).all()
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 1.0, losses[::8]


def test_microbatch_accumulation_matches_full_batch(mesh):
    """num_microbatches=4 must produce (numerically close) the same update
    as a single full batch."""
    cfg = get_smoke_config("paper-gpt2-124m")
    rules = make_rules(mesh, n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads)
    params = init_params(cfg, jax.random.PRNGKey(1))
    data = SyntheticLMDataset(cfg.vocab, seq_len=16, global_batch=8)
    batch = {k: jnp.asarray(v) for k, v in data.batch_at(0).items()}

    outs = {}
    for mb in (1, 4):
        hp = TrainHParams(num_microbatches=mb, total_steps=10,
                          warmup_steps=1)
        step = jax.jit(make_train_step(cfg, rules, hp))
        p2, _, m = step(params, adamw_init(params), batch)
        outs[mb] = (p2, float(m["loss"]))
    assert outs[1][1] == pytest.approx(outs[4][1], rel=2e-2)
    for a, b in zip(jax.tree.leaves(outs[1][0]), jax.tree.leaves(outs[4][0])):
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32),
            rtol=5e-2, atol=5e-3,
        )


def test_off_sample_robustness_mechanism():
    """Paper Fig. 3 / Table 6 mechanism: the sample-driven baseline pads
    off-sample shapes to its sample grid; Vortex's lattice bounds padding
    everywhere.  Compare padded-M waste directly (hardware-independent)."""
    wl = GemmWorkload(M=None, N=256, K=256)
    vortex = VortexKernel(HOST_CPU, wl, empirical_levels=())
    sampled = SampleDrivenCompiler(
        HOST_CPU, wl, samples=[128, 192, 256], search_budget=2, repeats=1
    )
    worst_vortex, worst_sampled = 0.0, 0.0
    for m in range(1, 300, 7):
        v = vortex.select(m).padded_m / m
        s = sampled.padded_m(m) / m
        worst_vortex = max(worst_vortex, v)
        worst_sampled = max(worst_sampled, s)
    # The sample-driven worst case (small M routed to sample 128) is far
    # worse than the lattice-bounded worst case.
    assert worst_sampled > worst_vortex


def test_offline_compile_time_gap():
    """Paper §7.4 mechanism: Vortex's sample-free offline stage must be much
    cheaper than tuning micro-kernels per sample on real hardware."""
    wl = GemmWorkload(M=None, N=128, K=128)
    t0 = time.perf_counter()
    vortex = VortexKernel(HOST_CPU, wl, empirical_levels=())
    vortex_s = time.perf_counter() - t0
    sampled = SampleDrivenCompiler(
        HOST_CPU, wl, samples=[32, 64, 96, 128], search_budget=4, repeats=2
    )
    assert sampled.tuning_seconds > vortex_s
    assert vortex.offline_stats.num_candidates > 0


def test_vendor_baseline_correctness():
    wl = GemmWorkload(M=None, N=64, K=32)
    vendor = VendorBaseline(wl)
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.normal(size=(17, 32)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(32, 64)), jnp.float32)
    np.testing.assert_allclose(
        np.asarray(vendor(a, b)), np.asarray(a) @ np.asarray(b), rtol=1e-4
    )


def test_dynamic_serving_end_to_end(mesh):
    """The serving driver handles shape-diverse requests with a bounded
    executable cache (Vortex bucketing)."""
    from repro.launch.serve import Request, VortexServer

    cfg = get_smoke_config("paper-gpt2-124m")
    server = VortexServer(cfg, mesh, max_cache=128)
    rng = np.random.default_rng(0)
    shapes = [(1, 5), (2, 9), (2, 12), (1, 14), (3, 30), (4, 60)]
    for (b, s) in shapes:
        out = server.generate(Request(
            tokens=rng.integers(0, cfg.vocab, (b, s)).astype(np.int32),
            max_new=2,
        ))
        assert out.shape == (b, 2)
    # 6 distinct request shapes must share a smaller bucket set.
    assert server.stats["prefill_compiles"] < len(shapes)


def test_server_buckets_are_engine_selector_buckets(mesh):
    """Acceptance (ISSUE 3): the server's sequence buckets must BE the
    engine selector's lattice buckets (`selections_upto`) — no second,
    hand-rolled bucketing scheme beside the selection table."""
    from repro.launch.serve import VortexServer

    cfg = get_smoke_config("paper-gpt2-124m")
    server = VortexServer(cfg, mesh, max_cache=128)
    selector = server._seq_op.kernel.selector
    expect = sorted({
        min(sel.padded_m, 128) for sel in selector.selections_upto(128)
    })
    assert server.seq_buckets() == expect
    for s in range(1, 129):
        assert server.seq_bucket(s) == min(selector.select(s).padded_m, 128)


def test_server_warmup_precompiles_buckets(mesh):
    """After warmup, in-range requests are all bucket hits: zero prefill
    AND zero decode compilations at serving time."""
    from repro.launch.serve import Request, VortexServer

    cfg = get_smoke_config("paper-gpt2-124m")
    server = VortexServer(cfg, mesh, max_cache=64)
    n = server.warmup(max_batch=2, m_max=64, max_new=4)
    n_prefill = server.stats["prefill_compiles"]
    n_decode = server.stats["decode_compiles"]
    assert n == n_prefill + n_decode
    assert n_prefill > 0 and n_decode > 0
    rng = np.random.default_rng(3)
    for (b, s) in [(1, 5), (2, 17), (1, 33)]:
        out = server.generate(Request(
            tokens=rng.integers(0, cfg.vocab, (b, s)).astype(np.int32),
            max_new=2,
        ))
        assert out.shape == (b, 2)
    assert server.stats["prefill_compiles"] == n_prefill  # nothing new
    assert server.stats["decode_compiles"] == n_decode
