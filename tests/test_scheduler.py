"""Continuous batching must be invisible in the outputs.

The step scheduler packs concurrent requests into the batch-bucket
dimension and advances them with ONE mixed-progress decode launch per
step — rows at different kv positions, free slots riding at pos 0, the
shared cache leased from the kv-bucket pool.  Every test here compares
against the serial ``generate()`` path on the SAME server (identical
params, identical prefill executables): per-request token sequences must
match exactly.

Structural contract, asserted alongside identity: launches == steps
(one AOT program per batched step), padded_calls == 0, and the pool's
lease ledger settles to 0 — on retirement, on ``generate()`` exceptions,
and after ``close()``.
"""
import threading

import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.mesh import make_host_mesh
from repro.launch.scheduler import (
    ContinuousScheduler,
    batched_decode_supported,
)
from repro.launch.serve import Request, VortexServer
from repro.models.registry import get_smoke_config

MAX_CACHE = 256


@pytest.fixture(scope="module")
def server():
    cfg = get_smoke_config("paper-gpt2-124m")
    return VortexServer(cfg, make_host_mesh(), max_cache=MAX_CACHE)


def _requests(rng, n, *, lo=4, hi=60, max_new=12, rows=1):
    return [
        Request(
            tokens=rng.integers(0, 512, (rows, int(s))).astype(np.int32),
            max_new=max_new,
        )
        for s in rng.integers(lo, hi, n)
    ]


def _serial(server, reqs):
    return [server.generate(r) for r in reqs]


def _assert_clean(server, sched):
    assert sched.stats["launches"] == sched.stats["steps"]
    assert sched.stats["padded_calls"] == 0
    sched.close()
    pool = server.engine_dispatch_stats()["kv_pool"]
    assert pool["leases_active"] == 0, pool


def test_batched_matches_serial_token_identical(server):
    """Five concurrent single-row requests at mixed prompt lengths, four
    slots: batched greedy decode must reproduce the serial tokens for
    every request, with at least one genuinely mixed-progress step."""
    rng = np.random.default_rng(0)
    reqs = _requests(rng, 5, max_new=12)
    serial = _serial(server, reqs)

    sched = ContinuousScheduler(server, batch_rows=4)
    rids = [sched.submit(r) for r in reqs]
    res = sched.drain()
    for rid, ser in zip(rids, serial):
        assert np.array_equal(res[rid], ser), rid
    mixed = [
        s for s in sched.step_positions
        if len(set(s["pos"].tolist())) >= 2
    ]
    assert mixed, "no step ever served two rows at different positions"
    _assert_clean(server, sched)


def test_bucket_boundary_staggering(server):
    """Rows at kvb-1 / kvb / kvb+1 in ONE step: three prompts at adjacent
    lengths march across the initial kv bucket boundary in lockstep, so
    one launch serves a row still inside the old bucket, one exactly at
    it, and one past it — and the outputs still match serial exactly."""
    rng = np.random.default_rng(1)
    base = 119
    reqs = [
        Request(
            tokens=rng.integers(0, 512, (1, base + d)).astype(np.int32),
            max_new=16,
        )
        for d in range(3)
    ]
    boundary = server.kv_bucket(server.seq_bucket(base + 2))
    assert base + 2 < boundary <= base + 16, (
        "prompt lengths no longer straddle the first kv bucket; "
        f"retune base for boundary {boundary}"
    )
    serial = _serial(server, reqs)

    sched = ContinuousScheduler(server, batch_rows=4)
    rids = [sched.submit(r) for r in reqs]
    res = sched.drain()
    for rid, ser in zip(rids, serial):
        assert np.array_equal(res[rid], ser), rid
    straddled = [
        s for s in sched.step_positions
        if {boundary - 1, boundary, boundary + 1} <= set(s["pos"].tolist())
    ]
    assert straddled, (
        f"no step served rows at {boundary - 1}/{boundary}/{boundary + 1}; "
        f"steps: {[sorted(s['pos'].tolist()) for s in sched.step_positions]}"
    )
    # The straddling step ran at the GROWN bucket (one program, one shape).
    assert all(s["kvb"] > boundary for s in straddled)
    _assert_clean(server, sched)


def test_nan_poisoned_pool_buffers_never_read(server):
    """Park NaN-poisoned buffers of exactly the shapes the scheduler will
    lease (shared cache + growth): if ANY stale tail byte were read, the
    greedy argmax would diverge from serial.  It must not."""
    from repro.models.model import abstract_cache

    rng = np.random.default_rng(2)
    reqs = _requests(rng, 4, lo=100, hi=130, max_new=16)
    serial = _serial(server, reqs)

    sched = ContinuousScheduler(server, batch_rows=4)
    # Poison: one parked buffer per leaf shape at the initial bucket AND
    # at every growable bucket up to max_cache.
    pool = server.kv_pool
    kvb = server.kv_bucket(server.seq_bucket(129))
    buckets = {kvb}
    while kvb < MAX_CACHE:
        kvb = server._grown_kv_bucket(kvb, kvb + 1)
        buckets.add(kvb)
    for b in buckets:
        spec = abstract_cache(server.cfg, sched.batch_rows, b)
        for entry in spec.values():
            for leaf in entry.values():
                key = (tuple(leaf.shape), jnp.dtype(leaf.dtype).name)
                pool._free.setdefault(key, []).append(
                    jnp.full(leaf.shape, jnp.nan, leaf.dtype)
                )
    rids = [sched.submit(r) for r in reqs]
    res = sched.drain()
    hits_after = pool.stats()["lease_hits"]
    assert hits_after > 0, "poisoned buffers were never leased — test inert"
    for rid, ser in zip(rids, serial):
        assert np.array_equal(res[rid], ser), rid
        assert not np.isnan(res[rid].astype(np.float64)).any()
    sched.close()
    assert pool.stats()["leases_active"] == 0


def test_multirow_request_and_stop_token(server):
    """A 2-row request occupies two slots and reassembles in submission
    order; a stop token retires its row early, padding the tail."""
    rng = np.random.default_rng(3)
    req = Request(
        tokens=rng.integers(0, 512, (2, 24)).astype(np.int32), max_new=10
    )
    serial = server.generate(req)

    sched = ContinuousScheduler(server, batch_rows=4)
    rid = sched.submit(req)
    res = sched.drain()
    assert np.array_equal(res[rid], serial)

    # Early stop: pick the token serial emits at step 3 of row 0 as the
    # stop token; the batched row must retire there and pad with it.
    stop = int(serial[0, 3])
    req2 = Request(tokens=req.tokens[:1], max_new=10, stop=stop)
    rid2 = sched.submit(req2)
    res2 = sched.drain()
    out = res2[rid2][0]
    cut = int(np.argmax(out == stop))
    assert out[cut] == stop and (out[cut:] == stop).all()
    assert np.array_equal(out[:cut], serial[0, :cut])
    _assert_clean(server, sched)


def test_admission_rejects_at_submit(server):
    """Oversized requests fail AT SUBMIT with a queue-level error — not
    mid-decode — and an over-wide request names the slot limit."""
    sched = ContinuousScheduler(server, batch_rows=4)
    big = Request(
        tokens=np.zeros((1, 200), np.int32), max_new=MAX_CACHE,
    )
    with pytest.raises(ValueError, match="admission refused"):
        sched.submit(big)
    wide = Request(tokens=np.zeros((8, 8), np.int32), max_new=2)
    with pytest.raises(ValueError, match="batch_rows"):
        sched.submit(wide)
    assert sched.drain() == {}
    _assert_clean(server, sched)


def test_generate_exception_releases_leases(server):
    """A decode failure mid-``generate`` must still settle every pool
    lease (the try/finally arm), or concurrent serving leaks buffers."""
    rng = np.random.default_rng(4)
    req = Request(
        tokens=rng.integers(0, 512, (1, 20)).astype(np.int32), max_new=8
    )
    before = server.kv_pool.stats()["leases_active"]
    orig = server._decode_exec_for
    calls = {"n": 0}

    def boom(bp, kvb):
        calls["n"] += 1
        if calls["n"] > 2:
            raise RuntimeError("injected decode failure")
        return orig(bp, kvb)

    server._decode_exec_for = boom
    try:
        with pytest.raises(RuntimeError, match="injected"):
            server.generate(req)
    finally:
        server._decode_exec_for = orig
    assert server.kv_pool.stats()["leases_active"] == before


def test_unsupported_arch_refused():
    """Non-attention decoders keep the serial path; the scheduler says so
    up front instead of corrupting a shared cache."""
    cfg = get_smoke_config("falcon-mamba-7b")
    assert not batched_decode_supported(cfg)
    srv = VortexServer(cfg, make_host_mesh(), max_cache=64)
    with pytest.raises(ValueError, match="serial generate"):
        ContinuousScheduler(srv, batch_rows=2)


def test_admit_fault_isolated_to_one_request(server):
    """A pool-lease fault while admitting resolves THAT request to a
    typed error; every other request completes token-identical to
    serial and the lease ledger settles."""
    from repro.launch.serve import RequestError
    from repro.runtime import faults

    rng = np.random.default_rng(6)
    reqs = _requests(rng, 3, max_new=6)
    serial = _serial(server, reqs)

    sched = ContinuousScheduler(server, batch_rows=4)
    plan = faults.FaultPlan({"pool_lease": [1]})
    with faults.installed(plan):
        rids = [sched.submit(r) for r in reqs]
        res = sched.drain()
    assert plan.fired == [("pool_lease", 1)]
    assert set(res) == set(rids)
    err = res[rids[0]]
    assert isinstance(err, RequestError)
    assert err.stage == "admit" and err.request_id == rids[0]
    for rid, ser in zip(rids[1:], serial[1:]):
        assert np.array_equal(res[rid], ser), rid
    assert sched.stats["request_errors"] == 1
    _assert_clean(server, sched)


def test_decode_fault_fails_sharers_loop_stays_serviceable(server):
    """A fault in the mixed-progress decode launch fails exactly the
    rows that shared it — and the NEXT submission on the same scheduler
    decodes normally (the step loop and shared cache survive)."""
    from repro.launch.serve import RequestError
    from repro.runtime import faults

    rng = np.random.default_rng(7)
    reqs = _requests(rng, 2, max_new=6)
    serial = _serial(server, reqs)

    sched = ContinuousScheduler(server, batch_rows=4)
    # scheduler_step occurrences: admit, admit, then the decode launch.
    plan = faults.FaultPlan({"scheduler_step": [3]})
    with faults.installed(plan):
        rids = [sched.submit(r) for r in reqs]
        res = sched.drain()
        assert plan.fired == [("scheduler_step", 3)]
        for rid in rids:
            assert isinstance(res[rid], RequestError)
            assert res[rid].stage == "decode"
        # Same scheduler, same (exhausted) plan: full recovery.
        rid2 = sched.submit(reqs[0])
        res2 = sched.drain()
    assert np.array_equal(res2[rid2], serial[0])
    _assert_clean(server, sched)


def test_bounded_queue_backpressure(server):
    """``max_queue`` bounds the admission queue: the overflow submit
    raises QueueFullError, the queued request still completes."""
    from repro.launch.serve import QueueFullError

    rng = np.random.default_rng(8)
    reqs = _requests(rng, 2, max_new=4)
    serial = _serial(server, reqs)

    sched = ContinuousScheduler(server, batch_rows=4, max_queue=1)
    rid = sched.submit(reqs[0])
    with pytest.raises(QueueFullError, match="admission queue is full"):
        sched.submit(reqs[1])
    res = sched.drain()
    assert np.array_equal(res[rid], serial[0])
    with pytest.raises(ValueError, match="max_queue"):
        ContinuousScheduler(server, batch_rows=4, max_queue=0)
    _assert_clean(server, sched)


def test_deadline_expires_and_slot_reuse(server):
    """An already-expired deadline resolves to DeadlineExceeded before
    any decode work; the freed capacity serves the next request."""
    from repro.launch.serve import DeadlineExceeded
    from repro.runtime import faults  # noqa: F401 (site parity import)

    rng = np.random.default_rng(9)
    reqs = _requests(rng, 2, max_new=4)
    serial = _serial(server, reqs)

    sched = ContinuousScheduler(server, batch_rows=4)
    doomed = Request(
        tokens=reqs[0].tokens, max_new=4, deadline_s=0.0
    )
    rid0 = sched.submit(doomed)
    rid1 = sched.submit(reqs[1])
    res = sched.drain()
    err = res[rid0]
    assert isinstance(err, DeadlineExceeded)
    assert err.stage == "deadline" and err.request_id == rid0
    assert np.array_equal(res[rid1], serial[1])
    assert sched.stats["deadline_expired"] == 1
    # The expired request's slot capacity is reusable immediately.
    rid2 = sched.submit(reqs[0])
    res2 = sched.drain()
    assert np.array_equal(res2[rid2], serial[0])
    _assert_clean(server, sched)


def test_cache_overflow_one_typed_error_both_paths(server):
    """``generate()`` and ``submit()`` refuse an impossible request with
    the SAME typed error (CacheOverflowError, a ValueError subclass) —
    one overflow contract across the serial and batched paths."""
    from repro.launch.serve import CacheOverflowError

    big = Request(tokens=np.zeros((1, 200), np.int32), max_new=MAX_CACHE)
    sched = ContinuousScheduler(server, batch_rows=4)
    with pytest.raises(CacheOverflowError, match="admission refused"):
        sched.submit(big)
    with pytest.raises(CacheOverflowError):
        server.generate(big)
    assert issubclass(CacheOverflowError, ValueError)
    assert sched.drain() == {}
    _assert_clean(server, sched)


@pytest.mark.contention
def test_threaded_submitters_stress(server):
    """Submitters race the scheduler thread: every request completes and
    matches its serial tokens, the ledger settles.  Timing-sensitive by
    design — nightly ``pytest -m contention``, not tier-1."""
    rng = np.random.default_rng(5)
    reqs = _requests(rng, 12, max_new=8)
    serial = _serial(server, reqs)
    sched = ContinuousScheduler(server, batch_rows=4)
    rids: dict[int, int] = {}
    lock = threading.Lock()

    def submitter(idxs):
        for i in idxs:
            rid = sched.submit(reqs[i])
            with lock:
                rids[i] = rid

    threads = [
        threading.Thread(target=submitter, args=(range(k, 12, 3),))
        for k in range(3)
    ]
    for t in threads:
        t.start()
    results: dict[int, np.ndarray] = {}
    while len(results) < len(reqs):
        results.update(sched.drain())
    for t in threads:
        t.join()
    for i, ser in enumerate(serial):
        assert np.array_equal(results[rids[i]], ser), i
    _assert_clean(server, sched)
