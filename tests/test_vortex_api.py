"""The repro.vortex public API: registry-driven ops (a workload registered
in THIS file is served with no engine edits), contextvar-scoped engine
sessions (nesting, exception restore, thread isolation), CompiledOp
handles, EngineConfig, precompile diagnostics, and the deprecation shims'
parity contract (bit-identical outputs, identical cache keys)."""
import dataclasses
import threading
from typing import ClassVar

import numpy as np
import pytest

import jax.numpy as jnp

from repro import vortex
from repro.core import GemmWorkload, PrecompileError, AttentionWorkload
from repro.core.workloads import WORKLOADS
from repro.kernels.ref import ref_attention, ref_conv2d, ref_gemm
from repro.vortex import (
    CompiledOp,
    Engine,
    EngineConfig,
    VortexDeprecationWarning,
)

RNG = np.random.default_rng(11)


def _arr(shape):
    return jnp.asarray(RNG.normal(size=shape), jnp.float32)


def _engine():
    return Engine("host_cpu", empirical_levels=())


# ---------------------------------------------------------------------------
# Registry-driven ops: @register_workload alone exposes vortex.ops.<kind>
# ---------------------------------------------------------------------------


def test_registered_toy_workload_served_with_no_engine_edits():
    """Acceptance: registering a workload in a TEST exposes a working
    vortex.ops.<kind> handle — no edits to any engine module."""

    @vortex.register_workload
    @dataclasses.dataclass(frozen=True)
    class DoubledGemm(GemmWorkload):
        """2 * (A @ B): distinct numerics so a routing mixup would show."""

        kind: ClassVar[str] = "doubled_gemm_toy"

        def build_executable(self, sel, *, impl, interpret):
            inner = GemmWorkload.build_executable(
                self, sel, impl=impl, interpret=interpret
            )

            # The staging contract: the fused executable takes the bucket
            # view plus the runtime-extent scalars (here gemm's m_true).
            def fn(a, b, m_true):
                return 2.0 * inner(a, b, m_true)

            return fn

    try:
        assert "doubled_gemm_toy" in WORKLOADS
        a, b = _arr((13, 32)), _arr((32, 24))
        with vortex.use(_engine()) as eng:
            out = vortex.ops.doubled_gemm_toy(a, b)
            np.testing.assert_allclose(
                np.asarray(out), 2.0 * np.asarray(ref_gemm(a, b)),
                rtol=1e-4, atol=1e-4,
            )
            # Served through the session's registry dispatch, with the
            # inherited raw-tuple hot-path key (kind, K, N).
            assert ("doubled_gemm_toy", 32, 24) in eng._dispatch
            # The generic handle works for the toy kind too.
            op = vortex.ops.doubled_gemm_toy.handle_for(a, b)
            assert isinstance(op, CompiledOp)
            assert op.kind == "doubled_gemm_toy"
            assert op.bucket(13) == op.select(13).padded_m
    finally:
        WORKLOADS.pop("doubled_gemm_toy", None)
        vortex.ops._OPS.pop("doubled_gemm_toy", None)


def test_ops_unknown_kind_raises():
    with pytest.raises(AttributeError, match="no workload kind"):
        vortex.ops.definitely_not_registered


def test_ops_dir_lists_registry():
    listing = dir(vortex.ops)
    assert {"gemm", "attention", "conv2d"} <= set(listing)


def test_compile_by_kind_name_and_instance_agree():
    eng = _engine()
    by_name = eng.compile("gemm", M=None, N=24, K=32)
    by_inst = eng.compile(GemmWorkload(M=None, N=24, K=32))
    assert by_name.kernel is by_inst.kernel  # one kernel per signature
    a, b = _arr((7, 32)), _arr((32, 24))
    np.testing.assert_array_equal(
        np.asarray(by_name(a, b)), np.asarray(by_inst(a, b))
    )


def test_compile_rejects_params_with_instance():
    with pytest.raises(TypeError, match="kind name"):
        _engine().compile(GemmWorkload(M=None, N=8, K=8), N=16)


# ---------------------------------------------------------------------------
# Sessions: contextvar scoping
# ---------------------------------------------------------------------------


def test_use_nests_and_restores():
    e1, e2 = _engine(), _engine()
    assert vortex.installed_engine() is None
    with vortex.use(e1):
        assert vortex.installed_engine() is e1
        assert vortex.current_engine() is e1
        with vortex.use(e2):
            assert vortex.installed_engine() is e2
        assert vortex.installed_engine() is e1
    assert vortex.installed_engine() is None


def test_use_restores_on_exception():
    e1, e2 = _engine(), _engine()
    with vortex.use(e1):
        with pytest.raises(ValueError):
            with vortex.use(e2):
                assert vortex.installed_engine() is e2
                raise ValueError("boom")
        assert vortex.installed_engine() is e1
    assert vortex.installed_engine() is None


def test_thread_isolation():
    """Two threads with different engines must not observe each other, and
    a fresh thread starts with NO installed engine even while the spawning
    thread holds one."""
    e_main, e_thread = _engine(), _engine()
    seen: dict[str, object] = {}
    installed = threading.Event()
    checked = threading.Event()

    def worker():
        seen["at_start"] = vortex.installed_engine()
        with vortex.use(e_thread):
            seen["inside"] = vortex.installed_engine()
            installed.set()
            checked.wait(timeout=10)
        seen["after"] = vortex.installed_engine()

    with vortex.use(e_main):
        t = threading.Thread(target=worker)
        t.start()
        installed.wait(timeout=10)
        # The worker holds e_thread; this thread still sees e_main.
        assert vortex.installed_engine() is e_main
        checked.set()
        t.join(timeout=10)
    assert seen["at_start"] is None
    assert seen["inside"] is e_thread
    assert seen["after"] is None


def test_current_engine_falls_back_to_process_default():
    assert vortex.installed_engine() is None
    d1 = vortex.current_engine()
    d2 = vortex.current_engine()
    assert d1 is d2 is vortex.default_engine()
    with vortex.use(_engine()) as eng:
        assert vortex.current_engine() is eng


def test_engine_use_shorthand():
    eng = _engine()
    with eng.use():
        assert vortex.installed_engine() is eng
    assert vortex.installed_engine() is None


# ---------------------------------------------------------------------------
# EngineConfig
# ---------------------------------------------------------------------------


def test_engine_config_is_frozen_and_overridable():
    cfg = EngineConfig(hardware="tpu_v5e", backends=["mxu"])
    assert cfg.backends == ("mxu",)  # normalized to a tuple (hashable)
    hash(cfg)
    with pytest.raises(dataclasses.FrozenInstanceError):
        cfg.impl = "pallas"
    eng = Engine(cfg, empirical_levels=())
    assert eng.config.hardware == "tpu_v5e"
    assert eng.config.empirical_levels == ()


def test_config_table_limits_reach_the_selector():
    eng = Engine(EngineConfig(
        hardware="host_cpu", empirical_levels=(), table_m_max=32,
        table_extend_limit=64,
    ))
    kern = eng.compile("gemm", M=None, N=16, K=16).kernel
    assert kern.selector.table.m_max == 32
    kern.select(1000)  # beyond the extension limit: table must not grow
    assert kern.selector.table.m_max == 32


def test_precompile_policy_warms_unspecialized_ops_only():
    eng = Engine(EngineConfig(
        hardware="host_cpu", empirical_levels=(), precompile_m_max=64
    ))
    gemm = eng.compile("gemm", M=None, N=16, K=16)
    expect = len(gemm.kernel.selector.selections_upto(64))
    assert gemm.stats()["exec"]["entries"] == expect > 0
    # Attention executables specialize on batch/head dims: eager precompile
    # without representative args would warm keys real calls never hit.
    attn = eng.compile("attention", seq=None, head_dim=32)
    assert attn.stats()["exec"]["entries"] == 0


# ---------------------------------------------------------------------------
# Precompile diagnostics (PrecompileError names the failing Selection)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("max_workers", [1, 4], ids=["serial", "parallel"])
def test_precompile_failure_names_selection(max_workers):
    op = _engine().compile("gemm", M=None, N=16, K=16)
    kern = op.kernel

    def broken(sel, args):
        raise RuntimeError("builder exploded")

    kern._build_executable = broken
    with pytest.raises(PrecompileError) as exc:
        op.precompile(64, max_workers=max_workers)
    msg = str(exc.value)
    assert "gemm" in msg and "bucket=" in msg and "backend=" in msg
    assert "builder exploded" in msg
    assert exc.value.selection.bucket[0] >= 1


# ---------------------------------------------------------------------------
# Deprecation shims: warn, delegate, and stay bit/key-identical
# ---------------------------------------------------------------------------


def test_vortex_engine_shim_parity_gemm():
    """VortexEngine.gemm must produce bit-identical outputs and identical
    dispatch/kernel/executable-cache keys to the registry-driven path."""
    from repro.core import VortexEngine

    a, b = _arr((13, 48)), _arr((48, 32))
    old = VortexEngine("host_cpu", empirical_levels=())
    new = _engine()
    with pytest.warns(VortexDeprecationWarning, match="VortexEngine.gemm"):
        y_old = old.gemm(a, b)
    y_new = new.dispatch("gemm", a, b)
    np.testing.assert_array_equal(np.asarray(y_old), np.asarray(y_new))
    assert set(old._dispatch) == set(new._dispatch) == {("gemm", 48, 32)}
    assert set(old._kernels) == set(new._kernels)
    k_old = next(iter(old._kernels.values()))
    k_new = next(iter(new._kernels.values()))
    assert set(k_old._exec_cache) == set(k_new._exec_cache)


def test_vortex_engine_shim_parity_attention_and_conv():
    from repro.core import VortexEngine

    old = VortexEngine("host_cpu", empirical_levels=())
    new = _engine()
    q, k, v = _arr((1, 4, 19, 32)), _arr((1, 2, 19, 32)), _arr((1, 2, 19, 32))
    with pytest.warns(VortexDeprecationWarning):
        y_old = old.attention(q, k, v, window=8)
    y_new = new.dispatch("attention", q, k, v, window=8)
    np.testing.assert_array_equal(np.asarray(y_old), np.asarray(y_new))

    x, w = _arr((2, 9, 9, 4)), _arr((3, 3, 4, 8))
    with pytest.warns(VortexDeprecationWarning):
        c_old = old.conv2d(x, w)
    c_new = new.dispatch("conv2d", x, w)
    np.testing.assert_array_equal(np.asarray(c_old), np.asarray(c_new))
    assert set(old._dispatch) == set(new._dispatch)
    assert set(old._kernels) == set(new._kernels)


def test_vortex_gemm_shim_warns_and_matches_kernel():
    from repro.core import VortexKernel, VortexGemm
    from repro.core.hardware import HOST_CPU

    wl = GemmWorkload(M=None, N=24, K=32)
    with pytest.warns(VortexDeprecationWarning, match="VortexGemm"):
        old = VortexGemm(HOST_CPU, wl, empirical_levels=())
    new = VortexKernel(HOST_CPU, wl, empirical_levels=())
    a, b = _arr((9, 32)), _arr((32, 24))
    np.testing.assert_array_equal(np.asarray(old(a, b)), np.asarray(new(a, b)))
    assert set(old._exec_cache) == set(new._exec_cache)
    assert old.select(9).bucket == new.select(9).bucket


def test_set_attention_engine_shim_delegates_to_contextvar():
    """The deprecated imperative surface must be a view over the SAME
    contextvar vortex.use writes."""
    from repro.models import layers

    eng = _engine()
    with pytest.warns(VortexDeprecationWarning, match="set_attention_engine"):
        prev = layers.set_attention_engine(eng)
    assert prev is None
    assert vortex.installed_engine() is eng  # same underlying session
    with pytest.warns(VortexDeprecationWarning, match="get_attention_engine"):
        assert layers.get_attention_engine() is eng
    with pytest.warns(VortexDeprecationWarning, match="set_attention_engine"):
        assert layers.set_attention_engine(None) is eng
    assert vortex.installed_engine() is None
    # And the other direction: a vortex.use install is visible through the
    # deprecated getter.
    with vortex.use(eng):
        with pytest.warns(VortexDeprecationWarning):
            assert layers.get_attention_engine() is eng


def test_attention_engine_contextmanager_shim():
    from repro.models import layers

    eng = _engine()
    with pytest.warns(VortexDeprecationWarning, match="attention_engine"):
        with layers.attention_engine(eng):
            assert vortex.installed_engine() is eng
    assert vortex.installed_engine() is None


def test_internal_deprecations_are_errors_by_default():
    """Tier-1 runs with repro's own DeprecationWarnings as errors (see
    pyproject filterwarnings): an un-caught shim call must raise, so
    internal callers cannot silently regress onto the old surface."""
    from repro.core import VortexEngine

    eng = VortexEngine("host_cpu", empirical_levels=())
    with pytest.raises(VortexDeprecationWarning):
        eng.gemm(_arr((4, 8)), _arr((8, 4)))


# ---------------------------------------------------------------------------
# CompiledOp handle surface
# ---------------------------------------------------------------------------


def test_compiled_op_call_select_bucket_stats():
    op = vortex.compile(
        GemmWorkload(M=None, N=32, K=48), engine=_engine()
    )
    a, b = _arr((21, 48)), _arr((48, 32))
    np.testing.assert_allclose(
        np.asarray(op(a, b)), np.asarray(ref_gemm(a, b)),
        rtol=1e-4, atol=1e-4,
    )
    sel = op.select(21)
    assert op.bucket(21) == sel.padded_m >= 21
    assert op.bucket(21) in op.buckets(64)
    n = op.precompile(64)
    assert n >= 1
    s = op.stats()
    assert s["kind"] == "gemm"
    assert s["select"]["selects"] >= 2
    assert s["exec"]["entries"] >= 1
    assert s["offline"].num_candidates > 0


def test_compiled_op_attention_with_representative_args():
    eng = _engine()
    op = eng.compile(AttentionWorkload(seq=None, head_dim=32))
    q, k, v = _arr((2, 4, 5, 32)), _arr((2, 2, 5, 32)), _arr((2, 2, 5, 32))
    op.precompile(64, q, k, v)
    entries = op.stats()["exec"]["entries"]
    assert entries >= 1
    with vortex.use(eng):
        out = vortex.ops.attention(
            q, k, v
        )  # same signature: served from the warmed cache
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(ref_attention(q, k, v, causal=True)),
        rtol=1e-4, atol=1e-4,
    )
    assert op.stats()["exec"]["entries"] == entries  # no new compiles
