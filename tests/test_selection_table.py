"""Selection-table correctness: the offline-materialized breakpoint table
must agree EXACTLY with the runtime argmin path for every M in range (it is
a memoization, not an approximation), extend itself past m_max, and keep
the separated table/LRU/argmin overhead accounting honest."""
import numpy as np
import pytest

from repro.core import (
    HOST_CPU,
    TPU_V5E,
    AttentionWorkload,
    Conv2dWorkload,
    GemmWorkload,
    StackedLattices,
    build_selection_table,
    merge_breakpoints,
)
from repro.core.analyzer import AnalyticalProfiler, HybridAnalyzer
from repro.core.candidates import generate_lattice
from repro.vortex import Engine
from repro.core.selector import RuntimeSelector


def _scored(hw, wl, backend):
    lat = generate_lattice(hw, wl, backend)
    analyzer = HybridAnalyzer(
        hw, wl, profiler=AnalyticalProfiler(hw), empirical_levels=()
    )
    return analyzer.score(lat)


def _scored_all(hw, wl):
    return {b: _scored(hw, wl, b) for b in hw.backends}


def _key(s):
    return (s.bucket, s.strategy.tiles, s.backend, s.grid, s.padded_m)


WLS = [
    GemmWorkload(M=None, N=768, K=2304),
    AttentionWorkload(seq=None, head_dim=64),
    Conv2dWorkload(m=None, cin=16, cout=32, kh=3, kw=3),
]
WL_IDS = [wl.kind for wl in WLS]


# ---------------------------------------------------------------------------
# Golden equivalence: table == argmin for EVERY M in [1, m_max]
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("wl", WLS, ids=WL_IDS)
def test_table_matches_argmin_for_every_m(wl):
    """SelectionTable.lookup(m) must equal the pure argmin selection
    (bucket, strategy, backend, grid, padded_m AND predicted cost) for all
    M in [1, m_max], with ALL hardware backends stacked."""
    m_max = 333  # not tile-aligned on purpose
    scored = _scored_all(TPU_V5E, wl)
    tabled = RuntimeSelector(TPU_V5E, wl, scored, table_m_max=m_max)
    argmin = RuntimeSelector(TPU_V5E, wl, scored, table_m_max=0)
    for m in range(1, m_max + 1):
        a = tabled.select(m)
        b = argmin._select_argmin(m)
        assert _key(a) == _key(b), m
        # Bit-identical float arithmetic between sweep and per-M argmin.
        assert a.predicted_cost == b.predicted_cost, m
    assert tabled.stats.table_hits == m_max
    assert tabled.stats.argmin_misses == 0


@pytest.mark.parametrize("wl", WLS, ids=WL_IDS)
def test_fallback_and_extend_past_m_max(wl):
    """Past the table, selection falls back to argmin (identical result)
    and the table extends itself by doubling so the next unseen extent in
    range is a table hit."""
    scored = _scored_all(TPU_V5E, wl)
    sel = RuntimeSelector(TPU_V5E, wl, scored, table_m_max=64)
    ref = RuntimeSelector(TPU_V5E, wl, scored, table_m_max=0)
    assert sel.table.m_max == 64

    beyond = 200
    got = sel.select(beyond)
    assert _key(got) == _key(ref._select_argmin(beyond))
    assert sel.stats.argmin_misses == 1
    # Doubled 64 -> 128 -> 256: the miss grew the table over the extent.
    assert sel.table.m_max == 256

    after = sel.select(199)  # unseen, now covered
    assert sel.stats.table_hits == 1
    assert _key(after) == _key(ref._select_argmin(199))


def test_degenerate_extent_bypasses_table():
    """m < 1 is outside every table interval: it must take the argmin path
    (which prices an empty extent exactly: zero grid rows, zero padding),
    not silently read the table's last entry."""
    wl = GemmWorkload(M=None, N=256, K=256)
    scored = {"simd": _scored(HOST_CPU, wl, "simd")}
    sel = RuntimeSelector(HOST_CPU, wl, scored)
    ref = RuntimeSelector(HOST_CPU, wl, scored, table_m_max=0)
    got = sel.select(0)
    assert sel.stats.table_hits == 0
    assert sel.stats.argmin_misses == 1
    assert got.padded_m == 0 and got.grid[0] == 0
    assert _key(got) == _key(ref._select_argmin(0))
    assert sel.table.m_max == 4096  # no spurious extension for m < 1


def test_extension_respects_limit():
    wl = GemmWorkload(M=None, N=256, K=256)
    scored = {"simd": _scored(HOST_CPU, wl, "simd")}
    sel = RuntimeSelector(
        HOST_CPU, wl, scored, table_m_max=32, table_extend_limit=64
    )
    sel.select(1000)  # beyond the extension limit
    assert sel.table.m_max == 32  # untouched
    sel.select(1000)
    assert sel.stats.lru_hits == 1  # LRU backs the uncovered tail


# ---------------------------------------------------------------------------
# Table structure
# ---------------------------------------------------------------------------


def test_merge_breakpoints_divisor_free():
    """Heap-merged interval starts == the brute-force breakpoint set."""
    periods, m_max = [3, 4, 6], 40
    expect = sorted(
        {1}
        | {j * t + 1 for t in periods for j in range(1, m_max) if j * t + 1 <= m_max}
    )
    assert merge_breakpoints(periods, m_max) == expect


@pytest.mark.parametrize("wl", WLS, ids=WL_IDS)
def test_table_entries_are_merged_and_sorted(wl):
    scored = _scored_all(TPU_V5E, wl)
    table = build_selection_table(
        TPU_V5E, wl, StackedLattices.stack(scored), 512
    )
    assert table.starts[0] == 1
    assert table.starts == sorted(set(table.starts))
    # Merging means adjacent entries always differ.
    for a, b in zip(table.entries, table.entries[1:]):
        assert _key(a) != _key(b)
    assert len(table) <= table.num_intervals


def test_table_entries_carry_zero_select_seconds():
    """Satellite: cached selections must not re-report the stale latency of
    their original miss — table entries are stamped 0.0 and the per-serve
    accounting lives in SelectorStats."""
    wl = GemmWorkload(M=None, N=256, K=256)
    sel = RuntimeSelector(HOST_CPU, wl, _scored_all(HOST_CPU, wl))
    s = sel.select(77)
    assert s.select_seconds == 0.0
    assert sel.stats.mean_select_us == 0.0  # no argmin misses yet
    sel.select(77)
    assert sel.stats.selects == 2
    assert sel.stats.table_hits == 2


# ---------------------------------------------------------------------------
# Engine hot path
# ---------------------------------------------------------------------------


def test_engine_dispatch_reuses_kernel_without_workload_rebuild():
    """Steady-state engine calls hit the raw-tuple dispatch dict: one
    kernel per call-site signature, found without constructing Workloads."""
    import jax.numpy as jnp

    eng = Engine("host_cpu", empirical_levels=())
    rng = np.random.default_rng(0)
    b = jnp.asarray(rng.normal(size=(64, 48)), jnp.float32)
    for m in (8, 16, 13):
        eng.dispatch("gemm", jnp.asarray(rng.normal(size=(m, 64)), jnp.float32), b)
    assert len(eng._dispatch) == 1
    assert len(eng._kernels) == 1
    assert eng._dispatch[("gemm", 64, 48)] is next(iter(eng._kernels.values()))


def test_stats_does_not_build_tables():
    """Introspection must not charge a breakpoint sweep to idle kernels."""
    eng = Engine("host_cpu", empirical_levels=())
    kern = eng.compile("gemm", M=None, N=48, K=64).kernel  # built, never dispatched
    s = eng.stats()["gemm"]
    assert s["table_entries"] == 0
    assert s["table_build_s"] == 0.0
    assert kern.selector.table_if_built is None


def test_engine_skips_pad_when_bucket_aligned():
    """A bucket-aligned extent must produce the same result via the no-pad
    fast path as the padded general path produces for a misaligned one."""
    import jax.numpy as jnp

    from repro.kernels.ref import ref_gemm

    eng = Engine("host_cpu", empirical_levels=())
    rng = np.random.default_rng(1)
    b = jnp.asarray(rng.normal(size=(96, 80)), jnp.float32)
    kern = eng.compile("gemm", M=None, N=80, K=96).kernel
    aligned_m = kern.select(64).padded_m  # an exactly-bucket-sized extent
    a = jnp.asarray(rng.normal(size=(aligned_m, 96)), jnp.float32)
    sel = kern.select(aligned_m)
    assert kern.workload.staged_shapes(sel, a, b)[0] == a.shape
    np.testing.assert_allclose(
        np.asarray(eng.dispatch("gemm", a, b)), np.asarray(ref_gemm(a, b)),
        rtol=1e-4, atol=1e-4,
    )
    # The aligned extent took the zero-copy fast path: one launch, no
    # staging, no pad fallback.
    d = eng.stats()["gemm"]
    assert d["aligned_calls"] == 1 and d["launches"] == 1
    assert d["stage_copies"] == 0 and d["padded_calls"] == 0


def test_parallel_precompile_matches_serial():
    """Threaded precompile warms exactly the keys serial precompile would,
    and subsequent calls add no entries."""
    import jax.numpy as jnp

    eng_p = Engine("host_cpu", empirical_levels=())
    eng_s = Engine("host_cpu", empirical_levels=())
    wl = GemmWorkload(M=None, N=48, K=64)
    n_p = eng_p.kernel_for(wl).precompile(128)
    n_s = eng_s.kernel_for(wl).precompile(128, max_workers=1)
    assert n_p == n_s
    kp, ks = eng_p.kernel_for(wl), eng_s.kernel_for(wl)
    assert set(kp._exec_cache) == set(ks._exec_cache)
    entries = kp.cache_info["entries"]
    rng = np.random.default_rng(2)
    b = jnp.asarray(rng.normal(size=(64, 48)), jnp.float32)
    for m in (3, 65, 127):
        eng_p.dispatch("gemm", jnp.asarray(rng.normal(size=(m, 64)), jnp.float32), b)
    assert kp.cache_info["entries"] == entries
