"""MoE numerics + the engine-served grouped-GEMM expert FFN.

Three surfaces:

  * the gather-only sort dispatch of ``moe_forward`` against a NAIVE
    loop-over-experts reference — bit-identical when capacity admits every
    assignment, and matching the documented drop semantics (an expert keeps
    its first C assignments in flat (token, choice) order; dropped
    assignments contribute exactly zero, no renormalization) below it;
  * ``dropped_frac``: 0 when nothing is dropped, > 0 and exact when the
    capacity bound bites;
  * the engine path: with a session installed, ``_expert_ffn`` serves all
    experts through exactly ONE grouped-GEMM launch per projection (three
    per MoE layer), zero padded calls, bit-identical to the inline dense
    einsums — and the inline fallback is untouched without a session.

Plus the decode-mode ``mamba_forward`` multi-token guard.
"""
import dataclasses
import math

import numpy as np
import pytest

import jax
import jax.numpy as jnp

import repro.vortex as vortex
from repro.configs.granite_moe_1b import SMOKE
from repro.models import layers as L
from repro.models.partitioning import AxisRules

RULES = AxisRules(rules={}, mesh_axes=())
RNG = np.random.default_rng(7)


def _moe_params(cfg, scale=0.05):
    m = cfg.moe
    d, E, dff = cfg.d_model, m.num_experts, m.d_ff_expert
    mk = lambda *s: jnp.asarray(RNG.normal(size=s) * scale, jnp.float32)
    return {
        "router": mk(d, E),
        "w_in": mk(E, d, dff),
        "w_gate": mk(E, d, dff),
        "w_out": mk(E, dff, d),
    }


def _with_capacity(cfg, capacity_factor):
    return dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=capacity_factor)
    )


def _naive_moe(p, x, cfg):
    """Loop-over-experts reference with explicit FIFO capacity drops.

    Routing matches ``moe_forward`` (same router/top-k/renormalize); each
    expert admits its first C assignments in flat (token, choice) order —
    the order the stable argsort dispatch preserves — and every dropped
    assignment contributes 0.  Returns (y, dropped_frac).
    """
    m = cfg.moe
    b, s, d = x.shape
    E, k = m.num_experts, m.top_k
    C = max(1, int(math.ceil(s * k * m.capacity_factor / E)))
    xf = x.astype(jnp.float32)
    probs = jax.nn.softmax(jnp.einsum("gtd,de->gte", xf, p["router"]), -1)
    topw, topi = jax.lax.top_k(probs, k)
    topw = np.asarray(topw / jnp.sum(topw, axis=-1, keepdims=True))
    topi = np.asarray(topi)

    def ffn(e, rows):
        # rows: (n, d) through expert e — the same jnp elementary ops as
        # the inline einsums so bit-identity is meaningful (a numpy BLAS
        # matmul rounds differently at the ulp level).
        h = rows @ p["w_in"][e]
        g = rows @ p["w_gate"][e]
        return np.asarray(L._glu_act(cfg, h, g) @ p["w_out"][e])

    xn = np.asarray(x)
    y = np.zeros((b, s, d), np.float32)
    dropped = 0
    for g in range(b):
        admitted = {e: 0 for e in range(E)}
        for t in range(s):
            for j in range(k):
                e = int(topi[g, t, j])
                if admitted[e] >= C:
                    dropped += 1
                    continue
                admitted[e] += 1
                y[g, t] += topw[g, t, j] * ffn(e, jnp.asarray(xn[g, t][None]))[0]
    return y.astype(np.asarray(x).dtype), dropped / (b * s * k)


@pytest.mark.parametrize("shape", [(1, 16), (2, 33)])
def test_moe_sort_dispatch_matches_naive_loop_no_drops(shape):
    """At a capacity factor admitting every assignment, the gather-only
    sorted dispatch is BIT-IDENTICAL to the naive per-expert loop and
    dropped_frac is exactly 0."""
    b, s = shape
    cfg = _with_capacity(SMOKE, float(SMOKE.moe.num_experts))
    p = _moe_params(cfg)
    x = jnp.asarray(RNG.normal(size=(b, s, cfg.d_model)), jnp.float32)
    y, aux, dropped = L.moe_forward(p, x, cfg, RULES)
    assert float(dropped) == 0.0
    y_ref, dropped_ref = _naive_moe(p, x, cfg)
    assert dropped_ref == 0.0
    np.testing.assert_array_equal(np.asarray(y), y_ref)


def test_moe_capacity_drops_are_surfaced_and_match_naive_fifo():
    """Below capacity the bound bites: dropped_frac reports the exact
    dropped fraction and the output matches the naive FIFO drop
    semantics (first-come within the flat (token, choice) order)."""
    cfg = _with_capacity(SMOKE, 0.25)
    p = _moe_params(cfg)
    x = jnp.asarray(RNG.normal(size=(2, 32, cfg.d_model)), jnp.float32)
    y, aux, dropped = L.moe_forward(p, x, cfg, RULES)
    y_ref, dropped_ref = _naive_moe(p, x, cfg)
    assert dropped_ref > 0.0, "test must exercise the capacity bound"
    assert float(dropped) == pytest.approx(dropped_ref, abs=1e-6)
    np.testing.assert_array_equal(np.asarray(y), y_ref)


def test_moe_engine_one_grouped_launch_per_projection():
    """With a session installed, the eager MoE layer serves every expert
    through ONE grouped-GEMM launch per projection (w_in, w_gate, w_out =
    3 per layer call), zero padded calls, bit-identical to the inline
    dense einsums."""
    cfg = SMOKE
    p = _moe_params(cfg)
    x = jnp.asarray(RNG.normal(size=(2, 33, cfg.d_model)), jnp.float32)
    y_inline, aux0, drop0 = L.moe_forward(p, x, cfg, RULES)

    eng = vortex.Engine("host_cpu", empirical_levels=(), impl="xla")
    with vortex.use(eng):
        y_eng, aux1, drop1 = L.moe_forward(p, x, cfg, RULES)
        y_eng2, _, _ = L.moe_forward(p, x, cfg, RULES)
    d = eng.stats()["grouped_gemm"]
    assert d["launches"] == 6  # 2 calls x 3 projections, all experts each
    assert d["padded_calls"] == 0
    np.testing.assert_array_equal(np.asarray(y_eng), np.asarray(y_inline))
    np.testing.assert_array_equal(np.asarray(y_eng2), np.asarray(y_inline))
    np.testing.assert_array_equal(np.asarray(aux1), np.asarray(aux0))
    np.testing.assert_array_equal(np.asarray(drop1), np.asarray(drop0))

    # Inline fallback after the session closes: no new engine traffic.
    y_after, _, _ = L.moe_forward(p, x, cfg, RULES)
    np.testing.assert_array_equal(np.asarray(y_after), np.asarray(y_inline))
    assert eng.stats()["grouped_gemm"]["launches"] == 6


def test_moe_engine_granite_shapes_serve_through_engine():
    """granite_moe_1b-shaped expert stacks (32 experts, top-8, d_ff 512)
    route through the engine — the acceptance shape of the workload."""
    from repro.configs.granite_moe_1b import CONFIG

    cfg = dataclasses.replace(
        CONFIG, d_model=128,
        moe=dataclasses.replace(CONFIG.moe, d_ff_expert=64),
    )
    p = _moe_params(cfg)
    x = jnp.asarray(RNG.normal(size=(1, 64, cfg.d_model)), jnp.float32)
    y_inline, _, _ = L.moe_forward(p, x, cfg, RULES)
    eng = vortex.Engine("host_cpu", empirical_levels=(), impl="xla")
    with vortex.use(eng):
        y_eng, _, _ = L.moe_forward(p, x, cfg, RULES)
    d = eng.stats()["grouped_gemm"]
    assert d["launches"] == 3 and d["padded_calls"] == 0
    np.testing.assert_array_equal(np.asarray(y_eng), np.asarray(y_inline))


def test_moe_traced_calls_keep_functional_path():
    """Inside an enclosing jit the layer must not capture engine-owned
    buffers: the inline einsums serve the traced call, numerics
    unchanged."""
    cfg = SMOKE
    p = _moe_params(cfg)
    x = jnp.asarray(RNG.normal(size=(1, 16, cfg.d_model)), jnp.float32)
    y_eager, _, _ = L.moe_forward(p, x, cfg, RULES)

    eng = vortex.Engine("host_cpu", empirical_levels=(), impl="xla")
    with vortex.use(eng):
        y_jit = jax.jit(
            lambda xx: L.moe_forward(p, xx, cfg, RULES)[0]
        )(x)
    assert "grouped_gemm" not in eng.stats()
    np.testing.assert_allclose(
        np.asarray(y_jit), np.asarray(y_eager), rtol=1e-5, atol=1e-5
    )


def test_mamba_decode_rejects_multi_token_input():
    """decode mode consumes exactly one token: a multi-token slab would
    silently corrupt the conv state, so it must raise a typed error."""
    from repro.configs.falcon_mamba_7b import CONFIG as MAMBA

    di = 8
    p = {"in_proj": jnp.zeros((4, 2 * di), jnp.float32)}
    cfg = dataclasses.replace(
        MAMBA, d_model=4,
        ssm=dataclasses.replace(
            MAMBA.ssm, d_inner=di, d_state=4, d_conv=4, dt_rank=2
        ),
    )
    cache = {"conv": jnp.zeros((1, 3, di)), "ssm": jnp.zeros((1, di, 4))}
    with pytest.raises(ValueError, match="one token per step"):
        L.mamba_forward(
            p, jnp.zeros((1, 2, 4), jnp.float32), cfg, RULES,
            mode="decode", cache=cache,
        )
