"""Algorithm 1 reference interpreter: hierarchical == flat, for any strategy
drawn from the lattice (hypothesis property)."""
import numpy as np

from conftest import optional_hypothesis

# Only the interpreter property test needs hypothesis; the program-structure
# test must keep running without it.
given, settings, st = optional_hypothesis()

from repro.core import GemmWorkload, TPU_V5E
from repro.core.candidates import generate_lattice
from repro.core.rkernel import Strategy, interpret_gemm, make_gemm_program

WL = GemmWorkload(M=None, N=256, K=256)
LAT = generate_lattice(TPU_V5E, WL, "mxu")
_PAIRS = [
    (child, l1)
    for l1 in LAT.l1[:24]
    for child in LAT.children[1][l1][:2]
]


@given(
    pair=st.sampled_from(_PAIRS),
    m=st.integers(1, 80),
    seed=st.integers(0, 2**16),
)
@settings(max_examples=25, deadline=None)
def test_interpret_gemm_matches_numpy(pair, m, seed):
    l0, l1 = pair
    # Scale tiles down so the test stays fast but keeps the multiples
    # structure (divide by the native granularity).
    scale = (8, 64, 64)
    l0s = tuple(max(a // s, 1) for a, s in zip(l0, scale))
    l1s = tuple(max(a // s, 1) for a, s in zip(l1, scale))
    # Re-snap l1 to a multiple of l0 after scaling.
    l1s = tuple(max(b - (b % a), a) for a, b in zip(l0s, l1s))
    strat = Strategy(tiles=(l0s, l1s))
    rng = np.random.default_rng(seed)
    a = rng.normal(size=(m, 24)).astype(np.float32)
    b = rng.normal(size=(24, 40)).astype(np.float32)
    out = interpret_gemm(a, b, strat)
    np.testing.assert_allclose(out, a @ b, rtol=1e-4, atol=1e-4)


def test_program_structure_matches_hardware():
    prog = make_gemm_program(TPU_V5E)
    assert prog.depth == TPU_V5E.num_levels
    for depth, layer in enumerate(prog.layers):
        assert layer.layer_depth == depth
    # k is temporal-reduction everywhere; m,n parallel only at the top.
    from repro.core.rkernel import LoopType

    top = prog.layers[-1]
    assert top.loop_type["m"] is LoopType.PARALLEL
    assert top.loop_type["k"] is LoopType.TEMPORAL_REDUCTION
