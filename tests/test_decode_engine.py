"""Differential suite for engine-served decode attention (ISSUE 5).

The sample-free claim for decode: EVERY (cache length, kv_len) pair is
served from hardware-derived kv buckets by the one-launch masked-tail
path, with correctness guaranteed by the kernel's kv_len score-mask and
value-row zeroing — NEVER by zero-filled padding.  Acceptance surface:

  * engine decode vs ``ref_attention`` across (batch, kv_len, heads,
    dtype, window), including every kv bucket boundary +-1, on both
    executable impls (hypothesis-driven where installed, deterministic
    sweeps regardless);
  * NaN-poisoned cache TAILS (rows past kv_len) and NaN-poisoned staging
    buffers must not move the output by one bit;
  * ``models/layers._decode_attend`` with a session installed matches its
    inline fallback (including the sliding-window slice path) and
    actually dispatches through the engine;
  * ``VortexServer`` decode: exactly one AOT launch per token, zero pad
    fallbacks, growth copies only at kv-bucket transitions, and the same
    kv bucket always serves from the same executable (mirrors
    test_staged_dispatch.py patterns).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh

from conftest import optional_hypothesis

given, settings, st = optional_hypothesis()

from repro.kernels.ref import ref_attention
from repro.models.layers import _decode_attend
from repro.vortex import Engine, use

RNG = np.random.default_rng(23)


def _arr(shape, dtype=jnp.float32):
    return jnp.asarray(RNG.normal(size=shape), dtype)


def _cache_args(b, hq, hkv, hd, kv_len, S, dtype=jnp.float32, poison=True):
    """(q, k, v) with a cache of length S >= kv_len; rows past kv_len are
    NaN-poisoned (the decode contract: they may hold ANYTHING)."""
    q = _arr((b, hq, 1, hd), dtype)
    k = _arr((b, hkv, S, hd), dtype)
    v = _arr((b, hkv, S, hd), dtype)
    if poison and S > kv_len:
        k = k.at[:, :, kv_len:, :].set(jnp.nan)
        v = v.at[:, :, kv_len:, :].set(jnp.nan)
    return q, k, v


def _ref(q, k, v, kv_len, window=None, softcap=None):
    """The garbage-free oracle: exact attention over the TRUE rows only."""
    return ref_attention(
        q, k[:, :, :kv_len], v[:, :, :kv_len], causal=False,
        window=window, softcap=softcap, offset=kv_len - 1,
    )


def _tol(dtype):
    return 2e-2 if dtype == jnp.bfloat16 else 2e-5


@pytest.fixture(scope="module", params=["xla", "pallas"])
def engine(request):
    return Engine(
        "host_cpu", empirical_levels=(), impl=request.param, interpret=True
    )


# ---------------------------------------------------------------------------
# Deterministic differential sweeps (run with or without hypothesis)
# ---------------------------------------------------------------------------


def _decode_buckets(engine, hd=32, n=4) -> list[int]:
    op = engine.compile("decode_attention", seq=None, head_dim=hd)
    buckets = [b for b in op.buckets(128) if b >= 2]
    # A spread of small/medium buckets keeps the sweep fast but boundary-rich.
    step = max(1, len(buckets) // n)
    return buckets[::step][:n]


def test_decode_matches_ref_at_every_bucket_boundary(engine):
    """kv_len at {bucket-1, bucket, bucket+1} for a spread of kv buckets,
    cache exactly kv_len long: every boundary serves correctly."""
    for bucket in _decode_buckets(engine):
        for kv_len in (bucket - 1, bucket, bucket + 1):
            if kv_len < 1:
                continue
            q, k, v = _cache_args(2, 4, 2, 32, kv_len, kv_len)
            out = engine.dispatch("decode_attention", q, k, v, kv_len)
            np.testing.assert_allclose(
                np.asarray(out), np.asarray(_ref(q, k, v, kv_len)),
                rtol=2e-5, atol=2e-5,
                err_msg=f"bucket {bucket}, kv_len {kv_len}",
            )


def test_decode_nan_poisoned_cache_tail_is_masked(engine):
    """The cache tail past kv_len holds NaNs; the output must be finite and
    bit-identical to the same call with a zero tail — correctness never
    depends on zero fill."""
    for bucket in _decode_buckets(engine, n=3):
        kv_len = max(bucket - 1, 1)
        S = bucket + 5  # tail inside AND beyond the bucket boundary
        q, k, v = _cache_args(1, 4, 4, 32, kv_len, S, poison=True)
        kz = k.at[:, :, kv_len:, :].set(0.0)
        vz = v.at[:, :, kv_len:, :].set(0.0)
        out = np.asarray(engine.dispatch("decode_attention", q, k, v, kv_len))
        zero = np.asarray(
            engine.dispatch("decode_attention", q, kz, vz, kv_len)
        )
        assert np.isfinite(out).all(), f"NaN tail leaked at bucket {bucket}"
        np.testing.assert_array_equal(
            out, zero, err_msg=f"tail bytes changed output (bucket {bucket})"
        )


def test_decode_poisoned_staging_buffers_do_not_leak(engine):
    """Unaligned cache lengths stage k/v into engine-owned kv-bucket
    buffers; poisoning the retained pool sets with NaN must not move the
    output (mirror of test_staged_dispatch poisoning)."""
    kern = engine.op_kernel(
        "decode_attention", _cache_args(2, 4, 2, 32, 8, 8) + (8,), {}
    )
    bucket = kern.workload.dynamic_bucket(kern.select(37))
    S = bucket - 1  # unaligned: staging in play
    kv_len = S - 1
    q, k, v = _cache_args(2, 4, 2, 32, kv_len, S)
    first = np.asarray(kern(q, k, v, kv_len))
    poisoned = 0
    for entry in kern._exec_cache.values():
        for bufs in entry.pool.retained:
            for i in list(bufs):
                bufs[i] = jnp.full_like(bufs[i], jnp.nan)
                poisoned += 1
    assert poisoned >= 1, "unaligned decode must have created staging buffers"
    again = np.asarray(kern(q, k, v, kv_len))
    assert np.isfinite(again).all(), "staging NaN poison leaked"
    np.testing.assert_array_equal(again, first)


def test_decode_gqa_dtype_window_grid(engine):
    """Deterministic (heads, dtype, window) cross product at an awkward
    kv_len: the differential grid hypothesis would sample."""
    kv_len = 23
    for hq, hkv in ((1, 1), (4, 2), (6, 3)):
        for dtype in (jnp.float32, jnp.bfloat16):
            for window in (None, 7, 64):
                q, k, v = _cache_args(2, hq, hkv, 32, kv_len, kv_len + 3,
                                      dtype=dtype)
                out = engine.dispatch(
                    "decode_attention", q, k, v, kv_len, window=window
                )
                ref = _ref(q, k, v, kv_len, window=window)
                np.testing.assert_allclose(
                    np.asarray(out, np.float32), np.asarray(ref, np.float32),
                    rtol=_tol(dtype), atol=_tol(dtype),
                    err_msg=f"hq={hq} hkv={hkv} {dtype} window={window}",
                )


# ---------------------------------------------------------------------------
# Hypothesis-driven randomized differential (skips without hypothesis)
# ---------------------------------------------------------------------------


@given(
    batch=st.integers(min_value=1, max_value=3),
    heads=st.sampled_from([(1, 1), (2, 1), (4, 2), (6, 2)]),
    kv_len=st.integers(min_value=1, max_value=90),
    tail=st.integers(min_value=0, max_value=9),
    bf16=st.sampled_from([False, True]),
    window=st.sampled_from([None, 5, 16]),
)
@settings(max_examples=40, deadline=None)
def test_decode_differential_hypothesis(batch, heads, kv_len, tail, bf16,
                                        window):
    """Randomized engine-vs-oracle sweep with NaN-poisoned tails."""
    eng = _hyp_engine()
    hq, hkv = heads
    dtype = jnp.bfloat16 if bf16 else jnp.float32
    q, k, v = _cache_args(batch, hq, hkv, 32, kv_len, kv_len + tail,
                          dtype=dtype)
    out = eng.dispatch("decode_attention", q, k, v, kv_len, window=window)
    assert np.isfinite(np.asarray(out, np.float32)).all()
    ref = _ref(q, k, v, kv_len, window=window)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32),
        rtol=_tol(dtype), atol=_tol(dtype),
    )


_HYP_ENGINE = None


def _hyp_engine() -> Engine:
    # One engine across hypothesis examples: the point is differential
    # correctness, not per-example compile time.
    global _HYP_ENGINE
    if _HYP_ENGINE is None:
        _HYP_ENGINE = Engine("host_cpu", empirical_levels=())
    return _HYP_ENGINE


# ---------------------------------------------------------------------------
# models/layers._decode_attend routing
# ---------------------------------------------------------------------------


def test_decode_attend_engine_matches_inline_fallback():
    """With a session installed, _decode_attend routes through the engine
    (launch counted) and matches the bit-identical inline fallback to
    numerical tolerance — including the sliding-window slice path."""
    b, hq, hkv, hd = 2, 4, 2, 32
    scale = hd ** -0.5
    for window, pos, S in ((None, 17, 40), (8, 30, 40), (8, 99, 240)):
        q = _arr((b, hq, 1, hd))
        kc = _arr((b, hkv, S, hd))
        vc = _arr((b, hkv, S, hd))
        p = jnp.asarray(pos, jnp.int32)
        inline = _decode_attend(q, kc, vc, p, window, None, scale)
        eng = Engine("host_cpu", empirical_levels=())
        with use(eng):
            routed = _decode_attend(q, kc, vc, p, window, None, scale)
        st_ = eng.stats()["decode_attention"]
        assert st_["launches"] == 1, "engine dispatch did not occur"
        assert st_["padded_calls"] == 0
        np.testing.assert_allclose(
            np.asarray(routed), np.asarray(inline), rtol=2e-5, atol=2e-5,
            err_msg=f"window={window} pos={pos}",
        )


def test_decode_attend_traced_context_uses_engine_kernel():
    """Inside a jit (the serving decode program) the routed attention
    inlines the engine's masked kernel as a traced call — no engine-owned
    buffers captured, outputs unchanged."""
    b, hq, hkv, hd, S = 1, 4, 2, 32, 48
    q = _arr((b, hq, 1, hd))
    kc = _arr((b, hkv, S, hd))
    vc = _arr((b, hkv, S, hd))
    scale = hd ** -0.5
    inline = _decode_attend(q, kc, vc, jnp.asarray(9, jnp.int32), None, None,
                            scale)
    eng = Engine("host_cpu", empirical_levels=())
    with use(eng):
        fn = jax.jit(
            lambda q, k, v, p: _decode_attend(q, k, v, p, None, None, scale)
        )
        routed = fn(q, kc, vc, jnp.asarray(9, jnp.int32))
    st_ = eng.stats()["decode_attention"]
    assert st_["traced_calls"] == 1 and st_["launches"] == 0
    np.testing.assert_allclose(
        np.asarray(routed), np.asarray(inline), rtol=2e-5, atol=2e-5
    )


# ---------------------------------------------------------------------------
# VortexServer decode contract
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def mesh():
    return Mesh(np.array(jax.devices()[:1]).reshape(1, 1), ("data", "model"))


def test_server_decode_one_launch_per_token_zero_pads(mesh):
    """Acceptance: every decode step is exactly one AOT launch with zero
    pad fallbacks, asserted from DispatchStats; growth copies appear only
    at kv-bucket transitions; same kv bucket => same compiled program."""
    from repro.launch.serve import Request, VortexServer
    from repro.models.registry import get_smoke_config

    cfg = get_smoke_config("paper-gpt2-124m")
    server = VortexServer(cfg, mesh, max_cache=256)
    rng = np.random.default_rng(7)
    s = 120
    kvb0 = server.kv_bucket(server.seq_bucket(s))
    # Enough new tokens to cross the first kv-bucket boundary (when the
    # cache cap leaves room to grow).
    max_new = min(kvb0 - s + 4, 24) if kvb0 < server.max_cache else 8
    req = Request(
        tokens=rng.integers(0, cfg.vocab, (2, s)).astype(np.int32),
        max_new=max_new,
    )
    out = server.generate(req)
    assert out.shape == (2, max_new)

    d = server.decode_stats
    assert d.calls == max_new - 1
    assert d.launches == d.calls, "decode must be ONE AOT launch per token"
    assert d.padded_calls == 0, "decode must never fall back to zero-pad"
    grew = kvb0 < server.max_cache and s + max_new - 1 > kvb0
    if grew:
        assert d.unaligned_calls >= 1 and d.stage_copies >= 1
        assert len(server._decode_exec) == 2  # one program per kv bucket
    else:
        assert d.unaligned_calls == 0 and d.stage_copies == 0
        assert len(server._decode_exec) == 1
    # Same kv bucket => same executable: decoding again adds no programs.
    n_exec = len(server._decode_exec)
    server.generate(req)
    assert len(server._decode_exec) == n_exec
    assert server.decode_stats.padded_calls == 0
    # The serving surface reports the decode section separately, and the
    # engine-measured lowering counters confirm no decode program had a
    # zero-pad baked in (every traced dispatch was bucket-aligned).
    stats = server.engine_dispatch_stats()
    assert stats["decode_step"]["launches"] == server.decode_stats.launches
    assert stats["decode_attention"]["traced_calls"] > 0
    assert stats["decode_attention"]["padded_calls"] == 0


def test_server_rejects_generation_past_cache_cap(mesh):
    """Past max_cache the cache cannot grow and the in-program cache write
    would clamp and stomp the last KV row — the server must refuse loudly
    instead of serving silently corrupted logits."""
    from repro.launch.serve import Request, VortexServer
    from repro.models.registry import get_smoke_config

    cfg = get_smoke_config("paper-gpt2-124m")
    server = VortexServer(cfg, mesh, max_cache=64)
    toks = np.zeros((1, 60), np.int32)
    with pytest.raises(ValueError, match="max_cache"):
        server.generate(Request(tokens=toks, max_new=8))
    # At the boundary (s + max_new - 1 == max_cache) it still serves.
    out = server.generate(Request(tokens=toks, max_new=5))
    assert out.shape == (1, 5)


def test_server_decode_greedy_tokens_stable_across_growth(mesh):
    """Greedy decode across a kv-bucket growth transition produces the
    same tokens as a server whose cache never needs to grow."""
    from repro.launch.serve import Request, VortexServer
    from repro.models.registry import get_smoke_config

    cfg = get_smoke_config("paper-gpt2-124m")
    small = VortexServer(cfg, mesh, max_cache=256)
    big = VortexServer(cfg, mesh, max_cache=256, seed=0)
    big.params = small.params  # identical weights
    rng = np.random.default_rng(11)
    s = 120
    kvb0 = small.kv_bucket(small.seq_bucket(s))
    if kvb0 >= small.max_cache:
        pytest.skip("lattice bucket already at the cache cap")
    max_new = min(kvb0 - s + 4, 24)
    toks = rng.integers(0, cfg.vocab, (1, s)).astype(np.int32)
    out_grow = small.generate(Request(tokens=toks, max_new=max_new))
    assert small.decode_stats.stage_copies >= 1  # growth actually happened
    # 'big' takes the same path but from a fresh server: determinism check.
    out_again = big.generate(Request(tokens=toks, max_new=max_new))
    np.testing.assert_array_equal(out_grow, out_again)
