"""Vortex-driven framework auto-configuration (core/autoconfig.py)."""
from repro.core.autoconfig import select_attn_chunk, select_microbatches


def test_attn_chunk_is_lattice_aligned_and_bounded():
    c = select_attn_chunk(seq=32768, head_dim=128, q_rows=4096)
    assert c % 128 == 0
    assert 128 <= c <= 32768
    # VMEM bound: K,V chunk + f32 scores must fit the budget.
    ws = 2 * c * 128 * 2 + 4096 * c * 4
    assert ws <= 0.25 * 128 * 1024 * 1024 * 0.5 + 0.25 * 64 * 1024 * 1024


def test_attn_chunk_shrinks_with_q_rows():
    big_q = select_attn_chunk(seq=32768, head_dim=128, q_rows=8192)
    small_q = select_attn_chunk(seq=32768, head_dim=128, q_rows=256)
    assert big_q <= small_q


def test_microbatches_grow_with_vocab():
    kw = dict(global_batch=256, seq=4096, d_model=4096,
              n_data_shards=16, n_model_shards=16)
    small = select_microbatches(vocab=32000, **kw)
    big = select_microbatches(vocab=256000, **kw)
    assert big >= small
    assert small >= 1 and (small & (small - 1)) == 0  # power of two


def test_microbatches_account_for_moe():
    kw = dict(global_batch=256, seq=4096, d_model=5120, vocab=102400,
              n_data_shards=16, n_model_shards=16)
    dense = select_microbatches(**kw)
    moe = select_microbatches(moe_experts=160, moe_topk=6, **kw)
    assert moe >= dense
