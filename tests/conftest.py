import os

# Tests run single-device (the dry-run sets its own 512-device flag in its
# own process); keep any inherited flag from leaking in.
os.environ.pop("XLA_FLAGS", None)

import jax  # noqa: E402

jax.config.update("jax_platform_name", "cpu")


def optional_hypothesis():
    """(given, settings, st): the real hypothesis API when installed, else
    stand-ins that skip-mark property tests so the rest of the module keeps
    running (requirements.txt pins hypothesis for CI)."""
    try:
        from hypothesis import given, settings, strategies as st
    except ImportError:  # pragma: no cover - exercised only without the dep
        import pytest

        def given(**kwargs):
            def deco(fn):
                return pytest.mark.skip(reason="hypothesis not installed")(fn)

            return deco

        def settings(**kwargs):
            return lambda fn: fn

        class st:  # stand-in strategies namespace
            floats = staticmethod(lambda *a, **k: None)
            integers = staticmethod(lambda *a, **k: None)
            sampled_from = staticmethod(lambda *a, **k: None)

    return given, settings, st
