import os

# Tests run single-device (the dry-run sets its own 512-device flag in its
# own process); keep any inherited flag from leaking in.
os.environ.pop("XLA_FLAGS", None)

import jax  # noqa: E402

jax.config.update("jax_platform_name", "cpu")
