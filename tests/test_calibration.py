"""Background calibrator: measurement-refined tables (DESIGN.md §10).

Acceptance surface:

  * the phase-robust timing helper (core/timing.py) shared by bench and
    calibrator — interleaved min-vs-min, adaptive stop, retry-keeping-best;
  * calibration hooks stay OFF-path exact: ``cost_scale=None`` /
    ``pinned=None`` build bit-identical tables, and an engine with
    ``calibration="off"`` (the default) never constructs a calibrator;
  * ``cost_scale`` re-ranks consistently with the scaled argmin and
    ``pinned`` overrides exactly the containing breakpoint interval;
  * the atomic swap — idempotent, validated, LRU-dropping — survives a
    threaded stress of concurrent dispatch against repeated table swaps
    with zero errors, zero padded calls, and consistent launch counters;
  * persistence: fingerprint-keyed roundtrip (fresh engine loads with
    ZERO re-measurements), fingerprint/lattice mismatches reject the
    stale file, truncated/corrupt JSON falls back to analytical serving;
  * the continuous scheduler donates idle slices (never counting them as
    request work) and only when its admission queue is empty.
"""
import dataclasses
import json
import os
import threading

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core.calibrate import (
    Calibrator,
    calibration_cache_dir,
    fingerprint_key,
    lattice_checksum,
)
from repro.core.selection_table import build_selection_table
from repro.core.timing import interleaved_minima, retry_best
from repro.vortex import Engine, EngineConfig

RNG = np.random.default_rng(7)


def _arr(shape, dtype=jnp.float32):
    return jnp.asarray(RNG.normal(size=shape), dtype)


SMALL = dict(
    m_max=128, max_buckets=2, min_rounds=2, max_rounds=3, patience=1,
    top_k=2,
)


@pytest.fixture()
def cache_dir(tmp_path, monkeypatch):
    d = str(tmp_path / "vortex-cache")
    monkeypatch.setenv("VORTEX_CACHE_DIR", d)
    return d


def gemm_engine(**over) -> Engine:
    eng = Engine("host_cpu", empirical_levels=(), **over)
    eng.dispatch("gemm", _arr((33, 64)), _arr((64, 64)))
    return eng


def calibrated(eng: Engine) -> Calibrator:
    cal = eng.calibrator
    cal.policy = dataclasses.replace(cal.policy, **SMALL)
    cal.run()
    return cal


# ---------------------------------------------------------------------------
# core/timing.py — the shared phase-robust harness
# ---------------------------------------------------------------------------


def test_interleaved_minima_basics():
    t = interleaved_minima(
        [lambda: np.zeros(4), lambda: np.zeros(4)],
        inner=1, min_rounds=3, max_rounds=5, patience=1,
    )
    assert 3 <= t.rounds <= 5
    assert len(t.best_s) == 2 and all(b > 0 for b in t.best_s)
    assert len(t.samples_us[0]) == t.rounds
    assert t.ratio(0, 1) == pytest.approx(t.best_s[0] / t.best_s[1])


def test_interleaved_minima_rejects_empty():
    with pytest.raises(ValueError):
        interleaved_minima([])


def test_retry_best_keeps_smallest_key():
    vals = iter([5.0, 2.0, 4.0, 3.0])
    out = retry_best(
        lambda: next(vals), attempts=4,
        accept=lambda v: v < 1.0, key=lambda v: v,
    )
    assert out == 2.0


def test_retry_best_accept_short_circuits():
    calls = []

    def measure():
        calls.append(1)
        return 0.5

    assert retry_best(
        measure, attempts=5, accept=lambda v: v < 1.0, key=lambda v: v
    ) == 0.5
    assert len(calls) == 1


# ---------------------------------------------------------------------------
# Off-path exactness: calibration hooks default to bit-identical behaviour
# ---------------------------------------------------------------------------


def test_off_is_default_and_builds_no_calibrator():
    eng = gemm_engine()
    assert eng.config.calibration == "off"
    assert eng.calibrator is None
    assert eng.stats()["calibration"] == {"enabled": False, "mode": "off"}


def test_hooks_default_to_bit_identical_tables():
    eng = gemm_engine()
    kern = next(iter(eng._kernels.values()))
    sel = kern.selector
    base = sel.table
    rebuilt = build_selection_table(
        sel._hw, sel.workload, sel.stacked, base.m_max,
        cost_scale=None, pinned=None,
    )
    unit = build_selection_table(
        sel._hw, sel.workload, sel.stacked, base.m_max,
        cost_scale=np.ones(sel.stacked.num_candidates),
    )
    for other in (rebuilt, unit):
        assert other.starts == base.starts
        for a, b in zip(other.entries, base.entries):
            assert (a.strategy, a.backend, a.grid) == (
                b.strategy, b.backend, b.grid
            )
            assert a.predicted_cost == b.predicted_cost


def test_bad_calibration_mode_rejected():
    with pytest.raises(ValueError, match="calibration"):
        EngineConfig(calibration="sometimes")


# ---------------------------------------------------------------------------
# cost_scale / pinned table semantics
# ---------------------------------------------------------------------------


def test_cost_scale_reranks_consistently_with_scaled_argmin():
    eng = gemm_engine()
    sel = next(iter(eng._kernels.values())).selector
    st = sel.stacked
    # Make one arbitrary non-winning candidate free: it must win everywhere.
    m = 100
    base_winner = int(np.argmin(sel.candidate_costs(m)))
    forced = (base_winner + 1) % st.num_candidates
    scale = np.ones(st.num_candidates)
    scale[forced] = 1e-9
    table = sel.build_calibrated_table(cost_scale=scale)
    got = table.lookup(m)
    assert (got.strategy, got.backend) == (
        st.strategy_for(forced), st.backend_of(forced)
    )


def test_pinned_overrides_exactly_the_containing_interval():
    eng = gemm_engine()
    sel = next(iter(eng._kernels.values())).selector
    st = sel.stacked
    base = sel.table
    m_pin = 100
    import bisect

    from repro.core.selection_table import merge_breakpoints

    # Pins override the PRE-merge breakpoint interval containing the
    # measured extent (cost is constant there, so one measurement speaks
    # for the whole interval) — compute its true bounds.
    wl = sel.workload
    starts = merge_breakpoints(
        st.dynamic_periods(wl.dynamic_tile_axes), base.m_max
    )
    b = bisect.bisect_right(starts, m_pin) - 1
    lo = starts[b]
    hi = starts[b + 1] - 1 if b + 1 < len(starts) else base.m_max
    winner = int(np.argmin(sel.candidate_costs(m_pin)))
    forced = (winner + 1) % st.num_candidates
    table = sel.build_calibrated_table(pinned={m_pin: forced})
    fstrat = st.strategy_for(forced)
    # The forced candidate serves the whole pinned interval...
    for m in {lo, m_pin, hi}:
        assert table.lookup(m).strategy == fstrat
    # ...and the analytical winners elsewhere are untouched.
    if lo > 1:
        before = base.lookup(lo - 1)
        assert table.lookup(lo - 1).strategy == before.strategy


# ---------------------------------------------------------------------------
# Atomic swap
# ---------------------------------------------------------------------------


def test_install_validates_table():
    eng = gemm_engine()
    sel = next(iter(eng._kernels.values())).selector
    bad = dataclasses.replace(sel.table, starts=[2] + sel.table.starts[1:])
    with pytest.raises(ValueError, match="cover extents from 1"):
        sel.install_table(bad)


def test_swap_is_idempotent():
    eng = gemm_engine()
    sel = next(iter(eng._kernels.values())).selector
    table = sel.build_calibrated_table()
    before = sel.select(77)
    sel.install_table(table)
    sel.install_table(table)
    assert sel.stats.table_swaps == 2
    assert sel.table is table
    after = sel.select(77)
    assert (after.strategy, after.backend, after.grid) == (
        before.strategy, before.backend, before.grid
    )


def test_threaded_dispatch_survives_concurrent_swaps():
    """The pool-race pattern against table swaps: worker threads dispatch
    gemm continuously while the main thread swaps analytical and
    re-ranked tables back and forth.  No torn reads (every result is
    numerically the reference product), no dropped or misrouted
    dispatches (calls == launches, zero padded calls)."""
    eng = gemm_engine()
    kern = next(iter(eng._kernels.values()))
    sel = kern.selector
    st = sel.stacked

    w = _arr((64, 64))
    ms = [5, 33, 77, 101]
    xs = {m: _arr((m, 64)) for m in ms}
    refs = {m: np.asarray(xs[m]) @ np.asarray(w) for m in ms}

    analytical = sel.build_calibrated_table()
    flipped_scale = np.ones(st.num_candidates)
    flipped_scale[int(np.argmin(sel.candidate_costs(64)))] = 1e3
    flipped = sel.build_calibrated_table(cost_scale=flipped_scale)
    assert any(
        a.strategy != b.strategy
        for a, b in zip(analytical.entries, flipped.entries)
    ), "flipped table must actually change winners for the stress to bite"

    base = kern.dispatch_stats.as_dict()
    errors: list = []
    stop = threading.Event()
    done = []

    def worker(i):
        try:
            n = 0
            while not stop.is_set() or n < 8:
                m = ms[(i + n) % len(ms)]
                got = np.asarray(kern(xs[m], w))
                np.testing.assert_allclose(got, refs[m], rtol=2e-4)
                n += 1
                if n >= 200:
                    break
            done.append(n)
        except Exception as exc:  # pragma: no cover - failure path
            errors.append((i, exc))

    threads = [
        threading.Thread(target=worker, args=(i,)) for i in range(4)
    ]
    for t in threads:
        t.start()
    for _ in range(50):
        sel.install_table(flipped, cost_scale=flipped_scale)
        sel.install_table(analytical)
    stop.set()
    for t in threads:
        t.join()
    assert not errors, errors[:2]
    assert sel.stats.table_swaps == 100
    delta = {
        k: v - base[k] for k, v in kern.dispatch_stats.as_dict().items()
    }
    assert delta["calls"] == sum(done)
    assert delta["launches"] == delta["calls"]
    assert delta["padded_calls"] == 0


# ---------------------------------------------------------------------------
# Persistence: fingerprint-keyed cache under ~/.cache/vortex
# ---------------------------------------------------------------------------


def test_cache_dir_resolution(tmp_path, monkeypatch):
    monkeypatch.delenv("VORTEX_CACHE_DIR", raising=False)
    assert calibration_cache_dir() == os.path.expanduser(
        "~/.cache/vortex"
    )
    monkeypatch.setenv("VORTEX_CACHE_DIR", str(tmp_path / "env"))
    assert calibration_cache_dir() == str(tmp_path / "env")
    # An explicit policy dir beats the environment.
    assert calibration_cache_dir(str(tmp_path / "x")) == str(tmp_path / "x")
    repo = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
    assert not calibration_cache_dir().startswith(repo)


def test_persistence_roundtrip_zero_remeasurements(cache_dir):
    eng = gemm_engine(calibration="on-idle")
    cal = calibrated(eng)
    assert cal.stats()["applied"] == 1
    assert cal.counters["saves"] >= 1

    eng2 = gemm_engine(calibration="on-idle")
    cal2 = eng2.calibrator
    cal2.policy = dataclasses.replace(cal2.policy, **SMALL)
    assert cal2.load() == 1
    assert cal2.counters["measurements"] == 0
    assert not cal2.pending()
    sel2 = next(iter(eng2._kernels.values())).selector
    assert sel2.stats.table_swaps == 1
    # The loaded model reproduces the measuring engine's decisions.
    sel1 = next(iter(eng._kernels.values())).selector
    for m in (5, 33, 77, 101):
        assert sel1.select(m).strategy == sel2.select(m).strategy


def test_fingerprint_mismatch_rejects_stale_table(cache_dir):
    eng = gemm_engine(calibration="on-idle")
    cal = calibrated(eng)
    path = cal.cache_path()
    with open(path) as f:
        data = json.load(f)
    data["fingerprint"]["hardware"] = "some_other_chip"
    with open(path, "w") as f:
        json.dump(data, f)
    # The doctored fingerprint changes the cache key, so point load at
    # the file explicitly: content-level verification must reject it.
    assert fingerprint_key(data["fingerprint"]) != os.path.splitext(
        os.path.basename(path)
    )[0]
    eng2 = gemm_engine(calibration="on-idle")
    cal2 = eng2.calibrator
    assert cal2.load(path) == 0
    assert cal2.counters["load_rejects"] == 1
    assert next(iter(eng2._kernels.values())).selector.stats.table_swaps == 0


def test_stale_lattice_checksum_rejected(cache_dir):
    eng = gemm_engine(calibration="on-idle")
    cal = calibrated(eng)
    path = cal.cache_path()
    with open(path) as f:
        data = json.load(f)
    for entry in data["kernels"].values():
        entry["lattice"] = "deadbeefdeadbeef"
    with open(path, "w") as f:
        json.dump(data, f)
    eng2 = gemm_engine(calibration="on-idle")
    cal2 = eng2.calibrator
    assert cal2.load() == 0
    assert cal2.counters["load_rejects"] == 1


def test_truncated_cache_file_falls_back_to_analytical(cache_dir):
    eng = gemm_engine(calibration="on-idle")
    cal = calibrated(eng)
    path = cal.cache_path()
    blob = open(path).read()
    with open(path, "w") as f:
        f.write(blob[: len(blob) // 2])  # torn write / killed process
    eng2 = gemm_engine(calibration="on-idle")
    cal2 = eng2.calibrator
    assert cal2.load() == 0
    assert cal2.counters["load_rejects"] == 1
    # Serving proceeds on the analytical table as if nothing was on disk.
    sel = next(iter(eng2._kernels.values())).selector
    assert sel.select(33).predicted_cost > 0
    assert sel.stats.table_swaps == 0
    assert cal2.pending()  # measurement work remains — nothing was applied


def test_missing_cache_file_is_not_an_error(cache_dir):
    eng = gemm_engine(calibration="on-idle")
    cal = eng.calibrator
    assert cal.load() == 0
    assert cal.counters["load_rejects"] == 0


def test_lattice_checksum_tracks_candidate_space():
    eng = gemm_engine()
    st = next(iter(eng._kernels.values())).selector.stacked
    # Stable across calls (it keys persisted entries)...
    assert lattice_checksum(st) == lattice_checksum(st)
    # ...and sensitive to ANY drift in the candidate space: re-scored
    # costs or re-generated tiles invalidate persisted candidate indices.
    assert lattice_checksum(
        dataclasses.replace(st, l1_costs=st.l1_costs * 1.01)
    ) != lattice_checksum(st)
    assert lattice_checksum(
        dataclasses.replace(st, l1_tiles=st.l1_tiles[::-1].copy())
    ) != lattice_checksum(st)


# ---------------------------------------------------------------------------
# Persistence under injected I/O faults (DESIGN.md §11): every failure is
# silent-but-counted, serving never crashes, tables stay usable in memory.
# ---------------------------------------------------------------------------


def test_save_fault_at_open_counted_never_raises(cache_dir):
    from repro.runtime import faults

    eng = gemm_engine(calibration="on-idle")
    cal = eng.calibrator
    cal.policy = dataclasses.replace(cal.policy, **SMALL)
    # cache_io occurrence 1 = save() entry: the write never starts.
    with faults.installed(faults.FaultPlan({"cache_io": [1]})):
        cal.run()
    assert cal.counters["save_errors"] == 1
    assert cal.counters["store_rejects"] == 1
    assert not os.path.exists(cal.cache_path())
    # The calibration itself still applied in memory — only persistence
    # was lost; the next clean save round-trips.
    assert cal.stats()["applied"] == 1
    cal.save()
    assert os.path.exists(cal.cache_path())


def test_save_fault_before_replace_leaves_no_partial_file(cache_dir):
    from repro.runtime import faults

    eng = gemm_engine(calibration="on-idle")
    cal = eng.calibrator
    cal.policy = dataclasses.replace(cal.policy, **SMALL)
    # cache_io occurrence 2 = just before os.replace: the tmp file was
    # fully written but never published — a reader can NEVER observe a
    # partial table at the real path.
    with faults.installed(faults.FaultPlan({"cache_io": [2]})):
        cal.run()
    assert cal.counters["store_rejects"] == 1
    path = cal.cache_path()
    assert not os.path.exists(path)
    assert os.path.exists(path + ".tmp")  # the orphaned atomic-write tmp
    # A fresh engine sees no table (missing file is not an error) and
    # keeps serving analytically.
    eng2 = gemm_engine(calibration="on-idle")
    cal2 = eng2.calibrator
    assert cal2.load() == 0
    assert cal2.counters["load_rejects"] == 0


def test_load_fault_counted_as_reject(cache_dir):
    from repro.runtime import faults

    eng = gemm_engine(calibration="on-idle")
    calibrated(eng)  # clean save

    eng2 = gemm_engine(calibration="on-idle")
    cal2 = eng2.calibrator
    cal2.policy = dataclasses.replace(cal2.policy, **SMALL)
    with faults.installed(faults.FaultPlan({"cache_io": [1]})):
        assert cal2.load() == 0
    assert cal2.counters["load_rejects"] == 1
    # The file is intact: a clean retry loads with zero re-measurements.
    assert cal2.load() == 1
    assert cal2.counters["measurements"] == 0


def test_measure_fault_skips_kernel_not_calibrator(cache_dir):
    from repro.runtime import faults

    eng = gemm_engine(calibration="on-idle")
    cal = eng.calibrator
    cal.policy = dataclasses.replace(cal.policy, **SMALL)
    with faults.installed(faults.FaultPlan({"calib_measure": [1]})):
        cal.run()
    s = cal.stats()
    assert s["applied"] == 0 and s["skipped"] == 1
    assert cal.counters["measurements"] == 0
    # Dispatch is untouched — analytical serving continues.
    eng.dispatch("gemm", _arr((45, 64)), _arr((64, 64)))


# ---------------------------------------------------------------------------
# Calibrator behaviour on live engines
# ---------------------------------------------------------------------------


def test_calibration_pins_make_measured_buckets_match_best(cache_dir):
    eng = gemm_engine(calibration="on-idle")
    cal = calibrated(eng)
    report = cal.report()
    assert "gemm" in report
    rep = report["gemm"]
    assert rep["measured_buckets"] >= 1
    assert rep["never_worse_on_measured"]
    assert 0.0 <= rep["agreement_rate"] <= 1.0
    assert rep["mode"] in ("coefficients", "rerank")


def test_exec_specialized_kernels_are_skipped(cache_dir):
    eng = gemm_engine(calibration="on-idle")
    q = _arr((1, 4, 33, 64))
    kv = _arr((1, 2, 33, 64))
    eng.dispatch("attention", q, kv, kv)
    cal = calibrated(eng)
    s = cal.stats()
    assert s["skipped"] == 1  # attention needs representative args
    assert s["applied"] == 1  # gemm still calibrates


def test_stats_surface_engine_and_selector_counters(cache_dir):
    eng = gemm_engine(calibration="on-idle")
    calibrated(eng)
    st = eng.stats()
    assert st["calibration"]["enabled"]
    assert st["calibration"]["table_swaps"] == 1
    assert st["gemm"]["table_swaps"] == 1
    assert st["gemm"]["calibration_seconds"] > 0


def test_eager_warmup_calibrates_at_build(cache_dir):
    # First engine measures (eager), second engine must load from disk.
    cfg = dict(
        calibration="eager-warmup",
        calibration_top_k=2,
        calibration_budget_s=10.0,
    )
    eng = Engine("host_cpu", empirical_levels=(), **cfg)
    cal = eng.calibrator
    cal.policy = dataclasses.replace(cal.policy, **SMALL)
    eng.dispatch("gemm", _arr((33, 64)), _arr((64, 64)))
    s = eng.stats()["calibration"]
    assert s["applied"] == 1 and s["measured_buckets"] >= 1

    eng2 = Engine("host_cpu", empirical_levels=(), **cfg)
    cal2 = eng2.calibrator
    cal2.policy = dataclasses.replace(cal2.policy, **SMALL)
    eng2.dispatch("gemm", _arr((33, 64)), _arr((64, 64)))
    s2 = eng2.stats()["calibration"]
    assert s2["applied"] == 1
    assert s2["loaded_from_disk"] == 1
    assert s2["measurements"] == 0


# ---------------------------------------------------------------------------
# Scheduler idle donation
# ---------------------------------------------------------------------------


class _StubCalibrator:
    def __init__(self):
        self.slices = 0
        self._pending = True

    def pending(self):
        return self._pending

    def run_slice(self, budget_s=None):
        self.slices += 1
        self._pending = False
        return 1


@pytest.fixture(scope="module")
def sched_server():
    from repro.launch.mesh import make_host_mesh
    from repro.launch.serve import VortexServer
    from repro.models.registry import get_smoke_config
    from repro.vortex import EngineConfig

    cfg = get_smoke_config("paper-gpt2-124m")
    engine = Engine(EngineConfig(
        hardware="tpu_v5e", backends=("mxu",), calibration="on-idle",
    ))
    return VortexServer(cfg, make_host_mesh(), max_cache=64, engine=engine)


def test_scheduler_donates_only_when_idle(sched_server):
    from repro.launch.scheduler import ContinuousScheduler

    sched = ContinuousScheduler(sched_server, batch_rows=2)
    stub = _StubCalibrator()
    sched_server.engine._calibrator = stub
    try:
        worked = sched.step()  # no queue, no rows -> donate one slice
        assert worked is False  # donation never counts as request work
        assert stub.slices == 1
        assert sched.stats["calibration_slices"] == 1
        sched.step()  # stub reports nothing pending: no second slice
        assert stub.slices == 1
    finally:
        sched_server.engine._calibrator = None
        sched.close()


def test_scheduler_drain_terminates_with_pending_calibration(sched_server):
    from repro.launch.scheduler import ContinuousScheduler
    from repro.launch.serve import Request

    class Greedy(_StubCalibrator):
        def run_slice(self, budget_s=None):  # never finishes
            self.slices += 1
            return 1

    sched = ContinuousScheduler(sched_server, batch_rows=2)
    stub = Greedy()
    sched_server.engine._calibrator = stub
    try:
        tokens = np.array([[1, 2, 3]], np.int32)
        rid = sched.submit(Request(tokens=tokens, max_new=2))
        out = sched.drain()  # must terminate despite endless pending()
        assert rid in out and out[rid].shape == (1, 2)
        assert stub.slices >= 1  # idle tail of the drain donated
    finally:
        sched_server.engine._calibrator = None
        sched.close()
