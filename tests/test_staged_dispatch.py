"""The padding-free hot path: masked-tail staging vs the zero-pad reference.

Acceptance surface of the staging contract (DESIGN.md §4):

  * every registered workload kind, at extents {1, bucket-1, bucket,
    bucket+1, prime}, is BIT-IDENTICAL between the staged hot path and the
    zero-pad reference path, on both executable impls;
  * poisoned staging — the engine-owned buffers' pad regions are filled
    with NaNs and the outputs must not move (correctness comes from the
    kernel masks, never from zero fill);
  * the copy/launch counters: an unaligned call is exactly ONE fused
    program launch plus its boundary copies, an aligned call is one launch
    with zero copies, and ``jnp.pad`` (the padded fallback) never fires;
  * a Selection that cannot be honored raises instead of being clamped.
"""
import dataclasses

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core.workloads import (
    AttentionWorkload,
    Conv2dWorkload,
    GemmWorkload,
    SelectionDeviationError,
)
from repro.vortex import Engine

RNG = np.random.default_rng(11)


def _arr(shape):
    return jnp.asarray(RNG.normal(size=shape), jnp.float32)


@pytest.fixture(scope="module", params=["xla", "pallas"])
def engine(request):
    return Engine(
        "host_cpu", empirical_levels=(), impl=request.param, interpret=True
    )


# One entry per registered workload kind: (workload params for
# engine.dispatch kwargs, args builder at a given dynamic extent m).
# Conv uses a 1x1 kernel on a (1, 1, m, cin) image so that the im2col
# extent is EXACTLY m — every probe extent is reachable.
def _gemm_args(m):
    return (_arr((m, 96)), _arr((96, 80)))


def _attn_args(m):
    return (_arr((2, 4, m, 32)), _arr((2, 2, m, 32)), _arr((2, 2, m, 32)))


def _decode_args(m):
    # One query row against a cache of length m; all m rows valid.
    return (_arr((2, 4, 1, 32)), _arr((2, 2, m, 32)), _arr((2, 2, m, 32)), m)


def _conv_args(m):
    return (_arr((1, 1, m, 5)), _arr((1, 1, 5, 7)))


def _grouped_args(m):
    # 6 groups over 3 experts (r = 2 groups per stack entry); ragged
    # per-group extents — several strictly below the capacity m — so the
    # per-group masked-tail contract is exercised at every probe extent.
    counts = np.clip(np.array([m, 1, 0, m - 1, 2, m]), 0, m).astype(np.int32)
    return (_arr((6, m, 96)), _arr((3, 96, 80)), jnp.asarray(counts))


KIND_CASES = [
    ("gemm", {}, _gemm_args),
    ("grouped_gemm", {}, _grouped_args),
    ("attention", {}, _attn_args),
    ("decode_attention", {}, _decode_args),
    ("conv2d", {}, _conv_args),
]


def _probe_extents(kern) -> list[int]:
    sel = kern.select(257)
    bucket = kern.workload.dynamic_bucket(sel)
    prime = 263
    return sorted({1, bucket - 1, bucket, bucket + 1, prime})


@pytest.mark.parametrize("kind,params,make", KIND_CASES,
                         ids=[c[0] for c in KIND_CASES])
def test_staged_bit_identical_to_padded_reference(engine, kind, params, make):
    """Staged hot path == zero-pad reference path, bitwise, at every
    boundary extent (1, bucket-1, bucket, bucket+1, prime)."""
    kern = engine.op_kernel(kind, make(8), params)
    for m in _probe_extents(kern):
        args = make(m)
        staged = np.asarray(kern(*args))
        padded = np.asarray(kern.call_padded(*args))
        np.testing.assert_array_equal(
            staged, padded,
            err_msg=f"{kind}: staged != padded at extent {m}",
        )
        ref = np.asarray(kern.workload.reference(*args))
        np.testing.assert_allclose(
            staged, ref, rtol=2e-3, atol=2e-3,
            err_msg=f"{kind}: staged != flat reference at extent {m}",
        )


@pytest.mark.parametrize("kind,params,make", KIND_CASES,
                         ids=[c[0] for c in KIND_CASES])
def test_poisoned_staging_buffers_do_not_leak(engine, kind, params, make):
    """Fill every staging buffer's pad region with NaNs (by poisoning the
    WHOLE buffer — staging then overwrites only the true extent) and assert
    the outputs are unaffected: correctness is the kernel's masking."""
    kern = engine.op_kernel(kind, make(8), params)
    bucket = kern.workload.dynamic_bucket(kern.select(257))
    m = bucket - 1  # unaligned: staging buffers are in play
    args = make(m)
    padded = np.asarray(kern.call_padded(*args))
    np.testing.assert_array_equal(np.asarray(kern(*args)), padded)
    poisoned = 0
    for entry in kern._exec_cache.values():
        for bufs in entry.pool.retained:
            for i, buf in bufs.items():
                bufs[i] = jnp.full_like(buf, jnp.nan)
                poisoned += 1
    assert poisoned >= 1, "unaligned dispatch must have created buffers"
    again = np.asarray(kern(*args))
    assert np.isfinite(again).all(), f"{kind}: NaN poison leaked"
    np.testing.assert_array_equal(
        again, padded, err_msg=f"{kind}: poisoned staging changed output"
    )


def test_unaligned_dispatch_is_one_launch_plus_boundary_copies():
    """The acceptance counter: an unaligned extent issues exactly one
    compiled-program launch, one staging copy per dynamic operand, one
    output slice — and never a jnp.pad fallback."""
    eng = Engine("host_cpu", empirical_levels=())
    a, b = _gemm_args(61)
    eng.dispatch("gemm", a, b)
    d = eng.stats()["gemm"]
    assert d["launches"] == 1
    assert d["unaligned_calls"] == 1 and d["aligned_calls"] == 0
    assert d["stage_copies"] == 1  # only A is dynamic; B passes through
    assert d["unstage_copies"] == 1
    assert d["padded_calls"] == 0 and d["traced_calls"] == 0

    q, k, v = _attn_args(37)
    eng.dispatch("attention", q, k, v)
    d = eng.stats()["attention"]
    assert d["launches"] == 1
    assert d["stage_copies"] == 3  # q, k and v all stage
    assert d["padded_calls"] == 0

    qd, kd, vd, kv_len = _decode_args(37)
    eng.dispatch("decode_attention", qd, kd, vd, kv_len)
    d = eng.stats()["decode_attention"]
    assert d["launches"] == 1
    assert d["stage_copies"] == 2  # only the k/v cache buffers stage
    assert d["unstage_copies"] == 0  # out is (b, h, 1, d): nothing to slice
    assert d["padded_calls"] == 0

    xg, wg, cg = _grouped_args(61)
    eng.dispatch("grouped_gemm", xg, wg, cg)
    d = eng.stats()["grouped_gemm"]
    assert d["launches"] == 1  # ONE launch for all 6 ragged groups
    assert d["stage_copies"] == 1  # only x stages; w and counts pass through
    assert d["unstage_copies"] == 1
    assert d["padded_calls"] == 0


def test_aligned_dispatch_is_one_launch_zero_copies():
    eng = Engine("host_cpu", empirical_levels=())
    kern = eng.op_kernel("gemm", _gemm_args(8), {})
    aligned_m = kern.select(257).padded_m
    eng.dispatch("gemm", *_gemm_args(aligned_m))
    d = eng.stats()["gemm"]
    assert d["aligned_calls"] == 1 and d["launches"] == 1
    assert d["stage_copies"] == 0 and d["unstage_copies"] == 0
    assert d["padded_calls"] == 0


def test_staging_buffers_are_reused_not_reallocated():
    """Two sequential unaligned calls in the same bucket reuse ONE pooled
    engine-owned buffer set (donated in place), and the executable cache
    does not grow."""
    eng = Engine("host_cpu", empirical_levels=())
    kern = eng.op_kernel("gemm", _gemm_args(8), {})
    bucket = kern.select(257).padded_m

    def pool_sets():
        return sum(len(e.pool.retained) for e in kern._exec_cache.values())

    kern(*_gemm_args(bucket - 1))
    entries = len(kern._exec_cache)
    assert pool_sets() == 1
    kern(*_gemm_args(bucket - 2))
    assert len(kern._exec_cache) == entries
    assert pool_sets() == 1  # the set was checked out, reused, returned
    assert kern.dispatch_stats.stage_copies == 2


def test_concurrent_same_bucket_dispatch_no_cross_talk():
    """N threads hammering ONE bucket concurrently: every output must be
    bit-identical to its own sequential reference — a shared/serialized
    staging buffer would interleave tenants' rows — and the pool retains
    at most its cap of buffer sets afterwards."""
    import threading

    eng = Engine("host_cpu", empirical_levels=())
    kern = eng.op_kernel("gemm", _gemm_args(8), {})
    bucket = kern.select(257).padded_m
    m = bucket - 3
    b = _arr((96, 80))
    inputs = [
        jnp.asarray(
            np.random.default_rng(100 + i).normal(size=(m, 96)), jnp.float32
        )
        for i in range(8)
    ]
    kern(inputs[0], b)  # warm: compile once, outside the threads
    expected = [np.asarray(kern.call_padded(a, b)) for a in inputs]

    failures: list = []

    def worker(idx: int):
        for _ in range(16):
            out = np.asarray(kern(inputs[idx], b))
            if not np.array_equal(out, expected[idx]):
                failures.append(idx)
                return

    threads = [
        threading.Thread(target=worker, args=(i,)) for i in range(len(inputs))
    ]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not failures, f"cross-talk detected for tenants {failures}"
    for entry in kern._exec_cache.values():
        assert len(entry.pool.retained) <= entry.pool.cap


def test_tracer_context_falls_back_to_functional_path():
    """Inside an enclosing jit the engine must not capture its own buffers:
    tracer calls take the zero-pad functional path (which XLA fuses into
    the surrounding program) and are counted as traced, not launched."""
    eng = Engine("host_cpu", empirical_levels=())
    a, b = _gemm_args(61)

    @jax.jit
    def outer(a, b):
        return eng.dispatch("gemm", a, b) * 2.0

    out = np.asarray(outer(a, b))
    ref = 2.0 * np.asarray(eng.dispatch("gemm", a, b))
    np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-5)
    d = eng.stats()["gemm"]
    assert d["traced_calls"] == 1
    assert d["launches"] == 1  # only the eager reference dispatch launched


def test_staging_disabled_knob_matches_staged_outputs():
    """EngineConfig.staging=False forces the zero-pad reference path; the
    numbers must not move (it is a parity/debug knob, not a semantics
    switch)."""
    staged = Engine("host_cpu", empirical_levels=())
    padded = Engine("host_cpu", empirical_levels=(), staging=False)
    for m in (1, 61, 128):
        args = _gemm_args(m)
        np.testing.assert_array_equal(
            np.asarray(staged.dispatch("gemm", *args)),
            np.asarray(padded.dispatch("gemm", *args)),
        )
    d = padded.stats()["gemm"]
    assert d["launches"] == 0 and d["stage_copies"] == 0


def test_selection_deviation_raises_instead_of_clamping():
    """A Selection whose bucket is not a multiple of its own tile cannot be
    honored; the builder must refuse loudly, never clamp the tile."""
    eng = Engine("host_cpu", empirical_levels=())
    kern = eng.op_kernel("gemm", _gemm_args(8), {})
    sel = kern.select(64)
    bad = dataclasses.replace(sel, padded_m=sel.padded_m + 1)
    with pytest.raises(SelectionDeviationError, match="not a multiple"):
        kern.workload.build_executable(bad, impl="pallas", interpret=True)

    wl = AttentionWorkload(seq=None, head_dim=32)
    akern = eng.kernel_for(wl)
    asel = akern.select(64)
    abad = dataclasses.replace(
        asel, bucket=(asel.bucket[0] + 1,) + asel.bucket[1:]
    )
    with pytest.raises(SelectionDeviationError, match="not a multiple"):
        wl.build_executable(abad, impl="pallas", interpret=True)


def test_conv_stage_view_feeds_the_gemm_bucket():
    """Conv's im2col runs in stage_view; the staged buffer is the GEMM-view
    bucket, and the unaligned call still serves in one fused launch."""
    eng = Engine("host_cpu", empirical_levels=())
    x, w = _conv_args(61)
    out = eng.dispatch("conv2d", x, w)
    wl = Conv2dWorkload(m=None, cin=5, cout=7, kh=1, kw=1)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(wl.reference(x, w)),
        rtol=1e-3, atol=1e-3,
    )
    d = eng.stats()["conv2d"]
    assert d["launches"] == 1 and d["stage_copies"] == 1
    assert d["padded_calls"] == 0


def test_gemm_workload_staged_shapes_contract():
    """The staged-shape tuple marks exactly the dynamic operands."""
    wl = GemmWorkload(M=None, N=80, K=96)
    eng = Engine("host_cpu", empirical_levels=())
    kern = eng.kernel_for(wl)
    a, b = _gemm_args(61)
    sel = kern.select(61)
    shapes = wl.staged_shapes(sel, a, b)
    assert shapes == ((sel.padded_m, 96), None)
    assert wl.runtime_scalars(sel, a, b) == (61,)
