"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.attention import flash_attention
from repro.kernels.gemm import vortex_gemm
from repro.kernels.ref import (
    chunked_attention,
    ref_attention,
    ref_gemm,
)

RNG = np.random.default_rng(42)


def _arr(shape, dtype):
    x = RNG.normal(size=shape).astype(np.float32)
    return jnp.asarray(x, dtype)


GEMM_CASES = [
    # (M, N, K, bm, bn, bk)
    (128, 128, 128, 64, 64, 64),
    (256, 128, 384, 128, 128, 128),
    (64, 256, 128, 64, 128, 128),
    (512, 64, 64, 128, 64, 64),
    (128, 128, 128, 128, 128, 128),  # single block
]


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("case", GEMM_CASES)
def test_gemm_matches_ref(case, dtype):
    m, n, k, bm, bn, bk = case
    a, b = _arr((m, k), dtype), _arr((k, n), dtype)
    out = vortex_gemm(a, b, block_m=bm, block_n=bn, block_k=bk,
                      interpret=True)
    ref = ref_gemm(a, b)
    tol = 1e-4 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32),
        rtol=tol, atol=tol,
    )


def test_gemm_masked_tails_handle_misaligned_shapes():
    """Shapes that are not block multiples run with masked boundary tiles —
    the selected blocks are honored verbatim, never clamped or rejected."""
    a, b = _arr((100, 150), jnp.float32), _arr((150, 130), jnp.float32)
    out = vortex_gemm(a, b, block_m=64, block_n=64, block_k=64,
                      interpret=True)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(ref_gemm(a, b)), rtol=1e-4, atol=1e-4
    )


def test_gemm_blocks_larger_than_shape_honored():
    """A selected tile larger than the whole problem still runs (grid 1,
    fully masked boundary) instead of being silently clamped to the shape."""
    a, b = _arr((5, 7), jnp.float32), _arr((7, 3), jnp.float32)
    out = vortex_gemm(a, b, block_m=64, block_n=64, block_k=64,
                      interpret=True)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(ref_gemm(a, b)), rtol=1e-4, atol=1e-4
    )


def test_gemm_rejects_degenerate_blocks():
    a, b = _arr((64, 64), jnp.float32), _arr((64, 64), jnp.float32)
    with pytest.raises(ValueError, match="cannot be honored"):
        vortex_gemm(a, b, block_m=0, block_n=64, block_k=64, interpret=True)


def test_gemm_m_true_masks_garbage_tail():
    """Rows past the runtime extent are masked on load: NaN garbage in the
    pad tail (a stale staging buffer) cannot reach the real rows, and the
    real rows are bit-identical to a zero-padded run."""
    m_true = 77
    a = _arr((128, 96), jnp.float32)
    b = _arr((96, 64), jnp.float32)
    a_zero = a.at[m_true:].set(0.0)
    a_nan = a.at[m_true:].set(jnp.nan)
    out_zero = vortex_gemm(a_zero, b, m_true, block_m=64, block_n=64,
                           block_k=64, interpret=True)
    out_nan = vortex_gemm(a_nan, b, m_true, block_m=64, block_n=64,
                          block_k=64, interpret=True)
    np.testing.assert_array_equal(
        np.asarray(out_zero)[:m_true], np.asarray(out_nan)[:m_true]
    )
    np.testing.assert_allclose(
        np.asarray(out_nan)[:m_true], np.asarray(ref_gemm(a, b))[:m_true],
        rtol=1e-4, atol=1e-4,
    )


def test_flash_attention_kv_len_masks_garbage_tail():
    """kv rows past the runtime kv_len are score-masked and value-zeroed:
    NaN garbage there cannot poison any real query row, causal or not."""
    kv_true = 53
    q = _arr((1, 2, 64, 32), jnp.float32)
    k = _arr((1, 2, 64, 32), jnp.float32)
    v = _arr((1, 2, 64, 32), jnp.float32)
    k_nan = k.at[:, :, kv_true:].set(jnp.nan)
    v_nan = v.at[:, :, kv_true:].set(jnp.nan)
    for causal in (True, False):
        out = flash_attention(
            q, k_nan, v_nan, kv_true, block_q=32, block_k=32,
            causal=causal, interpret=True,
        )
        ref = ref_attention(
            q[:, :, :kv_true] if causal else q,
            k[:, :, :kv_true], v[:, :, :kv_true], causal=causal,
        )
        got = np.asarray(out)[:, :, :kv_true] if causal else np.asarray(out)
        np.testing.assert_allclose(got, np.asarray(ref), rtol=2e-3,
                                   atol=2e-3)
    assert np.isfinite(np.asarray(out)).all()


def test_flash_attention_misaligned_seq_masked():
    """Sequence lengths that are not block multiples run with masked
    boundary tiles (no clamping, no pre-padding required)."""
    q = _arr((1, 2, 100, 32), jnp.float32)
    out = flash_attention(q, q, q, block_q=64, block_k=64, causal=True,
                          interpret=True)
    ref = ref_attention(q, q, q, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-3, atol=2e-3)


ATTN_CASES = [
    # (b, hq, hkv, s, d, causal, window, softcap)
    (1, 4, 4, 128, 64, True, None, None),
    (2, 4, 2, 128, 64, True, None, None),     # GQA
    (1, 2, 2, 256, 32, True, 64, None),       # sliding window
    (1, 2, 1, 128, 64, True, None, 50.0),     # softcap (gemma2)
    (1, 4, 4, 128, 64, False, None, None),    # bidirectional (encoder)
]


@pytest.mark.parametrize("case", ATTN_CASES)
def test_flash_attention_matches_ref(case):
    b, hq, hkv, s, d, causal, window, softcap = case
    q = _arr((b, hq, s, d), jnp.float32)
    k = _arr((b, hkv, s, d), jnp.float32)
    v = _arr((b, hkv, s, d), jnp.float32)
    out = flash_attention(
        q, k, v, block_q=64, block_k=64, causal=causal, window=window,
        softcap=softcap, interpret=True,
    )
    ref = ref_attention(q, k, v, causal=causal, window=window,
                        softcap=softcap)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(ref), rtol=2e-3, atol=2e-3,
    )


@pytest.mark.parametrize("case", ATTN_CASES)
def test_chunked_attention_matches_ref(case):
    """The scan-based flash attention (used inside the models) == oracle."""
    b, hq, hkv, s, d, causal, window, softcap = case
    q = _arr((b, hq, s, d), jnp.float32)
    k = _arr((b, hkv, s, d), jnp.float32)
    v = _arr((b, hkv, s, d), jnp.float32)
    out = chunked_attention(
        q, k, v, causal=causal, window=window, softcap=softcap, chunk=64,
    )
    ref = ref_attention(q, k, v, causal=causal, window=window,
                        softcap=softcap)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(ref), rtol=2e-3, atol=2e-3,
    )


def test_chunked_attention_mixed_v_dim():
    """MLA uses d_v != d_qk; the chunked path must support it."""
    q = _arr((1, 2, 128, 48), jnp.float32)
    k = _arr((1, 2, 128, 48), jnp.float32)
    v = _arr((1, 2, 128, 32), jnp.float32)
    out = chunked_attention(q, k, v, causal=True, chunk=32)
    ref = ref_attention(q, k, v, causal=True)
    assert out.shape == (1, 2, 128, 32)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-3, atol=2e-3)


def test_attention_kernel_blocks_from_vortex_lattice():
    """Block sizes drawn from the Vortex lattice are valid kernel configs."""
    from repro.core import GemmWorkload, TPU_V5E
    from repro.core.candidates import generate_lattice

    wl = GemmWorkload(M=None, N=128, K=64)
    lat = generate_lattice(TPU_V5E, wl, "mxu")
    bq = int(lat.l1[0][0])
    q = _arr((1, 2, max(bq, 128), 64), jnp.float32)
    out = flash_attention(
        q, q, q, block_q=min(bq, 128), block_k=128, interpret=True
    )
    ref = ref_attention(q, q, q)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-3, atol=2e-3)
