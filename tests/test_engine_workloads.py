"""Workload-generic pipeline end-to-end: registry-dispatched gemm /
attention / conv2d (vortex.ops through an Engine session) must match the
flat JAX references for prime (non-tile-aligned) dynamic sizes across
execution backends, selection must be deterministic, and the
bucketing/caching contracts must hold."""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import (
    HOST_CPU,
    TPU_V5E,
    AttentionWorkload,
    Conv2dWorkload,
    GemmWorkload,
    WORKLOADS,
)
from repro import vortex
from repro.vortex import Engine
from repro.core.analyzer import AnalyticalProfiler, HybridAnalyzer
from repro.core.candidates import generate_lattice
from repro.core.selector import RuntimeSelector
from repro.kernels.ref import ref_attention, ref_conv2d, ref_gemm

RNG = np.random.default_rng(7)


def _arr(shape):
    return jnp.asarray(RNG.normal(size=shape), jnp.float32)


@pytest.fixture(scope="module", params=["xla", "pallas"])
def engine(request):
    # pallas runs in interpret mode on this host; empirical_levels=() keeps
    # the offline stage fast and deterministic.
    return Engine(
        "host_cpu", empirical_levels=(), impl=request.param, interpret=True
    )


# ---------------------------------------------------------------------------
# End-to-end numerics at prime dynamic sizes
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("m", [1, 7, 61, 127])
def test_gemm_matches_reference(engine, m):
    a, b = _arr((m, 96)), _arr((96, 80))
    np.testing.assert_allclose(
        np.asarray(engine.dispatch("gemm", a, b)), np.asarray(ref_gemm(a, b)),
        rtol=1e-4, atol=1e-4,
    )


@pytest.mark.parametrize("seq", [3, 37, 101])
def test_attention_matches_reference(engine, seq):
    q = _arr((2, 4, seq, 32))
    k = _arr((2, 2, seq, 32))  # GQA: 2 query heads per kv head
    v = _arr((2, 2, seq, 32))
    out = engine.dispatch("attention", q, k, v)
    ref = ref_attention(q, k, v, causal=True)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(ref), rtol=1e-4, atol=1e-4
    )


def test_attention_window_matches_reference(engine):
    q = k = v = _arr((1, 2, 53, 32))
    out = engine.dispatch("attention", q, k, v, window=16)
    ref = ref_attention(q, k, v, causal=True, window=16)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(ref), rtol=1e-4, atol=1e-4
    )


@pytest.mark.parametrize("batch,hw_px", [(1, 9), (3, 11)])
def test_conv2d_matches_reference(engine, batch, hw_px):
    x = _arr((batch, hw_px, hw_px, 5))
    w = _arr((3, 3, 5, 7))
    out = engine.dispatch("conv2d", x, w)
    ref = ref_conv2d(x, w, stride=1, padding="VALID")
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(ref), rtol=1e-4, atol=1e-4
    )


def test_non_causal_attention_served(engine):
    """Bucket padding no longer leans on the causal structure: the explicit
    kv-validity mask makes bidirectional (encoder) attention bucket exactly
    as safely, at a prime (pad-exercising) sequence length."""
    q = _arr((1, 2, 53, 32))
    k = _arr((1, 2, 53, 32))
    v = _arr((1, 2, 53, 32))
    out = engine.dispatch("attention", q, k, v, causal=False)
    ref = ref_attention(q, k, v, causal=False)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(ref), rtol=2e-3, atol=2e-3
    )


# ---------------------------------------------------------------------------
# Registry / shared caches
# ---------------------------------------------------------------------------


def test_registry_serves_all_kinds():
    assert {"gemm", "attention", "conv2d"} <= set(WORKLOADS)


def test_one_kernel_per_signature_and_shared_lattice():
    eng = Engine("host_cpu", empirical_levels=())
    q = _arr((1, 2, 13, 32))
    k = v = _arr((1, 2, 13, 32))
    eng.dispatch("attention", q, k, v)
    eng.dispatch("attention", q, k, v, window=8)  # same lattice_key, new signature
    stats = eng.stats()["attention"]
    assert stats["signatures"] == 2
    # Masking flags share one scored lattice (engine-wide scored cache).
    assert len(eng._scored_cache) == 1


def test_attention_precompile_warms_serving_keys():
    """Precompiled attention entries must sit under the SAME executable-cache
    keys that real calls with the given batch/head layout hit — a later call
    at any seq <= m_max must not add cache entries."""
    eng = Engine("host_cpu", empirical_levels=())
    wl = AttentionWorkload(seq=None, head_dim=32)
    q = _arr((2, 4, 5, 32))
    k = v = _arr((2, 2, 5, 32))
    n = eng.precompile(wl, 64, q, k, v)
    assert n >= 1
    kernel = eng.kernel_for(wl)
    entries_before = kernel.cache_info["entries"]
    for seq in (5, 23, 61):
        qq = _arr((2, 4, seq, 32))
        kk = vv = _arr((2, 2, seq, 32))
        eng.dispatch("attention", qq, kk, vv)
    assert kernel.cache_info["entries"] == entries_before


def test_executable_cache_bounded_by_buckets():
    eng = Engine("host_cpu", empirical_levels=())
    b = _arr((64, 48))
    for m in range(1, 40):  # 39 distinct runtime shapes
        eng.dispatch("gemm", _arr((m, 64)), b)
    s = eng.stats()["gemm"]
    assert s["exec_hits"] == 39
    # Bounded by the lattice's bucket set, not by #distinct shapes.
    assert s["exec_entries"] <= 8


# ---------------------------------------------------------------------------
# Selector: determinism, bucket key, fast precompilation set, LRU bound
# ---------------------------------------------------------------------------


def _scored(hw, wl, backend):
    lat = generate_lattice(hw, wl, backend)
    analyzer = HybridAnalyzer(
        hw, wl, profiler=AnalyticalProfiler(hw), empirical_levels=()
    )
    return analyzer.score(lat)


GOLDEN_MS = [1, 7, 16, 61, 127, 128, 500, 1021]


@pytest.mark.parametrize(
    "wl",
    [
        GemmWorkload(M=None, N=768, K=2304),
        AttentionWorkload(seq=None, head_dim=64),
        Conv2dWorkload(m=None, cin=16, cout=32, kh=3, kw=3),
    ],
    ids=lambda wl: wl.kind,
)
def test_selector_determinism_golden(wl):
    """Two independently-built selectors must agree exactly on every
    selection — the sample-free pipeline has no stochastic stage."""
    picks = []
    for _ in range(2):
        sel = RuntimeSelector(TPU_V5E, wl, {"mxu": _scored(TPU_V5E, wl, "mxu")})
        picks.append(
            [(s.strategy.tiles, s.backend, s.grid, s.bucket)
             for s in map(sel.select, GOLDEN_MS)]
        )
    assert picks[0] == picks[1]


def test_bucket_uses_true_static_dims():
    """Selection.bucket must report the TRUE N/K extents: static dims are
    never padded at the bucket level (the executable pads internally when
    its blocks require it)."""
    wl = GemmWorkload(M=None, N=96, K=200)  # not multiples of any l1 tile
    sel = RuntimeSelector(HOST_CPU, wl, {"simd": _scored(HOST_CPU, wl, "simd")})
    s = sel.select(13)
    assert s.bucket == (s.padded_m, 96, 200)
    assert s.padded_m >= 13


def test_attention_bucket_pads_both_seq_dims():
    wl = AttentionWorkload(seq=None, head_dim=64)
    sel = RuntimeSelector(TPU_V5E, wl, {"mxu": _scored(TPU_V5E, wl, "mxu")})
    s = sel.select(37)
    pq, d, pkv = s.bucket
    assert d == 64
    assert pq >= 37 and pq % s.strategy.l1[0] == 0
    assert pkv >= 37 and pkv % s.strategy.l1[2] == 0


@pytest.mark.parametrize(
    "wl",
    [
        GemmWorkload(M=None, N=768, K=2304),
        AttentionWorkload(seq=None, head_dim=64),
    ],
    ids=lambda wl: wl.kind,
)
def test_buckets_upto_matches_bruteforce(wl):
    """The breakpoint-derived precompilation set must equal the exhaustive
    per-M enumeration (it is a speedup, not an approximation).  The brute
    side runs with the selection table disabled, so this cross-checks the
    table-derived set against the pure argmin path."""
    scored = {"mxu": _scored(TPU_V5E, wl, "mxu")}
    fast = RuntimeSelector(TPU_V5E, wl, scored)
    brute = RuntimeSelector(
        TPU_V5E, wl, scored, cache_size=1 << 16, table_m_max=0
    )
    m_max = 700
    expect = sorted({brute.select(m).padded_m for m in range(1, m_max + 1)})
    assert fast.buckets_upto(m_max) == expect


def test_selection_cache_is_lru_bounded():
    """With the table disabled, the argmin fallback's LRU stays bounded."""
    wl = GemmWorkload(M=None, N=256, K=256)
    sel = RuntimeSelector(
        HOST_CPU, wl, {"simd": _scored(HOST_CPU, wl, "simd")},
        cache_size=8, table_m_max=0,
    )
    for m in range(1, 100):
        sel.select(m)
    assert len(sel._cache) == 8
    assert sel.stats.selects == 99
    assert sel.stats.argmin_misses == 99
    assert sel.stats.table_hits == 0


def test_table_serves_without_lru_growth():
    """With the table on (the default), a high-cardinality shape stream is
    served entirely by table hits: no LRU entries, no argmin misses."""
    wl = GemmWorkload(M=None, N=256, K=256)
    sel = RuntimeSelector(
        HOST_CPU, wl, {"simd": _scored(HOST_CPU, wl, "simd")}, cache_size=8
    )
    for m in range(1, 100):
        sel.select(m)
    assert sel.stats.table_hits == 99
    assert sel.stats.argmin_misses == 0
    assert len(sel._cache) == 0


# ---------------------------------------------------------------------------
# Model-layer routing
# ---------------------------------------------------------------------------


def test_attn_forward_routes_through_engine():
    import jax
    from jax.sharding import Mesh

    from repro.models import layers
    from repro.models.config import LayerSpec
    from repro.models.partitioning import make_rules
    from repro.models.registry import get_smoke_config

    cfg = get_smoke_config("paper-gpt2-124m")
    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1), ("data", "model"))
    rules = make_rules(mesh, n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads)
    hd = cfg.resolved_head_dim
    d = cfg.d_model
    p = {
        "wq": _arr((d, cfg.n_heads * hd)) * 0.02,
        "wk": _arr((d, cfg.n_kv_heads * hd)) * 0.02,
        "wv": _arr((d, cfg.n_kv_heads * hd)) * 0.02,
        "wo": _arr((cfg.n_heads * hd, d)) * 0.02,
    }
    x = _arr((1, 23, d))  # prime seq: exercises bucketing
    spec = LayerSpec(mixer="attn")
    positions = jnp.arange(23)
    kw = dict(mode="prefill", positions=positions, cache_len=32)

    y_ref, _ = layers.attn_forward(p, x, cfg, spec, rules, **kw)
    eng = Engine("host_cpu", empirical_levels=())
    with vortex.use(eng):
        y_eng, _ = layers.attn_forward(p, x, cfg, spec, rules, **kw)
    assert vortex.installed_engine() is None  # scoped install restored
    np.testing.assert_allclose(
        np.asarray(y_eng), np.asarray(y_ref), rtol=1e-4, atol=1e-4
    )
    # The engine actually served the attention (one signature, one call).
    assert eng.stats()["attention"]["exec_hits"] == 1
