"""Checkpointing + fault-tolerance runtime tests."""
import os
import time

import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager
from repro.data.pipeline import Prefetcher, SyntheticLMDataset
from repro.runtime.elastic import plan_remesh
from repro.runtime.heartbeat import StepMonitor
from repro.runtime.supervisor import SimulatedFailure, Supervisor


def _state(v=0.0):
    return {"params": {"w": jnp.full((4, 4), v)}, "step_val": jnp.asarray(v)}


class TestCheckpoint:
    def test_roundtrip(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep_n=3)
        st = _state(3.5)
        mgr.save(10, st, {"note": "x"})
        assert mgr.steps() == [10]
        back = mgr.restore(10, _state())
        np.testing.assert_array_equal(
            np.asarray(back["params"]["w"]), np.asarray(st["params"]["w"])
        )
        assert mgr.meta(10)["note"] == "x"

    def test_keep_n_gc(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep_n=2)
        for s in (1, 2, 3, 4):
            mgr.save(s, _state(s))
        assert mgr.steps() == [3, 4]

    def test_async_write_and_wait(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep_n=2)
        mgr.save_async(5, _state(5.0))
        mgr.wait()
        assert mgr.latest_step() == 5

    def test_atomicity_no_partial_dirs(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path))
        mgr.save(7, _state())
        names = os.listdir(tmp_path)
        assert all(".tmp." not in n for n in names)

    def test_restore_shape_mismatch_raises(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path))
        mgr.save(1, {"w": jnp.zeros((2, 2))})
        with pytest.raises(ValueError):
            mgr.restore(1, {"w": jnp.zeros((3, 3))})


class TestMonitor:
    def test_straggler_detection(self):
        mon = StepMonitor(mad_threshold=4.0)
        for step in range(16):
            for h in range(8):
                mon.record(h, step, 1.0 + (3.0 if h == 5 else 0.0))
        assert mon.stragglers() == [5]

    def test_dead_host_detection(self):
        now = [0.0]
        mon = StepMonitor(dead_after=10.0, clock=lambda: now[0])
        for h in range(4):
            mon.record(h, 0, 1.0)
        now[0] = 5.0
        for h in range(3):  # host 3 goes silent
            mon.record(h, 1, 1.0)
        now[0] = 20.0
        for h in range(3):
            mon.record(h, 2, 1.0)
        assert mon.dead_hosts() == [3]
        assert mon.healthy_hosts() == [0, 1, 2]


class TestElastic:
    def test_shrinks_data_axis_keeps_model(self):
        plan = plan_remesh(
            healthy_chips=192, model_extent=16, old_data_extent=16
        )
        assert plan.mesh_shape == (8, 16)
        assert plan.microbatch_scale == 2
        assert plan.chips_used == 128

    def test_multi_pod(self):
        plan = plan_remesh(
            healthy_chips=480, model_extent=16, old_data_extent=16, pods=2
        )
        assert plan.mesh_axes == ("pod", "data", "model")
        assert plan.data_extent in (8, 16)

    def test_too_few_chips_raises(self):
        with pytest.raises(ValueError):
            plan_remesh(healthy_chips=8, model_extent=16, old_data_extent=16)


class TestSupervisor:
    def test_recovers_and_replays_deterministically(self, tmp_path):
        """A failure at step 7 restores step 5's checkpoint and replays —
        final state identical to a failure-free run."""
        data = SyntheticLMDataset(vocab=97, seq_len=8, global_batch=4)

        def step_fn(state, step):
            batch = data.batch_at(step)
            inc = float(batch["tokens"].sum() % 1000)
            return {"acc": state["acc"] + inc}

        def run(fail_at):
            mgr = CheckpointManager(str(tmp_path / f"ck{fail_at}"), keep_n=2)
            sup = Supervisor(mgr, ckpt_every=5)
            tripped = []

            def hook(step):
                if step == fail_at and not tripped:
                    tripped.append(step)
                    raise SimulatedFailure(f"node died at {step}")

            return sup.run(
                {"acc": 0.0}, step_fn, num_steps=12,
                failure_hook=hook if fail_at else None,
            ), sup

        clean, _ = run(0)
        failed, sup = run(7)
        assert failed["acc"] == clean["acc"]
        assert sup.stats.failures == 1 and sup.stats.restores == 1

    def test_gives_up_after_max_retries(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep_n=1)
        sup = Supervisor(mgr, ckpt_every=100, max_retries=2)

        def hook(step):
            raise SimulatedFailure("always")

        with pytest.raises(SimulatedFailure):
            sup.run({"x": 0}, lambda s, i: s, num_steps=5,
                    failure_hook=hook)


class TestData:
    def test_deterministic_across_restart(self):
        d1 = SyntheticLMDataset(101, 16, 8, seed=3)
        d2 = SyntheticLMDataset(101, 16, 8, seed=3)
        b1, b2 = d1.batch_at(42), d2.batch_at(42)
        np.testing.assert_array_equal(b1["tokens"], b2["tokens"])

    def test_hosts_get_distinct_shards(self):
        a = SyntheticLMDataset(101, 16, 8, host_id=0, num_hosts=2)
        b = SyntheticLMDataset(101, 16, 8, host_id=1, num_hosts=2)
        assert a.host_batch == 4
        assert not np.array_equal(
            a.batch_at(0)["tokens"], b.batch_at(0)["tokens"]
        )

    def test_labels_are_shifted_tokens(self):
        d = SyntheticLMDataset(101, 16, 4)
        b = d.batch_at(0)
        np.testing.assert_array_equal(
            b["labels"][:, :-1], b["tokens"][:, 1:]
        )

    def test_prefetcher_yields_in_order(self):
        d = SyntheticLMDataset(101, 8, 2)
        it = Prefetcher(iter([d.batch_at(i) for i in range(5)]), depth=2)
        outs = list(it)
        assert len(outs) == 5
        np.testing.assert_array_equal(
            outs[3]["tokens"], d.batch_at(3)["tokens"]
        )
