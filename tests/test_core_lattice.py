"""Property tests for the Vortex core: Algorithm 2's invariants, the cost
model, the hybrid analyzer and the runtime selector."""
import numpy as np
import pytest

from conftest import optional_hypothesis

# Only the property tests need hypothesis; the lattice-invariant and engine
# tests must keep running without it.
given, settings, st = optional_hypothesis()

from repro.core import (
    GemmWorkload,
    HOST_CPU,
    TPU_V5E,
    VortexKernel,
)
from repro.core.analyzer import AnalyticalProfiler, HybridAnalyzer
from repro.core.candidates import (
    generate_lattice,
    filter_by_multiples,
    init_cands,
)
from repro.core.cost_model import gemm_strategy_cost, l0_analytical_cost
from repro.core.rkernel import Strategy
from repro.core.selector import RuntimeSelector


WL = GemmWorkload(M=None, N=768, K=2304)


@pytest.fixture(scope="module")
def lattice():
    return generate_lattice(TPU_V5E, WL, "mxu")


@pytest.fixture(scope="module")
def scored(lattice):
    analyzer = HybridAnalyzer(
        TPU_V5E, WL, profiler=AnalyticalProfiler(TPU_V5E),
        empirical_levels=(),
    )
    return analyzer.score(lattice)


def test_l0_isa_granularity(lattice):
    """Every L0 candidate respects the MXU native tile (FilterByISA)."""
    bm, bn, bk = TPU_V5E.native_tile["mxu"]
    for (m, n, k) in lattice.l0:
        assert m % bm == 0 and n % bn == 0 and k % bk == 0


def test_l1_multiples_invariant(lattice):
    """Every L1 candidate is an elementwise multiple of >=1 L0 child, and
    the recorded children are correct (Fig. 8 integer-multiples design)."""
    for l1 in lattice.l1:
        children = lattice.children[1][l1]
        assert children
        for child in children:
            assert all(a % b == 0 for a, b in zip(l1, child))


def test_l1_vmem_bound(lattice):
    """L1 tiles fit the VMEM working set (InitCands hardware limit)."""
    cap = TPU_V5E.level(1).capacity_bytes
    for (m, n, k) in lattice.l1:
        stream = 2 * (m * k + k * n) * WL.dtype_bytes
        acc = m * n * WL.acc_bytes
        assert stream + acc <= cap


def test_lattice_size_order_of_magnitude(lattice):
    """Paper §7.4 reports 392 candidates for the tensor-core GEMM space;
    hardware pruning must keep ours in the same regime, not thousands."""
    assert 20 <= lattice.num_candidates() <= 2000


def test_multiples_sieve_drops_incompatible():
    cands = [(6, 6, 6), (8, 8, 8), (12, 4, 4)]
    prev = [(4, 4, 4)]
    kept, cmap = filter_by_multiples(cands, prev)
    assert (8, 8, 8) in kept and (12, 4, 4) in kept
    assert (6, 6, 6) not in kept
    assert cmap[(8, 8, 8)] == ((4, 4, 4),)


@given(
    m=st.integers(1, 4096),
    tile=st.sampled_from([(16, 128, 128), (64, 256, 256), (256, 512, 512)]),
)
@settings(max_examples=50, deadline=None)
def test_cost_model_padding_waste(m, tile):
    """Padding waste matches ceil arithmetic and never goes negative."""
    strat = Strategy(tiles=((16, 128, 128), tile))
    bd = gemm_strategy_cost(TPU_V5E, WL, strat, m_runtime=m)
    assert 0.0 <= bd.padding_waste < 1.0
    assert bd.total > 0.0
    gm = -(-m // tile[0])
    assert bd.padded_shape[0] == gm * tile[0]


def test_cost_model_monotone_in_m():
    """Cost is non-decreasing in the runtime M (more work, never less)."""
    strat = Strategy(tiles=((16, 128, 128), (128, 256, 256)))
    costs = [
        gemm_strategy_cost(TPU_V5E, WL, strat, m_runtime=m).total
        for m in (1, 128, 512, 2048, 8192)
    ]
    assert all(a <= b + 1e-12 for a, b in zip(costs, costs[1:]))


def test_l0_low_utilization_penalty():
    """A tile below native granularity pays for the full padded issue
    (paper Fig. 5: low-utilization configs always underperform)."""
    c_native = l0_analytical_cost(TPU_V5E, (16, 128, 128), "mxu")
    c_small = l0_analytical_cost(TPU_V5E, (1, 1, 1), "mxu")
    assert c_small == pytest.approx(c_native)


@given(m=st.integers(1, 2048))
@settings(max_examples=60, deadline=None)
def test_selector_bucket_bounds_padding(scored, m):
    """Selected bucket covers M, and padding is bounded by the chosen L1
    m-tile (padding confined to the outermost level, Fig. 8)."""
    sel = RuntimeSelector(TPU_V5E, WL, {"mxu": scored})
    s = sel.select(m)
    assert s.padded_m >= m
    assert s.padded_m - m < s.strategy.l1[0]
    assert s.grid[0] * s.strategy.l1[0] == s.padded_m


def test_selector_finite_buckets(scored):
    """The sample-free bucket set for M in [1, 512] is small and finite."""
    sel = RuntimeSelector(TPU_V5E, WL, {"mxu": scored})
    buckets = sel.buckets_upto(512)
    assert 1 <= len(buckets) <= 64


def test_selector_is_argmin(scored):
    """Selection equals the argmin of the vectorized cost evaluation."""
    from repro.core.cost_model import gemm_runtime_costs

    sel = RuntimeSelector(TPU_V5E, WL, {"mxu": scored})
    for m in (7, 100, 999):
        s = sel.select(m)
        costs = gemm_runtime_costs(
            TPU_V5E, WL, scored.l1_tiles, scored.l1_costs, m
        )
        assert s.predicted_cost == pytest.approx(float(np.min(costs)))


def test_engine_numerics_and_bucketing():
    """VortexKernel computes the right matmul for awkward dynamic M."""
    import jax.numpy as jnp

    wl = GemmWorkload(M=None, N=96, K=128)
    eng = VortexKernel(HOST_CPU, wl, empirical_levels=())
    rng = np.random.default_rng(0)
    for m in (1, 5, 33, 100):
        a = jnp.asarray(rng.normal(size=(m, 128)), jnp.float32)
        b = jnp.asarray(rng.normal(size=(128, 96)), jnp.float32)
        np.testing.assert_allclose(
            np.asarray(eng(a, b)), np.asarray(a) @ np.asarray(b),
            rtol=1e-4, atol=1e-4,
        )
    # Executable cache stays bounded by the bucket count, not by #distinct M.
    assert eng.cache_info["entries"] <= 4


def test_backend_adaptation_prefers_vpu_for_tiny_m():
    """Fig. 16: for very small M the VPU (no MXU padding) should win at
    least sometimes; for large M the MXU must win."""
    wl = GemmWorkload(M=None, N=1024, K=1024)
    eng = VortexKernel(TPU_V5E, wl, backends=("mxu", "vpu"))
    big = eng.select(4096)
    assert big.backend == "mxu"
    small = eng.select(1)
    # With M=1 the MXU pads 16x on the sublane dim; the analytical model
    # must at minimum *consider* vpu; assert the selection is consistent.
    assert small.backend in ("mxu", "vpu")
    assert small.predicted_cost <= big.predicted_cost
