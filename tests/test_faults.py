"""Deterministic fault injection + the kernel degradation ladder
(DESIGN.md §11).

Acceptance surface:

  * FaultPlan semantics: exact 1-based occurrences, per-site counters,
    the ``fired`` audit trail, seeded-random determinism, scoped
    install/restore;
  * the ladder: a failed candidate is quarantined and the next-best
    lattice candidate retried (correct output, no exception), the XLA
    reference rung absorbs a fully-hammered lattice, and when even the
    reference fails the in-memory quarantines roll back and the original
    error propagates (user errors never poison the denylist);
  * persistence: quarantines survive an engine restart through the
    fingerprint-keyed denylist file — a known-bad candidate is never
    re-attempted (zero quarantine events on the fresh engine);
  * zero overhead: with no plan installed the hot path is bit-identical
    and the ladder counters stay 0.
"""
import json
import os

import numpy as np
import pytest

import jax.numpy as jnp

from repro.runtime import faults
from repro.vortex import Engine

RNG = np.random.default_rng(11)


def _arr(shape):
    return jnp.asarray(RNG.normal(size=shape), jnp.float32)


@pytest.fixture()
def cache_dir(tmp_path, monkeypatch):
    d = str(tmp_path / "vortex-cache")
    monkeypatch.setenv("VORTEX_CACHE_DIR", d)
    return d


def _engine(**over):
    over.setdefault("denylist_persist", False)
    return Engine("host_cpu", empirical_levels=(), **over)


# ---------------------------------------------------------------------------
# FaultPlan semantics
# ---------------------------------------------------------------------------


def test_plan_fires_exact_occurrences():
    plan = faults.FaultPlan({"pool_lease": [2, 4]})
    fired = []
    for i in range(1, 6):
        try:
            plan.check("pool_lease")
        except faults.InjectedFault as exc:
            assert exc.site == "pool_lease" and exc.occurrence == i
            fired.append(i)
    assert fired == [2, 4]
    assert plan.fired == [("pool_lease", 2), ("pool_lease", 4)]
    assert plan.counts == {"pool_lease": 5}


def test_plan_counters_are_per_site():
    plan = faults.FaultPlan({"aot_launch": [1]})
    plan.check("precompile")  # other sites never trip this spec
    plan.check("scheduler_step")
    with pytest.raises(faults.InjectedFault):
        plan.check("aot_launch")
    assert plan.counts == {
        "precompile": 1, "scheduler_step": 1, "aot_launch": 1
    }


def test_plan_validates_sites_and_indices():
    with pytest.raises(ValueError, match="unknown fault site"):
        faults.FaultPlan({"warp_drive": [1]})
    with pytest.raises(ValueError, match="1-based"):
        faults.FaultPlan({"pool_lease": [0]})


def test_random_plan_deterministic_and_never_empty():
    a = faults.FaultPlan.random(123)
    b = faults.FaultPlan.random(123)
    assert a.spec == b.spec
    assert a.spec != faults.FaultPlan.random(124).spec
    # rate=0 would draw nothing: occurrence 1 of the first site is forced.
    c = faults.FaultPlan.random(0, sites=("cache_io",), rate=0.0)
    assert c.spec == {"cache_io": frozenset([1])}


def test_installed_scopes_and_restores():
    assert faults.ACTIVE is None
    outer = faults.FaultPlan({"pool_lease": [1]})
    inner = faults.FaultPlan({"cache_io": [1]})
    with faults.installed(outer):
        assert faults.ACTIVE is outer
        with faults.installed(inner):
            assert faults.ACTIVE is inner
        assert faults.ACTIVE is outer
    assert faults.ACTIVE is None
    # ...even when the body raises.
    with pytest.raises(RuntimeError):
        with faults.installed(outer):
            raise RuntimeError("boom")
    assert faults.ACTIVE is None


# ---------------------------------------------------------------------------
# The degradation ladder
# ---------------------------------------------------------------------------


def test_launch_fault_retries_next_best_candidate():
    eng = _engine()
    x, w = _arr((33, 64)), _arr((64, 64))
    ref = np.asarray(eng.dispatch("gemm", x, w))  # warm, no plan

    with faults.installed(faults.FaultPlan({"aot_launch": [1]})):
        got = np.asarray(eng.dispatch("gemm", x, w))
    np.testing.assert_allclose(got, ref, rtol=2e-4)
    st = eng.stats()["gemm"]
    assert st["quarantined"] == 1
    assert st["fallbacks"] == 0  # the lattice retry sufficed


def test_hammered_lattice_falls_back_to_reference():
    eng = _engine()
    x, w = _arr((45, 64)), _arr((64, 64))
    ref = np.asarray(x) @ np.asarray(w)

    hammer = faults.FaultPlan({
        "aot_launch": range(1, 200), "precompile": range(1, 200),
    })
    with faults.installed(hammer):
        got = np.asarray(eng.dispatch("gemm", x, w))
    np.testing.assert_allclose(got, ref, rtol=2e-4)
    st = eng.stats()["gemm"]
    assert st["fallbacks"] == 1
    # Primary + max_kernel_retries re-selections all quarantined.
    assert st["quarantined"] == 1 + eng.config.max_kernel_retries


def test_reference_failure_rolls_back_quarantines():
    """When even the XLA reference rung fails, the inputs (not the
    candidates) are at fault: the original error propagates and nothing
    stays quarantined — a user error never poisons the lattice."""
    eng = _engine()
    x, w = _arr((51, 64)), _arr((64, 64))
    eng.dispatch("gemm", x, w)  # warm
    kern = next(iter(eng._kernels.values()))

    orig = kern._fallback_dispatch

    def broken_fallback(m, args):
        raise RuntimeError("reference rung down too")

    kern._fallback_dispatch = broken_fallback
    try:
        with faults.installed(faults.FaultPlan({
            "aot_launch": range(1, 200), "precompile": range(1, 200),
        })):
            with pytest.raises(RuntimeError, match="reference rung") as ei:
                eng.dispatch("gemm", x, w)
        # The candidate failure that started the walk rides along as the
        # explicit cause (raise ... from).
        assert isinstance(ei.value.__cause__, faults.InjectedFault)
    finally:
        kern._fallback_dispatch = orig
    st = eng.stats()["gemm"]
    assert st["quarantined"] == 0  # rolled back
    assert not kern._quarantined
    # The kernel recovers completely once the fault clears.
    got = np.asarray(eng.dispatch("gemm", x, w))
    np.testing.assert_allclose(got, np.asarray(x) @ np.asarray(w), rtol=2e-4)


# ---------------------------------------------------------------------------
# Denylist persistence across restarts
# ---------------------------------------------------------------------------


def test_quarantine_survives_restart_never_reattempted(cache_dir):
    eng = _engine(denylist_persist=True)
    x, w = _arr((39, 64)), _arr((64, 64))
    ref = np.asarray(x) @ np.asarray(w)
    with faults.installed(faults.FaultPlan({
        "aot_launch": range(1, 200), "precompile": range(1, 200),
    })):
        eng.dispatch("gemm", x, w)
    kern = next(iter(eng._kernels.values()))
    quarantined = set(kern._quarantined)
    assert quarantined and eng.stats()["gemm"]["fallbacks"] == 1

    deny = [
        f for f in os.listdir(cache_dir) if f.endswith(".deny.json")
    ]
    assert len(deny) == 1
    blob = json.load(open(os.path.join(cache_dir, deny[0])))
    assert blob["version"] == 1
    assert set(*blob["kernels"].values()) == quarantined

    # Fresh engine, same fingerprint: the quarantine pre-seeds and the
    # known-bad candidates are NEVER re-attempted — no plan installed,
    # yet zero quarantine events and zero fallbacks.
    eng2 = _engine(denylist_persist=True)
    got = np.asarray(eng2.dispatch("gemm", x, w))
    np.testing.assert_allclose(got, ref, rtol=2e-4)
    kern2 = next(iter(eng2._kernels.values()))
    assert kern2._quarantined == quarantined
    st2 = eng2.stats()["gemm"]
    assert st2["quarantined"] == 0 and st2["fallbacks"] == 0


def test_denylist_io_fault_is_quiet(cache_dir):
    """A cache_io fault during denylist persistence never reaches the
    dispatch path: the quarantine stays effective in memory."""
    eng = _engine(denylist_persist=True)
    x, w = _arr((29, 64)), _arr((64, 64))
    ref = np.asarray(eng.dispatch("gemm", x, w))
    # Occurrence 1 = the denylist load at kernel build already happened
    # (before install); fail the store instead.
    with faults.installed(faults.FaultPlan({
        "aot_launch": [1], "cache_io": [1, 2],
    })):
        got = np.asarray(eng.dispatch("gemm", x, w))
    np.testing.assert_allclose(got, ref, rtol=2e-4)
    assert eng.stats()["gemm"]["quarantined"] == 1
    assert not os.path.exists(cache_dir) or not [
        f for f in os.listdir(cache_dir) if f.endswith(".deny.json")
    ]


# ---------------------------------------------------------------------------
# Zero overhead with no plan
# ---------------------------------------------------------------------------


def test_no_plan_is_bit_identical_and_ladder_silent():
    assert faults.ACTIVE is None
    eng = _engine()
    x, w = _arr((77, 64)), _arr((64, 64))
    a = np.asarray(eng.dispatch("gemm", x, w))
    b = np.asarray(eng.dispatch("gemm", x, w))
    assert np.array_equal(a, b)  # bit-identical replay
    st = eng.stats()["gemm"]
    assert st["fallbacks"] == 0 and st["quarantined"] == 0
