"""Distributed flash-decode (§Perf B) vs the dense reference, on 8 simulated
devices.  Runs in a subprocess because the device count must be fixed via
XLA_FLAGS before jax initializes (the main test process stays 1-device)."""
import os
import subprocess
import sys
import textwrap

import pytest

_SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys
    sys.path.insert(0, "src")
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
    from repro.models.partitioning import make_rules
    from repro.models.layers import flash_decode_sharded, _decode_attend

    mesh = Mesh(np.array(jax.devices()).reshape(2, 4), ("data", "model"))
    rules = make_rules(mesh, n_heads=4, n_kv_heads=2)
    rng = np.random.default_rng(0)
    b, H, KV, S, hd = 4, 4, 2, 64, 16
    for pos_i, window in [(13, None), (40, 16), (63, None), (0, None)]:
        q = jnp.asarray(rng.normal(size=(b, H, 1, hd)), jnp.float32)
        kc = jnp.asarray(rng.normal(size=(b, KV, S, hd)), jnp.float32)
        vc = jnp.asarray(rng.normal(size=(b, KV, S, hd)), jnp.float32)
        kn = jnp.asarray(rng.normal(size=(b, KV, 1, hd)), jnp.float32)
        vn = jnp.asarray(rng.normal(size=(b, KV, 1, hd)), jnp.float32)
        pos = jnp.asarray(pos_i, jnp.int32)
        kr = jax.lax.dynamic_update_slice(kc, kn, (0, 0, pos_i, 0))
        vr = jax.lax.dynamic_update_slice(vc, vn, (0, 0, pos_i, 0))
        ref = _decode_attend(q, kr, vr, pos, window, 30.0, hd ** -0.5)
        cache_sh = NamedSharding(mesh, P("data", None, "model", None))
        kc_s = jax.device_put(kc, cache_sh)
        vc_s = jax.device_put(vc, cache_sh)
        out, k2, v2 = jax.jit(
            lambda *a: flash_decode_sharded(
                *a, window, 30.0, hd ** -0.5, rules
            )
        )(q, kc_s, vc_s, kn, vn, pos)
        np.testing.assert_allclose(
            np.asarray(out), np.asarray(ref), rtol=2e-4, atol=2e-4
        )
        np.testing.assert_allclose(np.asarray(k2), np.asarray(kr))
    print("OK")
    """
)


def test_flash_decode_matches_dense_on_8_devices():
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    out = subprocess.run(
        [sys.executable, "-c", _SCRIPT],
        capture_output=True, text=True, timeout=300,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        env=env,
    )
    assert out.returncode == 0, out.stderr[-2000:]
    assert "OK" in out.stdout
