"""Lazy bucket handles: zero boundary copies across chained engine ops.

Acceptance surface of DESIGN.md §8:

  * :class:`LazyBucket` semantics — true-shape reporting, cached one-slice
    realization (identity when aligned), shared copy accounting across
    ``rewrap``/``map``/``clamp``, the ``__jax_array__`` protocol, and
    ``lazy_map`` compatibility/fallback rules;
  * forwarding — a dispatch whose operand is a handle in a compatible
    bucket consumes the raw buffer directly (``forwarded`` counted, zero
    stage/unstage), with NaN-poisoned pad tails proving the masked-tail
    contract holds ACROSS op boundaries, for gemm, prefill attention and
    decode attention (the kv cache consuming k/v projection buffers);
  * fallbacks stay correct and honestly counted — incompatible buckets
    restage (stage copy), mixed handle/plain attention realizes, and every
    path is bit-identical to the eager per-op reference;
  * whole-model chained prefill (launch/serve.py ``prefill="chained"``) is
    bit-identical to its eager per-op reference with ZERO interior
    unstage+restage pairs (boundary copies per block == 0 at a chain-
    aligned bucket) and at least one forward per block;
  * the staging pool retains at most ``staging_pool_cap`` idle buffer sets
    (LRU eviction, MRU reuse) and eviction can never race an in-flight
    dispatch (checked-out sets are not in the free list).
"""
import threading

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core.engine import (
    DispatchStats,
    LazyBucket,
    _StagingPool,
    lazy_map,
)
from repro.core.workloads import GemmWorkload
from repro.launch.mesh import make_host_mesh
from repro.launch.serve import Request, VortexServer
from repro.models.registry import get_smoke_config
from repro.vortex import Engine, EngineConfig

RNG = np.random.default_rng(23)


def _arr(shape, dtype=jnp.float32):
    return jnp.asarray(RNG.normal(size=shape), dtype)


def _delta(before: dict, after: dict) -> dict:
    return {k: after[k] - before[k] for k in after}


@pytest.fixture(scope="module")
def eng():
    return Engine("host_cpu", empirical_levels=())


# ---------------------------------------------------------------------------
# LazyBucket unit semantics
# ---------------------------------------------------------------------------


def test_handle_reports_true_shape():
    h = LazyBucket(_arr((8, 5)), 6, 0)
    assert h.shape == (6, 5)
    assert h.padded_extent == 8
    assert not h.is_aligned
    assert h.ndim == 2
    assert h.dtype == jnp.float32


def test_realize_unaligned_slices_once_and_caches():
    st = DispatchStats()
    buf = _arr((8, 5))
    h = LazyBucket(buf, 6, 0, st)
    r = h.realize()
    assert r.shape == (6, 5)
    assert st.realize_slices == 1
    assert h.realize() is r  # cached: repeated forcing pays once
    assert st.realize_slices == 1
    np.testing.assert_array_equal(np.asarray(r), np.asarray(buf[:6]))


def test_realize_aligned_is_identity():
    st = DispatchStats()
    buf = _arr((8, 5))
    h = LazyBucket(buf, 8, 0, st)
    assert h.realize() is buf
    assert st.realize_slices == 0


def test_jax_array_protocol_forces_realization():
    buf = _arr((8, 5))
    h = LazyBucket(buf, 6, 0)
    np.testing.assert_array_equal(
        np.asarray(jnp.asarray(h)), np.asarray(buf[:6])
    )


def test_rewrap_shares_copy_accounting():
    st = DispatchStats()
    h = LazyBucket(_arr((8, 5)), 8, 0, st)
    g = h.rewrap(_arr((8, 5)), extent=3)
    g.realize()
    assert st.realize_slices == 1  # counted into the ORIGIN's stats


def test_map_is_row_local_and_keeps_geometry():
    st = DispatchStats()
    buf = _arr((8, 5))
    h = LazyBucket(buf, 6, 0, st)
    g = h.map(lambda b: b * 2.0)
    assert isinstance(g, LazyBucket)
    assert g.extent == 6 and g.padded_extent == 8
    np.testing.assert_array_equal(np.asarray(g.buffer), np.asarray(buf * 2))
    with pytest.raises(ValueError, match="bucket axis"):
        h.map(lambda b: b[:4])


def test_clamp_rebuckets_without_touching_extent():
    st = DispatchStats()
    h = LazyBucket(_arr((8, 5)), 6, 0, st)
    assert h.clamp(8) is h  # identity at the current bucket
    c = h.clamp(6)
    assert st.realize_slices == 1  # one counted boundary slice
    assert c.extent == 6 and c.padded_extent == 6 and c.is_aligned
    with pytest.raises(ValueError, match="below the true extent"):
        h.clamp(5)


def test_lazy_map_plain_compatible_and_fallback():
    # No handles: plain application.
    a, b = _arr((4, 3)), _arr((4, 3))
    np.testing.assert_array_equal(
        np.asarray(lazy_map(jnp.add, a, b)), np.asarray(a + b)
    )
    # Compatible handles: runs on raw buffers, NaN tails stay confined,
    # extent is the min of the operands'.
    st = DispatchStats()
    b1 = _arr((8, 5)).at[6:].set(np.nan)
    b2 = _arr((8, 5)).at[4:].set(np.nan)
    h1 = LazyBucket(b1, 6, 0, st)
    h2 = LazyBucket(b2, 4, 0, st)
    out = lazy_map(jnp.add, h1, h2)
    assert isinstance(out, LazyBucket)
    assert out.extent == 4 and out.padded_extent == 8
    got = np.asarray(out.realize())
    assert not np.isnan(got).any()
    np.testing.assert_array_equal(got, np.asarray((b1 + b2)[:4]))
    # Plain operands broadcast against the BUFFER shape (per-feature
    # weights, row-local).
    w = _arr((5,))
    np.testing.assert_array_equal(
        np.asarray(lazy_map(jnp.multiply, h1, w).buffer),
        np.asarray(b1 * w),
    )
    # Incompatible bucket geometry: realize-everything fallback (counted).
    before = st.realize_slices
    h3 = LazyBucket(_arr((4, 5)), 4, 0, st).rewrap(_arr((4, 5)), extent=3)
    h4 = LazyBucket(_arr((8, 5)), 3, 0, st)
    out = lazy_map(jnp.add, h3, h4)
    assert not isinstance(out, LazyBucket)
    assert out.shape == (3, 5)
    assert st.realize_slices - before == 2
    # A fn that changes the bucket axis is a contract violation.
    with pytest.raises(ValueError, match="bucket axis"):
        lazy_map(lambda t: t[:4], h1)


# ---------------------------------------------------------------------------
# Forwarding: bucket-to-bucket dispatch
# ---------------------------------------------------------------------------


def _gemm_kern(eng, n, k):
    return eng.kernel_for(GemmWorkload(M=None, N=n, K=k))


def test_gemm_chain_aligned_forwarding_is_bitwise(eng):
    k1, k2 = _gemm_kern(eng, 64, 96), _gemm_kern(eng, 48, 64)
    fix = [
        m for m in range(1, 257)
        if k1.select(m).padded_m == m and k2.select(m).padded_m == m
    ]
    assert fix, "no shared gemm fixpoint <= 256"
    m = fix[-1]
    a, w1, w2 = _arr((m, 96)), _arr((96, 64)), _arr((64, 48))
    ref = k2(k1(a, w1), w2)

    b1 = k1.dispatch_stats.as_dict()
    b2 = k2.dispatch_stats.as_dict()
    h = k1(a, w1, lazy=True)
    assert isinstance(h, LazyBucket) and h.is_aligned and h.extent == m
    out = k2(h, w2)
    d2 = _delta(b2, k2.dispatch_stats.as_dict())
    d1 = _delta(b1, k1.dispatch_stats.as_dict())
    assert d2["forwarded"] == 1
    assert d2["aligned_calls"] == 1 and d2["launches"] == 1
    assert d2["stage_copies"] == 0 and d2["unstage_copies"] == 0
    assert d1["realize_slices"] == 0  # the handle was never forced
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


def test_gemm_forwarding_masks_nan_tail(eng):
    """A handle whose pad tail is NaN-poisoned forwards bit-identically:
    the scalars come from the TRUE shape, so the executable never reads
    past the extent."""
    k2 = _gemm_kern(eng, 48, 64)
    fix = [m for m in range(2, 257) if k2.select(m).padded_m == m]
    assert fix
    bucket = fix[-1]
    ms = [m for m in range(bucket - 1, 0, -1)
          if k2.select(m).padded_m == bucket]
    assert ms, f"no extent buckets to {bucket}"
    m = ms[0]
    w2 = _arr((64, 48))
    clean = _arr((bucket, 64))
    poisoned = clean.at[m:].set(np.nan)
    ref = k2(jnp.asarray(clean[:m]), w2)

    before = k2.dispatch_stats.as_dict()
    h = LazyBucket(poisoned, m, 0, k2.dispatch_stats)
    out = k2(h, w2)
    d = _delta(before, k2.dispatch_stats.as_dict())
    assert d["forwarded"] == 1 and d["stage_copies"] == 0
    assert d["aligned_calls"] == 1  # selection at the PADDED extent
    assert d["unstage_copies"] == 1  # finalize slices back to m rows
    got = np.asarray(out)
    assert got.shape == (m, 48)
    assert not np.isnan(got).any()
    np.testing.assert_array_equal(got, np.asarray(ref))


def test_gemm_lazy_output_defers_the_unstage(eng):
    k1 = _gemm_kern(eng, 64, 96)
    m = next(m for m in range(3, 257) if k1.select(m).padded_m > m)
    a, w1 = _arr((m, 96)), _arr((96, 64))
    ref = k1(a, w1)

    before = k1.dispatch_stats.as_dict()
    h = k1(a, w1, lazy=True)
    d = _delta(before, k1.dispatch_stats.as_dict())
    assert isinstance(h, LazyBucket) and not h.is_aligned
    assert d["stage_copies"] == 1 and d["launches"] == 1
    assert d["unstage_copies"] == 0  # deferred: only paid if forced ...
    assert d["realize_slices"] == 0
    np.testing.assert_array_equal(np.asarray(h.realize()), np.asarray(ref))
    assert k1.dispatch_stats.realize_slices - before["realize_slices"] == 1


def test_incompatible_bucket_restages_and_stays_correct(eng):
    """A handle whose buffer does not match the selection's staged shape
    restages (counted) — the whole buffer, garbage tail included — and the
    true-shape scalars keep the result bit-identical."""
    k2 = _gemm_kern(eng, 48, 64)
    w = next(w for w in range(2, 257) if k2.select(w).padded_m > w)
    m = w - 1
    w2 = _arr((64, 48))
    clean = _arr((w, 64))
    poisoned = clean.at[m:].set(np.nan)
    ref = k2(jnp.asarray(clean[:m]), w2)

    before = k2.dispatch_stats.as_dict()
    h = LazyBucket(poisoned, m, 0, k2.dispatch_stats)
    out = k2(h, w2)
    d = _delta(before, k2.dispatch_stats.as_dict())
    assert d["forwarded"] == 0 and d["stage_copies"] == 1
    assert d["unaligned_calls"] == 1 and d["launches"] == 1
    got = np.asarray(out)
    assert not np.isnan(got).any()
    np.testing.assert_array_equal(got, np.asarray(ref))


def _attn_kern(eng, hd=32):
    args = (
        _arr((1, 2, 8, hd)), _arr((1, 1, 8, hd)), _arr((1, 1, 8, hd)),
    )
    return eng.op_kernel(
        "attention", args, {"causal": True, "window": None, "softcap": None}
    )


def test_attention_forwards_nan_poisoned_kv_tails(eng):
    kern = _attn_kern(eng)
    hd = 32
    fix = [
        s for s in range(2, 257)
        if kern.select(s).bucket == (s, hd, s)
    ]
    assert fix, "no attention bucket fixpoint <= 256"
    sb = fix[-1]
    ms = [m for m in range(sb - 1, 0, -1)
          if kern.select(m).bucket == (sb, hd, sb)]
    assert ms
    m = ms[0]
    q = _arr((1, 2, sb, hd)).at[:, :, m:].set(np.nan)
    k = _arr((1, 1, sb, hd)).at[:, :, m:].set(np.nan)
    v = _arr((1, 1, sb, hd)).at[:, :, m:].set(np.nan)
    ref = kern(
        jnp.asarray(q[:, :, :m]), jnp.asarray(k[:, :, :m]),
        jnp.asarray(v[:, :, :m]),
    )

    st = kern.dispatch_stats
    before = st.as_dict()
    hq = LazyBucket(q, m, 2, st)
    hk = LazyBucket(k, m, 2, st)
    hv = LazyBucket(v, m, 2, st)
    out = kern(hq, hk, hv)
    d = _delta(before, st.as_dict())
    assert d["forwarded"] == 3 and d["stage_copies"] == 0
    assert d["aligned_calls"] == 1 and d["launches"] == 1
    got = np.asarray(out)
    assert got.shape == (1, 2, m, hd)
    assert not np.isnan(got).any()
    np.testing.assert_array_equal(got, np.asarray(ref))


def test_attention_mixed_handle_plain_realizes(eng):
    """A plain q at the TRUE extent alongside padded k/v handles trips the
    q/kv seq-match assertion — the dispatch falls back to realize-all and
    stays bit-identical (counted slices, no crash)."""
    kern = _attn_kern(eng)
    hd = 32
    sb = max(
        s for s in range(2, 257) if kern.select(s).bucket == (s, hd, s)
    )
    m = sb - 1
    q = _arr((1, 2, m, hd))
    k = _arr((1, 1, sb, hd)).at[:, :, m:].set(np.nan)
    v = _arr((1, 1, sb, hd)).at[:, :, m:].set(np.nan)
    ref = kern(q, jnp.asarray(k[:, :, :m]), jnp.asarray(v[:, :, :m]))

    st = kern.dispatch_stats
    before = st.as_dict()
    out = kern(q, LazyBucket(k, m, 2, st), LazyBucket(v, m, 2, st))
    d = _delta(before, st.as_dict())
    assert d["realize_slices"] == 2 and d["forwarded"] == 0
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


def test_decode_consumes_lazy_kv_buffers(eng):
    """Decode attention consumes NaN-tailed k/v bucket handles directly —
    the serving scenario where the prefill chain's projection buffers
    BECOME the cache without a copy."""
    hd = 32
    rep = (_arr((2, 4, 1, hd)), _arr((2, 2, 8, hd)), _arr((2, 2, 8, hd)), 8)
    kern = eng.op_kernel("decode_attention", rep, {})
    wl = kern.workload
    fix = [
        s for s in range(2, 257)
        if wl.dynamic_bucket(kern.select(s)) == s
    ]
    assert fix
    kvb = fix[-1]
    m = kvb - 1
    q = _arr((2, 4, 1, hd))
    k = _arr((2, 2, kvb, hd)).at[:, :, m:].set(np.nan)
    v = _arr((2, 2, kvb, hd)).at[:, :, m:].set(np.nan)
    ref = kern(q, jnp.asarray(k[:, :, :m]), jnp.asarray(v[:, :, :m]), m)

    st = kern.dispatch_stats
    before = st.as_dict()
    out = kern(q, LazyBucket(k, m, 2, st), LazyBucket(v, m, 2, st), m)
    d = _delta(before, st.as_dict())
    assert d["forwarded"] == 2
    assert d["aligned_calls"] == 1 and d["launches"] == 1
    assert d["stage_copies"] == 0 and d["unstage_copies"] == 0
    got = np.asarray(out)
    assert not np.isnan(got).any()
    np.testing.assert_array_equal(got, np.asarray(ref))


# ---------------------------------------------------------------------------
# Staging pool: LRU retention bound
# ---------------------------------------------------------------------------


def test_staging_pool_lru_cap_and_mru_reuse():
    pool = _StagingPool(cap=2)
    need = {0: ((4, 4), jnp.float32)}
    sets = [pool.acquire(need) for _ in range(3)]  # all checked out
    assert len({id(s) for s in sets}) == 3
    assert pool.retained == []  # in-flight sets are NOT in the free list
    for s in sets:
        pool.release(s)
    assert len(pool.retained) == 2  # LRU (first released) evicted
    assert pool.retained[-1] is sets[-1]
    assert all(s is not sets[0] for s in pool.retained)
    assert pool.acquire(need) is sets[-1]  # MRU-first reuse


def test_staging_pool_cap_zero_retains_nothing():
    pool = _StagingPool(cap=0)
    need = {0: ((4, 4), jnp.float32)}
    pool.release(pool.acquire(need))
    assert pool.retained == []


def test_engine_config_threads_pool_cap():
    e = Engine(EngineConfig(
        hardware="host_cpu", empirical_levels=(), staging_pool_cap=0,
    ))
    kern = e.op_kernel("gemm", (_arr((5, 16)), _arr((16, 8))), {})
    m = next(m for m in range(3, 257) if kern.select(m).padded_m > m)
    out = kern(_arr((m, 16)), _arr((16, 8)))
    assert out.shape == (m, 8)
    assert kern.dispatch_stats.stage_copies >= 1
    pools = [entry.pool for entry in kern._exec_cache.values()]
    assert pools and all(p.cap == 0 and p.retained == [] for p in pools)


def test_pool_eviction_never_races_in_flight():
    """cap=1 under concurrent unaligned dispatch: every result stays
    bit-identical to its serial reference (a set in use is checked out, so
    eviction can only ever drop idle sets) and at most one set is retained
    after the burst."""
    e = Engine(EngineConfig(
        hardware="host_cpu", empirical_levels=(), staging_pool_cap=1,
    ))
    kern = e.op_kernel("gemm", (_arr((5, 16)), _arr((16, 8))), {})
    m = next(m for m in range(3, 257) if kern.select(m).padded_m > m)
    w = _arr((16, 8))
    xs = [_arr((m, 16)) for _ in range(8)]
    refs = [np.asarray(kern(x, w)) for x in xs]

    errors: list = []

    def worker(i):
        try:
            for _ in range(4):
                got = np.asarray(kern(xs[i], w))
                np.testing.assert_array_equal(got, refs[i])
        except Exception as exc:  # pragma: no cover - failure path
            errors.append((i, exc))

    threads = [
        threading.Thread(target=worker, args=(i,)) for i in range(len(xs))
    ]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors, errors[:2]
    for entry in kern._exec_cache.values():
        assert len(entry.pool.retained) <= 1


# ---------------------------------------------------------------------------
# Whole-model chained prefill (launch/serve.py prefill="chained")
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def chained_server():
    cfg = get_smoke_config("paper-gpt2-124m")
    return VortexServer(
        cfg, make_host_mesh(), max_cache=256, prefill="chained"
    )


def _engine_chain_stats(server) -> dict:
    agg = {
        "stage_copies": 0, "unstage_copies": 0, "realize_slices": 0,
        "forwarded": 0, "launches": 0,
    }
    for kind, st in server.engine.stats().items():
        if kind == "calibration":  # engine-level section, not a kind
            continue
        for key in agg:
            agg[key] += st[key]
    return agg


def test_prefill_knob_validated():
    with pytest.raises(ValueError, match="prefill"):
        VortexServer(
            get_smoke_config("paper-gpt2-124m"), make_host_mesh(),
            prefill="nope",
        )


def test_chain_seq_bucket_is_aligned(chained_server):
    srv = chained_server
    assert srv._prefill_chained_supported()
    sp = srv.chain_seq_bucket(100, 1)
    assert sp >= srv.seq_bucket(100)
    assert srv._chain_aligned(1, sp)
    assert srv.kv_bucket(sp) == sp


def test_chained_prefill_bitwise_vs_eager_with_zero_copies(chained_server):
    """The tentpole acceptance: a whole-model chained prefill is
    bit-identical to the eager per-op reference (same dispatch sequence on
    plain arrays) and performs ZERO interior unstage+restage pairs — the
    boundary-copy counters don't move at a chain-aligned bucket."""
    srv = chained_server
    cfg = srv.cfg
    sp = srv.chain_seq_bucket(100, 1)
    tokens = (np.arange(100, dtype=np.int32)[None] * 7) % cfg.vocab
    batch = srv._make_batch(1, sp, tokens)

    before = _engine_chain_stats(srv)
    last, cache = srv.prefill_chained(1, sp, batch)
    d = _delta(before, _engine_chain_stats(srv))

    n_blocks = cfg.n_layers
    copies = d["stage_copies"] + d["unstage_copies"] + d["realize_slices"]
    assert copies == 0, d
    assert copies / n_blocks <= 1  # the per-block gate, trivially
    assert d["forwarded"] >= n_blocks
    assert d["launches"] >= 6 * n_blocks  # q/k/v/attn/o + mlp, per block

    last_e, cache_e = srv.prefill_chained(1, sp, batch, eager=True)
    np.testing.assert_array_equal(np.asarray(last), np.asarray(last_e))
    jax.tree_util.tree_map(
        lambda a, b: np.testing.assert_array_equal(
            np.asarray(a), np.asarray(b)
        ),
        cache, cache_e,
    )
    # Cache leaves landed kv-bucket shaped, dtype matching the model cache.
    kvb = srv.kv_bucket(sp)
    for entry in cache.values():
        for leaf in entry.values():
            assert leaf.shape[3] == kvb
            assert leaf.dtype == jnp.dtype(cfg.dtype)


def test_chained_prefill_matches_aot_loosely(chained_server):
    """Chained vs the AOT program is an INFORMATIONAL closeness check only
    (different fusion in bf16) — the structural contract (shapes, dtypes,
    cache tree) is exact."""
    srv = chained_server
    sp = srv.chain_seq_bucket(64, 1)
    tokens = (np.arange(64, dtype=np.int32)[None] * 11) % srv.cfg.vocab
    batch = srv._make_batch(1, sp, tokens)
    last_c, cache_c = srv.prefill_chained(1, sp, batch)
    last_a, cache_a = srv._prefill_exec_for(1, sp, batch)(srv.params, batch)
    assert last_c.shape == last_a.shape and last_c.dtype == last_a.dtype
    flat_c = jax.tree_util.tree_leaves(cache_c)
    flat_a = jax.tree_util.tree_leaves(cache_a)
    assert [(a.shape, a.dtype) for a in flat_c] == \
        [(a.shape, a.dtype) for a in flat_a]
    a = np.asarray(last_c, np.float32)
    b = np.asarray(last_a, np.float32)
    scale = max(float(np.max(np.abs(b))), 1.0)
    assert float(np.max(np.abs(a - b))) / scale < 0.15


def test_generate_routes_chained_and_decodes(chained_server):
    srv = chained_server
    before = srv.stats["chained_prefills"]
    launches = srv.decode_stats.launches
    tokens = (RNG.integers(0, srv.cfg.vocab, (2, 37))).astype(np.int32)
    out = srv.generate(Request(tokens=tokens, max_new=4))
    assert out.shape == (2, 4)
    assert srv.stats["chained_prefills"] == before + 1
    assert srv.decode_stats.launches == launches + 3
    assert srv.decode_stats.padded_calls == 0


def test_chained_unsupported_arch_reports_fallback():
    cfg = get_smoke_config("falcon-mamba-7b")  # mamba mixer: no chain
    srv = VortexServer(
        cfg, make_host_mesh(), max_cache=64, prefill="chained"
    )
    assert not srv._prefill_chained_supported()


def test_engine_dispatch_stats_surfaces_chain_counters(chained_server):
    stats = chained_server.engine_dispatch_stats()
    for kind, st in stats.items():
        if kind in ("kv_pool", "calibration"):  # engine-level sections
            continue
        assert "forwarded" in st and "realize_slices" in st, kind
