"""Optimizer, schedule, and gradient-compression tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from conftest import optional_hypothesis

# Only the int8-roundtrip property test needs hypothesis; the rest of the
# optimizer suite must keep running without it.
given, settings, st = optional_hypothesis()

from repro.optim.adamw import (
    AdamWConfig,
    adamw_init,
    adamw_update,
    opt_state_pspecs,
)
from repro.optim.compression import (
    compress_int8,
    decompress_int8,
    ef_compress_update,
)
from repro.optim.schedule import linear_warmup_cosine


def test_adamw_converges_on_quadratic():
    params = {"w": jnp.asarray([5.0, -3.0, 2.0])}
    opt = adamw_init(params)
    cfg = AdamWConfig(weight_decay=0.0)
    lr = jnp.asarray(0.1)

    def loss(p):
        return jnp.sum(jnp.square(p["w"] - 1.0))

    for _ in range(200):
        g = jax.grad(loss)(params)
        params, opt = adamw_update(cfg, params, g, opt, lr)
    np.testing.assert_allclose(np.asarray(params["w"]), 1.0, atol=1e-2)
    assert int(opt["step"]) == 200


def test_adamw_grad_clip_bounds_update():
    params = {"w": jnp.zeros((4,))}
    opt = adamw_init(params)
    cfg = AdamWConfig(grad_clip=1.0, weight_decay=0.0)
    huge = {"w": jnp.full((4,), 1e9)}
    p2, _ = adamw_update(cfg, params, huge, opt, jnp.asarray(0.1))
    # First-step Adam update magnitude is ~lr regardless of gradient scale.
    assert float(jnp.max(jnp.abs(p2["w"]))) < 0.2


def test_warmup_then_decay():
    lrs = [
        float(linear_warmup_cosine(jnp.asarray(s), 1.0, 10, 100))
        for s in range(100)
    ]
    assert lrs[0] < lrs[5] < lrs[9]          # warming up
    assert lrs[20] > lrs[50] > lrs[99]       # decaying
    assert lrs[99] >= 0.1 - 1e-6             # floor


def test_opt_state_zero1_sharding():
    specs = {"w": P(None, "model"), "b": P()}
    shapes = {
        "w": jax.ShapeDtypeStruct((64, 128), jnp.float32),
        "b": jax.ShapeDtypeStruct((7,), jnp.float32),
    }
    out = opt_state_pspecs(specs, shapes, data_axis_size=16)
    # w's first (unsharded, divisible) axis picks up 'data'; b (7) cannot.
    assert out["mu"]["w"] == P("data", "model")
    assert out["mu"]["b"] == P()
    assert out["step"] == P()


@given(
    scale=st.floats(1e-6, 1e6),
    seed=st.integers(0, 2**16),
)
@settings(max_examples=40, deadline=None)
def test_int8_roundtrip_error_bound(scale, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(256,)) * scale, jnp.float32)
    q, s = compress_int8(x)
    back = decompress_int8(q, s)
    # Quantization error per element is at most half a quantization step.
    step = float(s)
    assert float(jnp.max(jnp.abs(back - x))) <= 0.5 * step + 1e-12
    assert q.dtype == jnp.int8


def test_error_feedback_is_unbiased_over_time():
    """With error feedback, the *sum* of compressed gradients tracks the sum
    of true gradients (residual never grows unboundedly)."""
    rng = np.random.default_rng(0)
    err = jnp.zeros((64,), jnp.float32)
    true_sum = np.zeros((64,))
    sent_sum = np.zeros((64,))
    for _ in range(50):
        g = jnp.asarray(rng.normal(size=(64,)), jnp.float32)
        _, _, err, approx = ef_compress_update(g, err)
        true_sum += np.asarray(g)
        sent_sum += np.asarray(approx)
    resid = np.abs(true_sum - sent_sum)
    # Residual equals the final error buffer: bounded by one quantization
    # step, NOT accumulating over the 50 steps.
    np.testing.assert_allclose(resid, np.abs(np.asarray(err)), atol=1e-4)
    assert resid.max() < 1.0
