"""Per-architecture smoke tests: reduced same-family configs, one forward
and one train step on CPU; output shapes + no NaNs (assignment deliverable f).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh

from repro.models import model as M
from repro.models.params import count_params, init_params
from repro.models.partitioning import make_rules
from repro.models.registry import _MODULES, get_config, get_smoke_config
from repro.train.step import TrainHParams, make_train_step

ARCHS = list(_MODULES)


@pytest.fixture(scope="module")
def mesh():
    return Mesh(np.array(jax.devices()[:1]).reshape(1, 1), ("data", "model"))


def _extras(cfg, b, key):
    kw = {}
    if cfg.vision_prefix:
        kw["vision_embeds"] = jax.random.normal(
            key, (b, cfg.vision_prefix, cfg.d_model)
        ).astype(jnp.dtype(cfg.dtype))
    if cfg.encoder_decoder:
        kw["encoder_frames"] = jax.random.normal(
            key, (b, cfg.encoder_seq, cfg.d_model)
        ).astype(jnp.dtype(cfg.dtype))
    return kw


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_and_finiteness(arch, mesh):
    cfg = get_smoke_config(arch)
    rules = make_rules(
        mesh, fsdp=cfg.fsdp, n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads
    )
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    b, s = 2, 32
    tokens = jax.random.randint(key, (b, s), 0, cfg.vocab)
    logits, cache, aux = M.forward(
        cfg, rules, params, tokens, mode="train", **_extras(cfg, b, key)
    )
    assert logits.shape == (b, s, cfg.vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    assert np.isfinite(float(aux))


@pytest.mark.parametrize("arch", ARCHS)
def test_one_train_step(arch, mesh):
    cfg = get_smoke_config(arch)
    rules = make_rules(
        mesh, fsdp=cfg.fsdp, n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads
    )
    key = jax.random.PRNGKey(1)
    params = init_params(cfg, key)
    from repro.optim.adamw import adamw_init

    opt = adamw_init(params)
    hp = TrainHParams(num_microbatches=2, total_steps=10, warmup_steps=2)
    step = make_train_step(cfg, rules, hp)
    b, s = 4, 32
    batch = {
        "tokens": jax.random.randint(key, (b, s), 0, cfg.vocab),
        "labels": jax.random.randint(key, (b, s), 0, cfg.vocab),
        **_extras(cfg, b, key),
    }
    params2, opt2, metrics = jax.jit(step)(params, opt, batch)
    loss = float(metrics["loss"])
    assert np.isfinite(loss) and loss > 0
    assert int(opt2["step"]) == 1
    # Parameters actually moved.
    moved = any(
        not np.allclose(
            np.asarray(a, np.float32), np.asarray(b_, np.float32)
        )
        for a, b_ in zip(
            jax.tree.leaves(params), jax.tree.leaves(params2)
        )
    )
    assert moved


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_matches_assignment(arch):
    """The FULL config matches the assigned hyperparameters exactly."""
    cfg = get_config(arch)
    expected = {
        "gemma2-9b": (42, 3584, 16, 8, 14336, 256000),
        "phi4-mini-3.8b": (32, 3072, 24, 8, 8192, 200064),
        "h2o-danube-3-4b": (24, 3840, 32, 8, 10240, 32000),
        "starcoder2-15b": (40, 6144, 48, 4, 24576, 49152),
        "deepseek-v2-236b": (60, 5120, 128, 128, 1536, 102400),
        "granite-moe-1b-a400m": (24, 1024, 16, 8, 512, 49155),
        "internvl2-26b": (48, 6144, 48, 8, 16384, 92553),
        "whisper-small": (12, 768, 12, 12, 3072, 51865),
        "jamba-v0.1-52b": (32, 4096, 32, 8, 14336, 65536),
        "falcon-mamba-7b": (64, 4096, 1, 1, 0, 65024),
        "paper-gpt2-124m": (12, 768, 12, 12, 3072, 50257),
    }[arch]
    got = (
        cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
        cfg.d_ff, cfg.vocab,
    )
    assert got == expected


def test_param_counts_in_expected_range():
    """Schema-derived parameter counts land near the advertised sizes."""
    expect = {
        "gemma2-9b": (8.0e9, 10.5e9),
        "phi4-mini-3.8b": (3.3e9, 4.4e9),
        "h2o-danube-3-4b": (3.3e9, 4.5e9),
        "starcoder2-15b": (13e9, 17e9),
        "deepseek-v2-236b": (200e9, 260e9),
        "granite-moe-1b-a400m": (0.9e9, 1.5e9),
        "internvl2-26b": (17e9, 27e9),   # backbone only (ViT stubbed)
        "whisper-small": (0.14e9, 0.30e9),
        "jamba-v0.1-52b": (44e9, 58e9),
        "falcon-mamba-7b": (6e9, 8.5e9),
        "paper-gpt2-124m": (0.08e9, 0.15e9),
    }
    for arch, (lo, hi) in expect.items():
        n = count_params(get_config(arch))
        assert lo <= n <= hi, (arch, f"{n:,}")


def test_moe_active_params_below_total():
    cfg = get_config("deepseek-v2-236b")
    assert cfg.active_param_count() < 0.15 * cfg.param_count()
