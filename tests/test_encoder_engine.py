"""Non-causal encoder attention on the engine (ISSUE 5 satellite).

The whisper/internvl encoder stacks run bidirectional self-attention
(``attn_forward(causal=False)``) — with PR 4's kv_len masking the causal
structure is no longer load-bearing for bucketing, so a session routes
them through the engine too.  These tests assert (a) engine dispatch
actually occurs (DispatchStats delta) and (b) the outputs match the
sessionless inline path bit-for-bit at fully-aligned single-chunk
sequence lengths (where both paths reduce to the identical oracle on the
identical buffers — any difference would be a routing bug, not float
noise), plus to tight tolerance at arbitrary lengths.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh

from repro.models import model as M
from repro.models.config import LayerSpec
from repro.models.layers import attn_forward
from repro.models.params import init_params
from repro.models.partitioning import make_rules
from repro.models.registry import get_smoke_config
from repro.vortex import Engine, use

ARCHS = ["whisper-small", "internvl2-26b"]


@pytest.fixture(scope="module")
def mesh():
    return Mesh(np.array(jax.devices()[:1]).reshape(1, 1), ("data", "model"))


def _encoder_weights(cfg, rng):
    """A GQA attention parameter set shaped like the model's own."""
    d, hd = cfg.d_model, cfg.resolved_head_dim
    H, KV = cfg.n_heads, cfg.n_kv_heads

    def w(shape):
        return jnp.asarray(rng.normal(size=shape) * 0.05, jnp.float32)

    return {
        "wq": w((d, H * hd)),
        "wk": w((d, KV * hd)),
        "wv": w((d, KV * hd)),
        "wo": w((H * hd, d)),
    }


def _bitwise_seqs(engine, cfg, limit=64) -> list[int]:
    """Sequence lengths where the engine path is the IDENTICAL program to
    the inline path: fully aligned bucket (no staging, no padding) and a
    single kv chunk (no online-softmax re-ordering)."""
    hd = cfg.resolved_head_dim
    q = jnp.zeros((1, cfg.n_heads, 8, hd))
    kv = jnp.zeros((1, cfg.n_kv_heads, 8, hd))
    kern = engine.op_kernel(
        "attention", (q, kv, kv),
        {"causal": False, "window": None, "softcap": cfg.attn_softcap},
    )
    out = []
    for s in range(1, limit + 1):
        sel = kern.select(s)
        if sel.bucket[0] == s and sel.bucket[2] == s and sel.grid[2] == 1:
            out.append(s)
    return out


@pytest.mark.parametrize("arch", ARCHS)
def test_encoder_attn_forward_engine_parity(arch, mesh):
    """attn_forward(causal=False) with a session: dispatch occurs (stats
    delta) and outputs are bit-for-bit at aligned single-chunk lengths."""
    cfg = get_smoke_config(arch)
    rules = make_rules(mesh, n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads)
    rng = np.random.default_rng(5)
    p = _encoder_weights(cfg, rng)
    spec = LayerSpec(mixer="attn", mlp="dense")  # the encoder's own spec
    eng = Engine("host_cpu", empirical_levels=())
    seqs = _bitwise_seqs(eng, cfg)
    assert seqs, "no aligned single-chunk seq found for bitwise parity"

    for s in seqs[-2:]:
        x = jnp.asarray(rng.normal(size=(2, s, cfg.d_model)) * 0.1,
                        jnp.float32)
        kw = dict(
            mode="prefill", positions=jnp.arange(s), cache_len=s,
            causal=False, use_rope=cfg.use_rope,
        )
        inline, _ = attn_forward(p, x, cfg, spec, rules, **kw)
        before = eng.stats().get("attention", {}).get("launches", 0)
        with use(eng):
            routed, _ = attn_forward(p, x, cfg, spec, rules, **kw)
        after = eng.stats()["attention"]
        assert after["launches"] == before + 1, "engine dispatch must occur"
        assert after["padded_calls"] == 0
        np.testing.assert_array_equal(
            np.asarray(routed), np.asarray(inline),
            err_msg=f"{arch}: engine path differs bitwise at seq {s}",
        )


@pytest.mark.parametrize("arch", ARCHS)
def test_encoder_attn_forward_engine_close_at_unaligned_seq(arch, mesh):
    """At an arbitrary (staged, multi-chunk) length the routed path stays
    within float accumulation-order tolerance of the inline path."""
    cfg = get_smoke_config(arch)
    rules = make_rules(mesh, n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads)
    rng = np.random.default_rng(9)
    p = _encoder_weights(cfg, rng)
    spec = LayerSpec(mixer="attn", mlp="dense")
    s = 27  # prime: unaligned on every lattice
    x = jnp.asarray(rng.normal(size=(2, s, cfg.d_model)) * 0.1, jnp.float32)
    kw = dict(
        mode="prefill", positions=jnp.arange(s), cache_len=s,
        causal=False, use_rope=cfg.use_rope,
    )
    inline, _ = attn_forward(p, x, cfg, spec, rules, **kw)
    eng = Engine("host_cpu", empirical_levels=())
    with use(eng):
        routed, _ = attn_forward(p, x, cfg, spec, rules, **kw)
    assert eng.stats()["attention"]["launches"] == 1
    np.testing.assert_allclose(
        np.asarray(routed), np.asarray(inline), rtol=2e-5, atol=2e-5
    )


@pytest.mark.parametrize("arch", ARCHS)
def test_full_model_prefill_with_engine_routes_encoder(arch, mesh):
    """Whole-model prefill under a session: the encoder's non-causal
    attention dispatches through the engine at trace time (traced_calls
    delta) and the logits match the sessionless forward bit-for-bit."""
    cfg = get_smoke_config(arch)
    rules = make_rules(mesh, n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads)
    params = init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(1)
    b, s = 2, 16
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (b, s)), jnp.int32)
    kw = {}
    if cfg.vision_prefix:
        kw["vision_embeds"] = jnp.asarray(
            rng.normal(size=(b, cfg.vision_prefix, cfg.d_model)),
            jnp.dtype(cfg.dtype),
        )
    if cfg.encoder_decoder:
        kw["encoder_frames"] = jnp.asarray(
            rng.normal(size=(b, cfg.encoder_seq, cfg.d_model)),
            jnp.dtype(cfg.dtype),
        )
    logits0, _, _ = M.forward(
        cfg, rules, params, toks, mode="prefill", cache_len=32, **kw
    )
    eng = Engine("host_cpu", empirical_levels=())
    with use(eng):
        logits1, _, _ = M.forward(
            cfg, rules, params, toks, mode="prefill", cache_len=32, **kw
        )
    st = eng.stats()["attention"]
    # Both the causal decoder prefill and (for whisper) the non-causal
    # encoder route; lax.scan bodies trace once => small fixed counts.
    assert st["traced_calls"] >= (2 if cfg.encoder_decoder else 1)
    np.testing.assert_array_equal(
        np.asarray(logits0, np.float32), np.asarray(logits1, np.float32),
        err_msg=f"{arch}: engine-routed forward differs from inline",
    )
