"""Partitioning rules, spec sanitization, and the roofline HLO parser."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh, PartitionSpec as P

from repro.models.config import SHAPES
from repro.models import model as M
from repro.models.params import abstract_params, param_pspecs
from repro.models.partitioning import AxisRules, make_rules
from repro.models.registry import get_config
from repro.roofline.hlo_parse import parse_hlo_costs
from repro.roofline.memory import tree_device_bytes
from repro.train.step import serve_input_specs, train_input_specs


def _abstract_mesh(shape, axes):
    try:
        return jax.sharding.AbstractMesh(shape, axes)  # jax >= 0.5
    except TypeError:
        # jax 0.4.x signature: AbstractMesh(((name, size), ...)).
        return jax.sharding.AbstractMesh(tuple(zip(axes, shape)))


def _abstract_rules(shape=(16, 16), axes=("data", "model"),
                    fsdp=False, n_heads=16, n_kv_heads=8):
    mesh = _abstract_mesh(shape, axes)
    return make_rules(
        mesh, fsdp=fsdp, n_heads=n_heads, n_kv_heads=n_kv_heads
    )


class TestRules:
    def test_sanitize_drops_non_divisible(self):
        r = _abstract_rules()
        assert r.sanitize(P("model"), (49155,)) == P()
        assert r.sanitize(P("model"), (49152,)) == P("model")
        assert r.sanitize(P(("pod", "data")), (1,)) == P()

    def test_heads_act_requires_divisibility(self):
        r = _abstract_rules(n_heads=24)  # 24 % 16 != 0
        assert r.rules["heads_act"] is None
        r2 = _abstract_rules(n_heads=32)
        assert r2.rules["heads_act"] == "model"

    def test_fsdp_maps_embed_to_data(self):
        r = _abstract_rules(fsdp=True)
        assert r.rules["embed"] == "data"
        r2 = _abstract_rules(fsdp=False)
        assert r2.rules["embed"] is None

    def test_multipod_batch_spans_pod_and_data(self):
        r = _abstract_rules(
            shape=(2, 16, 16), axes=("pod", "data", "model")
        )
        assert r.rules["batch"] == ("pod", "data")


class TestSpecTrees:
    @pytest.mark.parametrize("arch", ["gemma2-9b", "deepseek-v2-236b",
                                      "falcon-mamba-7b", "whisper-small"])
    def test_param_specs_cover_every_leaf_and_divide(self, arch):
        cfg = get_config(arch)
        r = _abstract_rules(
            fsdp=cfg.fsdp, n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads
        )
        params = abstract_params(cfg)
        specs = param_pspecs(cfg, r)
        flat_p = jax.tree.leaves(params)
        flat_s = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
        assert len(flat_p) == len(flat_s)
        for leaf, spec in zip(flat_p, flat_s):
            for i, part in enumerate(tuple(spec)):
                if part is None:
                    continue
                ext = r._extent(part)
                assert leaf.shape[i] % ext == 0, (leaf.shape, spec)

    def test_big_models_fit_hbm_under_sharding(self):
        """The FSDP+TP layout puts deepseek-v2 params well under 16 GB/chip."""
        cfg = get_config("deepseek-v2-236b")
        r = _abstract_rules(fsdp=True, n_heads=128, n_kv_heads=128)
        params = abstract_params(cfg)
        specs = param_pspecs(cfg, r)
        nbytes = tree_device_bytes(
            params, specs, {"data": 16, "model": 16}
        )
        assert nbytes < 4 * 2**30  # params alone < 4 GiB/chip

    def test_cache_specs_match_cache_tree(self):
        cfg = get_config("jamba-v0.1-52b")
        r = _abstract_rules(n_heads=32, n_kv_heads=8)
        cache = M.abstract_cache(cfg, batch=128, cache_len=1024)
        specs = M.cache_pspecs(cfg, r, batch=128, cache_len=1024)
        # encoder_out absent; same tree structure otherwise
        assert set(cache) == set(specs)
        jax.tree.map(
            lambda c, s: None, cache, specs,
            is_leaf=lambda x: isinstance(x, (P, jax.ShapeDtypeStruct)),
        )

    def test_input_specs_all_cells(self):
        """Every assigned (arch x shape) produces well-formed input specs."""
        from repro.models.registry import ARCH_IDS

        r = _abstract_rules()
        for arch in ARCH_IDS:
            cfg = get_config(arch)
            for shape in SHAPES.values():
                if shape.kind == "train":
                    specs, ps = train_input_specs(cfg, shape, r)
                else:
                    specs, ps = serve_input_specs(cfg, shape, r)
                assert "tokens" in specs and "tokens" in ps


class TestHloParser:
    def test_scan_trip_count_correction(self):
        def f(x):
            def body(c, _):
                return c @ c, None
            c, _ = jax.lax.scan(body, x, None, length=7)
            return c

        compiled = jax.jit(f).lower(
            jax.ShapeDtypeStruct((64, 64), jnp.float32)
        ).compile()
        costs = parse_hlo_costs(compiled.as_text())
        assert costs.flops == pytest.approx(7 * 2 * 64**3, rel=0.01)
        assert 7 in costs.while_trip_counts.values()

    def test_plain_dot_flops(self):
        compiled = jax.jit(lambda a, b: a @ b).lower(
            jax.ShapeDtypeStruct((32, 48), jnp.float32),
            jax.ShapeDtypeStruct((48, 16), jnp.float32),
        ).compile()
        costs = parse_hlo_costs(compiled.as_text())
        assert costs.flops == pytest.approx(2 * 32 * 48 * 16, rel=0.01)

    def test_collectives_counted_with_bytes(self):
        """An explicitly sharded reduction must show an all-reduce (or
        reduce-scatter) with nonzero bytes."""
        from jax.sharding import NamedSharding

        devs = jax.devices()
        if len(devs) < 2:
            pytest.skip("needs >1 device for a real collective")

    def test_memory_bytes_positive(self):
        compiled = jax.jit(lambda a, b: a @ b).lower(
            jax.ShapeDtypeStruct((32, 48), jnp.float32),
            jax.ShapeDtypeStruct((48, 16), jnp.float32),
        ).compile()
        costs = parse_hlo_costs(compiled.as_text())
        expect = 4 * (32 * 48 + 48 * 16 + 32 * 16)
        assert costs.memory_bytes >= expect
