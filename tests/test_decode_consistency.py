"""Prefill+decode must reproduce the full-forward logits (KV-cache, MLA
absorbed decode, mamba recurrent state, sliding windows, cross-attention).
MoE archs are tested with a no-drop capacity factor, since capacity dropping
legitimately perturbs train-mode outputs."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh

from repro.models import model as M
from repro.models.params import init_params
from repro.models.partitioning import make_rules
from repro.models.registry import _MODULES, get_smoke_config

ARCHS = list(_MODULES)


@pytest.fixture(scope="module")
def mesh():
    return Mesh(np.array(jax.devices()[:1]).reshape(1, 1), ("data", "model"))


def _no_drop(cfg):
    if cfg.moe is None:
        return cfg
    return dataclasses.replace(
        cfg,
        moe=dataclasses.replace(
            cfg.moe, capacity_factor=float(cfg.moe.num_experts)
        ),
    )


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_matches_full_forward(arch, mesh):
    cfg = _no_drop(get_smoke_config(arch))
    rules = make_rules(
        mesh, n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads
    )
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    b, prefill_len, extra = 2, 32, 3
    total = prefill_len + extra
    tokens = jax.random.randint(key, (b, total), 0, cfg.vocab)
    kw = {}
    if cfg.vision_prefix:
        kw["vision_embeds"] = jax.random.normal(
            key, (b, cfg.vision_prefix, cfg.d_model)
        ).astype(jnp.dtype(cfg.dtype))
    if cfg.encoder_decoder:
        kw["encoder_frames"] = jax.random.normal(
            key, (b, cfg.encoder_seq, cfg.d_model)
        ).astype(jnp.dtype(cfg.dtype))

    full, _, _ = M.forward(cfg, rules, params, tokens, mode="train", **kw)
    _, cache, _ = M.forward(
        cfg, rules, params, tokens[:, :prefill_len], mode="prefill",
        cache_len=total, **kw,
    )

    def _gate(full_logits, dec_logits, pos):
        """bf16 end-to-end through up-to-8-layer stacks: typical rel-err
        is ~1e-2.  Gate what a real decode/cache bug would actually move:
        the TYPICAL error (90th percentile — a genuine mismatch perturbs
        most logits) strictly, and severe outliers only as a fraction."""
        a = np.asarray(full_logits[:, pos], np.float32)
        b_ = np.asarray(dec_logits[:, 0], np.float32)
        err = np.abs(a - b_) / (np.max(np.abs(a)) + 1e-9)
        p90 = float(np.percentile(err, 90))
        severe = float(np.mean(err > 0.25))
        return (p90 < 0.03 and severe < 0.02), (p90, severe)

    # Decode the remaining tokens one by one; each must match the parallel
    # (train-mode) logits at that position.
    for i in range(extra):
        pos = prefill_len + i
        step_args = (cfg, rules, params, tokens[:, pos: pos + 1])
        step_kw = dict(
            mode="decode", cache=cache, pos=jnp.asarray(pos, jnp.int32),
            cache_len=total,
        )
        dec, cache, _ = M.forward(*step_args, **step_kw)
        ok, stats = _gate(full, dec, pos)
        if not ok:
            # Under heavy CPU contention XLA's threaded reductions can
            # reorder and blow up a FEW logits by large margins on either
            # side of the comparison (documented pre-existing flake).
            # Such blowups are nondeterministic per execution, while a
            # real decode bug reproduces — so recompute both sides once
            # before declaring failure (caches are functional values, the
            # re-run is side-effect-free).
            full_retry, _, _ = M.forward(
                cfg, rules, params, tokens, mode="train", **kw
            )
            dec, cache, _ = M.forward(*step_args, **step_kw)
            ok, stats = _gate(full_retry, dec, pos)
        assert ok, (arch, i, stats)


def test_windowed_decode_ignores_out_of_window(mesh):
    """A sliding-window layer's decode must not attend past the window."""
    cfg = get_smoke_config("h2o-danube-3-4b")
    rules = make_rules(mesh, n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads)
    key = jax.random.PRNGKey(3)
    params = init_params(cfg, key)
    b, s = 1, 40  # window is 16 in the smoke config
    tokens = jax.random.randint(key, (b, s), 0, cfg.vocab)
    _, cache, _ = M.forward(
        cfg, rules, params, tokens, mode="prefill", cache_len=64
    )
    # Corrupt cache entries strictly outside the window of position s.
    w = cfg.pattern[0].window
    corrupted = jax.tree.map(lambda x: x, cache)
    for p in corrupted:
        if p.startswith("pos"):
            k = corrupted[p]["k"]
            noise = jnp.asarray(
                np.random.default_rng(0).normal(size=k[..., : s - w, :].shape),
                k.dtype,
            ) * 100
            corrupted[p]["k"] = k.at[..., : s - w, :].set(noise)
    tok = tokens[:, :1]
    out_clean, _, _ = M.forward(
        cfg, rules, params, tok, mode="decode", cache=cache,
        pos=jnp.asarray(s, jnp.int32), cache_len=64,
    )
    out_corr, _, _ = M.forward(
        cfg, rules, params, tok, mode="decode", cache=corrupted,
        pos=jnp.asarray(s, jnp.int32), cache_len=64,
    )
    np.testing.assert_allclose(
        np.asarray(out_clean, np.float32),
        np.asarray(out_corr, np.float32),
        rtol=1e-5, atol=1e-5,
    )
