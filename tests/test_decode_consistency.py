"""Prefill+decode must reproduce the full-forward logits (KV-cache, MLA
absorbed decode, mamba recurrent state, sliding windows, cross-attention).
MoE archs are tested with a no-drop capacity factor, since capacity dropping
legitimately perturbs train-mode outputs.

De-flaked (ISSUE 5): the per-arch sweep runs in float32, where the only
nondeterminism left (XLA's threaded reduction order under CPU contention)
is ~1e-6 relative — far under the gate — so the comparison is strict and
deterministic; the historical bf16 run, whose tolerance cliff made the p90
gate contention-sensitive, is kept as ONE smoke behind the ``contention``
marker (deselected from tier-1 via pyproject addopts).  The engine-side
decode determinism claim — same kv bucket => same executable — is asserted
structurally from DispatchStats/cache_info in
``test_decode_bucket_identity`` (and differentially in
tests/test_decode_engine.py), not from wall-clock-sensitive numerics.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh

from repro.models import model as M
from repro.models.params import init_params
from repro.models.partitioning import make_rules
from repro.models.registry import _MODULES, get_smoke_config

ARCHS = list(_MODULES)


@pytest.fixture(scope="module")
def mesh():
    return Mesh(np.array(jax.devices()[:1]).reshape(1, 1), ("data", "model"))


def _no_drop(cfg):
    if cfg.moe is None:
        return cfg
    return dataclasses.replace(
        cfg,
        moe=dataclasses.replace(
            cfg.moe, capacity_factor=float(cfg.moe.num_experts)
        ),
    )


def _decode_inputs(cfg, key, b=2, prefill_len=32, extra=3):
    total = prefill_len + extra
    tokens = jax.random.randint(key, (b, total), 0, cfg.vocab)
    kw = {}
    if cfg.vision_prefix:
        kw["vision_embeds"] = jax.random.normal(
            key, (b, cfg.vision_prefix, cfg.d_model)
        ).astype(jnp.dtype(cfg.dtype))
    if cfg.encoder_decoder:
        kw["encoder_frames"] = jax.random.normal(
            key, (b, cfg.encoder_seq, cfg.d_model)
        ).astype(jnp.dtype(cfg.dtype))
    return tokens, total, kw


def _run_decode_vs_full(cfg, mesh, gate):
    """Decode the last tokens one by one against the train-mode logits,
    calling ``gate(full_logits_at_pos, decode_logits)`` per step."""
    rules = make_rules(mesh, n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads)
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    prefill_len, extra = 32, 3
    tokens, total, kw = _decode_inputs(cfg, key, 2, prefill_len, extra)

    full, _, _ = M.forward(cfg, rules, params, tokens, mode="train", **kw)
    _, cache, _ = M.forward(
        cfg, rules, params, tokens[:, :prefill_len], mode="prefill",
        cache_len=total, **kw,
    )
    for i in range(extra):
        pos = prefill_len + i
        dec, cache, _ = M.forward(
            cfg, rules, params, tokens[:, pos: pos + 1], mode="decode",
            cache=cache, pos=jnp.asarray(pos, jnp.int32), cache_len=total,
        )
        gate(full[:, pos], dec[:, 0], pos)


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_matches_full_forward(arch, mesh):
    """float32 end-to-end: the comparison is deterministic, so the gate is
    strict — a real decode/cache bug moves logits by orders of magnitude
    more than f32 reduction-order noise."""
    cfg = dataclasses.replace(
        _no_drop(get_smoke_config(arch)), dtype="float32"
    )

    def gate(full_pos, dec, pos):
        a = np.asarray(full_pos, np.float32)
        b_ = np.asarray(dec, np.float32)
        err = np.abs(a - b_) / (np.max(np.abs(a)) + 1e-9)
        assert float(np.max(err)) < 2e-3, (arch, pos, float(np.max(err)))

    _run_decode_vs_full(cfg, mesh, gate)


@pytest.mark.contention
def test_decode_matches_full_forward_bf16_smoke(mesh):
    """The historical bf16 comparison for ONE arch: its p90/severe gate is
    contention-sensitive on shared CPUs (threaded bf16 reductions reorder),
    so it lives behind the ``contention`` marker as an opt-in timing smoke
    (`pytest -m contention`), out of tier-1."""
    cfg = _no_drop(get_smoke_config("paper-gpt2-124m"))

    def gate(full_pos, dec, pos):
        a = np.asarray(full_pos, np.float32)
        b_ = np.asarray(dec, np.float32)
        err = np.abs(a - b_) / (np.max(np.abs(a)) + 1e-9)
        p90 = float(np.percentile(err, 90))
        severe = float(np.mean(err > 0.25))
        assert p90 < 0.03 and severe < 0.02, (pos, p90, severe)

    _run_decode_vs_full(cfg, mesh, gate)


def test_decode_bucket_identity():
    """Deterministic replacement for wall-clock decode gating: every
    decode dispatch at the SAME cache length serves from the SAME compiled
    executable (no per-kv_len growth), asserted from DispatchStats and the
    executable cache — and a different kv bucket adds exactly one."""
    from repro.vortex import Engine

    eng = Engine("host_cpu", empirical_levels=())
    rng = np.random.default_rng(0)

    def args(S, kv_len):
        return (
            jnp.asarray(rng.normal(size=(1, 4, 1, 32)), jnp.float32),
            jnp.asarray(rng.normal(size=(1, 2, S, 32)), jnp.float32),
            jnp.asarray(rng.normal(size=(1, 2, S, 32)), jnp.float32),
            kv_len,
        )

    kern = eng.op_kernel("decode_attention", args(8, 8), {})
    S = kern.workload.dynamic_bucket(kern.select(64))  # a bucket length
    for kv_len in range(1, S + 1, max(S // 7, 1)):
        eng.dispatch("decode_attention", *args(S, kv_len))
    d = eng.stats()["decode_attention"]
    assert d["launches"] == d["calls"], "one launch per decode step"
    assert d["padded_calls"] == 0
    assert d["exec_entries"] == 1, (
        "same kv bucket must serve every kv_len from ONE executable"
    )
    # Crossing into another bucket compiles exactly one more program.
    S2 = kern.workload.dynamic_bucket(kern.select(S + 1))
    assert S2 > S
    eng.dispatch("decode_attention", *args(S2, S + 1))
    assert eng.stats()["decode_attention"]["exec_entries"] == 2


def test_windowed_decode_ignores_out_of_window(mesh):
    """A sliding-window layer's decode must not attend past the window."""
    cfg = get_smoke_config("h2o-danube-3-4b")
    rules = make_rules(mesh, n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads)
    key = jax.random.PRNGKey(3)
    params = init_params(cfg, key)
    b, s = 1, 40  # window is 16 in the smoke config
    tokens = jax.random.randint(key, (b, s), 0, cfg.vocab)
    _, cache, _ = M.forward(
        cfg, rules, params, tokens, mode="prefill", cache_len=64
    )
    # Corrupt cache entries strictly outside the window of position s.
    w = cfg.pattern[0].window
    corrupted = jax.tree.map(lambda x: x, cache)
    for p in corrupted:
        if p.startswith("pos"):
            k = corrupted[p]["k"]
            noise = jnp.asarray(
                np.random.default_rng(0).normal(size=k[..., : s - w, :].shape),
                k.dtype,
            ) * 100
            corrupted[p]["k"] = k.at[..., : s - w, :].set(noise)
    tok = tokens[:, :1]
    out_clean, _, _ = M.forward(
        cfg, rules, params, tok, mode="decode", cache=cache,
        pos=jnp.asarray(s, jnp.int32), cache_len=64,
    )
    out_corr, _, _ = M.forward(
        cfg, rules, params, tok, mode="decode", cache=corrupted,
        pos=jnp.asarray(s, jnp.int32), cache_len=64,
    )
    np.testing.assert_allclose(
        np.asarray(out_clean, np.float32),
        np.asarray(out_corr, np.float32),
        rtol=1e-5, atol=1e-5,
    )
