from repro.train.step import (
    TrainHParams,
    make_train_step,
    make_prefill_step,
    make_decode_step,
    train_input_specs,
    serve_input_specs,
)

__all__ = [n for n in dir() if not n.startswith("_")]
