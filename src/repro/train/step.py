"""Train / prefill / decode step builders, plus their input specs.

``make_train_step`` builds the full training step: microbatch gradient
accumulation (lax.scan, so the HLO stays one loop), remat'd forward, AdamW
with warmup+cosine LR, optional error-feedback int8 compression of the
cross-pod gradient hop.  These are the functions the multi-pod dry-run
lowers and compiles for every (arch x shape) cell.

Input stand-ins (``*_input_specs``) are ShapeDtypeStructs — the dry-run
never allocates a batch.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.config import ModelConfig, ShapeSpec
from repro.models.model import forward, loss_fn, make_cache
from repro.models.partitioning import AxisRules
from repro.optim.adamw import AdamWConfig, adamw_update
from repro.optim.schedule import linear_warmup_cosine

__all__ = [
    "TrainHParams",
    "make_train_step",
    "make_prefill_step",
    "make_decode_step",
    "train_input_specs",
    "serve_input_specs",
]


@dataclasses.dataclass(frozen=True)
class TrainHParams:
    base_lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10000
    num_microbatches: int = 1
    adamw: AdamWConfig = AdamWConfig()
    aux_weight: float = 0.01


def _split_batch(batch: dict, num_mb: int) -> dict:
    """(B, ...) -> (num_mb, B/num_mb, ...) for every batch leaf."""

    def split(x):
        b = x.shape[0]
        assert b % num_mb == 0, (b, num_mb)
        return x.reshape(num_mb, b // num_mb, *x.shape[1:])

    return jax.tree.map(split, batch)


def make_train_step(
    cfg: ModelConfig,
    rules: AxisRules,
    hp: TrainHParams,
    grad_pspecs=None,
):
    """Returns train_step(params, opt_state, batch) -> (params, opt_state,
    metrics).  ``batch`` holds tokens/labels (+ modality extras).

    ``grad_pspecs`` (a PartitionSpec tree matching params) pins the
    microbatch gradient accumulator's sharding: without it XLA may keep the
    accumulator replicated and all-reduce full gradients every microbatch
    (§Perf A4); with it the per-microbatch reduction becomes a
    reduce-scatter onto the FSDP shards.
    """

    def mb_loss(params, mb):
        extras = {
            k: mb[k]
            for k in ("vision_embeds", "encoder_frames")
            if k in mb
        }
        return loss_fn(
            cfg, rules, params, mb["tokens"], mb["labels"],
            aux_weight=hp.aux_weight, **extras,
        )

    grad_fn = jax.value_and_grad(mb_loss, has_aux=True)

    def pin_grads(g):
        if grad_pspecs is None or rules.mesh is None:
            return g
        from jax.sharding import NamedSharding

        return jax.tree.map(
            lambda t, s: jax.lax.with_sharding_constraint(
                t, NamedSharding(rules.mesh, s)
            ),
            g,
            grad_pspecs,
        )

    def train_step(params, opt_state, batch):
        if hp.num_microbatches <= 1:
            (loss, parts), grads = grad_fn(params, batch)
        else:
            mbs = _split_batch(batch, hp.num_microbatches)

            def acc_body(carry, mb):
                g_acc, l_acc = carry
                (l, _parts), g = grad_fn(params, mb)
                g_acc = pin_grads(jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), g_acc, g
                ))
                return (g_acc, l_acc + l), None

            g0 = pin_grads(jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            ))
            (g_sum, l_sum), _ = jax.lax.scan(acc_body, (g0, 0.0), mbs)
            inv = 1.0 / hp.num_microbatches
            grads = jax.tree.map(lambda g: g * inv, g_sum)
            loss = l_sum * inv
            parts = {}

        lr = linear_warmup_cosine(
            opt_state["step"], hp.base_lr, hp.warmup_steps, hp.total_steps
        )
        params, opt_state = adamw_update(
            hp.adamw, params, grads, opt_state, lr
        )
        metrics = {"loss": loss, "lr": lr}
        metrics.update({k: v for k, v in parts.items()})
        return params, opt_state, metrics

    return train_step


def make_prefill_step(cfg: ModelConfig, rules: AxisRules, cache_len: int):
    """prefill(params, batch) -> (last_logits, cache)."""

    def prefill_step(params, batch):
        extras = {
            k: batch[k]
            for k in ("vision_embeds", "encoder_frames")
            if k in batch
        }
        logits, cache, _ = forward(
            cfg, rules, params, batch["tokens"], mode="prefill",
            cache_len=cache_len, **extras,
        )
        return logits[:, -1], cache

    return prefill_step


def make_decode_step(cfg: ModelConfig, rules: AxisRules, cache_len: int):
    """decode(params, cache, tokens(b,1), pos) -> (logits(b,vocab), cache)."""

    def decode_step(params, cache, tokens, pos):
        logits, new_cache, _ = forward(
            cfg, rules, params, tokens, mode="decode",
            cache=cache, pos=pos, cache_len=cache_len,
        )
        return logits[:, 0], new_cache

    return decode_step


# ---------------------------------------------------------------------------
# Input specs (ShapeDtypeStruct stand-ins) + their PartitionSpecs
# ---------------------------------------------------------------------------


def _batch_axes(rules: AxisRules):
    return rules.rules.get("batch")


def train_input_specs(
    cfg: ModelConfig, shape: ShapeSpec, rules: AxisRules
) -> tuple[dict, dict]:
    """(ShapeDtypeStruct batch, PartitionSpec batch) for a training cell."""
    b, s = shape.global_batch, shape.seq_len
    batch_ax = _batch_axes(rules)
    bspec = rules.sanitize(P(batch_ax), (b,))
    specs = {
        "tokens": jax.ShapeDtypeStruct((b, s), jnp.int32),
        "labels": jax.ShapeDtypeStruct((b, s), jnp.int32),
    }
    pspecs = {"tokens": bspec, "labels": bspec}
    if cfg.vision_prefix:
        specs["vision_embeds"] = jax.ShapeDtypeStruct(
            (b, cfg.vision_prefix, cfg.d_model), jnp.dtype(cfg.dtype)
        )
        pspecs["vision_embeds"] = bspec
    if cfg.encoder_decoder:
        specs["encoder_frames"] = jax.ShapeDtypeStruct(
            (b, cfg.encoder_seq, cfg.d_model), jnp.dtype(cfg.dtype)
        )
        pspecs["encoder_frames"] = bspec
    return specs, pspecs


def serve_input_specs(
    cfg: ModelConfig, shape: ShapeSpec, rules: AxisRules
) -> tuple[dict, dict]:
    """Inputs for prefill (full request) or decode (one token)."""
    b, s = shape.global_batch, shape.seq_len
    batch_ax = _batch_axes(rules)
    bspec = rules.sanitize(P(batch_ax), (b,))
    if shape.kind == "prefill":
        specs = {"tokens": jax.ShapeDtypeStruct((b, s), jnp.int32)}
        pspecs = {"tokens": bspec}
        if cfg.vision_prefix:
            specs["vision_embeds"] = jax.ShapeDtypeStruct(
                (b, cfg.vision_prefix, cfg.d_model), jnp.dtype(cfg.dtype)
            )
            pspecs["vision_embeds"] = bspec
        if cfg.encoder_decoder:
            specs["encoder_frames"] = jax.ShapeDtypeStruct(
                (b, cfg.encoder_seq, cfg.d_model), jnp.dtype(cfg.dtype)
            )
            pspecs["encoder_frames"] = bspec
        return specs, pspecs
    # decode: one new token against a cache of length s
    specs = {
        "tokens": jax.ShapeDtypeStruct((b, 1), jnp.int32),
        "pos": jax.ShapeDtypeStruct((), jnp.int32),
    }
    pspecs = {"tokens": bspec, "pos": P()}
    return specs, pspecs
