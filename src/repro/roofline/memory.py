"""Analytic per-device memory accounting from (ShapeDtypeStruct, PartitionSpec)
trees — the "fits in 16 GB/chip" proof for the dry-run, independent of what
``compiled.memory_analysis()`` exposes on this backend.
"""
from __future__ import annotations

import math
from typing import Any, Mapping

import jax
import numpy as np
from jax.sharding import PartitionSpec as P

__all__ = ["tree_device_bytes", "fits_hbm"]


def _leaf_device_bytes(
    leaf: jax.ShapeDtypeStruct, spec: P, axis_sizes: Mapping[str, int]
) -> float:
    total = float(np.prod(leaf.shape) or 1) * np.dtype(leaf.dtype).itemsize
    div = 1
    for i, part in enumerate(tuple(spec)):
        if part is None:
            continue
        names = part if isinstance(part, tuple) else (part,)
        extent = 1
        for n in names:
            extent *= axis_sizes.get(n, 1)
        # GSPMD pads uneven dims; account for the padded shard.
        dim = leaf.shape[i]
        shard = math.ceil(dim / extent)
        div *= dim / max(shard, 1) if shard else 1
    return total / max(div, 1)


def tree_device_bytes(
    tree: Any, spec_tree: Any, axis_sizes: Mapping[str, int]
) -> float:
    leaves = jax.tree.leaves(tree)
    specs = jax.tree.leaves(
        spec_tree, is_leaf=lambda x: isinstance(x, P)
    )
    assert len(leaves) == len(specs), (len(leaves), len(specs))
    return sum(
        _leaf_device_bytes(l, s, axis_sizes) for l, s in zip(leaves, specs)
    )


def fits_hbm(per_device_bytes: float, hbm_bytes: float,
             headroom: float = 0.9) -> bool:
    return per_device_bytes <= hbm_bytes * headroom
