"""HLO text parser: per-device FLOPs, HBM bytes and collective bytes with
while-loop trip-count correction.

``compiled.cost_analysis()`` counts a ``while`` body ONCE regardless of trip
count (verified empirically in this container), which under-counts a scanned
transformer by ~n_layers.  This parser walks the computation call graph
(ENTRY -> fusions -> while bodies), extracts each while's trip count from
the integer constant in its condition computation, and accumulates

  * dot / convolution FLOPs (from operand shapes + contracting dims),
  * a memory-traffic upper bound (operands+outputs of dots, convs and
    collectives — i.e. the streamed tensors; fused elementwise traffic is
    folded into these),
  * collective bytes per kind (all-gather, all-reduce, reduce-scatter,
    all-to-all, collective-permute), counted at the op's OUTPUT size.

Since the compiled module under SPMD is the per-device program, every
number is per-device.
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict
from typing import Mapping

__all__ = ["ModuleCosts", "parse_hlo_costs"]

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.+?)\s+([\w\-]+)\((.*)$"
)
_CALL_RE = re.compile(r"(?:calls|body|condition|to_apply)=%?([\w\.\-]+)")
_CONST_RE = re.compile(r"constant\((\d+)\)")

_COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)


def _shape_bytes(shape_str: str) -> int:
    """Total bytes of a (possibly tuple) shape string."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_dims(shape_str: str) -> tuple[list[int], str] | None:
    m = _SHAPE_RE.search(shape_str)
    if not m:
        return None
    dt, dims = m.groups()
    return ([int(d) for d in dims.split(",")] if dims else [], dt)


@dataclasses.dataclass
class _Op:
    name: str
    shape_str: str
    opcode: str
    rest: str  # text after the opening paren


@dataclasses.dataclass
class _Computation:
    name: str
    ops: list
    shapes: dict  # op name -> shape_str


@dataclasses.dataclass
class ModuleCosts:
    flops: float
    memory_bytes: float
    collective_bytes: float
    collective_by_kind: Mapping[str, float]
    collective_counts: Mapping[str, int]
    while_trip_counts: Mapping[str, int]

    def as_dict(self) -> dict:
        return {
            "flops": self.flops,
            "memory_bytes": self.memory_bytes,
            "collective_bytes": self.collective_bytes,
            "collective_by_kind": dict(self.collective_by_kind),
            "collective_counts": dict(self.collective_counts),
            "while_trip_counts": dict(self.while_trip_counts),
        }


def _split_computations(text: str) -> dict[str, _Computation]:
    comps: dict[str, _Computation] = {}
    cur: _Computation | None = None
    for line in text.splitlines():
        stripped = line.strip()
        header = re.match(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->.*{",
                          stripped)
        if header and not stripped.startswith("//"):
            cur = _Computation(header.group(1), [], {})
            comps[cur.name] = cur
            continue
        if stripped.startswith("}"):
            cur = None
            continue
        if cur is None:
            continue
        m = _OP_RE.match(line)
        if not m:
            continue
        name, shape_str, opcode, rest = m.groups()
        cur.ops.append(_Op(name, shape_str, opcode, rest))
        cur.shapes[name] = shape_str
    return comps


def _operand_names(rest: str) -> list[str]:
    """First-level operand names from an op's argument text."""
    # cut at the matching close paren level; text may include ), attrs
    depth = 1
    out_chars = []
    for ch in rest:
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                break
        out_chars.append(ch)
    args = "".join(out_chars)
    return re.findall(r"%([\w\.\-]+)", args)


def _dot_flops(op: _Op, comp: _Computation) -> float:
    out = _shape_dims(op.shape_str)
    if out is None:
        return 0.0
    out_dims, _ = out
    out_elems = 1
    for d in out_dims:
        out_elems *= d
    # contraction size from lhs shape + lhs_contracting_dims
    mc = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.rest)
    operands = _operand_names(op.rest)
    contract = 1
    if mc and operands:
        lhs_shape = comp.shapes.get(operands[0])
        if lhs_shape:
            dims = _shape_dims(lhs_shape)
            if dims:
                for ci in mc.group(1).split(","):
                    if ci:
                        idx = int(ci)
                        if idx < len(dims[0]):
                            contract *= dims[0][idx]
    return 2.0 * out_elems * contract


def _conv_flops(op: _Op, comp: _Computation) -> float:
    out = _shape_dims(op.shape_str)
    if out is None:
        return 0.0
    out_elems = 1
    for d in out[0]:
        out_elems *= d
    operands = _operand_names(op.rest)
    kernel_elems = 1
    if len(operands) >= 2:
        ksh = comp.shapes.get(operands[1])
        if ksh:
            kd = _shape_dims(ksh)
            if kd:
                for d in kd[0]:
                    kernel_elems *= d
    mg = re.search(r"feature_group_count=(\d+)", op.rest)
    groups = int(mg.group(1)) if mg else 1
    out_feats = out[0][-1] if out[0] else 1
    # per output element: 2 * (kernel elems / out_features) / groups... use
    # the standard 2 * out_elems * kernel_elems / (out_feats * groups) * cout?
    # kernel already includes cin/groups * cout; per out elem work is
    # kernel_elems / out_features spatial*cin contributions.
    per_out = kernel_elems / max(out_feats, 1)
    return 2.0 * out_elems * per_out


def _op_stream_bytes(op: _Op, comp: _Computation) -> float:
    total = _shape_bytes(op.shape_str)
    for name in _operand_names(op.rest):
        sh = comp.shapes.get(name)
        if sh:
            total += _shape_bytes(sh)
    return float(total)


def _trip_count(cond: _Computation) -> int:
    best = 1
    for op in cond.ops:
        if op.opcode == "constant":
            m = _CONST_RE.search(op.opcode + "(" + op.rest)
            if m:
                best = max(best, int(m.group(1)))
        else:
            for m in _CONST_RE.finditer(op.rest):
                best = max(best, int(m.group(1)))
    return best


def parse_hlo_costs(text: str) -> ModuleCosts:
    comps = _split_computations(text)
    entry = None
    for line in text.splitlines():
        m = re.match(r"^ENTRY\s+%?([\w\.\-]+)", line.strip())
        if m:
            entry = m.group(1)
            break
    if entry is None:
        # fall back: the computation named like the module, else the last one
        entry = next(reversed(comps)) if comps else ""

    flops = 0.0
    mem = 0.0
    coll: dict[str, float] = defaultdict(float)
    coll_n: dict[str, int] = defaultdict(int)
    trips: dict[str, int] = {}

    # NOTE: no memoization — a computation called from N sites must
    # contribute N times.  The call graph is a shallow DAG (fusions are
    # leaf computations; while bodies nest at most ~3 deep), so repeated
    # traversal is cheap.  Guard only against direct self-recursion.
    stack: list[str] = []

    def visit(name: str, mult: float) -> None:
        comp = comps.get(name)
        if comp is None or name in stack:
            return
        stack.append(name)
        nonlocal flops, mem
        for op in comp.ops:
            oc = op.opcode
            if oc == "dot":
                flops += mult * _dot_flops(op, comp)
                mem += mult * _op_stream_bytes(op, comp)
            elif oc == "convolution":
                flops += mult * _conv_flops(op, comp)
                mem += mult * _op_stream_bytes(op, comp)
            elif any(oc.startswith(c) for c in _COLLECTIVES):
                kind = next(c for c in _COLLECTIVES if oc.startswith(c))
                nbytes = _shape_bytes(op.shape_str)
                coll[kind] += mult * nbytes
                coll_n[kind] += int(mult)
                mem += mult * nbytes
            if oc == "while":
                mcall = dict(
                    re.findall(r"(body|condition)=%?([\w\.\-]+)", op.rest)
                )
                body, cond = mcall.get("body"), mcall.get("condition")
                n = _trip_count(comps[cond]) if cond in comps else 1
                trips[body or op.name] = n
                if body:
                    visit(body, mult * n)
            else:
                for m in _CALL_RE.finditer(op.rest):
                    callee = m.group(1)
                    if callee != name:
                        visit(callee, mult)
        stack.pop()

    visit(entry, 1.0)
    total_coll = sum(coll.values())
    return ModuleCosts(
        flops=flops,
        memory_bytes=mem,
        collective_bytes=total_coll,
        collective_by_kind=dict(coll),
        collective_counts=dict(coll_n),
        while_trip_counts=trips,
    )
