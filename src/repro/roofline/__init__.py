from repro.roofline.hlo_parse import ModuleCosts, parse_hlo_costs
from repro.roofline.analysis import RooflineReport, roofline_report, V5E

__all__ = [
    "ModuleCosts",
    "parse_hlo_costs",
    "RooflineReport",
    "roofline_report",
    "V5E",
]
