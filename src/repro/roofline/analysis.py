"""Three-term roofline analysis from the compiled dry-run artifact.

Per the assignment:

    compute term    = per-device FLOPs / peak_FLOP/s        (197 TF bf16)
    memory term     = per-device HBM bytes / HBM bandwidth  (819 GB/s)
    collective term = per-device collective bytes / ICI bw  (~50 GB/s/link)

FLOPs/bytes come from the trip-count-corrected HLO parse (hlo_parse.py);
``compiled.cost_analysis()`` numbers are retained in the report for
comparison (they undercount while bodies).  MODEL_FLOPS is 6·N·D for
training (N params, D tokens) and 2·N_active·D for inference steps.
"""
from __future__ import annotations

import dataclasses
from typing import Mapping

from repro.models.config import ModelConfig, ShapeSpec
from repro.roofline.hlo_parse import ModuleCosts, parse_hlo_costs

__all__ = ["V5E", "RooflineReport", "roofline_report", "model_flops"]


@dataclasses.dataclass(frozen=True)
class ChipSpec:
    name: str
    peak_flops: float       # bf16
    hbm_bw: float           # bytes/s
    ici_bw: float           # bytes/s per link
    hbm_bytes: float


V5E = ChipSpec(
    name="tpu_v5e",
    peak_flops=197e12,
    hbm_bw=819e9,
    ici_bw=50e9,
    hbm_bytes=16 * 1024**3,
)


def model_flops(
    cfg: ModelConfig, shape: ShapeSpec, params: int, active_params: int
) -> float:
    """Useful model FLOPs for the whole step (all chips)."""
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * active_params * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * active_params * tokens
    # decode: one token per sequence
    return 2.0 * active_params * shape.global_batch


@dataclasses.dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    chips: int
    # per-device, trip-corrected
    flops: float
    memory_bytes: float
    collective_bytes: float
    # terms (seconds)
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    model_flops_total: float
    useful_ratio: float       # MODEL_FLOPS / (per-dev flops * chips)
    mfu_bound: float          # min step time / compute-bound time
    collective_by_kind: Mapping[str, float]
    raw_cost_analysis: Mapping[str, float]
    trip_counts: Mapping[str, int] = dataclasses.field(default_factory=dict)
    note: str = ""

    def as_dict(self) -> dict:
        d = dataclasses.asdict(self)
        return d


def roofline_report(
    *,
    arch: str,
    shape: ShapeSpec,
    mesh_name: str,
    chips: int,
    hlo_text: str,
    cost_analysis: Mapping[str, float] | None,
    cfg: ModelConfig,
    params: int,
    active_params: int,
    chip: ChipSpec = V5E,
    note: str = "",
) -> RooflineReport:
    costs = parse_hlo_costs(hlo_text)
    compute_s = costs.flops / chip.peak_flops
    memory_s = costs.memory_bytes / chip.hbm_bw
    collective_s = costs.collective_bytes / chip.ici_bw
    terms = {
        "compute": compute_s, "memory": memory_s, "collective": collective_s
    }
    dominant = max(terms, key=terms.get)
    mf = model_flops(cfg, shape, params, active_params)
    hlo_total = costs.flops * chips
    useful = mf / hlo_total if hlo_total else 0.0
    bound = max(terms.values())
    mfu_bound = compute_s / bound if bound else 0.0
    raw = dict(cost_analysis or {})
    raw = {
        k: float(v) for k, v in raw.items()
        if isinstance(v, (int, float)) and k in (
            "flops", "bytes accessed", "transcendentals",
            "bytes accessed output", "optimal_seconds",
        )
    }
    return RooflineReport(
        arch=arch,
        shape=shape.name,
        mesh=mesh_name,
        chips=chips,
        flops=costs.flops,
        memory_bytes=costs.memory_bytes,
        collective_bytes=costs.collective_bytes,
        compute_s=compute_s,
        memory_s=memory_s,
        collective_s=collective_s,
        dominant=dominant,
        model_flops_total=mf,
        useful_ratio=useful,
        mfu_bound=mfu_bound,
        collective_by_kind=dict(costs.collective_by_kind),
        raw_cost_analysis=raw,
        trip_counts=dict(costs.while_trip_counts),
        note=note,
    )
