"""Offline-materialized selection tables: constant-time dynamic dispatch.

The vectorized runtime cost of every candidate (cost_model.runtime_costs)
is *piecewise constant* in the dynamic extent M: it changes only where some
``ceil(M / t)`` ticks over, i.e. at M = j*t + 1 for a dynamic tile extent
``t`` present in the lattice.  ``selections_upto`` has always exploited
that property to enumerate the finite precompilation set; this module takes
the same observation to its runtime conclusion — the ENTIRE selection
decision for all M <= m_max can be materialized offline:

  1. merge the breakpoint streams of every distinct dynamic period
     (heap-merge of arithmetic progressions — divisor-free: nothing ever
     enumerates the integers 1..m_max),
  2. evaluate ONE fused numpy cost matrix over (all backends' candidates x
     all breakpoint intervals) — ``runtime_cost_matrix`` — and take the
     argmin per interval,
  3. merge consecutive intervals whose winner AND launch grid coincide, and
     store a sorted ``starts -> Selection`` array.

Runtime selection is then ``entries[bisect_right(starts, m) - 1]``:
O(log B) comparisons on a Python list — zero numpy, zero allocation, zero
hashing — for EVERY M <= m_max, seen before or not.  This is what keeps
dispatch in the sub-microsecond regime under high-cardinality shape streams
(every sequence length distinct), where an LRU keyed by raw M thrashes.

Beyond ``m_max`` the selector falls back to the fused argmin and the table
extends itself by doubling (selector.py), so the table is an accelerator,
never a correctness boundary: table lookups and the argmin path agree
exactly (bit-identical float arithmetic; see tests/test_selection_table.py).
"""
from __future__ import annotations

import bisect
import dataclasses
import time
from typing import TYPE_CHECKING, Sequence

import numpy as np

from repro.core.analyzer import StackedLattices
from repro.core.cost_model import runtime_cost_matrix
from repro.core.hardware import HardwareSpec
from repro.core.workloads import Workload

if TYPE_CHECKING:  # circular at runtime: selector.py imports this module
    from repro.core.selector import Selection

__all__ = ["SelectionTable", "merge_breakpoints", "build_selection_table"]

# Element budget of one fused sweep chunk (candidates x breakpoints): the
# (C, B) cost matrix is evaluated in column blocks so extending a table to
# a large m_max stays at tens of MB of intermediates, not gigabytes.
_SWEEP_CHUNK_ELEMS = 1 << 23


def merge_breakpoints(periods: Sequence[int], m_max: int) -> list[int]:
    """Sorted, deduped interval starts partitioning [1, m_max].

    The cost vector is constant on [j*t + 1, (j+1)*t] for every period t,
    so the starts are 1 plus every j*t + 1 <= m_max.  The arithmetic
    progressions are materialized directly and merged with one vectorized
    unique — divisor-free: nothing ever touches the integers in between
    (the old ``selections_upto`` built a Python set of ALL multiples).
    """
    streams = [np.asarray([1], np.int64)]
    for t in sorted({int(t) for t in periods}):
        if t >= 1:
            streams.append(np.arange(t + 1, m_max + 1, t, dtype=np.int64))
    return np.unique(np.concatenate(streams)).tolist()


@dataclasses.dataclass(frozen=True)
class SelectionTable:
    """Sorted ``starts -> Selection`` array covering every M in [1, m_max].

    ``starts`` is strictly increasing with ``starts[0] == 1``; entry ``i``
    serves all M in [starts[i], starts[i+1]) (the last entry serves up to
    ``m_max``).  Lookup is a bisect on a plain Python list: the serving hot
    path does no numpy and allocates nothing.
    """

    m_max: int
    starts: list[int]  # interval start per entry, strictly increasing
    entries: list  # Selection per entry (one per merged interval)
    num_intervals: int  # breakpoint intervals swept (pre-merge)
    build_seconds: float

    def __len__(self) -> int:
        return len(self.entries)

    def covers(self, m: int) -> bool:
        return 1 <= m <= self.m_max

    def lookup(self, m: int) -> "Selection":
        """The materialized selection for M = ``m`` (requires covers(m))."""
        return self.entries[bisect.bisect_right(self.starts, m) - 1]


def build_selection_table(
    hw: HardwareSpec,
    wl: Workload,
    stacked: StackedLattices,
    m_max: int,
    num_cores: int = 1,
    cost_scale: np.ndarray | None = None,
    pinned: dict[int, int] | None = None,
) -> SelectionTable:
    """Sweep the breakpoint set once and materialize the selection table.

    One ``runtime_cost_matrix`` call scores every (backend-stacked)
    candidate at every interval representative; everything after the argmin
    is integer bookkeeping.  Intervals whose winner and launch grid both
    repeat are merged (the grid is constant within an interval by
    construction — every dynamic-axis tile extent is a period — so equal
    (winner, grid) pairs imply byte-identical Selections).

    Calibration hooks (core/calibrate.py; both default to the analytical
    sweep bit-for-bit):

    * ``cost_scale`` — (C,) per-candidate multiplier (refined per-backend
      coefficients).  A constant scale keeps every cost piecewise constant
      in M, so the breakpoint set — and everything about the lookup hot
      path — is unchanged; only the argmin can differ.
    * ``pinned`` — {measured extent -> candidate index}: the breakpoint
      interval CONTAINING each extent gets its winner overridden (cost is
      constant on the interval, so a measurement at any point in it speaks
      for the whole interval).  Ground truth where we have it; the model
      (scaled or not) decides everywhere else.
    """
    from repro.core.selector import Selection

    t0 = time.perf_counter()
    m_max = max(int(m_max), 1)
    periods = stacked.dynamic_periods(wl.dynamic_tile_axes)
    starts = merge_breakpoints(periods, m_max)
    reps = np.asarray(starts, np.float64)

    n_b = len(starts)
    winners = np.empty(n_b, np.int64)
    win_costs = np.empty(n_b, np.float64)
    chunk = max(1, _SWEEP_CHUNK_ELEMS // max(stacked.num_candidates, 1))
    for lo in range(0, n_b, chunk):
        costs = runtime_cost_matrix(
            hw, wl, stacked.l1_tiles, stacked.l1_costs,
            reps[lo:lo + chunk], num_cores, cost_scale,
        )
        w = np.argmin(costs, axis=0)
        winners[lo:lo + chunk] = w
        win_costs[lo:lo + chunk] = costs[w, np.arange(costs.shape[1])]

    if pinned:
        for m_pin, idx in pinned.items():
            if not 1 <= m_pin <= m_max:
                continue
            b = bisect.bisect_right(starts, int(m_pin)) - 1
            winners[b] = int(idx)
            win_costs[b] = runtime_cost_matrix(
                hw, wl, stacked.l1_tiles, stacked.l1_costs,
                reps[b:b + 1], num_cores, cost_scale,
            )[int(idx), 0]

    M, N, K = wl.runtime_dims(reps)
    tiles = stacked.l1_tiles[winners].astype(np.float64)  # (B, 3)
    gm = np.ceil(np.asarray(M, np.float64) / tiles[:, 0]).astype(np.int64)
    gn = np.ceil(np.asarray(N, np.float64) / tiles[:, 1]).astype(np.int64)
    gk = np.ceil(np.asarray(K, np.float64) / tiles[:, 2]).astype(np.int64)

    # Merge consecutive intervals with identical (winner, grid): only the
    # change points materialize a Selection (vectorized change detection —
    # the sweep may cover hundreds of thousands of intervals, the merged
    # table typically holds a few hundred entries).
    keys = np.stack([winners, gm, gn, gk], axis=1)  # (B, 4)
    change = np.ones(n_b, bool)
    change[1:] = np.any(keys[1:] != keys[:-1], axis=1)

    out_starts: list[int] = []
    out_entries: list[Selection] = []
    for b in np.flatnonzero(change):
        idx = int(winners[b])
        strategy = stacked.strategy_for(idx)
        grid = (int(gm[b]), int(gn[b]), int(gk[b]))
        out_starts.append(int(starts[b]))
        out_entries.append(
            Selection(
                strategy=strategy,
                backend=stacked.backend_of(idx),
                grid=grid,
                padded_m=grid[0] * strategy.l1[0],
                bucket=wl.bucket_dims(grid, strategy.l1),
                predicted_cost=float(win_costs[b]),
                select_seconds=0.0,  # amortized: see SelectorStats
            )
        )

    return SelectionTable(
        m_max=m_max,
        starts=out_starts,
        entries=out_entries,
        num_intervals=len(starts),
        build_seconds=time.perf_counter() - t0,
    )
