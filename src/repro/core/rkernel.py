"""rKernel: the unified recursive abstraction (paper §4, Algorithm 1, Fig. 10).

A tensor program is decomposed into hierarchical layers.  Each layer owns
three loop sets — Parallel (PL), Temporal-Spatial (TSL) and
Temporal-Reduction (TRL) — and three stages: ``Load``, the recursive
``rKernel(L-1)``, and ``Store``.  The layer metadata mirrors the paper's
``layer_meta_info`` struct verbatim (Fig. 10): depth, per-axis loop types,
the analyzer kind used at that layer, and the load/store/compute hooks.

Two things live here:

  * the declarative metadata (:class:`LayerMetaInfo`, :class:`RKernelProgram`)
    consumed by the candidate generator, analyzer and code generator, and
  * :func:`interpret` — a pure-Python reference interpreter of Algorithm 1,
    used by the test-suite to check that the hierarchical decomposition of a
    workload computes exactly what the flat definition computes, for any
    strategy drawn from the candidate lattice.
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Mapping

import numpy as np

from repro.core.hardware import HardwareSpec

__all__ = [
    "LoopType",
    "AnalyzeType",
    "LayerMetaInfo",
    "RKernelProgram",
    "Strategy",
    "make_gemm_program",
    "interpret_gemm",
]


class LoopType(enum.Enum):
    """Loop classification at one layer (Algorithm 1)."""

    PARALLEL = "PL"
    TEMPORAL_SPATIAL = "TSL"
    TEMPORAL_REDUCTION = "TRL"


class AnalyzeType(enum.Enum):
    """Which analyzer evaluates strategies at a layer (paper Fig. 10)."""

    EMPIRICAL = "empirical"
    ANALYTICAL = "analytical"


@dataclasses.dataclass(frozen=True)
class LayerMetaInfo:
    """Metadata for one rKernel layer (paper Fig. 10 ``layer_meta_info``).

    ``load_func``/``store_func``/``compute_func`` are *names* resolved by the
    code generator (kernels/) rather than function pointers: the same program
    description must drive both the Pallas TPU lowering and the reference
    interpreter.
    """

    layer_depth: int
    loop_type: Mapping[str, LoopType]
    analyzer: AnalyzeType
    load_func: str
    store_func: str
    compute_func: str

    def axes_of(self, kind: LoopType) -> tuple[str, ...]:
        return tuple(a for a, t in self.loop_type.items() if t is kind)


@dataclasses.dataclass(frozen=True)
class Strategy:
    """A fully-specified hierarchical strategy: one tile per rKernel layer.

    ``tiles[d]`` is the (m, n, k) tile computed by ONE instance at depth d.
    Invariant (paper §5.1, Fig. 8): every dim of ``tiles[d+1]`` is an integer
    multiple of the corresponding dim of ``tiles[d]``.
    ``backend`` selects the level-0 compute unit (mxu vs vpu; §6.2).
    """

    tiles: tuple[tuple[int, int, int], ...]
    backend: str = "mxu"

    def __post_init__(self) -> None:
        for lo, hi in zip(self.tiles, self.tiles[1:]):
            for a, b in zip(lo, hi):
                if b % a:
                    raise ValueError(
                        f"strategy violates the multiples invariant: {hi} is "
                        f"not an elementwise multiple of {lo}"
                    )

    @property
    def l0(self) -> tuple[int, int, int]:
        return self.tiles[0]

    @property
    def l1(self) -> tuple[int, int, int]:
        return self.tiles[-1]


def make_gemm_program(hw: HardwareSpec) -> RKernelProgram:
    """The rKernel description of GEMM on ``hw`` (paper Fig. 7 / Table 1)."""
    layers = []
    names = [lvl.name for lvl in hw.levels]
    for depth, name in enumerate(names):
        if depth == 0:
            load, store, compute = "load_tile_to_reg", "store_reg", "dot"
        elif depth == 1:
            load, store, compute = "copy_hbm_to_vmem", "copy_vmem_to_hbm", ""
        else:
            load, store, compute = "", "", ""
        layers.append(
            LayerMetaInfo(
                layer_depth=depth,
                loop_type={
                    "m": LoopType.PARALLEL if depth == hw.num_levels - 1
                    else LoopType.TEMPORAL_SPATIAL,
                    "n": LoopType.PARALLEL if depth == hw.num_levels - 1
                    else LoopType.TEMPORAL_SPATIAL,
                    "k": LoopType.TEMPORAL_REDUCTION,
                },
                analyzer=AnalyzeType.EMPIRICAL if depth == 0
                else AnalyzeType.ANALYTICAL,
                load_func=load,
                store_func=store,
                compute_func=compute,
            )
        )
    return RKernelProgram(kind="gemm", layers=tuple(layers), hardware=hw.name)


@dataclasses.dataclass(frozen=True)
class RKernelProgram:
    """A tensor program decomposed per Algorithm 1: one LayerMetaInfo per
    hardware level, innermost first."""

    kind: str
    layers: tuple[LayerMetaInfo, ...]
    hardware: str

    @property
    def depth(self) -> int:
        return len(self.layers)


# ---------------------------------------------------------------------------
# Reference interpreter of Algorithm 1 (for tests).
# ---------------------------------------------------------------------------


def interpret_gemm(
    a: np.ndarray, b: np.ndarray, strategy: Strategy
) -> np.ndarray:
    """Execute GEMM through the recursive rKernel structure, literally.

    Follows Algorithm 1: at each layer, iterate parallel loops, then temporal
    spatial loops, then temporal reduction loops; Load the operand tiles,
    recurse, Store.  Inputs are padded to the outermost tile (runtime padding
    is confined to the outermost level — Fig. 8's integer-multiples design),
    and the padding is sliced off the result.

    This is deliberately slow and simple; it is the semantic oracle that the
    Pallas lowering and the cost model's loop-count bookkeeping are tested
    against.
    """
    M, K = a.shape
    K2, N = b.shape
    assert K == K2
    m1, n1, k1 = strategy.l1

    def pad_to(x: np.ndarray, m: int, n: int) -> np.ndarray:
        pm = (-x.shape[0]) % m
        pn = (-x.shape[1]) % n
        return np.pad(x, ((0, pm), (0, pn)))

    ap = pad_to(a.astype(np.float32), m1, k1)
    bp = pad_to(b.astype(np.float32), k1, n1)
    Mp, Kp = ap.shape
    _, Np = bp.shape
    out = np.zeros((Mp, Np), np.float32)

    def rkernel(depth: int, a_t: np.ndarray, b_t: np.ndarray) -> np.ndarray:
        """rKernel(depth) over already-Loaded tiles (Algorithm 1 recursion)."""
        if depth < 0:
            raise AssertionError("recursed past level 0")
        tm, tn, tk = strategy.tiles[depth]
        if depth == 0:
            # compute_func: the native tile contraction ("the instruction").
            return a_t @ b_t
        sm, sn, sk = strategy.tiles[depth - 1]
        acc = np.zeros((tm, tn), np.float32)
        for i in range(tm // sm):           # temporal spatial (m)
            for j in range(tn // sn):       # temporal spatial (n)
                for kk in range(tk // sk):  # temporal reduction (k)
                    # Load_Func: slice the child tiles out of this layer's
                    # memory (VMEM->VREG at depth 1, HBM->VMEM at depth 2).
                    a_s = a_t[i * sm : (i + 1) * sm, kk * sk : (kk + 1) * sk]
                    b_s = b_t[kk * sk : (kk + 1) * sk, j * sn : (j + 1) * sn]
                    acc[i * sm : (i + 1) * sm, j * sn : (j + 1) * sn] += (
                        rkernel(depth - 1, a_s, b_s)
                    )
                    # Store_Func: accumulate back into this layer's buffer.
        return acc

    top = len(strategy.tiles) - 1
    # Outermost (grid) level: parallel loops over (m, n), temporal reduction
    # over k — each instance Loads its HBM tiles and recurses.
    for i in range(Mp // m1):
        for j in range(Np // n1):
            for kk in range(Kp // k1):
                a_t = ap[i * m1 : (i + 1) * m1, kk * k1 : (kk + 1) * k1]
                b_t = bp[kk * k1 : (kk + 1) * k1, j * n1 : (j + 1) * n1]
                out[i * m1 : (i + 1) * m1, j * n1 : (j + 1) * n1] += rkernel(
                    top, a_t, b_t
                )
    return out[:M, :N]
