"""Persistent quarantine of known-bad kernel candidates (DESIGN.md §11).

The degradation ladder (core/engine.py) quarantines a candidate the moment
it fails at precompile or launch and re-selects the next-best analytical
candidate from the stacked lattice.  This store makes the quarantine
survive restarts: entries persist next to the calibration cache under the
same hardware fingerprint key (``<fingerprint>.deny.json``), so a fresh
engine on the same host skips candidates this host has already proven bad
— without re-failing them.

The file maps a workload signature key (``repr(wl.signature)``, the same
key the calibrator uses) to a list of quarantine keys
(``repr((bucket, backend, tiles))`` strings).  I/O is quiet and counted:
a corrupt or foreign file is ignored (``load_rejects``), a failed write
drops the persistence but never the in-memory quarantine
(``store_rejects``) — the ladder works identically with no disk at all.
"""
from __future__ import annotations

import json
import os
import tempfile
import threading

from repro.runtime import faults

__all__ = ["DenylistStore"]

_SCHEMA_VERSION = 1


class DenylistStore:
    """Fingerprint-keyed persistent denylist shared by an engine's kernels.

    Loading is lazy (first :meth:`get`) and at most once; every
    :meth:`add` rewrites the file atomically (tmp + ``os.replace``) so a
    mid-write kill leaves the previous snapshot intact.
    """

    def __init__(
        self,
        hw,
        backends: tuple[str, ...],
        impl: str,
        interpret: bool,
        *,
        cache_dir: str | None = None,
    ):
        self._hw = hw
        self._backends = tuple(backends)
        self._impl = impl
        self._interpret = bool(interpret)
        self._cache_dir = cache_dir
        self._lock = threading.Lock()
        self._loaded = False
        self._path: str | None = None
        self._entries: dict[str, list[str]] = {}
        self.counters = {
            "loads": 0,
            "load_rejects": 0,
            "saves": 0,
            "store_rejects": 0,
        }

    # -- location -----------------------------------------------------------

    def path(self) -> str:
        """``<calibration_cache_dir>/<fingerprint_key>.deny.json``."""
        if self._path is None:
            from repro.core.calibrate import (
                calibration_cache_dir,
                fingerprint_key,
                hardware_fingerprint,
            )

            fp = hardware_fingerprint(
                self._hw, self._backends, self._impl, self._interpret
            )
            self._path = os.path.join(
                calibration_cache_dir(self._cache_dir),
                f"{fingerprint_key(fp)}.deny.json",
            )
        return self._path

    # -- query / update -----------------------------------------------------

    def get(self, sig_key: str) -> frozenset[str]:
        """Quarantine keys persisted for one workload signature."""
        with self._lock:
            self._load_once()
            return frozenset(self._entries.get(sig_key, ()))

    def add(self, sig_key: str, qkey: str) -> None:
        """Record a quarantined candidate and persist quietly."""
        with self._lock:
            self._load_once()
            keys = self._entries.setdefault(sig_key, [])
            if qkey not in keys:
                keys.append(qkey)
            self._save_quietly()

    # -- quiet, counted I/O -------------------------------------------------

    def _load_once(self) -> None:
        if self._loaded:
            return
        self._loaded = True
        path = self.path()
        if not os.path.exists(path):
            return
        try:
            if faults.ACTIVE is not None:
                faults.ACTIVE.check("cache_io")
            with open(path) as f:
                data = json.load(f)
            if data.get("version") != _SCHEMA_VERSION:
                raise ValueError("schema version mismatch")
            entries = data["kernels"]
            if not all(
                isinstance(ks, list) and all(isinstance(k, str) for k in ks)
                for ks in entries.values()
            ):
                raise ValueError("malformed denylist entries")
            self._entries = {str(s): list(ks) for s, ks in entries.items()}
            self.counters["loads"] += 1
        except Exception:
            self.counters["load_rejects"] += 1
            self._entries = {}

    def _save_quietly(self) -> None:
        path = self.path()
        try:
            if faults.ACTIVE is not None:
                faults.ACTIVE.check("cache_io")
            os.makedirs(os.path.dirname(path), exist_ok=True)
            blob = json.dumps(
                {"version": _SCHEMA_VERSION, "kernels": self._entries},
                indent=1,
            )
            fd, tmp = tempfile.mkstemp(
                dir=os.path.dirname(path), suffix=".tmp"
            )
            try:
                with os.fdopen(fd, "w") as f:
                    f.write(blob)
                if faults.ACTIVE is not None:
                    faults.ACTIVE.check("cache_io")
                os.replace(tmp, path)
            finally:
                if os.path.exists(tmp):
                    os.unlink(tmp)
            self.counters["saves"] += 1
        except Exception:
            self.counters["store_rejects"] += 1
