"""Hybrid analytical-empirical analyzer (paper §5.2).

Two observations drive the design (quoted from the paper): the bottom-up
construction means candidate counts *grow* with layer height, and
hard-to-model hardware behaviour (out-of-order issue, pipelining)
concentrates at the *lowest* layers.  So:

  * layer 0 (and optionally layer 1) strategies are scored **empirically**
    via a pluggable :class:`Profiler`,
  * all higher layers — and everything at runtime — use the **analytical**
    model (cost_model.py), keeping runtime selection overhead negligible.

In this CPU-only container the wall-clock profiler measures real host-CPU
matmul timings (the paper's CPU leg); for the TPU target, where no hardware
is attached, a calibrated-table profiler stands in for the machine and the
analyzer structure is unchanged — on a real pod the same interface times
``pallas_call`` variants.
"""
from __future__ import annotations

import dataclasses
import json
import os
import time
from typing import Callable, Mapping, Sequence

import numpy as np

from repro.core.candidates import CandidateLattice, Tile
from repro.core.cost_model import l0_analytical_cost, strategy_cost
from repro.core.hardware import HardwareSpec
from repro.core.rkernel import Strategy
from repro.core.workloads import Workload

__all__ = [
    "Profiler",
    "AnalyticalProfiler",
    "WallClockProfiler",
    "TableProfiler",
    "ScoredLattice",
    "StackedLattices",
    "HybridAnalyzer",
]


class Profiler:
    """Interface: measure the cost (seconds) of one layer-0 tile contraction."""

    name = "abstract"

    def measure_l0(self, tile: Tile, backend: str) -> float:
        raise NotImplementedError

    def measure_l1(self, tile: Tile, backend: str) -> float | None:
        """Optionally measure a whole layer-1 tile; ``None`` -> analytical."""
        return None


class AnalyticalProfiler(Profiler):
    """Pure-analytical stand-in (used when a layer is configured analytical)."""

    name = "analytical"

    def __init__(self, hw: HardwareSpec):
        self._hw = hw

    def measure_l0(self, tile: Tile, backend: str) -> float:
        return l0_analytical_cost(self._hw, tile, backend)


class TableProfiler(Profiler):
    """Calibrated-efficiency table for detached hardware (TPU in this box).

    Efficiency factors model the MXU pipeline: tiles below the native shape
    waste systolic slots; very deep k amortizes issue overhead.  The factors
    are calibration inputs, not measurements — they play the role the
    empirical leg plays on attached hardware and are swappable for real
    ``pallas_call`` timings on a pod.
    """

    name = "table"

    def __init__(self, hw: HardwareSpec):
        self._hw = hw

    def measure_l0(self, tile: Tile, backend: str) -> float:
        base = l0_analytical_cost(self._hw, tile, backend)
        bm, bn, bk = self._hw.native_tile[backend]
        m, n, k = tile
        # Occupancy of the systolic array within the padded issue.
        occ = min(m / max(bm, 1), 8.0) / max(1.0, np.ceil(m / bm))
        depth_bonus = 1.0 / (1.0 + 0.25 * (128.0 / max(k, 1)))
        eff = max(0.05, min(1.0, 0.6 + 0.05 * occ) * depth_bonus)
        return base / eff


class WallClockProfiler(Profiler):
    """Real wall-clock measurement of tile contractions on the host backend.

    Timings are cached (optionally on disk) so the offline stage stays in the
    tens-of-seconds regime the paper reports for Vortex, rather than the
    hours-to-days of sample-driven tuning.
    """

    name = "wallclock"

    def __init__(self, cache_path: str | None = None, repeats: int = 5):
        self._repeats = repeats
        self._cache_path = cache_path
        self._cache: dict[str, float] = {}
        if cache_path and os.path.exists(cache_path):
            with open(cache_path) as f:
                self._cache = json.load(f)

    def _key(self, tile: Tile, backend: str, level: int) -> str:
        return f"L{level}:{backend}:{tile[0]}x{tile[1]}x{tile[2]}"

    def _time_matmul(self, m: int, n: int, k: int) -> float:
        import jax
        import jax.numpy as jnp

        a = jnp.zeros((m, k), jnp.float32)
        b = jnp.zeros((k, n), jnp.float32)
        f = jax.jit(lambda x, y: x @ y)
        f(a, b).block_until_ready()  # compile + warm
        best = float("inf")
        for _ in range(self._repeats):
            t0 = time.perf_counter()
            f(a, b).block_until_ready()
            best = min(best, time.perf_counter() - t0)
        return best

    def _measure(self, tile: Tile, backend: str, level: int) -> float:
        key = self._key(tile, backend, level)
        if key not in self._cache:
            m, n, k = tile
            self._cache[key] = self._time_matmul(m, n, k)
            if self._cache_path:
                tmp = self._cache_path + ".tmp"
                with open(tmp, "w") as f:
                    json.dump(self._cache, f)
                os.replace(tmp, self._cache_path)
        return self._cache[key]

    def measure_l0(self, tile: Tile, backend: str) -> float:
        return self._measure(tile, backend, 0)

    def measure_l1(self, tile: Tile, backend: str) -> float:
        return self._measure(tile, backend, 1)


@dataclasses.dataclass(frozen=True)
class ScoredLattice:
    """Analyzer output: layer-1 candidates with per-tile costs, ready for the
    vectorized runtime selector (numpy arrays, no Python loops at runtime).
    """

    backend: str
    l1_tiles: np.ndarray  # (C, 3) int64
    l1_costs: np.ndarray  # (C,) seconds per layer-1 tile
    best_l0: tuple[Tile, ...]  # chosen layer-0 child per layer-1 tile
    analyze_seconds: float
    num_measured: int

    def strategy_for(self, idx: int) -> Strategy:
        l1 = tuple(int(x) for x in self.l1_tiles[idx])
        return Strategy(tiles=(self.best_l0[idx], l1), backend=self.backend)


@dataclasses.dataclass(frozen=True)
class StackedLattices:
    """All backends' scored lattices fused into flat candidate arrays.

    The runtime selector and the offline selection-table builder both want
    ONE numpy cost evaluation over the whole multi-backend strategy space
    (the per-tile costs already encode each backend's level-0/1 behaviour),
    so the per-backend ScoredLattices are concatenated once here and indexed
    by a single global candidate id.  Backend order follows the mapping
    order, so argmin tie-breaking is deterministic.
    """

    backends: tuple[str, ...]
    scored: tuple[ScoredLattice, ...]
    l1_tiles: np.ndarray  # (C, 3) int64, backends concatenated in order
    l1_costs: np.ndarray  # (C,) seconds per layer-1 tile
    backend_idx: np.ndarray  # (C,) int64: candidate -> backends index
    offsets: tuple[int, ...]  # per-backend start offset into the flat arrays

    @classmethod
    def stack(cls, scored: Mapping[str, ScoredLattice]) -> "StackedLattices":
        if not scored:
            raise ValueError("need at least one scored lattice")
        backends = tuple(scored)
        sls = tuple(scored[b] for b in backends)
        offsets, acc = [], 0
        for sl in sls:
            offsets.append(acc)
            acc += sl.l1_costs.shape[0]
        return cls(
            backends=backends,
            scored=sls,
            l1_tiles=np.concatenate([sl.l1_tiles for sl in sls], axis=0),
            l1_costs=np.concatenate([sl.l1_costs for sl in sls], axis=0),
            backend_idx=np.concatenate(
                [
                    np.full(sl.l1_costs.shape[0], i, np.int64)
                    for i, sl in enumerate(sls)
                ]
            ),
            offsets=tuple(offsets),
        )

    @property
    def num_candidates(self) -> int:
        return int(self.l1_costs.shape[0])

    def backend_of(self, idx: int) -> str:
        return self.backends[int(self.backend_idx[idx])]

    def strategy_for(self, idx: int) -> Strategy:
        b = int(self.backend_idx[idx])
        return self.scored[b].strategy_for(int(idx) - self.offsets[b])

    def dynamic_periods(self, axes: Sequence[int]) -> tuple[int, ...]:
        """Distinct l1 extents along the dynamic tile axes, across ALL
        backends — the periods at which any candidate's grid cost ticks."""
        return tuple(
            sorted({int(t) for ax in axes for t in self.l1_tiles[:, ax]})
        )


class HybridAnalyzer:
    """Score a candidate lattice with the hybrid empirical/analytical split.

    ``empirical_levels`` mirrors the paper's per-platform defaults (Table 7):
    ``(0,)`` for CPU, ``(0, 1)`` for GPU/TPU-style targets.
    """

    def __init__(
        self,
        hw: HardwareSpec,
        wl: Workload,
        profiler: Profiler | None = None,
        empirical_levels: Sequence[int] = (0,),
    ):
        self._hw = hw
        self._wl = wl
        self._profiler = profiler or AnalyticalProfiler(hw)
        self._empirical_levels = tuple(empirical_levels)

    def _l0_cost(self, tile: Tile, backend: str) -> float:
        if 0 in self._empirical_levels:
            return self._profiler.measure_l0(tile, backend)
        return l0_analytical_cost(self._hw, tile, backend)

    def score(self, lattice: CandidateLattice) -> ScoredLattice:
        """For every layer-1 candidate, pick its cheapest layer-0 child and
        record the layer-1 per-tile cost (Eq. 2 composition, or an empirical
        layer-1 measurement when level 1 is configured empirical)."""
        t0 = time.perf_counter()
        backend = lattice.backend
        l0_cost_cache: dict[Tile, float] = {}
        measured = 0

        tiles: list[Tile] = []
        costs: list[float] = []
        best_children: list[Tile] = []
        for l1 in lattice.l1:
            children = lattice.children[1][l1]
            best_c, best_child = float("inf"), children[0]
            for child in children:
                if child not in l0_cost_cache:
                    l0_cost_cache[child] = self._l0_cost(child, backend)
                    measured += 1
                strat = Strategy(tiles=(child, l1), backend=backend)
                # Cost of ONE layer-1 tile: evaluate the recursion at a shape
                # equal to the tile itself (grid = 1x1x1).
                bd = strategy_cost(
                    self._hw,
                    self._wl,
                    strat,
                    cost_l0=l0_cost_cache[child],
                    dims=(int(l1[0]), int(l1[1]), int(l1[2])),
                )
                if bd.l1_per_tile < best_c:
                    best_c, best_child = bd.l1_per_tile, child
            if 1 in self._empirical_levels:
                emp = self._profiler.measure_l1(l1, backend)
                if emp is not None:
                    best_c = emp
                    measured += 1
            tiles.append(l1)
            costs.append(best_c)
            best_children.append(best_child)

        return ScoredLattice(
            backend=backend,
            l1_tiles=np.asarray(tiles, np.int64),
            l1_costs=np.asarray(costs, np.float64),
            best_l0=tuple(best_children),
            analyze_seconds=time.perf_counter() - t0,
            num_measured=measured,
        )
