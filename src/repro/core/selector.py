"""Runtime strategy selection and kernel construction (paper §6.2).

At runtime the shape becomes known.  The selector returns the winning
strategy plus launch geometry — the candidate evaluation uses the
*analytical* grid-level model (including padding waste) over the pre-scored
lattices of every compute backend (MXU vs VPU here; Tensor vs CUDA core in
the paper, Fig. 16).

The serving hot path is CONSTANT TIME: because the cost of every candidate
is piecewise constant in M between lattice breakpoints, the whole decision
for all M <= table.m_max is materialized offline into a sorted
breakpoint table (selection_table.py) and served by a bisect — O(log B),
zero numpy, zero allocation, covering unseen shapes as cheaply as repeated
ones.  Beyond the table, selection falls back to a fused multi-backend
numpy argmin (all backends' candidates stacked into one evaluation — no
per-backend Python loop) and the table extends itself by doubling, so a
growing stream pays O(log m) rebuilds, amortized to nothing.

A small LRU remains for extents past the extension limit; ``SelectorStats``
accounts table hits, LRU hits and argmin misses separately so the Fig. 14
overhead numbers stay meaningful.
"""
from __future__ import annotations

import collections
import dataclasses
import math
import time
from typing import Mapping

import numpy as np

from repro.core.analyzer import ScoredLattice, StackedLattices
from repro.core.cost_model import runtime_costs
from repro.core.hardware import HardwareSpec
from repro.core.rkernel import Strategy
from repro.core.selection_table import SelectionTable, build_selection_table
from repro.core.workloads import Workload

__all__ = ["Selection", "RuntimeSelector", "SelectorStats"]


@dataclasses.dataclass(frozen=True)
class Selection:
    """A constructed kernel for one runtime shape.

    ``bucket`` is the executable-cache key shape: padding is confined to the
    dynamic dims and only up to the lattice tile, while static dims keep
    their TRUE extents (they are never padded at the bucket level) — the
    sample-free bucketing induced by the candidate lattice (DESIGN.md §4).

    ``select_seconds`` is the argmin-path scheduling overhead that produced
    this object; table-materialized selections carry 0.0 (their cost was
    paid once offline — per-serve accounting lives in SelectorStats).
    """

    strategy: Strategy
    backend: str
    grid: tuple[int, int, int]            # (gm, gn, gk) launch geometry
    padded_m: int                          # dynamic dim rounded to l1 m-tile
    bucket: tuple[int, int, int]           # executable-cache key shape
    predicted_cost: float                  # seconds (analytical)
    select_seconds: float                  # argmin overhead (0.0 from table)


@dataclasses.dataclass
class SelectorStats:
    """Runtime-overhead accounting for the serving path (Fig. 14).

    Every serve is exactly one of: a table hit (bisect, constant time), an
    LRU hit (dict lookup), or an argmin miss (fused numpy evaluation).
    ``select_seconds`` accumulates ONLY argmin time, so ``mean_select_us``
    is the true per-miss cost — a cached selection no longer re-reports the
    stale latency of its original miss.
    """

    selects: int = 0
    table_hits: int = 0
    lru_hits: int = 0
    argmin_misses: int = 0
    select_seconds: float = 0.0          # argmin-path time only
    table_builds: int = 0
    table_build_seconds: float = 0.0
    # Background-calibration accounting (core/calibrate.py): wall-clock
    # spent measuring/refitting on behalf of this selector, and how many
    # times a rebuilt table was atomically swapped in.  Off the serving
    # path entirely — the hot-path counters above never include these.
    calibration_seconds: float = 0.0
    table_swaps: int = 0

    @property
    def cache_hits(self) -> int:
        """Serves that skipped the argmin entirely (table + LRU)."""
        return self.table_hits + self.lru_hits

    @property
    def mean_select_us(self) -> float:
        return (
            self.select_seconds / self.argmin_misses * 1e6
            if self.argmin_misses else 0.0
        )


class RuntimeSelector:
    """Select strategies for runtime shapes from pre-scored lattices.

    ``scored`` maps backend name -> ScoredLattice; the lattices are stacked
    into one fused candidate array at construction.  ``num_cores`` is the
    number of level-2 units the kernel may occupy (per-shard TensorCores).

    ``table_m_max`` sizes the offline-materialized selection table (0
    disables it: pure argmin + LRU, used by equivalence tests and as the
    behaviour past ``table_extend_limit``).  ``cache_size`` bounds the LRU
    that backs extents the table does not cover.
    """

    def __init__(
        self,
        hw: HardwareSpec,
        wl: Workload,
        scored: Mapping[str, ScoredLattice],
        num_cores: int = 1,
        cache_size: int = 4096,
        table_m_max: int = 4096,
        table_extend_limit: int = 1 << 17,
    ):
        if not scored:
            raise ValueError("need at least one scored lattice")
        self._hw = hw
        self._wl = wl
        self._scored = dict(scored)
        self._stacked = StackedLattices.stack(self._scored)
        self._num_cores = num_cores
        self._cache: collections.OrderedDict[int, Selection] = (
            collections.OrderedDict()
        )
        self._cache_size = cache_size
        self._table_m_max = table_m_max
        self._table_extend_limit = table_extend_limit
        self.stats = SelectorStats()
        # Calibration state (core/calibrate.py): a per-candidate cost
        # multiplier and measured-bucket winner pins.  Both None/empty by
        # default — the analytical sweep runs bit-identically — and only
        # replaced through install_table(), so doubling extensions rebuild
        # with the SAME refined model the installed table was built from.
        self._cost_scale: np.ndarray | None = None
        self._pinned: dict[int, int] = {}
        # Built lazily on first use: throwaway selectors (benchmarks,
        # analysis scripts) shouldn't pay the breakpoint sweep up front.
        self._table: SelectionTable | None = None

    @property
    def workload(self) -> Workload:
        return self._wl

    @property
    def scored(self) -> dict[str, ScoredLattice]:
        """The per-backend scored lattices this selector serves from."""
        return dict(self._scored)

    @property
    def table(self) -> SelectionTable | None:
        """The materialized selection table (built on first access; None
        when disabled via ``table_m_max=0``)."""
        if self._table is None and self._table_m_max > 0:
            self._table = self._build_table(self._table_m_max)
        return self._table

    @property
    def table_if_built(self) -> SelectionTable | None:
        """The installed table WITHOUT triggering the lazy build — what
        introspection (engine stats) should read, so reporting never
        charges a sweep to an idle selector."""
        return self._table

    @property
    def stacked(self) -> StackedLattices:
        """The fused multi-backend candidate stack (what the background
        calibrator ranks, measures and refits over)."""
        return self._stacked

    # -- offline table ------------------------------------------------------

    def _build_table(self, m_max: int) -> SelectionTable:
        table = build_selection_table(
            self._hw, self._wl, self._stacked, m_max, self._num_cores,
            cost_scale=self._cost_scale, pinned=self._pinned or None,
        )
        self.stats.table_builds += 1
        self.stats.table_build_seconds += table.build_seconds
        return table

    def _table_covering(self, m_max: int) -> SelectionTable:
        """A table covering [1, m_max], extending the installed one by
        doubling when enabled; transient when the table is disabled."""
        table = self.table  # materializes the initial table when enabled
        if table is None:
            return self._build_table(m_max)
        if table.m_max >= m_max:
            return table
        new_max = table.m_max
        while new_max < m_max:
            new_max *= 2
        self._table = self._build_table(new_max)
        return self._table

    # -- runtime selection ---------------------------------------------------

    def select(self, m_runtime: int) -> Selection:
        """Pick the (backend, strategy) minimizing predicted cost at M.

        Hot path: bisect into the materialized table.  Fallbacks: LRU, then
        the fused argmin (which also triggers a doubling table extension so
        the NEXT unseen extent of this magnitude is a table hit).
        """
        stats = self.stats
        stats.selects += 1
        table = self.table  # materializes on the first select
        # covers() also rejects m < 1: degenerate (empty) extents take the
        # argmin path, which prices them exactly (grid 0, zero cost).
        if table is not None and table.covers(m_runtime):
            stats.table_hits += 1
            return table.lookup(m_runtime)
        cached = self._cache.get(m_runtime)
        if cached is not None:
            self._cache.move_to_end(m_runtime)
            stats.lru_hits += 1
            return cached
        sel = self._select_argmin(m_runtime)
        stats.argmin_misses += 1
        stats.select_seconds += sel.select_seconds
        self._cache[m_runtime] = sel
        if len(self._cache) > self._cache_size:
            self._cache.popitem(last=False)
        if (
            table is not None
            and table.m_max < m_runtime <= self._table_extend_limit
        ):
            self._table_covering(m_runtime)
        return sel

    def _select_argmin(self, m_runtime: int) -> Selection:
        """One fused numpy evaluation over ALL backends' candidates.

        Applies the installed calibration scale (if any) so the beyond-
        table fallback and doubling extensions stay consistent with the
        calibrated table contents; winner pins are table-only (they live
        inside the calibrated coverage by construction).
        """
        t0 = time.perf_counter()
        st = self._stacked
        costs = runtime_costs(
            self._hw, self._wl, st.l1_tiles, st.l1_costs,
            m_runtime, self._num_cores, self._cost_scale,
        )
        idx = int(np.argmin(costs))
        strategy = st.strategy_for(idx)
        m1, n1, k1 = strategy.l1
        M, N, K = self._wl.runtime_dims(m_runtime)
        grid = (
            math.ceil(M / m1),
            math.ceil(N / n1),
            math.ceil(K / k1),
        )
        return Selection(
            strategy=strategy,
            backend=st.backend_of(idx),
            grid=grid,
            padded_m=grid[0] * m1,
            bucket=self._wl.bucket_dims(grid, strategy.l1),
            predicted_cost=float(costs[idx]),
            select_seconds=time.perf_counter() - t0,
        )

    def select_excluding(
        self, m_runtime: int, excluded, keyfn
    ) -> Selection | None:
        """Cheapest candidate at ``m_runtime`` whose ``keyfn(Selection)``
        is NOT in ``excluded`` — the degradation ladder's re-selection
        (core/engine.py).  Walks candidates in scaled-cost order off the
        hot path (one fused cost evaluation, Selections built only until
        the first healthy candidate); returns ``None`` when every
        candidate is quarantined, which sends the ladder to the XLA
        reference rung."""
        t0 = time.perf_counter()
        st = self._stacked
        costs = runtime_costs(
            self._hw, self._wl, st.l1_tiles, st.l1_costs,
            m_runtime, self._num_cores, self._cost_scale,
        )
        M, N, K = self._wl.runtime_dims(m_runtime)
        for idx in np.argsort(costs, kind="stable"):
            idx = int(idx)
            strategy = st.strategy_for(idx)
            m1, n1, k1 = strategy.l1
            grid = (
                math.ceil(M / m1),
                math.ceil(N / n1),
                math.ceil(K / k1),
            )
            sel = Selection(
                strategy=strategy,
                backend=st.backend_of(idx),
                grid=grid,
                padded_m=grid[0] * m1,
                bucket=self._wl.bucket_dims(grid, strategy.l1),
                predicted_cost=float(costs[idx]),
                select_seconds=time.perf_counter() - t0,
            )
            if keyfn(sel) not in excluded:
                return sel
        return None

    # -- calibration surface (core/calibrate.py) -----------------------------

    def candidate_selection(self, idx: int, m_runtime: int) -> Selection:
        """The Selection candidate ``idx`` (stacked index) would serve at
        extent ``m_runtime`` — what the calibrator builds executables for
        when timing non-winning candidates.  ``predicted_cost`` is the
        UNSCALED analytical cost; ``select_seconds`` is 0."""
        st = self._stacked
        strategy = st.strategy_for(idx)
        m1, n1, k1 = strategy.l1
        M, N, K = self._wl.runtime_dims(m_runtime)
        grid = (
            math.ceil(M / m1),
            math.ceil(N / n1),
            math.ceil(K / k1),
        )
        return Selection(
            strategy=strategy,
            backend=st.backend_of(idx),
            grid=grid,
            padded_m=grid[0] * m1,
            bucket=self._wl.bucket_dims(grid, strategy.l1),
            predicted_cost=float(self.candidate_costs(m_runtime)[idx]),
            select_seconds=0.0,
        )

    def candidate_costs(self, m_runtime: int) -> np.ndarray:
        """(C,) UNSCALED analytical costs at ``m_runtime`` — the paper's
        Eq. 2-4 ranking the calibrator takes its top-K from."""
        st = self._stacked
        return runtime_costs(
            self._hw, self._wl, st.l1_tiles, st.l1_costs,
            m_runtime, self._num_cores,
        )

    def rank_candidates(self, m_runtime: int, k: int) -> list[int]:
        """Indices of the ``k`` analytically-cheapest candidates at
        ``m_runtime``, cheapest first (the calibrator's measurement set)."""
        costs = self.candidate_costs(m_runtime)
        k = min(max(int(k), 1), costs.shape[0])
        top = np.argpartition(costs, k - 1)[:k]
        return [int(i) for i in top[np.argsort(costs[top])]]

    def build_calibrated_table(
        self,
        m_max: int | None = None,
        cost_scale: np.ndarray | None = None,
        pinned: Mapping[int, int] | None = None,
    ) -> SelectionTable:
        """Build (OFFLINE — nothing installed, serving untouched) a table
        from the refined model: per-candidate ``cost_scale`` multipliers
        plus measured-bucket winner ``pinned`` overrides."""
        table = self.table
        m_max = m_max if m_max is not None else (
            table.m_max if table is not None else self._table_m_max or 1
        )
        built = build_selection_table(
            self._hw, self._wl, self._stacked, m_max, self._num_cores,
            cost_scale=cost_scale,
            pinned=dict(pinned) if pinned else None,
        )
        self.stats.table_builds += 1
        self.stats.table_build_seconds += built.build_seconds
        return built

    def install_table(
        self,
        table: SelectionTable,
        *,
        cost_scale: np.ndarray | None = None,
        pinned: Mapping[int, int] | None = None,
        calibration_seconds: float = 0.0,
    ) -> None:
        """ATOMICALLY swap a fully-built table into the serving hot path.

        The swap protocol (DESIGN.md §10): install the refined model first
        (so the argmin fallback and any future doubling extension rebuild
        consistently), drop the LRU (its entries priced the old model),
        then publish the table with ONE reference assignment — readers go
        through a single ``self._table`` load per select, and
        SelectionTable is frozen, so there is no torn state to observe:
        every concurrent select sees entirely the old table or entirely
        the new one.  The bisect lookup itself is byte-for-byte untouched.
        """
        if not table.starts or table.starts[0] != 1:
            raise ValueError("selection table must cover extents from 1")
        self._cost_scale = (
            None if cost_scale is None
            else np.asarray(cost_scale, np.float64)
        )
        self._pinned = dict(pinned) if pinned else {}
        self._cache.clear()
        self._table = table  # the atomic publish
        self.stats.table_swaps += 1
        self.stats.calibration_seconds += calibration_seconds

    # -- sample-free precompilation set --------------------------------------

    def selections_upto(self, m_max: int) -> list[Selection]:
        """One representative Selection per distinct outcome reachable for M
        in [1, m_max] — the finite, sample-free precompilation set.

        Shared machinery with the serving table: the breakpoint sweep
        already materializes one Selection per cost-constant interval
        (divisor-free heap merge of the dynamic periods — no O(m_max)
        range-set enumeration), so this is a dedupe over the table entries
        by executable-relevant identity (bucket + strategy + backend).
        """
        table = self._table_covering(m_max)
        seen: set[tuple] = set()
        out: list[Selection] = []
        for start, sel in zip(table.starts, table.entries):
            if start > m_max:
                break
            key = (sel.bucket, sel.strategy.tiles, sel.backend)
            if key not in seen:
                seen.add(key)
                out.append(sel)
        return out

    def buckets_upto(self, m_max: int) -> list[int]:
        """All distinct padded dynamic-extent buckets the selector can emit
        for M in [1, m_max] (``Workload.dynamic_bucket``: padded_m for
        GEMM-view workloads, the kv bucket for decode attention)."""
        return sorted({
            self._wl.dynamic_bucket(s) for s in self.selections_upto(m_max)
        })
