"""Runtime strategy selection and kernel construction (paper §6.2).

At runtime the shape becomes known.  The selector evaluates the (small,
pre-scored) candidate lattice with the *analytical* grid-level model —
including the padding-waste that a given layer-1 tile implies for this shape
— and returns the winning strategy plus launch geometry.  When multiple
compute backends exist (MXU vs VPU here; Tensor vs CUDA core in the paper),
the selector compares their best candidates and routes adaptively (Fig. 16).

Selection is pure numpy over precomputed arrays: the overhead budget is the
microseconds regime of the paper's Fig. 14.
"""
from __future__ import annotations

import dataclasses
import math
import time
from typing import Mapping, Sequence

import numpy as np

from repro.core.analyzer import ScoredLattice
from repro.core.cost_model import gemm_runtime_costs
from repro.core.hardware import HardwareSpec
from repro.core.rkernel import GemmWorkload, Strategy

__all__ = ["Selection", "RuntimeSelector"]


@dataclasses.dataclass(frozen=True)
class Selection:
    """A constructed kernel for one runtime shape."""

    strategy: Strategy
    backend: str
    grid: tuple[int, int, int]            # (gm, gn, gk) launch geometry
    padded_m: int                          # M rounded up to the l1 m-tile
    predicted_cost: float                  # seconds (analytical)
    select_seconds: float                  # runtime scheduling overhead

    @property
    def bucket(self) -> tuple[int, int, int]:
        """The executable-cache key shape: padding is confined to M (the
        dynamic dim) and only up to the lattice tile — the sample-free
        bucketing induced by the candidate lattice (DESIGN.md §2)."""
        m1, n1, k1 = self.strategy.l1
        return (self.padded_m, self.grid[1] * n1, self.grid[2] * k1)


class RuntimeSelector:
    """Select strategies for runtime shapes from pre-scored lattices.

    ``scored`` maps backend name -> ScoredLattice.  ``num_cores`` is the
    number of level-2 units the kernel may occupy (per-shard TensorCores).
    """

    def __init__(
        self,
        hw: HardwareSpec,
        wl: GemmWorkload,
        scored: Mapping[str, ScoredLattice],
        num_cores: int = 1,
    ):
        if not scored:
            raise ValueError("need at least one scored lattice")
        self._hw = hw
        self._wl = wl
        self._scored = dict(scored)
        self._num_cores = num_cores
        self._cache: dict[int, Selection] = {}

    def select(self, m_runtime: int) -> Selection:
        """Pick the (backend, strategy) minimizing predicted cost at M."""
        if m_runtime in self._cache:
            return self._cache[m_runtime]
        t0 = time.perf_counter()
        best: tuple[float, str, int] | None = None
        for backend, sl in self._scored.items():
            costs = gemm_runtime_costs(
                self._hw, self._wl, sl.l1_tiles, sl.l1_costs,
                m_runtime, self._num_cores,
            )
            idx = int(np.argmin(costs))
            cand = (float(costs[idx]), backend, idx)
            if best is None or cand[0] < best[0]:
                best = cand
        assert best is not None
        cost, backend, idx = best
        sl = self._scored[backend]
        strategy = sl.strategy_for(idx)
        m1, n1, k1 = strategy.l1
        grid = (
            math.ceil(m_runtime / m1),
            math.ceil(self._wl.N / n1),
            math.ceil(self._wl.K / k1),
        )
        sel = Selection(
            strategy=strategy,
            backend=backend,
            grid=grid,
            padded_m=grid[0] * m1,
            predicted_cost=cost,
            select_seconds=time.perf_counter() - t0,
        )
        self._cache[m_runtime] = sel
        return sel

    def buckets_upto(self, m_max: int) -> list[int]:
        """All distinct padded-M buckets the selector can emit for M in
        [1, m_max] — the finite, sample-free precompilation set for serving.
        """
        out = set()
        for m in range(1, m_max + 1):
            out.add(self.select(m).padded_m)
        return sorted(out)
