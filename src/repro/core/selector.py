"""Runtime strategy selection and kernel construction (paper §6.2).

At runtime the shape becomes known.  The selector evaluates the (small,
pre-scored) candidate lattice with the *analytical* grid-level model —
including the padding-waste that a given layer-1 tile implies for this shape
— and returns the winning strategy plus launch geometry.  When multiple
compute backends exist (MXU vs VPU here; Tensor vs CUDA core in the paper),
the selector compares their best candidates and routes adaptively (Fig. 16).

Selection is pure numpy over precomputed arrays: the overhead budget is the
microseconds regime of the paper's Fig. 14.  The per-shape cache is
LRU-bounded so long-running serving processes don't grow it without limit,
and the sample-free precompilation set (``buckets_upto``) is derived from
the lattice's distinct dynamic tile extents rather than by selecting every
shape in range.
"""
from __future__ import annotations

import collections
import dataclasses
import math
import time
from typing import Mapping

import numpy as np

from repro.core.analyzer import ScoredLattice
from repro.core.cost_model import runtime_costs
from repro.core.hardware import HardwareSpec
from repro.core.rkernel import Strategy
from repro.core.workloads import Workload

__all__ = ["Selection", "RuntimeSelector", "SelectorStats"]


@dataclasses.dataclass(frozen=True)
class Selection:
    """A constructed kernel for one runtime shape.

    ``bucket`` is the executable-cache key shape: padding is confined to the
    dynamic dims and only up to the lattice tile, while static dims keep
    their TRUE extents (they are never padded at the bucket level) — the
    sample-free bucketing induced by the candidate lattice (DESIGN.md §4).
    """

    strategy: Strategy
    backend: str
    grid: tuple[int, int, int]            # (gm, gn, gk) launch geometry
    padded_m: int                          # dynamic dim rounded to l1 m-tile
    bucket: tuple[int, int, int]           # executable-cache key shape
    predicted_cost: float                  # seconds (analytical)
    select_seconds: float                  # runtime scheduling overhead


@dataclasses.dataclass
class SelectorStats:
    """Runtime-overhead accounting for the serving path (Fig. 14)."""

    selects: int = 0
    cache_hits: int = 0
    select_seconds: float = 0.0

    @property
    def mean_select_us(self) -> float:
        misses = self.selects - self.cache_hits
        return (self.select_seconds / misses * 1e6) if misses else 0.0


class RuntimeSelector:
    """Select strategies for runtime shapes from pre-scored lattices.

    ``scored`` maps backend name -> ScoredLattice.  ``num_cores`` is the
    number of level-2 units the kernel may occupy (per-shard TensorCores).
    ``cache_size`` bounds the per-shape LRU selection cache.
    """

    def __init__(
        self,
        hw: HardwareSpec,
        wl: Workload,
        scored: Mapping[str, ScoredLattice],
        num_cores: int = 1,
        cache_size: int = 4096,
    ):
        if not scored:
            raise ValueError("need at least one scored lattice")
        self._hw = hw
        self._wl = wl
        self._scored = dict(scored)
        self._num_cores = num_cores
        self._cache: collections.OrderedDict[int, Selection] = (
            collections.OrderedDict()
        )
        self._cache_size = cache_size
        self.stats = SelectorStats()

    @property
    def workload(self) -> Workload:
        return self._wl

    def select(self, m_runtime: int) -> Selection:
        """Pick the (backend, strategy) minimizing predicted cost at M."""
        self.stats.selects += 1
        cached = self._cache.get(m_runtime)
        if cached is not None:
            self._cache.move_to_end(m_runtime)
            self.stats.cache_hits += 1
            return cached
        t0 = time.perf_counter()
        best: tuple[float, str, int] | None = None
        for backend, sl in self._scored.items():
            costs = runtime_costs(
                self._hw, self._wl, sl.l1_tiles, sl.l1_costs,
                m_runtime, self._num_cores,
            )
            idx = int(np.argmin(costs))
            cand = (float(costs[idx]), backend, idx)
            if best is None or cand[0] < best[0]:
                best = cand
        assert best is not None
        cost, backend, idx = best
        sl = self._scored[backend]
        strategy = sl.strategy_for(idx)
        m1, n1, k1 = strategy.l1
        M, N, K = self._wl.runtime_dims(m_runtime)
        grid = (
            math.ceil(M / m1),
            math.ceil(N / n1),
            math.ceil(K / k1),
        )
        dt = time.perf_counter() - t0
        sel = Selection(
            strategy=strategy,
            backend=backend,
            grid=grid,
            padded_m=grid[0] * m1,
            bucket=self._wl.bucket_dims(grid, strategy.l1),
            predicted_cost=cost,
            select_seconds=dt,
        )
        self.stats.select_seconds += dt
        self._cache[m_runtime] = sel
        if len(self._cache) > self._cache_size:
            self._cache.popitem(last=False)
        return sel

    def _dynamic_periods(self) -> set[int]:
        """Distinct l1 extents along the workload's dynamic tile axes."""
        periods: set[int] = set()
        for sl in self._scored.values():
            for axis in self._wl.dynamic_tile_axes:
                periods.update(int(t) for t in sl.l1_tiles[:, axis])
        return periods

    def selections_upto(self, m_max: int) -> list[Selection]:
        """One representative Selection per distinct outcome reachable for M
        in [1, m_max] — the finite, sample-free precompilation set.

        The vectorized cost of every candidate is piecewise constant in M:
        it changes only where some ceil(M / t) ticks over, i.e. just past a
        multiple of a dynamic tile extent ``t`` in the lattice.  So instead
        of selecting all m_max shapes (O(m_max) selections), select only one
        representative per constant interval — the interval's right endpoint
        (multiples of the distinct tile extents, clipped at m_max) — and
        dedupe by the executable-relevant identity (bucket + strategy +
        backend).  Every runtime M <= m_max lands in some interval, whose
        representative produced the identical selection.
        """
        points: set[int] = {m_max}
        for t in self._dynamic_periods():
            points.update(range(t, m_max + 1, t))
        seen: set[tuple] = set()
        out: list[Selection] = []
        for p in sorted(points):
            s = self.select(p)
            key = (s.bucket, s.strategy.tiles, s.backend)
            if key not in seen:
                seen.add(key)
                out.append(s)
        return out

    def buckets_upto(self, m_max: int) -> list[int]:
        """All distinct padded-M buckets the selector can emit for M in
        [1, m_max]."""
        return sorted({s.padded_m for s in self.selections_upto(m_max)})
