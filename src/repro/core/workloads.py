"""Workload protocol + registry: the workload-generic face of the pipeline.

The paper's central claim (§4) is that ONE hardware-hierarchized strategy
space serves *all* dynamic-shape tensor programs.  This module is where a
tensor program declares everything the pipeline needs to know about it:

  * its axes and which of them are dynamic (unknown until runtime),
  * its rKernel program (rkernel.py metadata, per hardware level),
  * its per-tile footprint / FLOP / traffic model (consumed by the candidate
    generator's ``InitCands`` capacity checks and by the Eq. 2-4 cost model),
  * how a runtime shape maps onto the (m, n, k) contraction view, and
  * a backend-kernel builder that turns a runtime :class:`Selection` into an
    executable (XLA or Pallas).

``generate_lattice`` (candidates.py), :class:`HybridAnalyzer` (analyzer.py),
``runtime_costs`` (cost_model.py), :class:`RuntimeSelector` (selector.py) and
the bucketed executable cache (engine.py) all operate on this protocol, so
registering a new workload here is the ONLY step needed to route it through
the sample-free pipeline end to end (DESIGN.md §3).

The registered workloads:

  * :class:`GemmWorkload`        — C[M,N] = A[M,K] @ B[K,N], dynamic M,
  * :class:`GroupedGemmWorkload` — ragged batched GEMM over a shared expert
    weight stack (MoE FFN), dynamic capacity with PER-GROUP runtime extents,
  * :class:`AttentionWorkload`   — flash attention, dynamic sequence length
    (both GEMMs of attention share the seq-tiled lattice: the l1 m-tile is
    the query block, the l1 k-tile the key/value block),
  * :class:`DecodeAttentionWorkload` — single-token decode against a
    kv-bucketed cache (shares the attention lattice),
  * :class:`Conv2dWorkload`      — Conv2D through the im2col GEMM view,
    dynamic batch/spatial (M = b*h'*w').
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, ClassVar, Mapping

import numpy as np

from repro.core.hardware import HardwareSpec
from repro.core.rkernel import (
    AnalyzeType,
    LayerMetaInfo,
    LoopType,
    RKernelProgram,
)

__all__ = [
    "Workload",
    "GemmWorkload",
    "GroupedGemmWorkload",
    "AttentionWorkload",
    "DecodeAttentionWorkload",
    "Conv2dWorkload",
    "SelectionDeviationError",
    "WORKLOADS",
    "register_workload",
    "make_workload",
]

Tile = tuple[int, int, int]

# kind -> workload class; the single registry the engine serves from.
WORKLOADS: dict[str, type["Workload"]] = {}


def register_workload(cls: type["Workload"]) -> type["Workload"]:
    """Class decorator: expose a workload to the engine by its ``kind``."""
    if not cls.kind:
        raise ValueError(f"{cls.__name__} must set a non-empty `kind`")
    WORKLOADS[cls.kind] = cls
    return cls


def make_workload(kind: str, **kwargs: Any) -> "Workload":
    try:
        cls = WORKLOADS[kind]
    except KeyError:
        raise KeyError(
            f"unknown workload {kind!r}; registered: {sorted(WORKLOADS)}"
        ) from None
    return cls(**kwargs)


def _make_program(
    hw: HardwareSpec, kind: str, funcs: Mapping[int, tuple[str, str, str]]
) -> RKernelProgram:
    """Shared rKernel skeleton (paper Fig. 10): PL loops at the top level,
    TSL below, TRL on k everywhere; empirical analyzer only at level 0."""
    layers = []
    for depth in range(hw.num_levels):
        load, store, compute = funcs.get(depth, ("", "", ""))
        layers.append(
            LayerMetaInfo(
                layer_depth=depth,
                loop_type={
                    "m": LoopType.PARALLEL if depth == hw.num_levels - 1
                    else LoopType.TEMPORAL_SPATIAL,
                    "n": LoopType.PARALLEL if depth == hw.num_levels - 1
                    else LoopType.TEMPORAL_SPATIAL,
                    "k": LoopType.TEMPORAL_REDUCTION,
                },
                analyzer=AnalyzeType.EMPIRICAL if depth == 0
                else AnalyzeType.ANALYTICAL,
                load_func=load,
                store_func=store,
                compute_func=compute,
            )
        )
    return RKernelProgram(kind=kind, layers=tuple(layers), hardware=hw.name)


class SelectionDeviationError(RuntimeError):
    """An executable would have to deviate from its Selection to run.

    The masked-tail kernels honor the selected layer-1 tile verbatim (tails
    are masked in-kernel, never clamped), so the only way a Selection can
    fail to be honored is an internal inconsistency — e.g. a bucket that is
    not a multiple of its own tile.  Raising beats silently running a tile
    the cost model never priced.
    """


def _check_bucket_tiles(kind: str, sel, pairs) -> None:
    """Every (bucket extent, tile) pair must divide exactly — the staged
    buffers are bucket-shaped, so a non-dividing tile would force the grid
    to deviate from the priced launch geometry."""
    for name, extent, tile in pairs:
        if tile < 1 or extent % tile:
            raise SelectionDeviationError(
                f"{kind}: bucket {name}={extent} is not a multiple of the "
                f"selected l1 tile {tile} (strategy l1={sel.strategy.l1}, "
                f"bucket={sel.bucket}); refusing to clamp the tile"
            )


@dataclasses.dataclass(frozen=True)
class Workload:
    """Protocol base.  A workload is viewed through its (m, n, k) contraction:
    ``m`` is the (single) dynamic extent; ``n``/``k`` may be static (GEMM,
    conv) or tied to the dynamic extent (attention's key length).

    Subclasses override the hooks below; the defaults encode the plain-GEMM
    behaviour so GEMM-like workloads (conv) stay thin.
    """

    kind: ClassVar[str] = ""
    axis_names: ClassVar[tuple[str, ...]] = ("m", "n", "k")
    # Which tile axes scale with the dynamic extent at runtime.  The selector
    # uses this to enumerate grid breakpoints sample-free (buckets_upto).
    dynamic_tile_axes: ClassVar[tuple[int, ...]] = (0,)

    # ---- call-site binding (registry-driven ops) --------------------------
    # These two classmethods are what makes ``repro.vortex.ops.<kind>``
    # work with no engine edits: the engine resolves a call site entirely
    # through the registry — ``dispatch_key`` gives the raw-tuple hot-path
    # key (ints/flags straight off the arrays, no dataclass construction),
    # ``bind`` constructs the Workload instance on the first call per key.

    @classmethod
    def bind(cls, *args: Any, **kwargs: Any) -> "Workload":
        """Construct the workload instance implied by a call site: runtime
        arrays in ``args`` (what the executable consumes), workload
        parameters in ``kwargs`` (masking flags, strides, ...)."""
        raise NotImplementedError(
            f"{cls.__name__} does not define bind(); it cannot be called "
            "through vortex.ops — use vortex.compile(workload) with an "
            "explicit instance instead"
        )

    @classmethod
    def dispatch_key(cls, *args: Any, **kwargs: Any) -> tuple | None:
        """Cheap hashable key identifying the call-site signature (the
        static dims/flags, NOT the dynamic extent).  Returning None opts
        out of the raw-tuple dispatch cache: every call pays bind()."""
        return None

    # ---- identity --------------------------------------------------------

    @property
    def signature(self) -> tuple:
        """Engine-level cache key: one compiled VortexKernel per signature."""
        return (self.kind,) + tuple(
            getattr(self, f.name) for f in dataclasses.fields(self)
        )

    @property
    def lattice_key(self) -> tuple:
        """Scored-lattice cache key: the subset of the signature that the
        candidate generator + analyzer actually depend on.  Workloads whose
        runtime flags (masking etc.) don't change tile costs share scores."""
        return self.signature

    # ---- contraction view ------------------------------------------------

    def runtime_dims(self, m_runtime: int | None = None) -> Tile:
        """Map the dynamic extent to concrete (M, N, K)."""
        raise NotImplementedError

    def flops(self, m: int | None = None) -> float:
        M, N, K = self.runtime_dims(m)
        return 2.0 * M * N * K

    # ---- capacity models (InitCands hardware limits) ---------------------

    def l0_fragment_bytes(self, tile: Tile) -> int:
        """Register-file bytes of one level-0 operand fragment."""
        m, n, k = tile
        return (m * k + k * n) * self.dtype_bytes + m * n * self.acc_bytes

    def l1_tile_bytes(self, tile: Tile) -> int:
        """VMEM working set of one layer-1 tile (double-buffered streams +
        resident f32 accumulator)."""
        m, n, k = tile
        stream = 2 * (m * k + k * n) * self.dtype_bytes
        acc = m * n * self.acc_bytes
        return stream + acc

    def l0_axis_multipliers(self) -> Tile:
        """Upper pow2 multipliers over the native tile for level-0 ranges."""
        return (16, 4, 4)

    def l1_axis_caps(self, native: Tile) -> Tile:
        """Absolute upper bounds for the level-1 pow2 ranges."""
        return (8192, 8192, 8192)

    # ---- Eq. 2 grid-level traffic (scalar or numpy arrays) ---------------

    def tile_traffic_bytes(self, m1, n1, k1) -> tuple:
        """(load, store) HBM bytes per layer-1 tile per reduction step."""
        load = (m1 * k1 + k1 * n1) * self.dtype_bytes
        store = m1 * n1 * self.dtype_bytes
        return load, store

    # ---- runtime geometry -------------------------------------------------

    def bucket_dims(self, grid: Tile, l1: Tile) -> Tile:
        """Executable-cache key shape.  Padding is confined to the dynamic
        dims and only up to the lattice tile; static dims appear at their
        TRUE size (the executable pads them internally if its blocks need
        it) — the sample-free bucketing contract (DESIGN.md §4)."""
        _, N, K = self.runtime_dims(1)
        return (grid[0] * l1[0], N, K)

    def dynamic_bucket(self, sel) -> int:
        """The padded DYNAMIC extent of a Selection — what serving layers
        quantize to (``CompiledOp.bucket``).  The default is the padded m
        axis; workloads whose dynamic dim lives elsewhere in the
        contraction view (decode attention: the kv/reduction axis)
        override this to point at the right bucket component."""
        return sel.padded_m

    # ---- rKernel program --------------------------------------------------

    def program(self, hw: HardwareSpec) -> RKernelProgram:
        raise NotImplementedError

    # ---- execution (engine hooks): the masked-tail staging contract -------
    # ``sel`` below is a selector.Selection; jax is imported lazily so the
    # analytical core stays importable without an accelerator stack.
    #
    # The fused per-bucket executable built by ``build_executable`` consumes
    # bucket-shaped buffers PLUS the true runtime extents as trailing i32
    # scalars (``runtime_scalars``), and masks the pad tail in-kernel — the
    # pad region of a staged buffer may hold ARBITRARY GARBAGE (stale bytes
    # from an earlier call), never relying on zero fill.  The engine:
    #
    #   1. maps the call args through ``stage_view`` (identity for GEMM and
    #      attention; im2col for conv),
    #   2. compares each view arg's shape against ``staged_shapes`` — args
    #      that already match run with ZERO copies (the aligned fast path),
    #   3. stages mismatched args into engine-owned, donated bucket buffers
    #      (``lax.dynamic_update_slice``: O(true-size) writes, no alloc, no
    #      zero-fill) and launches the one compiled program,
    #   4. slices the bucket-shaped output back via ``finalize``.
    #
    # ``prepare`` (zero-pad the view to the bucket) remains as the REFERENCE
    # path: functionally identical, used for parity tests and for calls that
    # arrive as tracers inside an enclosing jit (where XLA fuses the pads
    # into the surrounding program anyway and engine-owned buffers must not
    # be captured).

    supports_staging: ClassVar[bool] = False
    # Whether finalize() performs a boundary copy (the out[:m] slice) on
    # unaligned calls.  Workloads whose output shape never depends on the
    # bucket (decode attention: out is always (b, h, 1, d)) set this False
    # so DispatchStats.unstage_copies stays an honest copy count.
    unstages: ClassVar[bool] = True
    # -- lazy handle (bucket-to-bucket) contract --------------------------
    # Call-arg positions that may arrive as engine LazyBucket handles —
    # bucket-shaped buffers whose tail rows past the true extent are
    # GARBAGE.  The value documents why that stale tail is safe:
    #   "rowlocal" — output row i depends only on input row i, so garbage
    #                rows produce garbage rows confined past the extent
    #                (sliced off by finalize/realize);
    #   "masked"   — the kernel masks reads past the runtime extent scalar
    #                (kv_len), so garbage rows are never consumed at all.
    # The engine only tests membership; handles at any OTHER position are
    # realized before dispatch.  Declare positions only for workloads whose
    # ``stage_view`` is the identity (view index == arg index) — transformed
    # views (conv's im2col) cannot consume a raw bucket buffer, so conv
    # keeps this empty.
    consumes_staged: ClassVar[dict[int, str]] = {}
    # The buffer axis of a bucket-shaped OUTPUT that holds the dynamic
    # extent — what a ``lazy=True`` dispatch wraps a LazyBucket around.
    # None: the output is never bucket-shaped (decode's (b, h, 1, d)), so
    # there is nothing to defer and ``lazy`` is ignored.
    staged_out_axis: ClassVar[int | None] = None

    def dynamic_extent(self, *args) -> int:
        """The runtime value of the dynamic dim, from the call arguments."""
        raise NotImplementedError

    def exec_key(self, *args) -> tuple:
        """Extra executable-cache key parts beyond the bucket (outer dims
        that the compiled artifact is specialized on)."""
        return ()

    def stage_view(self, *args) -> tuple:
        """Map call args to the arrays the fused executable consumes
        (identity unless the workload transforms data first, e.g. im2col)."""
        return args

    def staged_shapes(self, sel, *view) -> tuple:
        """Per view arg: the bucket-shaped staging-buffer shape, or None
        for static args that are passed through unstaged."""
        raise NotImplementedError

    def runtime_scalars(self, sel, *view) -> tuple:
        """True runtime extents appended to every executable call as i32
        scalars — what the masked-tail kernels mask against."""
        return ()

    def prepare(self, sel, *view) -> tuple:
        """Reference path: zero-pad the view args to the bucket shapes."""
        raise NotImplementedError

    def finalize(self, sel, out, *args):
        """Slice the bucket-shaped output back to the true extents (and
        reshape where the view changed layout).  Must be an identity-cheap
        no-op when the call was already bucket-aligned."""
        raise NotImplementedError

    def build_executable(
        self, sel, *, impl: str, interpret: bool
    ) -> Callable:
        """Build the fused bucket-shaped executable for a runtime selection:
        ``fn(*bucket_view_args, *runtime_scalars) -> bucket-shaped out``.
        Raises :class:`SelectionDeviationError` rather than adjusting the
        selected tile."""
        raise NotImplementedError

    def example_args(self, sel, *args) -> tuple:
        """Zero arrays + scalars matching the executable's full signature
        (AOT lowering / warmup)."""
        raise NotImplementedError

    def reference(self, *args):
        """Flat (non-hierarchized) JAX reference for correctness tests."""
        raise NotImplementedError


# ---------------------------------------------------------------------------
# GEMM
# ---------------------------------------------------------------------------


@register_workload
@dataclasses.dataclass(frozen=True)
class GemmWorkload(Workload):
    """A (possibly dynamic) GEMM: C[M, N] = A[M, K] @ B[K, N].

    ``dynamic_dims`` lists the dims unknown until runtime (for LM inference
    that is M = batch*seq; N and K are weights-side and static).
    """

    M: int | None
    N: int
    K: int
    dtype_bytes: int = 2
    acc_bytes: int = 4
    dynamic_dims: tuple[str, ...] = ("M",)

    kind: ClassVar[str] = "gemm"
    supports_staging: ClassVar[bool] = True
    # Row i of a@b depends only on row i of a: a bucket-shaped ``a`` with a
    # garbage tail yields garbage output rows past the extent, nothing else.
    consumes_staged: ClassVar[dict[int, str]] = {0: "rowlocal"}
    staged_out_axis: ClassVar[int | None] = 0

    @classmethod
    def bind(cls, a, b) -> "GemmWorkload":
        return cls(M=None, N=b.shape[1], K=b.shape[0])

    @classmethod
    def dispatch_key(cls, a, b) -> tuple:
        return (b.shape[0], b.shape[1])

    def runtime_dims(self, m_runtime: int | None = None) -> Tile:
        m = self.M if m_runtime is None else m_runtime
        assert m is not None, "runtime M required for dynamic workloads"
        return (m, self.N, self.K)

    def flops(self, m: int | None = None) -> float:
        m = self.M if m is None else m
        assert m is not None
        return 2.0 * m * self.N * self.K

    def program(self, hw: HardwareSpec) -> RKernelProgram:
        return _make_program(
            hw,
            self.kind,
            {
                0: ("load_tile_to_reg", "store_reg", "dot"),
                1: ("copy_hbm_to_vmem", "copy_vmem_to_hbm", ""),
            },
        )

    # -- execution ---------------------------------------------------------

    def dynamic_extent(self, a, b) -> int:
        return a.shape[0]

    def staged_shapes(self, sel, a, b) -> tuple:
        return ((sel.padded_m, self.K), None)

    def runtime_scalars(self, sel, a, b) -> tuple:
        return (np.int32(a.shape[0]),)

    def prepare(self, sel, a, b) -> tuple:
        import jax.numpy as jnp

        mp = sel.padded_m
        if mp != a.shape[0]:
            a = jnp.pad(a, ((0, mp - a.shape[0]), (0, 0)))
        return a, b

    def finalize(self, sel, out, a, b):
        m = a.shape[0]
        return out[:m] if sel.padded_m != m else out

    def build_executable(self, sel, *, impl: str, interpret: bool):
        import jax
        import jax.numpy as jnp

        m1, n1, k1 = sel.strategy.l1
        _check_bucket_tiles(self.kind, sel, (("m", sel.padded_m, m1),))
        if impl == "pallas":
            from repro.kernels.gemm import vortex_gemm

            # The selected tile runs verbatim: N/K tails that don't divide
            # (n1, k1) are masked in-kernel, the m pad tail is masked via
            # the runtime extent — no in-program pads or slices remain.
            def fn(a, b, m_true):
                return vortex_gemm(
                    a, b, m_true, block_m=m1, block_n=n1, block_k=k1,
                    interpret=interpret,
                )

        else:

            def fn(a, b, m_true):
                # Rows of A @ B are independent, so garbage pad rows cannot
                # contaminate the real rows; the extent scalar is unused.
                del m_true
                return jax.lax.dot_general(
                    a, b, (((1,), (0,)), ((), ())),
                    preferred_element_type=jnp.float32,
                ).astype(a.dtype)

        return fn

    def example_args(self, sel, *args) -> tuple:
        import jax.numpy as jnp

        # Match the caller's dtypes when representative args are present:
        # the AOT artifact lowered from these IS the steady-state fast
        # path, and a dtype mismatch would demote every call to jit
        # dispatch.
        da = args[0].dtype if args else jnp.float32
        db = args[1].dtype if args else jnp.float32
        return (
            jnp.zeros((sel.padded_m, self.K), da),
            jnp.zeros((self.K, self.N), db),
            np.int32(sel.padded_m),
        )

    def reference(self, a, b):
        from repro.kernels.ref import ref_gemm

        return ref_gemm(a, b)


# ---------------------------------------------------------------------------
# Grouped GEMM (ragged MoE expert FFN)
# ---------------------------------------------------------------------------


@register_workload
@dataclasses.dataclass(frozen=True)
class GroupedGemmWorkload(Workload):
    """Ragged grouped GEMM: out[g] = x[g] @ w[g // (G//E)], per-group extents.

    The MoE expert FFN after capacity-bucketed routing: G groups of
    capacity-shaped ``(C, K)`` activation slabs multiply against a shared
    ``(E, K, N)`` expert weight stack (``r = G // E`` consecutive groups —
    expert-major layout — share each stack entry).  Only ``counts[g]`` rows
    of slab g are real; the rest is routing pad.

    This is the first workload whose DYNAMIC extent is a *routing outcome*
    rather than an input length: the capacity C moves with how the router
    distributed the batch's tokens, which is exactly the dynamism
    sample-driven tuners cannot pre-enumerate.  The masked-tail contract
    handles it unchanged — C buckets like any dynamic extent, and the true
    extents ride into the kernel as a ``(G,)`` i32 vector (the per-row
    ``kv_len`` contract of batched decode, lifted to per-group row counts).
    One launch covers all G groups at any routing skew.

    Selection prices the PER-GROUP ``(C, N, K)`` contraction view: G is a
    constant multiplier on every candidate's time under Eq. 2-4, so the
    per-group argmin is the whole-launch argmin and the plain-GEMM lattice
    applies verbatim (``lattice_key`` shares the scored gemm lattice, like
    decode shares prefill attention's).  ``flops()`` still reports the TRUE
    G-scaled work.

    Call signature: ``grouped_gemm(x, w, counts)`` with x ``(G, C, K)``,
    w ``(E, K, N)``, counts ``(G,)`` i32.  Rows of ``x[g]`` at or past
    ``counts[g]`` may hold arbitrary garbage (stale staging bytes, NaNs);
    the matching output rows are exactly zero in every impl, which keeps
    staged dispatch bit-identical to the zero-padded reference path.
    """

    C: int | None  # capacity (rows per group), dynamic
    G: int  # total groups = E * groups_per_expert
    E: int  # weight stack entries
    N: int
    K: int
    dtype_bytes: int = 2
    acc_bytes: int = 4
    dynamic_dims: tuple[str, ...] = ("C",)

    kind: ClassVar[str] = "grouped_gemm"
    supports_staging: ClassVar[bool] = True
    # stage_view only coerces counts; x could in principle arrive as a
    # bucket handle on axis 1, but LazyBucket forwarding is axis-0/row
    # oriented — keep the lazy contract opted out for now.
    consumes_staged: ClassVar[dict[int, str]] = {}
    staged_out_axis: ClassVar[int | None] = None

    @classmethod
    def bind(cls, x, w, counts) -> "GroupedGemmWorkload":
        return cls(
            C=None, G=x.shape[0], E=w.shape[0], N=w.shape[2], K=w.shape[1]
        )

    @classmethod
    def dispatch_key(cls, x, w, counts) -> tuple:
        return (x.shape[0], w.shape[0], w.shape[1], w.shape[2])

    @property
    def lattice_key(self) -> tuple:
        # The per-group (C, N, K) view prices exactly like a plain GEMM of
        # the same (N, K) — identical capacity/traffic models, and G is a
        # constant factor across candidates so the ranking is unchanged.
        # Share the scored gemm lattice (the literal GemmWorkload signature,
        # so both workloads hash to one cache entry).
        return (
            "gemm", None, self.N, self.K,
            self.dtype_bytes, self.acc_bytes, ("M",),
        )

    def runtime_dims(self, m_runtime: int | None = None) -> Tile:
        c = self.C if m_runtime is None else m_runtime
        assert c is not None, "runtime capacity required"
        return (c, self.N, self.K)

    def flops(self, m: int | None = None) -> float:
        c = self.C if m is None else m
        assert c is not None
        return 2.0 * self.G * c * self.N * self.K  # true work, all groups

    def program(self, hw: HardwareSpec) -> RKernelProgram:
        return _make_program(
            hw,
            self.kind,
            {
                0: ("load_tile_to_reg", "store_reg", "dot"),
                1: ("copy_hbm_to_vmem", "copy_vmem_to_hbm", ""),
            },
        )

    # -- execution ---------------------------------------------------------

    def dynamic_extent(self, x, w, counts) -> int:
        return x.shape[1]

    def stage_view(self, x, w, counts) -> tuple:
        # Coerce list/tuple/int-dtype counts to a concrete (G,) i32 array so
        # the steady-state call matches the AOT artifact's dtypes; traced
        # and already-i32 values pass through.
        if isinstance(counts, (list, tuple)) or (
            getattr(counts, "dtype", None) != np.int32
            and not hasattr(counts, "aval")
        ):
            counts = np.asarray(counts, np.int32).reshape(self.G)
        return x, w, counts

    def staged_shapes(self, sel, x, w, counts) -> tuple:
        # Only the activation slabs are bucket-shaped (on the capacity
        # axis); weights and the counts vector pass through unstaged.
        return ((self.G, sel.padded_m, self.K), None, None)

    def runtime_scalars(self, sel, x, w, counts) -> tuple:
        return ()  # the per-group extents already ride in the view

    def prepare(self, sel, x, w, counts) -> tuple:
        import jax.numpy as jnp

        cp = sel.padded_m
        if cp != x.shape[1]:
            x = jnp.pad(x, ((0, 0), (0, cp - x.shape[1]), (0, 0)))
        return x, w, counts

    def finalize(self, sel, out, x, w, counts):
        c = x.shape[1]
        return out[:, :c] if sel.padded_m != c else out

    def build_executable(self, sel, *, impl: str, interpret: bool):
        import jax.numpy as jnp

        m1, n1, k1 = sel.strategy.l1
        _check_bucket_tiles(self.kind, sel, (("c", sel.padded_m, m1),))
        G, E, K = self.G, self.E, self.K

        if impl == "pallas":
            from repro.kernels.grouped_gemm import vortex_grouped_gemm

            def fn(x, w, counts):
                return vortex_grouped_gemm(
                    x, w, counts, block_m=m1, block_n=n1, block_k=k1,
                    interpret=interpret,
                )

        else:

            def fn(x, w, counts):
                # Mask rows at each group's extent BEFORE the matmul: the
                # staged pad tail is garbage, and rows past counts[g] must
                # come out exactly zero (the kernel contract).  The einsum
                # over the (E, r, C, K) reshape shares the weight stack
                # without materializing a per-group copy.
                cb = x.shape[1]
                valid = (
                    jnp.arange(cb)[None, :]
                    < jnp.asarray(counts, jnp.int32).reshape(G, 1)
                )
                xf = jnp.where(valid[..., None], x.astype(jnp.float32), 0)
                out = jnp.einsum(
                    "erck,ekn->ercn",
                    xf.reshape(E, G // E, cb, K),
                    w.astype(jnp.float32),
                )
                return out.reshape(G, cb, -1).astype(x.dtype)

        return fn

    def example_args(self, sel, *args) -> tuple:
        import jax.numpy as jnp

        dx = args[0].dtype if args else jnp.float32
        dw = args[1].dtype if args else jnp.float32
        return (
            jnp.zeros((self.G, sel.padded_m, self.K), dx),
            jnp.zeros((self.E, self.K, self.N), dw),
            np.zeros((self.G,), np.int32),
        )

    def reference(self, x, w, counts):
        from repro.kernels.ref import ref_grouped_gemm

        return ref_grouped_gemm(x, w, counts)


# ---------------------------------------------------------------------------
# Flash attention
# ---------------------------------------------------------------------------


@register_workload
@dataclasses.dataclass(frozen=True)
class AttentionWorkload(Workload):
    """Flash attention with a dynamic sequence length.

    Both contractions (QK^T: (sq,d)@(d,skv); PV: (sq,skv)@(skv,d)) tile on
    the SAME sequence blocks, so one lattice governs both: the l1 m-tile is
    the query block and the l1 k-tile the key/value block (the pairing the
    Pallas kernel consumes as (block_q, block_k)).  The n axis is pinned to
    the native lane tile — head_dim is static and fits one block — which
    keeps the attention lattice free of meaningless n variation.

    Padding correctness comes from an EXPLICIT key-validity mask: the true
    kv length rides along as a runtime scalar and the kernel masks scores
    (and zeroes value rows) past it, so bucket pad — even garbage bytes in
    a staging buffer — can never reach a real query row.  The causal
    structure is no longer load-bearing for padding, which is why
    ``causal=False`` (encoder/bidirectional attention) buckets just as
    safely as the causal LM case.
    """

    seq: int | None
    head_dim: int
    causal: bool = True
    window: int | None = None
    softcap: float | None = None
    dtype_bytes: int = 2
    acc_bytes: int = 4
    dynamic_dims: tuple[str, ...] = ("seq",)

    kind: ClassVar[str] = "attention"
    dynamic_tile_axes: ClassVar[tuple[int, ...]] = (0, 2)
    supports_staging: ClassVar[bool] = True
    # q rows are independent queries (rowlocal on the seq axis); k/v rows
    # past the kv_len scalar are score-masked AND value-zeroed in-kernel.
    consumes_staged: ClassVar[dict[int, str]] = {
        0: "rowlocal", 1: "masked", 2: "masked",
    }
    staged_out_axis: ClassVar[int | None] = 2  # out (b, hq, sq_bucket, d)

    @classmethod
    def bind(
        cls, q, k, v, *, causal: bool = True,
        window: int | None = None, softcap: float | None = None,
    ) -> "AttentionWorkload":
        return cls(
            seq=None, head_dim=q.shape[-1], causal=causal,
            window=window, softcap=softcap,
        )

    @classmethod
    def dispatch_key(
        cls, q, k, v, *, causal: bool = True,
        window: int | None = None, softcap: float | None = None,
    ) -> tuple:
        return (q.shape[-1], causal, window, softcap)

    @property
    def lattice_key(self) -> tuple:
        # Masking flags don't move tile costs; share scored lattices.
        return (self.kind, self.head_dim, self.dtype_bytes, self.acc_bytes)

    def runtime_dims(self, m_runtime: int | None = None) -> Tile:
        s = self.seq if m_runtime is None else m_runtime
        assert s is not None, "runtime seq required"
        return (s, self.head_dim, s)

    def flops(self, m: int | None = None) -> float:
        s = self.seq if m is None else m
        assert s is not None
        return 4.0 * s * s * self.head_dim  # QK^T + PV

    def l1_tile_bytes(self, tile: Tile) -> int:
        m1, _, k1 = tile
        d = self.head_dim
        stream = 2 * (m1 * d + 2 * k1 * d) * self.dtype_bytes  # Q + K,V
        resident = m1 * d * self.acc_bytes + m1 * k1 * 4  # acc + f32 scores
        return stream + resident

    def l0_axis_multipliers(self) -> Tile:
        return (16, 1, 4)  # n pinned to the native lane tile

    def l1_axis_caps(self, native: Tile) -> Tile:
        return (8192, native[1], 8192)

    def tile_traffic_bytes(self, m1, n1, k1) -> tuple:
        d = self.head_dim
        load = 2 * k1 * d * self.dtype_bytes  # stream K and V blocks
        store = m1 * d * self.dtype_bytes  # output block, once per tile
        return load, store

    def bucket_dims(self, grid: Tile, l1: Tile) -> Tile:
        return (grid[0] * l1[0], self.head_dim, grid[2] * l1[2])

    def program(self, hw: HardwareSpec) -> RKernelProgram:
        return _make_program(
            hw,
            self.kind,
            {
                0: ("load_tile_to_reg", "store_reg", "dot"),
                1: ("copy_qkv_to_vmem", "online_softmax_store", ""),
            },
        )

    # -- execution ---------------------------------------------------------

    def dynamic_extent(self, q, k, v) -> int:
        assert q.shape[-2] == k.shape[-2], (
            "engine attention is self-attention: query/key lengths must "
            f"match, got {q.shape[-2]} vs {k.shape[-2]}"
        )
        return q.shape[-2]

    def exec_key(self, q, k, v) -> tuple:
        # Outer (batch, heads) dims specialize the compiled artifact.
        return (q.shape[0], q.shape[1], k.shape[1])

    def staged_shapes(self, sel, q, k, v) -> tuple:
        pq, d, pkv = sel.bucket
        b, hq, _, _ = q.shape
        hkv = k.shape[1]
        return (
            (b, hq, pq, d),
            (b, hkv, pkv, d),
            (b, hkv, pkv, d),
        )

    def runtime_scalars(self, sel, q, k, v) -> tuple:
        return (np.int32(k.shape[-2]),)

    def prepare(self, sel, q, k, v) -> tuple:
        import jax.numpy as jnp

        pq, _, pkv = sel.bucket
        sq = q.shape[-2]
        if pq != sq:
            q = jnp.pad(q, ((0, 0), (0, 0), (0, pq - sq), (0, 0)))
        if pkv != k.shape[-2]:
            pad = ((0, 0), (0, 0), (0, pkv - k.shape[-2]), (0, 0))
            k = jnp.pad(k, pad)
            v = jnp.pad(v, pad)
        return q, k, v

    def finalize(self, sel, out, q, k, v):
        sq = q.shape[-2]
        return out[..., :sq, :] if sel.bucket[0] != sq else out

    def build_executable(self, sel, *, impl: str, interpret: bool):
        pq, _, pkv = sel.bucket
        m1, _, k1 = sel.strategy.l1
        _check_bucket_tiles(
            self.kind, sel, (("q", pq, m1), ("kv", pkv, k1))
        )
        causal, window, softcap = self.causal, self.window, self.softcap

        if impl == "pallas":
            from repro.kernels.attention import flash_attention

            def fn(q, k, v, kv_len):
                return flash_attention(
                    q, k, v, kv_len, block_q=m1, block_k=k1,
                    causal=causal, window=window, softcap=softcap,
                    interpret=interpret,
                )

        else:
            from repro.kernels.ref import chunked_attention

            def fn(q, k, v, kv_len):
                return chunked_attention(
                    q, k, v, causal=causal, window=window, softcap=softcap,
                    chunk=k1, kv_len=kv_len,
                )

        return fn

    def example_args(self, sel, *args) -> tuple:
        import jax.numpy as jnp

        pq, d, pkv = sel.bucket
        if args:
            b, hq, hkv = self.exec_key(*args)
            dts = tuple(a.dtype for a in args)
        else:
            b, hq, hkv = 1, 1, 1
            dts = (jnp.float32,) * 3
        return (
            jnp.zeros((b, hq, pq, d), dts[0]),
            jnp.zeros((b, hkv, pkv, d), dts[1]),
            jnp.zeros((b, hkv, pkv, d), dts[2]),
            np.int32(pkv),
        )

    def reference(self, q, k, v):
        from repro.kernels.ref import ref_attention

        return ref_attention(
            q, k, v, causal=self.causal, window=self.window,
            softcap=self.softcap,
        )


# ---------------------------------------------------------------------------
# Decode attention (q_len == 1 against a kv-bucketed cache)
# ---------------------------------------------------------------------------


@register_workload
@dataclasses.dataclass(frozen=True)
class DecodeAttentionWorkload(AttentionWorkload):
    """Single-token decode attention against a KV cache.

    The DYNAMIC extent is the cache length S — a static per-call-site
    shape, which is what makes selection work both eagerly and inside a
    traced decode program.  Selection prices the same (S, head_dim, S)
    view as prefill :class:`AttentionWorkload`: decode streams exactly the
    kv block (l1 k-tile) the prefill kernel would stream at sequence
    length S, so the decode kv-bucket set IS the prefill kv-bucket set
    (lattice-granular, not degenerate — a literal (1, d, S) view makes
    Eq. 2-4 flat in the k-tile and the argmin collapses to the smallest
    tile, a bucket every 2 tokens).  Only the q block differs at
    execution: q_len == 1 is static, so the kernel runs block_q == 1 and
    the lattice m-tile never materializes.  The TRUE number of valid
    cache rows rides as the ``kv_len`` runtime scalar (a Python int in
    eager serving, a traced i32 inside a compiled decode step): scores
    past it are masked and value rows zeroed by the kernel, so the cache
    tail beyond ``kv_len`` — bucket pad, stale staging bytes, NaNs — can
    never reach the query row.  Causality needs no flag: the query sits at
    absolute position ``kv_len - 1``, so the key-validity mask IS the
    causal mask; sliding windows re-base through the same offset.

    Call signature: ``decode_attention(q, k, v, kv_len)`` with q
    (b, hq, 1, d) and k/v (b, hkv, S, d), S >= kv_len >= 1.  ``kv_len``
    is a scalar (whole batch at one position) or a (b,) i32 vector giving
    each batch row its OWN valid-row count — mixed-progress batched
    decode, one launch serving rows at different positions, a 0 masking a
    row to zero work.  The two ranks lower to different AOT programs
    (``exec_key`` carries the rank), and per-row causality still needs no
    flag: row i's query sits at ``kv_len[i] - 1``.  Two serving shapes
    hit the padding-free path:

      * S already a kv bucket (the serving cache lives in bucket-shaped
        buffers and grows in place by ``dynamic_update_slice``) — aligned,
        one launch, zero copies, every token;
      * arbitrary S — k/v stage into engine-owned kv-bucket buffers whose
        tails keep stale garbage, then one launch.

    The scored lattice is SHARED with :class:`AttentionWorkload` (same
    ``lattice_key``): the kv block is the same l1 k-tile the prefill
    kernel streams, so decode adds zero offline lattice work.
    """

    kind: ClassVar[str] = "decode_attention"
    supports_staging: ClassVar[bool] = True
    unstages: ClassVar[bool] = False  # out is (b, hq, 1, d): nothing to slice
    # The kv cache may arrive as bucket-shaped handles (e.g. the prefill
    # chain's k/v projection buffers): rows past kv_len are masked.  q is
    # a single token, never bucket-shaped; kv_len is a scalar.
    consumes_staged: ClassVar[dict[int, str]] = {1: "masked", 2: "masked"}
    staged_out_axis: ClassVar[int | None] = None

    @classmethod
    def bind(
        cls, q, k, v, kv_len, *,
        window: int | None = None, softcap: float | None = None,
    ) -> "DecodeAttentionWorkload":
        return cls(
            seq=None, head_dim=q.shape[-1], causal=True,
            window=window, softcap=softcap,
        )

    @classmethod
    def dispatch_key(
        cls, q, k, v, kv_len, *,
        window: int | None = None, softcap: float | None = None,
    ) -> tuple:
        return (q.shape[-1], window, softcap)

    @property
    def lattice_key(self) -> tuple:
        # Decode streams the same (block_q, block_k) tile space as prefill
        # attention; share its scored lattices (the literal kind string —
        # NOT self.kind — so both workloads hash to one cache entry).
        return ("attention", self.head_dim, self.dtype_bytes, self.acc_bytes)

    # runtime_dims stays the inherited (S, head_dim, S) prefill view — the
    # selection pricing contract above.  flops() reports the TRUE decode
    # work (one query row), not the priced view.

    def flops(self, m: int | None = None) -> float:
        s = self.seq if m is None else m
        assert s is not None
        return 4.0 * s * self.head_dim  # one query row: QK^T + PV

    def bucket_dims(self, grid: Tile, l1: Tile) -> Tile:
        return (1, self.head_dim, grid[2] * l1[2])

    def dynamic_bucket(self, sel) -> int:
        return sel.bucket[2]

    # -- execution ---------------------------------------------------------

    def dynamic_extent(self, q, k, v, kv_len) -> int:
        assert q.shape[-2] == 1, (
            f"decode attention takes ONE query row, got q_len={q.shape[-2]}"
        )
        return k.shape[-2]

    def exec_key(self, q, k, v, kv_len) -> tuple:
        # kv_len's rank is part of the key: a scalar (whole batch at one
        # position) and a (b,) per-row vector (mixed-progress batched
        # decode) lower to DIFFERENT programs — the AOT artifact is
        # shape-specialized, so they must not share a cache entry.
        return (
            q.shape[0], q.shape[1], k.shape[1],
            getattr(kv_len, "ndim", 0),
        )

    def stage_view(self, q, k, v, kv_len) -> tuple:
        # Coerce a Python-int kv_len to np.int32 so the steady-state call
        # matches the AOT artifact's dtypes (a bare int would demote every
        # dispatch to jit re-dispatch); traced/jax values (including (b,)
        # per-row vectors) pass through.
        if isinstance(kv_len, (bool, int, np.integer)):
            kv_len = np.int32(kv_len)
        return q, k, v, kv_len

    def staged_shapes(self, sel, q, k, v, kv_len) -> tuple:
        _, d, pkv = sel.bucket
        b, hkv = k.shape[0], k.shape[1]
        # q and the kv_len scalar pass through unstaged; only the cache
        # buffers are bucket-shaped.
        return (None, (b, hkv, pkv, d), (b, hkv, pkv, d), None)

    def runtime_scalars(self, sel, q, k, v, kv_len) -> tuple:
        return ()  # kv_len already rides in the view

    def prepare(self, sel, q, k, v, kv_len) -> tuple:
        import jax.numpy as jnp

        pkv = sel.bucket[2]
        if pkv != k.shape[-2]:
            pad = ((0, 0), (0, 0), (0, pkv - k.shape[-2]), (0, 0))
            k = jnp.pad(k, pad)
            v = jnp.pad(v, pad)
        return q, k, v, kv_len

    def finalize(self, sel, out, q, k, v, kv_len):
        return out  # (b, hq, 1, d) — never bucket-shaped

    def build_executable(self, sel, *, impl: str, interpret: bool):
        pkv = sel.bucket[2]
        _, _, k1 = sel.strategy.l1
        _check_bucket_tiles(self.kind, sel, (("kv", pkv, k1),))
        window, softcap = self.window, self.softcap

        if impl == "pallas":
            from repro.kernels.attention import flash_attention

            def fn(q, k, v, kv_len):
                # causal=False: the kv_len validity mask already excludes
                # every key past the query's absolute position kv_len-1.
                return flash_attention(
                    q, k, v, kv_len, q_offset=kv_len - 1,
                    block_q=1, block_k=k1, causal=False,
                    window=window, softcap=softcap, interpret=interpret,
                )

        else:
            from repro.kernels.ref import chunked_attention

            def fn(q, k, v, kv_len):
                return chunked_attention(
                    q, k, v, causal=False, window=window, softcap=softcap,
                    chunk=k1, offset=kv_len - 1, kv_len=kv_len,
                )

        return fn

    def example_args(self, sel, *args) -> tuple:
        import jax.numpy as jnp

        _, d, pkv = sel.bucket
        if args:
            b, hq, hkv, kv_ndim = self.exec_key(*args)
            dts = tuple(a.dtype for a in args[:3])
        else:
            b, hq, hkv, kv_ndim = 1, 1, 1, 0
            dts = (jnp.float32,) * 3
        # The warm kv_len must match the live calls' rank: the AOT program
        # a (b,) vector lowers embeds per-row masking.
        kv_ex = (
            jnp.full((b,), pkv, jnp.int32) if kv_ndim else np.int32(pkv)
        )
        return (
            jnp.zeros((b, hq, 1, d), dts[0]),
            jnp.zeros((b, hkv, pkv, d), dts[1]),
            jnp.zeros((b, hkv, pkv, d), dts[2]),
            kv_ex,
        )

    def reference(self, q, k, v, kv_len):
        from repro.kernels.ref import ref_attention

        if getattr(kv_len, "ndim", 0):
            kv_len = np.asarray(kv_len, np.int32)
        else:
            kv_len = int(kv_len)
        return ref_attention(
            q, k, v, causal=False, window=self.window,
            softcap=self.softcap, offset=kv_len - 1, kv_len=kv_len,
        )


# ---------------------------------------------------------------------------
# Conv2D (im2col GEMM view)
# ---------------------------------------------------------------------------


@register_workload
@dataclasses.dataclass(frozen=True)
class Conv2dWorkload(Workload):
    """Conv2D (VALID padding) lowered to the hierarchized GEMM space.

    im2col turns Conv2D into a GEMM with M = b*h'*w' (dynamic batch and
    spatial extents), N = cout, K = kh*kw*cin — after which the entire
    lattice/analyzer/selector machinery applies unchanged (paper Table 4).
    """

    m: int | None  # b*h'*w', dynamic
    cin: int
    cout: int
    kh: int
    kw: int
    stride: int = 1
    dtype_bytes: int = 2
    acc_bytes: int = 4
    dynamic_dims: tuple[str, ...] = ("m",)

    kind: ClassVar[str] = "conv2d"
    supports_staging: ClassVar[bool] = True
    # stage_view is im2col, not the identity: a raw bucket buffer is not a
    # valid program input, so handles always realize before dispatch.
    consumes_staged: ClassVar[dict[int, str]] = {}

    @classmethod
    def bind(cls, x, w, *, stride: int = 1) -> "Conv2dWorkload":
        kh, kw, cin, cout = w.shape
        return cls(m=None, cin=cin, cout=cout, kh=kh, kw=kw, stride=stride)

    @classmethod
    def dispatch_key(cls, x, w, *, stride: int = 1) -> tuple:
        kh, kw, cin, cout = w.shape
        return (kh, kw, cin, cout, stride)

    @property
    def N(self) -> int:
        return self.cout

    @property
    def K(self) -> int:
        return self.kh * self.kw * self.cin

    def runtime_dims(self, m_runtime: int | None = None) -> Tile:
        m = self.m if m_runtime is None else m_runtime
        assert m is not None, "runtime output-pixel count required"
        return (m, self.N, self.K)

    def program(self, hw: HardwareSpec) -> RKernelProgram:
        return _make_program(
            hw,
            self.kind,
            {
                0: ("load_tile_to_reg", "store_reg", "dot"),
                1: ("im2col_hbm_to_vmem", "copy_vmem_to_hbm", ""),
            },
        )

    # -- execution ---------------------------------------------------------

    def _out_hw(self, x) -> tuple[int, int]:
        _, h, w, _ = x.shape
        return (
            (h - self.kh) // self.stride + 1,
            (w - self.kw) // self.stride + 1,
        )

    def dynamic_extent(self, x, w) -> int:
        ho, wo = self._out_hw(x)
        return x.shape[0] * ho * wo

    def stage_view(self, x, w) -> tuple:
        from repro.kernels.conv import im2col

        cols, _ = im2col(x, self.kh, self.kw, self.stride)
        # conv_general_dilated_patches orders features (cin, kh, kw).
        wmat = w.transpose(2, 0, 1, 3).reshape(self.K, self.cout)
        return cols, wmat

    def staged_shapes(self, sel, cols, wmat) -> tuple:
        return ((sel.padded_m, self.K), None)

    def runtime_scalars(self, sel, cols, wmat) -> tuple:
        return (np.int32(cols.shape[0]),)

    def prepare(self, sel, cols, wmat) -> tuple:
        import jax.numpy as jnp

        m = cols.shape[0]
        if sel.padded_m != m:
            cols = jnp.pad(cols, ((0, sel.padded_m - m), (0, 0)))
        return cols, wmat

    def finalize(self, sel, out, x, w):
        ho, wo = self._out_hw(x)
        m = x.shape[0] * ho * wo
        return out[:m, : self.cout].reshape(x.shape[0], ho, wo, self.cout)

    def build_executable(self, sel, *, impl: str, interpret: bool):
        # The executable is the GEMM-view kernel on the im2col matrix; the
        # im2col expansion itself runs eagerly in stage_view() so the cached
        # artifact depends only on the bucket, not on (b, h, w) directly.
        return GemmWorkload(
            M=None, N=self.N, K=self.K, dtype_bytes=self.dtype_bytes,
            acc_bytes=self.acc_bytes,
        ).build_executable(sel, impl=impl, interpret=interpret)

    def example_args(self, sel, *args) -> tuple:
        import jax.numpy as jnp

        # args are the raw (x, w) call args; the executable consumes the
        # im2col view, which keeps the input dtypes.
        dx = args[0].dtype if args else jnp.float32
        dw = args[1].dtype if args else jnp.float32
        return (
            jnp.zeros((sel.padded_m, self.K), dx),
            jnp.zeros((self.K, self.N), dw),
            np.int32(sel.padded_m),
        )

    def reference(self, x, w):
        from repro.kernels.ref import ref_conv2d

        return ref_conv2d(x, w, stride=self.stride, padding="VALID")
