"""Bottom-up hardware-aware candidate generation (paper §5.1, Algorithm 2).

For each rKernel layer, from the innermost out:

  1. ``init_cands``        — seed the candidate range from that layer's
     hardware resource limits (paper ``InitCands``/``GetHardwareInfo``) and
     the *workload's* per-tile footprint model (workloads.py).
  2. ``filter_by_isa``     — at layer 0, keep only tiles compatible with the
     ISA granularity (MMA/AVX512 in the paper; MXU/VREG tiling here).
  3. ``filter_by_multiples`` — keep only tiles that are elementwise integer
     multiples of at least one surviving lower-layer tile (the sieve), and
     record the child map.  This confines padding loss to the outermost
     runtime level (paper Fig. 8).

The generator is workload-generic: every capacity check routes through the
:class:`~repro.core.workloads.Workload` protocol, so attention and conv reuse
Algorithm 2 unchanged.  The output is a :class:`CandidateLattice`: per-layer
candidate lists plus the parent→children map the analyzer scores.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Mapping, Sequence

from repro.core.hardware import HardwareLevel, HardwareSpec
from repro.core.workloads import Workload

__all__ = [
    "Tile",
    "CandidateLattice",
    "init_cands",
    "filter_by_isa",
    "filter_by_multiples",
    "generate_lattice",
]

Tile = tuple[int, int, int]  # (m, n, k)


@dataclasses.dataclass(frozen=True)
class CandidateLattice:
    """All surviving candidates, per layer, innermost first.

    ``children[d]`` maps a layer-d tile to the layer-(d-1) tiles it is a
    multiple of (Algorithm 2's ``map``); ``children[0]`` is empty.
    """

    backend: str
    layers: tuple[tuple[Tile, ...], ...]
    children: tuple[Mapping[Tile, tuple[Tile, ...]], ...]

    @property
    def l0(self) -> tuple[Tile, ...]:
        return self.layers[0]

    @property
    def l1(self) -> tuple[Tile, ...]:
        return self.layers[1]

    def num_candidates(self) -> int:
        return sum(len(layer) for layer in self.layers)


def _pow2_range(lo: int, hi: int) -> list[int]:
    out = []
    v = lo
    while v <= hi:
        out.append(v)
        v *= 2
    return out


def init_cands(
    level: HardwareLevel, wl: Workload, backend_tile: Tile
) -> list[Tile]:
    """Seed candidates for one layer from hardware limits (``InitCands``).

    The enumeration is powers-of-two multiples of the backend's native tile,
    bounded above by the layer's storage capacity against the workload's
    footprint model — exactly the paper's "deduce a feasible range for
    candidate shapes based on hardware utilization metrics" step.
    Power-of-two steps keep the multiples sieve dense without exploding the
    space (the paper reports 392 candidates for the tensor-core GEMM space;
    ours is the same order of magnitude).
    """
    bm, bn, bk = backend_tile
    if level.depth == 0:
        # Level-0 range: from 1x the native tile up to the register-file
        # capacity (operand fragments must fit the VREG file).
        mm, mn, mk = wl.l0_axis_multipliers()
        ms = _pow2_range(bm, bm * mm)
        ns = _pow2_range(bn, bn * mn)
        ks = _pow2_range(bk, bk * mk)
        cap = level.capacity_bytes
        out = []
        for t in itertools.product(ms, ns, ks):
            if cap is None or wl.l0_fragment_bytes(t) <= cap * 16:
                # VREG fragments are pipelined; allow a 16x over-subscription
                # factor (operands stream through, not resident all at once).
                out.append(t)
        return out
    # Upper layers: bounded by this layer's memory capacity.
    cm, cn, ck = wl.l1_axis_caps(backend_tile)
    ms = _pow2_range(bm, max(cm, bm))
    ns = _pow2_range(bn, max(cn, bn))
    ks = _pow2_range(bk, max(ck, bk))
    out = []
    for t in itertools.product(ms, ns, ks):
        if level.capacity_bytes is None or (
            wl.l1_tile_bytes(t) <= level.capacity_bytes
        ):
            out.append(t)
    return out


def filter_by_isa(
    cands: Sequence[Tile], hw: HardwareSpec, backend: str
) -> list[Tile]:
    """Layer-0 ISA-compatibility filter (``FilterByISA``).

    On TPU: the lane dims (n, k) must be multiples of 128 and the sublane dim
    (m) a multiple of the dtype's native sublane count — the MXU analogue of
    the paper's MMA-shape / AVX512-width constraints.
    """
    bm, bn, bk = hw.native_tile[backend]
    return [
        (m, n, k)
        for (m, n, k) in cands
        if m % bm == 0 and n % bn == 0 and k % bk == 0
    ]


def filter_by_multiples(
    cands: Sequence[Tile], prev_cands: Sequence[Tile]
) -> tuple[list[Tile], dict[Tile, tuple[Tile, ...]]]:
    """Multiples sieve (``FilterByMultiples``): keep layer-L tiles that are
    elementwise integer multiples of >=1 layer-(L-1) tile; return the map
    from each survivor to its compatible children (Algorithm 2's table).
    """
    child_map: dict[Tile, list[Tile]] = {}
    cand_set = set(cands)
    # Sieve direction follows the paper: iterate *previous-layer* candidates
    # and generate their multiples inside the current layer's range, rather
    # than testing every (cand, prev) pair.
    for prev in prev_cands:
        pm, pn, pk = prev
        for cand in cand_set:
            m, n, k = cand
            if m % pm == 0 and n % pn == 0 and k % pk == 0:
                child_map.setdefault(cand, []).append(prev)
    filtered = sorted(child_map)
    return filtered, {t: tuple(cs) for t, cs in child_map.items()}


def generate_lattice(
    hw: HardwareSpec, wl: Workload, backend: str | None = None
) -> CandidateLattice:
    """Run Algorithm 2 bottom-up across all strategy layers.

    Only layers 0 and 1 carry tile candidates (level 2, the grid, is fully
    determined by the runtime shape and the layer-1 tile); this matches the
    paper's GPU setting where grid geometry is computed at kernel
    construction time (§6.2).
    """
    backend = backend or hw.default_backend
    native = hw.native_tile[backend]

    l0 = init_cands(hw.level(0), wl, native)
    l0 = filter_by_isa(l0, hw, backend)
    if not l0:
        raise ValueError(f"no level-0 candidates for backend {backend!r}")

    l1 = init_cands(hw.level(1), wl, native)
    l1, child_map = filter_by_multiples(l1, l0)
    if not l1:
        raise ValueError("no level-1 candidates survived the sieve")

    return CandidateLattice(
        backend=backend,
        layers=(tuple(l0), tuple(l1)),
        children=({}, child_map),
    )
