"""Analytical cost model (paper §5.2, Eqs. 2-4), workload-generic.

The model is recursive over rKernel layers.  At layer L, with a serial
(temporal) loop of ``n`` iterations whose body is the layer-(L-1) kernel:

    T_temporal = T_load + (n - 1) * max(T_load, Cost_{L-1})
                 + Cost_{L-1} + T_store                          (Eq. 2)

i.e. a software pipeline: the first load is exposed, then loads overlap with
compute, and the last body + store drain the pipe.  Parallel loops amplify by
the ceil-division occupancy factor:

    F_parallel = ceil(|ParallelLoop| / |HardwareUnit|)           (Eq. 3)
    Cost_L     = F_parallel * T_temporal                         (Eq. 4)

Level-0 cost comes from the analyzer (empirical where available, else the
native-tile analytical estimate here), so this module exposes the recursion
with an injectable ``cost_l0`` — the hybrid split of §5.2.

The recursion itself is workload-agnostic: concrete (M, N, K) dims come from
``wl.runtime_dims`` and grid-level traffic from ``wl.tile_traffic_bytes``
(workloads.py), so GEMM, attention and conv all evaluate through the same
Eq. 2-4 arithmetic.  ``gemm_strategy_cost``/``gemm_runtime_costs`` remain as
aliases of the generic entry points.

All costs are seconds.  A vectorized (numpy) evaluator over many layer-1
candidates is provided for the runtime selector, whose overhead must stay
negligible (paper Fig. 14).
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np

from repro.core.hardware import HardwareSpec
from repro.core.rkernel import Strategy
from repro.core.workloads import Workload

__all__ = [
    "CostBreakdown",
    "l0_analytical_cost",
    "strategy_cost",
    "runtime_costs",
    "runtime_cost_matrix",
    "gemm_strategy_cost",
    "gemm_runtime_costs",
]


@dataclasses.dataclass(frozen=True)
class CostBreakdown:
    """Per-layer decomposition of a strategy's predicted cost."""

    total: float
    l0_per_tile: float
    l1_per_tile: float
    f_parallel: float
    padded_shape: tuple[int, int, int]
    padding_waste: float  # fraction of computed FLOPs that are padding


def l0_analytical_cost(
    hw: HardwareSpec, tile: tuple[int, int, int], backend: str
) -> float:
    """Analytical level-0 cost of one native-tile-group contraction.

    Models the systolic array: a tile smaller than the native granularity
    still occupies a full native issue, so cost is the *padded* tile's FLOPs
    over peak — this is where low-utilization candidates get their penalty
    (paper Fig. 5) before any empirical correction.
    """
    bm, bn, bk = hw.native_tile[backend]
    m, n, k = tile
    pm, pn, pk = (
        math.ceil(m / bm) * bm,
        math.ceil(n / bn) * bn,
        math.ceil(k / bk) * bk,
    )
    peak = hw.backends[backend]
    issue_overhead = 5e-9  # fixed per-issue latency (pipeline fill)
    return 2.0 * pm * pn * pk / peak + issue_overhead


def _t_temporal(
    t_load: float, n_iter: float, body: float, t_store: float
) -> float:
    """Eq. 2 with a guard for degenerate 0-iteration loops."""
    if n_iter <= 0:
        return 0.0
    return t_load + (n_iter - 1.0) * max(t_load, body) + body + t_store


def strategy_cost(
    hw: HardwareSpec,
    wl: Workload,
    strategy: Strategy,
    m_runtime: int | None = None,
    cost_l0: float | None = None,
    num_cores: int = 1,
    dims: tuple[int, int, int] | None = None,
) -> CostBreakdown:
    """Full Eq. 2-4 recursion for a strategy at a concrete shape.

    ``cost_l0`` overrides the analytical level-0 estimate with an empirical
    measurement (the hybrid analyzer passes it in).  ``num_cores`` is the
    level-2 |HardwareUnit| — TensorCores across the shard this runs on.
    ``dims`` overrides the workload's runtime (M, N, K) view entirely — the
    analyzer uses it to cost ONE layer-1 tile (grid = 1x1x1).
    """
    M, N, K = dims if dims is not None else wl.runtime_dims(m_runtime)
    m0, n0, k0 = strategy.l0
    m1, n1, k1 = strategy.l1

    c0 = cost_l0 if cost_l0 is not None else l0_analytical_cost(
        hw, strategy.l0, strategy.backend
    )

    # ---- layer 1: temporal-spatial (m, n) x temporal-reduction (k) over
    # level-0 tiles, operands already in VMEM.
    l0_iters_k = k1 // k0
    l0_iters_sp = (m1 // m0) * (n1 // n0)
    reg_bw = hw.level(0).load_bandwidth
    t_load0 = (m0 * k0 + k0 * n0) * wl.dtype_bytes / reg_bw
    t_store0 = 0.0  # accumulator stays resident in VREG/VMEM across k
    inner_chain = _t_temporal(t_load0, l0_iters_k, c0, t_store0)
    cost_l1_tile = l0_iters_sp * inner_chain  # spatial tiles run back-to-back

    # ---- layer 2: grid. Parallel loops over ceil(M/m1) * ceil(N/n1)
    # instances on num_cores cores; temporal reduction over ceil(K/k1)
    # steps, each streaming the workload's per-tile operands from HBM.
    gm, gn, gk = (
        math.ceil(M / m1),
        math.ceil(N / n1),
        math.ceil(K / k1),
    )
    hbm_bw = hw.level(1).load_bandwidth
    load_bytes, store_bytes = wl.tile_traffic_bytes(m1, n1, k1)
    t_load1 = load_bytes / hbm_bw
    t_store1 = store_bytes / hbm_bw
    t_tile = _t_temporal(t_load1, gk, cost_l1_tile, t_store1)
    f_parallel = math.ceil(gm * gn / max(num_cores, 1))  # Eq. 3
    total = f_parallel * t_tile  # Eq. 4

    padded = (gm * m1, gn * n1, gk * k1)
    useful = 2.0 * M * N * K
    waste = 1.0 - useful / (2.0 * padded[0] * padded[1] * padded[2])
    return CostBreakdown(
        total=total,
        l0_per_tile=c0,
        l1_per_tile=cost_l1_tile,
        f_parallel=f_parallel,
        padded_shape=padded,
        padding_waste=waste,
    )


def runtime_cost_matrix(
    hw: HardwareSpec,
    wl: Workload,
    l1_tiles: np.ndarray,
    l1_costs: np.ndarray,
    ms: np.ndarray,
    num_cores: int = 1,
    cost_scale: np.ndarray | None = None,
) -> np.ndarray:
    """Fused Eq. 2-4 sweep: C candidates x B runtime extents -> (C, B).

    ``l1_tiles`` may stack candidates from MANY backends — the grid-level
    recursion only consumes the per-tile cost ``l1_costs`` (which already
    encodes the backend's level-0/1 behaviour), so one numpy evaluation
    covers the whole multi-backend strategy space.  ``ms`` is a vector of
    dynamic extents; the offline table builder passes every breakpoint at
    once, the runtime argmin fallback passes a single element.

    ``cost_scale`` is an optional (C,) per-candidate multiplier on the
    final cost — the background calibrator's refined per-backend
    coefficients (core/calibrate.py).  A constant scale preserves the
    piecewise-constant-in-M structure (breakpoints are unchanged), so a
    calibrated selection table is built by the exact same sweep.

    Every arithmetic op is elementwise, so the (C,) column at ``ms=[m]`` is
    bit-identical to the same column of a wider sweep containing ``m`` —
    the table/argmin equivalence tests rely on this.
    """
    ms = np.atleast_1d(np.asarray(ms, np.float64))
    M, N, K = wl.runtime_dims(ms)
    m1 = l1_tiles[:, 0:1].astype(np.float64)  # (C, 1)
    n1 = l1_tiles[:, 1:2].astype(np.float64)
    k1 = l1_tiles[:, 2:3].astype(np.float64)
    gm = np.ceil(M / m1)  # (C, B)
    gn = np.ceil(N / n1)  # (C, 1) static dims, (C, B) dynamic-tied ones
    gk = np.ceil(K / k1)
    hbm_bw = hw.level(1).load_bandwidth
    load_bytes, store_bytes = wl.tile_traffic_bytes(m1, n1, k1)
    t_load = load_bytes / hbm_bw
    t_store = store_bytes / hbm_bw
    body = l1_costs[:, None]
    t_tile = t_load + np.maximum(gk - 1.0, 0.0) * np.maximum(t_load, body) \
        + body + t_store
    f_parallel = np.ceil(gm * gn / max(num_cores, 1))
    out = f_parallel * t_tile
    if cost_scale is not None:
        out = out * np.asarray(cost_scale, np.float64)[:, None]
    return np.broadcast_to(out, (l1_tiles.shape[0], ms.shape[0]))


def runtime_costs(
    hw: HardwareSpec,
    wl: Workload,
    l1_tiles: np.ndarray,
    l1_costs: np.ndarray,
    m_runtime: int,
    num_cores: int = 1,
    cost_scale: np.ndarray | None = None,
) -> np.ndarray:
    """Vectorized layer-2 cost over many layer-1 candidates at runtime.

    ``l1_tiles`` is (C, 3) int — possibly backend-stacked (see
    :class:`~repro.core.analyzer.StackedLattices`); ``l1_costs`` is (C,)
    seconds per layer-1 tile (precomputed offline by the analyzer — at
    runtime only the cheap Eq. 2-4 arithmetic at the grid level runs,
    keeping selection overhead at the microsecond scale Fig. 14 demands).
    """
    return runtime_cost_matrix(
        hw, wl, l1_tiles, l1_costs, np.asarray([m_runtime]), num_cores,
        cost_scale,
    )[:, 0]


# Back-compat aliases (the pre-generic names; same call signatures).
gemm_strategy_cost = strategy_cost
gemm_runtime_costs = runtime_costs
