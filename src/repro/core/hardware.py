"""Hardware hierarchy descriptors for Vortex's strategy-space hierarchization.

The paper (§2.3, §4) observes that CPUs and GPUs share a multi-level
hierarchical structure — each level has a fixed number of compute/storage
units, and kernel performance collapses when a strategy's resource usage at
any level exceeds that level's limit.  Vortex encodes those limits explicitly
and uses them to prune the strategy space *before* any profiling.

This module provides the TPU adaptation of that idea (see DESIGN.md §2):

  level 2  "grid"   — parallel distribution of program instances over
                      TensorCores (Pallas grid / mesh shards),
  level 1  "vmem"   — a BlockSpec tile resident in VMEM, streamed from HBM,
  level 0  "mxu"    — the native systolic-array tile executed per issue.

A host-CPU spec is also provided; it backs the empirical side of the hybrid
analyzer in this (CPU-only) container and mirrors the paper's CPU target.
"""
from __future__ import annotations

import dataclasses
from typing import Mapping, Sequence

__all__ = [
    "HardwareLevel",
    "HardwareSpec",
    "TPU_V5E",
    "HOST_CPU",
    "get_hardware",
]


@dataclasses.dataclass(frozen=True)
class HardwareLevel:
    """One level of the hardware hierarchy (paper Table 1 rows).

    Attributes:
      depth: level index; 0 is the innermost (ISA/compute) level.
      name: human-readable level name ("mxu", "vmem", "grid", ...).
      parallel_units: number of sibling units that execute in parallel at
        this level (Eq. 3's |HardwareUnit|).  1 for purely temporal levels.
      capacity_bytes: storage capacity available to ONE unit at this level
        (VMEM bytes, cache bytes, register-file bytes).  ``None`` when the
        level has no explicit working-set limit (e.g. the grid level).
      load_bandwidth: bytes/s from the parent level's memory into this
        level's memory (HBM→VMEM, DRAM→cache, ...).  Used for T_Load/T_Store
        in Eq. 2.
      compute_flops: peak FLOP/s of ONE unit at this level; only meaningful
        at depth 0 (the level that actually computes).
    """

    depth: int
    name: str
    parallel_units: int
    capacity_bytes: int | None
    load_bandwidth: float
    compute_flops: float = 0.0


@dataclasses.dataclass(frozen=True)
class HardwareSpec:
    """A full hardware target: an ordered hierarchy plus ISA granularities.

    Attributes:
      name: target name.
      levels: levels ordered by depth (levels[0].depth == 0).
      native_tile: per-backend ISA granularity for level-0 candidates, as a
        mapping from backend name to an (m, n, k) tile that level-0 candidate
        dims must be multiples of (paper's FilterByISA: AVX512 lanes on CPU,
        MMA m16n8k16 on GPU; MXU/VREG tiling here).
      backends: compute backends selectable at runtime (§6.2 "dynamic
        hardware adaptation": CUDA core vs Tensor Core on GPU; MXU vs VPU
        here).  Maps backend name -> peak FLOP/s of one level-0 unit group.
      link_bandwidth: per-chip interconnect bandwidth (ICI), bytes/s; used by
        the roofline collective term, not by single-chip strategy costs.
      min_utilization: strategies whose level-0 occupancy of the native tile
        falls below this are pruned (paper Fig. 5: extremely low utilization
        configs always underperform).
    """

    name: str
    levels: tuple[HardwareLevel, ...]
    native_tile: Mapping[str, tuple[int, int, int]]
    backends: Mapping[str, float]
    link_bandwidth: float
    min_utilization: float = 0.03125

    def level(self, depth: int) -> HardwareLevel:
        return self.levels[depth]

    @property
    def num_levels(self) -> int:
        return len(self.levels)

    @property
    def default_backend(self) -> str:
        return next(iter(self.backends))


def _tpu_v5e() -> HardwareSpec:
    # Roofline constants fixed by the assignment: 197 TFLOP/s bf16,
    # 819 GB/s HBM, ~50 GB/s/link ICI.
    hbm_bw = 819e9
    peak_bf16 = 197e12
    # The VPU (8x128 vector unit) peak is ~2 orders below the MXU; it wins
    # only for skinny-M shapes where MXU padding burns >98% of the array.
    vpu_flops = 4e12
    levels = (
        HardwareLevel(
            depth=0,
            name="mxu",
            # 4 MXUs per TensorCore issue in lockstep; model them as one
            # level-0 unit with the combined peak (the candidate generator
            # works in units of the native tile, not individual MXUs).
            parallel_units=1,
            capacity_bytes=32 * 1024,  # VREG file per core (32 KiB)
            load_bandwidth=2.6e13,  # VMEM->VREG streaming bandwidth
            compute_flops=peak_bf16,
        ),
        HardwareLevel(
            depth=1,
            name="vmem",
            parallel_units=1,
            # 128 MiB VMEM per v5e core; leave headroom for the compiler's
            # own scratch: strategies may claim at most half.
            capacity_bytes=64 * 1024 * 1024,
            load_bandwidth=hbm_bw,
            compute_flops=0.0,
        ),
        HardwareLevel(
            depth=2,
            name="grid",
            parallel_units=1,  # TensorCores per chip (v5e: 1)
            capacity_bytes=None,
            load_bandwidth=hbm_bw,
            compute_flops=0.0,
        ),
    )
    return HardwareSpec(
        name="tpu_v5e",
        levels=levels,
        native_tile={
            # MXU: contracting/output lane dims in multiples of 128; the
            # sublane dim in multiples of 16 for bf16 (8 for f32).
            "mxu": (16, 128, 128),
            # VPU path: elementwise/outer-product style — sublane 8, lane 128,
            # no systolic contraction granularity.
            "vpu": (8, 128, 8),
        },
        backends={"mxu": peak_bf16, "vpu": vpu_flops},
        link_bandwidth=50e9,
    )


def _host_cpu() -> HardwareSpec:
    """Generic host-CPU spec (empirical-profiler backend in this container).

    Mirrors the paper's Intel CPU target structurally: L0 = SIMD registers,
    L1 = per-core cache ("CacheBuffer"), L2 = multi-core process level.
    Constants are deliberately conservative; the empirical profiler corrects
    level-0 costs with real wall-clock measurements (§5.2).
    """
    levels = (
        HardwareLevel(
            depth=0,
            name="simd",
            parallel_units=1,
            capacity_bytes=2 * 1024,
            load_bandwidth=2e11,
            compute_flops=5e10,
        ),
        HardwareLevel(
            depth=1,
            name="cache",
            parallel_units=1,
            capacity_bytes=1 * 1024 * 1024,
            load_bandwidth=3e10,
            compute_flops=0.0,
        ),
        HardwareLevel(
            depth=2,
            name="cores",
            parallel_units=1,
            capacity_bytes=None,
            load_bandwidth=3e10,
            compute_flops=0.0,
        ),
    )
    return HardwareSpec(
        name="host_cpu",
        levels=levels,
        native_tile={"simd": (1, 16, 1)},
        backends={"simd": 5e10},
        link_bandwidth=1e10,
    )


TPU_V5E: HardwareSpec = _tpu_v5e()
HOST_CPU: HardwareSpec = _host_cpu()

_REGISTRY: dict[str, HardwareSpec] = {s.name: s for s in (TPU_V5E, HOST_CPU)}


def get_hardware(name: str) -> HardwareSpec:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown hardware {name!r}; known: {sorted(_REGISTRY)}"
        ) from None
