"""Vortex-driven framework auto-configuration (beyond-paper integration).

The paper selects GEMM micro-kernel tiles from a hardware-pruned lattice.
The same machinery configures two framework-level knobs, sample-free:

* :func:`select_attn_chunk` — the flash-attention KV-chunk length.  The
  chunk is the N-extent of the QK^T GEMM tile; candidates come from the
  Vortex L1 lattice (VMEM-bounded, MXU-aligned) and are scored with the
  Eq. 2 pipeline model (per-chunk HBM load vs MXU compute + per-iteration
  scan overhead).
* :func:`select_microbatches` — gradient-accumulation factor: the smallest
  power-of-two count whose per-device transient working set (logits block
  + MoE dispatch buffers + attention scores) fits the HBM activation
  budget.  This replaces the hand heuristic in launch/dryrun.py with the
  same hardware-limit reasoning the paper applies to tiles (InitCands).
"""
from __future__ import annotations

import math

from repro.core.hardware import TPU_V5E, HardwareSpec
from repro.core.candidates import generate_lattice
from repro.core.workloads import GemmWorkload

__all__ = ["select_attn_chunk", "select_microbatches"]

_SCAN_OVERHEAD_S = 2e-6  # per scan-iteration dispatch overhead (fixed cost)


def select_attn_chunk(
    seq: int,
    head_dim: int,
    q_rows: int,
    *,
    hw: HardwareSpec = TPU_V5E,
    dtype_bytes: int = 2,
    vmem_frac: float = 0.25,
) -> int:
    """Pick the flash-attention KV-chunk from the Vortex lattice.

    Eq. 2 shape: per chunk, T_load = chunk*(head_dim*2 + q_rows)*bytes/HBM
    (K,V tiles + score block), body = 2*q_rows*chunk*head_dim*2 / peak
    (QK^T and PV), pipelined; plus a fixed per-iteration overhead that
    penalizes tiny chunks.  Bounded above by the VMEM working set.
    """
    wl = GemmWorkload(M=None, N=256, K=max(head_dim, 128))
    lattice = generate_lattice(hw, wl, hw.default_backend)
    cands = sorted({t[2] for t in lattice.l1})  # k-extent candidates
    vmem = (hw.level(1).capacity_bytes or 1 << 27) * vmem_frac
    hbm = hw.level(1).load_bandwidth
    peak = hw.backends[hw.default_backend]

    best, best_cost = None, float("inf")
    for c in cands:
        if c < 128 or c > seq:
            continue
        # K,V chunk + f32 score block resident per step.
        ws = 2 * c * head_dim * dtype_bytes + q_rows * c * 4
        if ws > vmem:
            continue
        n_iter = math.ceil(seq / c)
        t_load = c * (2 * head_dim + q_rows) * dtype_bytes / hbm
        body = 2 * 2 * q_rows * c * head_dim / peak
        per = max(t_load, body) + _SCAN_OVERHEAD_S
        cost = n_iter * per
        if cost < best_cost:
            best, best_cost = c, cost
    return best or min(1024, seq)


def select_microbatches(
    *,
    global_batch: int,
    seq: int,
    d_model: int,
    vocab: int,
    n_data_shards: int,
    n_model_shards: int,
    moe_experts: int = 0,
    moe_topk: int = 0,
    capacity_factor: float = 1.25,
    hw: HardwareSpec = TPU_V5E,
    hbm_activation_frac: float = 0.25,
) -> int:
    """Smallest power-of-two microbatch count whose transient per-device
    working set fits the activation share of HBM (paper InitCands logic at
    the framework level)."""
    budget = 16 * 2**30 * hbm_activation_frac
    mb = 1
    while mb < global_batch:
        b_loc = max(global_batch // mb // max(n_data_shards, 1), 1)
        logits = b_loc * seq * math.ceil(vocab / max(n_model_shards, 1)) * 4
        ws = logits + b_loc * seq * d_model * 2 * 4  # residual + f32 temp
        if moe_experts:
            cap = math.ceil(seq * moe_topk * capacity_factor / moe_experts)
            e_loc = math.ceil(moe_experts / max(n_model_shards, 1))
            ws += b_loc * e_loc * cap * d_model * 2 * 3
        if ws <= budget:
            return mb
        mb *= 2
    return mb
