"""VortexKernel: the end-to-end sample-free compiler (paper Fig. 6).

Offline stage (no shape samples anywhere):
  1. top-down: describe the workload as an rKernel program (workloads.py
     declares it; rkernel.py holds the layer metadata),
  2. bottom-up: generate the hardware-pruned candidate lattice per backend
     (candidates.py, Algorithm 2),
  3. score it with the hybrid analyzer (analyzer.py).

Runtime stage:
  4. given the actual shape, select strategy + launch geometry + backend
     (selector.py) — a bisect into the offline-materialized selection table
     (selection_table.py) on the hot path, the fused analytical argmin past
     the table,
  5. construct/fetch the executable for the induced bucket and run (skipping
     pad/unpad entirely when the extent is already bucket-aligned).

:class:`VortexKernel` drives ANY registered
:class:`~repro.core.workloads.Workload` through the same lattice → analyzer →
selector → bucketed-executable pipeline.  The multi-workload session layer —
one engine serving every registered kind from one scored-lattice cache and
one dispatch table — lives in :mod:`repro.vortex` (the public API);
``VortexEngine``/``VortexGemm`` remain importable from here as deprecation
shims over that package.

Execution backends:
  * ``xla``    — flat JAX ops on the bucket shape (host-CPU execution in
                 this container; what the benchmarks time),
  * ``pallas`` — the Vortex-tiled Pallas TPU kernels (kernels/) with
                 BlockSpecs taken from the selected strategy; run in
                 interpret mode off-TPU and compile natively on TPU.
"""
from __future__ import annotations

import dataclasses
import functools
import os
import threading
import time
from concurrent.futures import ThreadPoolExecutor, as_completed
from typing import Any, Callable

import jax

from repro.core.analyzer import HybridAnalyzer, Profiler, ScoredLattice
from repro.core.candidates import generate_lattice
from repro.core.hardware import HardwareSpec
from repro.core.selector import RuntimeSelector, Selection
from repro.core.workloads import Workload
from repro.runtime import faults

__all__ = [
    "DispatchStats",
    "LazyBucket",
    "OfflineStats",
    "PrecompileError",
    "VortexKernel",
    "VortexGemm",
    "VortexEngine",
    "lazy_map",
]


@dataclasses.dataclass(frozen=True)
class OfflineStats:
    """Offline-stage accounting (paper §7.4 'Offline Overhead Analysis')."""

    num_candidates: int
    num_measured: int
    build_seconds: float
    backends: tuple[str, ...]


class PrecompileError(RuntimeError):
    """A bucket failed to compile during :meth:`VortexKernel.precompile`.

    Parallel precompiles surface through ``as_completed`` futures, which
    would otherwise raise the bare builder exception with no hint of WHICH
    bucket died; this wrapper names the failing Selection so a fleet-wide
    warmup failure is diagnosable from the message alone.
    """

    def __init__(self, kind: str, sel: Selection, cause: BaseException):
        self.kind = kind
        self.selection = sel
        super().__init__(
            f"precompile failed for workload {kind!r}: bucket={sel.bucket} "
            f"backend={sel.backend} strategy l1={sel.strategy.l1} "
            f"grid={sel.grid}: {type(cause).__name__}: {cause}"
        )


@dataclasses.dataclass
class DispatchStats:
    """Per-call accounting for the serving hot path (the numbers the
    Fig. 8/Fig. 14 'padding confined to the outermost level' claim is
    checked against).

    ``launches`` counts executions of the ONE fused per-bucket program;
    ``stage_copies``/``unstage_copies`` count the O(true-size) boundary
    copies an unaligned extent pays (dynamic_update_slice into a donated
    engine buffer / the output slice back).  ``padded_calls`` counts falls
    back to the zero-pad reference path (tracer-context calls and
    workloads without staging support); ``traced_calls`` counts calls that
    arrived as tracers inside an enclosing jit (they become part of the
    surrounding program, not runtime launches).

    ``forwarded`` counts :class:`LazyBucket` operands whose buffer entered
    the next program directly — an op boundary crossed with NO unstage and
    NO restage; ``realize_slices`` counts deferred output slices forced by
    a non-engine consumer (``LazyBucket.realize``).  Whole-chain boundary
    traffic is exactly ``stage_copies + unstage_copies + realize_slices``.

    ``quarantined`` counts candidates the degradation ladder denylisted
    after a precompile/launch failure; ``fallbacks`` counts dispatches
    that exhausted the lattice retries and ran the XLA reference rung.
    Both are zero on every healthy host (DESIGN.md §11).
    """

    calls: int = 0
    launches: int = 0
    aligned_calls: int = 0
    unaligned_calls: int = 0
    stage_copies: int = 0
    unstage_copies: int = 0
    padded_calls: int = 0
    traced_calls: int = 0
    forwarded: int = 0
    realize_slices: int = 0
    fallbacks: int = 0
    quarantined: int = 0

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


@functools.partial(jax.jit, donate_argnums=0)
def _stage_into(buf, x):
    """Copy ``x`` into the leading corner of the engine-owned bucket buffer
    IN PLACE (``buf`` is donated): only the true extent is written, the pad
    tail keeps whatever stale bytes it held — the masked-tail kernels never
    read them — and no fresh zero-filled allocation is made."""
    return jax.lax.dynamic_update_slice(buf, x, (0,) * buf.ndim)


class LazyBucket:
    """A bucket-shaped engine result that has NOT been sliced to its true
    extent: ``buffer`` is the raw per-bucket program output (rows past
    ``extent`` along ``axis`` hold garbage the masked-tail contract never
    reads), ``extent`` is the true dynamic size.

    ``.shape`` reports the TRUE shape, so workload ``bind``/``dispatch_key``
    /``dynamic_extent`` hooks (which only read ``.shape``/``.dtype``) treat
    a handle exactly like the realized array.  Realization — the deferred
    output slice — happens once, lazily: when a non-engine consumer forces
    it via :meth:`realize` or the ``__jax_array__`` protocol.  An engine
    dispatch whose operand is a handle in a compatible bucket skips it
    entirely and consumes ``buffer`` directly (``DispatchStats.forwarded``).

    Handles are eager-only plumbing between dispatches; they are not pytree
    leaves and must not cross a ``jit`` boundary unrealized.
    """

    __slots__ = ("buffer", "extent", "axis", "_stats", "_lock", "_realized")

    def __init__(self, buffer, extent, axis, stats=None, lock=None):
        self.buffer = buffer
        self.extent = int(extent)
        self.axis = axis
        self._stats = stats
        self._lock = lock
        self._realized = None

    # -- array-protocol surface (what shape-reading hooks consume) ---------

    @property
    def shape(self) -> tuple:
        s = list(self.buffer.shape)
        s[self.axis] = self.extent
        return tuple(s)

    @property
    def dtype(self):
        return self.buffer.dtype

    @property
    def ndim(self) -> int:
        return self.buffer.ndim

    @property
    def padded_extent(self) -> int:
        """The bucket size the buffer is shaped to along ``axis``."""
        return self.buffer.shape[self.axis]

    @property
    def is_aligned(self) -> bool:
        return self.padded_extent == self.extent

    def _count_slice(self) -> None:
        if self._stats is not None:
            if self._lock is not None:
                with self._lock:
                    self._stats.realize_slices += 1
            else:
                self._stats.realize_slices += 1

    def realize(self) -> jax.Array:
        """The true-extent array (the deferred unstage).  Identity for an
        aligned bucket; otherwise ONE counted slice, cached so repeated
        forcing pays once."""
        if self._realized is None:
            if self.is_aligned:
                self._realized = self.buffer
            else:
                self._realized = jax.lax.slice_in_dim(
                    self.buffer, 0, self.extent, axis=self.axis
                )
                self._count_slice()
        return self._realized

    def __jax_array__(self) -> jax.Array:
        return self.realize()

    def rewrap(self, buffer, extent=None, axis=None) -> "LazyBucket":
        """A new handle over ``buffer`` sharing this handle's copy
        accounting — for extent-preserving reshapes/transposes between
        dispatches (split/merge heads, flattening batch into rows)."""
        return LazyBucket(
            buffer,
            self.extent if extent is None else extent,
            self.axis if axis is None else axis,
            self._stats,
            self._lock,
        )

    def map(self, fn) -> "LazyBucket":
        """Apply a ROW-LOCAL ``fn`` (output row i depends only on input row
        i along ``axis``) to the raw buffer: garbage tail rows stay confined
        past ``extent``.  The handle's bucket geometry must survive."""
        out = fn(self.buffer)
        if out.shape[self.axis] != self.padded_extent:
            raise ValueError(
                f"map changed the bucket axis: {self.padded_extent} -> "
                f"{out.shape[self.axis]}"
            )
        return self.rewrap(out)

    def clamp(self, padded: int) -> "LazyBucket":
        """This handle re-bucketed to ``padded`` rows along ``axis`` (true
        extent unchanged).  Identity when already that size; otherwise one
        counted boundary slice — how chain drivers pin a dispatch output
        that came back in a larger bucket to the chain's width."""
        if self.padded_extent == padded:
            return self
        if padded < self.extent:
            raise ValueError(
                f"cannot clamp below the true extent: {padded} < "
                f"{self.extent}"
            )
        buf = jax.lax.slice_in_dim(self.buffer, 0, padded, axis=self.axis)
        self._count_slice()
        return self.rewrap(buf)

    def __repr__(self) -> str:
        return (
            f"LazyBucket(shape={self.shape}, padded_extent="
            f"{self.padded_extent}, axis={self.axis}, dtype={self.dtype})"
        )


def lazy_map(fn, *xs):
    """Apply an elementwise/row-local ``fn`` across arrays and LazyBuckets
    without realizing: the chain glue for the non-engine ops between
    dispatches (norms, residual adds, activations).

    ``fn`` must be ROW-LOCAL along the handles' bucket axis.  All handle
    operands must share (axis, padded_extent) — then ``fn`` runs on the raw
    buffers and the result is re-wrapped (extent = min of the operands', so
    any row past a partial operand's extent is conservatively garbage).
    Incompatible handles fall back to realizing everything (counted).
    Plain-array operands must broadcast against the BUFFER shape (e.g.
    per-feature norm weights).  With no handle operands this is ``fn(*xs)``.
    """
    handles = [x for x in xs if isinstance(x, LazyBucket)]
    if not handles:
        return fn(*xs)
    ref = handles[0]
    if any(
        h.axis != ref.axis or h.padded_extent != ref.padded_extent
        for h in handles[1:]
    ):
        return fn(
            *(x.realize() if isinstance(x, LazyBucket) else x for x in xs)
        )
    out = fn(*(x.buffer if isinstance(x, LazyBucket) else x for x in xs))
    if out.shape[ref.axis] != ref.padded_extent:
        raise ValueError(
            "lazy_map fn changed the bucket axis: "
            f"{ref.padded_extent} -> {out.shape[ref.axis]}"
        )
    return ref.rewrap(out, extent=min(h.extent for h in handles))


class _StagingPool:
    """A small pool of engine-owned staging-buffer SETS for one cache entry.

    One set (a dict mapping view-arg index -> bucket-shaped buffer) serves
    one in-flight unaligned dispatch: concurrent same-bucket calls each
    check out their own set, stage and launch WITHOUT any entry-wide lock,
    and return the set afterwards — the per-dtype-singleton design this
    replaces serialized staging AND the launch of every concurrent
    same-bucket call behind one lock (ROADMAP: multi-tenant serialization).

    The pool lock covers only the list pop/append (nanoseconds).  A set's
    buffers keep whatever stale bytes the last staging left past the true
    extent — never re-zeroed; correctness is the kernel's kv_len/m_true
    masking (the poisoned-staging tests assert it).  Retention is an LRU
    bounded at ``cap`` sets (``EngineConfig.staging_pool_cap``): a release
    lands at the MRU end and evicts from the LRU end when over cap, so a
    burst beyond the cap allocates transient sets that age out instead of
    pinning device memory forever.  Eviction can never touch an in-flight
    dispatch: a checked-out set is not in the free list at all until its
    caller releases it.
    """

    __slots__ = ("cap", "_lock", "_free")

    def __init__(self, cap: int = 4):
        self.cap = cap
        self._lock = threading.Lock()
        self._free: list[dict] = []

    def acquire(self, need: dict) -> dict:
        """A buffer set satisfying ``need`` (index -> (shape, dtype)).
        Reuses a pooled set when every needed slot matches; otherwise
        builds fresh zero-initialized buffers (zeros only because a fresh
        buffer must not leak other tenants' bytes through the never-read
        pad — the kernels never rely on it)."""
        with self._lock:
            # MRU-first scan: the most recently released set is the most
            # likely to still match (and the least likely to be evicted).
            for i in range(len(self._free) - 1, -1, -1):
                bufs = self._free[i]
                for idx, (shape, dtype) in need.items():
                    b = bufs.get(idx)
                    if b is None or b.shape != shape or b.dtype != dtype:
                        break
                else:
                    return self._free.pop(i)
        return {
            idx: jax.numpy.zeros(shape, dtype)
            for idx, (shape, dtype) in need.items()
        }

    def release(self, bufs: dict) -> None:
        with self._lock:
            self._free.append(bufs)  # MRU end
            while len(self._free) > self.cap:
                self._free.pop(0)  # evict LRU

    @property
    def retained(self) -> list[dict]:
        """The currently pooled buffer sets (tests poison these)."""
        return self._free


@dataclasses.dataclass
class _CacheEntry:
    """One fused per-bucket program + its engine-owned staging state.

    ``fn`` is the dtype-flexible jitted program (also what tracer-context
    calls inline); ``aot`` is the AOT ``lower().compile()`` artifact for the
    bucket's canonical dtypes — the steady-state serve path, which skips
    jit's dispatch machinery entirely.  ``pool`` holds the engine-owned
    bucket-shaped staging buffer sets (created lazily on the first
    unaligned call; their pad regions are NEVER re-zeroed — correctness
    is the kernel's masking, asserted by the poisoned-staging tests).
    """

    fn: Callable
    compile_seconds: float
    aot: Any = None
    aot_dtypes: tuple = ()
    hits: int = 0
    pool: _StagingPool = dataclasses.field(default_factory=_StagingPool)

    def run(self, *args):
        if faults.ACTIVE is not None:
            faults.ACTIVE.check("aot_launch")
        if self.aot is not None and len(args) == len(self.aot_dtypes):
            for a, d in zip(args, self.aot_dtypes):
                if getattr(a, "dtype", None) != d:
                    break
            else:
                return self.aot(*args)
        return self.fn(*args)


class VortexKernel:
    """One dynamic-shape workload, compiled sample-free.

    Generic over the Workload protocol: the workload declares its lattice
    footprints, its runtime-dims view and its executable builder; this class
    owns the offline build (lattice + scoring, optionally shared through
    ``scored_cache``), the runtime selector and the bucketed executable
    cache.  This is the unit the paper evaluates (BERT GEMMs with
    M = batch*seq; attention/conv ride the same machinery).

    ``table_m_max``/``table_extend_limit`` size the selector's offline
    selection table (see selector.py); they are what
    :class:`repro.vortex.EngineConfig` threads through.
    """

    def __init__(
        self,
        hw: HardwareSpec,
        wl: Workload,
        profiler: Profiler | None = None,
        empirical_levels: tuple[int, ...] = (0,),
        backends: tuple[str, ...] | None = None,
        num_cores: int = 1,
        impl: str = "xla",
        interpret: bool = True,
        scored_cache: dict | None = None,
        table_m_max: int = 4096,
        table_extend_limit: int = 1 << 17,
        staging: bool = True,
        staging_pool_cap: int = 4,
        max_retries: int = 2,
        denylist=None,
    ):
        self._hw = hw
        self._wl = wl
        self._impl = impl
        self._interpret = interpret
        self._staging = staging and wl.supports_staging
        self._pool_cap = staging_pool_cap
        self._max_retries = max(int(max_retries), 0)
        # The degradation ladder's quarantine (DESIGN.md §11): string keys
        # of candidates that failed at precompile or launch on THIS host.
        # Seeded from the persisted denylist (same fingerprint key as the
        # calibration cache) so restarts never re-fail a known-bad
        # candidate; empty on every healthy host, so the hot path pays one
        # falsy set check.
        self._denylist = denylist
        self._sig_key = repr(wl.signature)
        self._quarantined: set[str] = (
            set(denylist.get(self._sig_key)) if denylist is not None
            else set()
        )
        self.dispatch_stats = DispatchStats()
        t0 = time.perf_counter()
        backends = backends or tuple(hw.backends)
        scored: dict[str, ScoredLattice] = {}
        n_cands = 0
        n_meas = 0
        for backend in backends:
            cache_key = (wl.lattice_key, hw.name, backend, empirical_levels)
            hit = scored_cache.get(cache_key) if scored_cache is not None \
                else None
            if hit is not None:
                scored[backend] = hit
                continue
            lattice = generate_lattice(hw, wl, backend)
            n_cands += lattice.num_candidates()
            analyzer = HybridAnalyzer(
                hw, wl, profiler=profiler, empirical_levels=empirical_levels
            )
            sl = analyzer.score(lattice)
            n_meas += sl.num_measured
            scored[backend] = sl
            if scored_cache is not None:
                scored_cache[cache_key] = sl
        self.selector = RuntimeSelector(
            hw, wl, scored, num_cores=num_cores,
            table_m_max=table_m_max, table_extend_limit=table_extend_limit,
        )
        self.offline_stats = OfflineStats(
            num_candidates=n_cands,
            num_measured=n_meas,
            build_seconds=time.perf_counter() - t0,
            backends=backends,
        )
        self._exec_cache: dict[tuple, _CacheEntry] = {}
        # DispatchStats increments are read-modify-writes; concurrent
        # same-bucket dispatch (the staging pool's whole point) would lose
        # counts without this.  Never held across a launch.
        self._stats_lock = threading.Lock()

    @property
    def workload(self) -> Workload:
        return self._wl

    @property
    def impl(self) -> str:
        """Executable implementation ("xla"/"pallas") — what the background
        calibrator builds candidate executables with, so measured costs
        price the SAME lowering the serving path launches."""
        return self._impl

    @property
    def interpret(self) -> bool:
        return self._interpret

    # -- executable construction ------------------------------------------

    def _build_executable(self, sel: Selection, args: tuple) -> _CacheEntry:
        if faults.ACTIVE is not None:
            faults.ACTIVE.check("precompile")
        fn = self._wl.build_executable(
            sel, impl=self._impl, interpret=self._interpret
        )
        jfn = jax.jit(fn)
        t0 = time.perf_counter()
        warm = self._wl.example_args(sel, *args)
        # ONE AOT program per bucket (the same lower().compile() pattern the
        # serving driver uses for prefill): staging + masked kernel + no
        # in-program pads means this single artifact IS the whole dispatch.
        aot = jfn.lower(*warm).compile()
        aot_dtypes = tuple(
            jax.numpy.asarray(w).dtype for w in warm
        )
        return _CacheEntry(
            fn=jfn, compile_seconds=time.perf_counter() - t0,
            aot=aot, aot_dtypes=aot_dtypes,
            pool=_StagingPool(self._pool_cap),
        )

    def _exec_cache_key(self, sel: Selection, args: tuple) -> tuple:
        return (
            sel.bucket, sel.strategy.l1, sel.backend, self._impl,
            self._wl.exec_key(*args) if args else (),
        )

    def _entry_for(self, sel: Selection, args: tuple = ()) -> _CacheEntry:
        key = self._exec_cache_key(sel, args)
        entry = self._exec_cache.get(key)
        if entry is None:
            entry = self._build_executable(sel, args)
            self._exec_cache[key] = entry
        entry.hits += 1
        return entry

    # -- public API ---------------------------------------------------------

    def select(self, m: int) -> Selection:
        return self.selector.select(m)

    def precompile(
        self, m_max: int, *args, max_workers: int | None = None
    ) -> int:
        """Precompile every bucket reachable for M <= m_max (sample-free:
        the bucket set comes from the lattice, not from shape samples).

        Workloads whose executables specialize on outer dims beyond the
        bucket (``exec_key``, e.g. attention's batch/head counts) need
        representative call ``args`` — otherwise the warmed entries sit
        under a key real calls never hit.  Only the args' shapes matter.

        Missing buckets compile on a thread pool (XLA compilation releases
        the GIL); ``max_workers`` caps it, defaulting to min(8, cpu count).
        A failing bucket raises :class:`PrecompileError` naming the failing
        Selection — after every other bucket has drained and registered, so
        a retry after fixing the bad bucket recompiles nothing else.
        """
        sels = self.selector.selections_upto(m_max)
        pending: dict[tuple, Selection] = {}
        for sel in sels:
            key = self._exec_cache_key(sel, args)
            if key not in self._exec_cache and key not in pending:
                pending[key] = sel
        if pending:
            workers = min(
                max_workers or 8, os.cpu_count() or 1, len(pending)
            )
            if workers > 1:
                # Drain ALL futures, registering each success as it
                # completes, and only then raise for the first failure:
                # raising mid-drain would block in the executor's shutdown
                # anyway (no cancel) while discarding every in-flight build
                # that finishes after the failure — a retry would recompile
                # buckets that had already built fine.
                failed: tuple[Selection, Exception] | None = None
                with ThreadPoolExecutor(max_workers=workers) as pool:
                    futures = {
                        pool.submit(self._build_executable, sel, args): key
                        for key, sel in pending.items()
                    }
                    for fut in as_completed(futures):
                        key = futures[fut]
                        try:
                            self._exec_cache[key] = fut.result()
                        except Exception as e:
                            if failed is None:
                                failed = (pending[key], e)
                if failed is not None:
                    sel, e = failed
                    raise PrecompileError(self._wl.kind, sel, e) from e
            else:
                for key, sel in pending.items():
                    try:
                        self._exec_cache[key] = self._build_executable(
                            sel, args
                        )
                    except Exception as e:
                        raise PrecompileError(self._wl.kind, sel, e) from e
        return len(sels)

    def __call__(self, *args, lazy: bool = False):
        """Dynamic-shape dispatch through the masked-tail staging contract.

        Select on the runtime extent, then launch the ONE fused per-bucket
        AOT program:

          * bucket-aligned extent — the call args are the program inputs
            directly: zero copies, one launch;
          * unaligned extent — dynamic args are staged into engine-owned,
            donated bucket buffers (O(true-size) writes, no allocation, no
            zero fill; the pad tail keeps stale bytes that the kernel masks
            via the runtime-extent scalar), then one launch, then the
            output slice back to the true extent.

        ``jnp.pad`` never runs on this path.  Calls arriving as tracers
        (inside an enclosing jit, e.g. serve's AOT prefill lowering) take
        the functional zero-pad reference path instead — XLA fuses it into
        the surrounding program, and engine-owned buffers must not be
        captured by a trace.

        :class:`LazyBucket` operands at positions the workload declares in
        ``consumes_staged`` forward their bucket buffer into the program
        directly (``_call_forwarded``): no unstage of the producer, no
        restage here when the buckets agree.  Handles at any other
        position realize first (one counted slice).  With ``lazy=True``
        the output is returned as a LazyBucket instead of being finalized
        — best-effort: reference-path calls (tracers, staging disabled)
        still return plain finalized arrays, so chain drivers must accept
        both.

        A candidate that raises at executable build or launch walks the
        degradation ladder (``_degrade``): quarantine, re-select the
        next-best lattice candidate, retry up to ``max_retries``, then the
        XLA reference rung — the call still returns a correct result
        whenever any rung works.
        """
        wl = self._wl
        if any(isinstance(a, LazyBucket) for a in args):
            fwd = wl.consumes_staged if self._staging else {}
            args = tuple(
                a.realize()
                if isinstance(a, LazyBucket) and i not in fwd else a
                for i, a in enumerate(args)
            )
            handles = {
                i for i, a in enumerate(args) if isinstance(a, LazyBucket)
            }
            if handles:
                return self._call_forwarded(args, handles, lazy)
        m = wl.dynamic_extent(*args)
        sel = self._select_healthy(m)
        try:
            return self._dispatch(sel, m, args, lazy)
        except Exception as exc:
            return self._degrade(m, sel, args, lazy, exc)

    def _dispatch(self, sel: Selection, m: int, args: tuple, lazy: bool):
        """One dispatch attempt at a fixed Selection (the ladder's rung
        body; exactly the pre-ladder dispatch path)."""
        wl = self._wl
        entry = self._entry_for(sel, args)
        st = self.dispatch_stats
        view = wl.stage_view(*args)
        if not self._staging:
            with self._stats_lock:
                st.calls += 1
            return self._call_padded(sel, entry, args, view)
        if any(isinstance(a, jax.core.Tracer) for a in view):
            with self._stats_lock:
                st.calls += 1
                st.traced_calls += 1
            return self._call_padded(sel, entry, args, view)
        lazy_out = lazy and wl.staged_out_axis is not None
        scalars = wl.runtime_scalars(sel, *view)
        shapes = wl.staged_shapes(sel, *view)
        unaligned = [
            i for i, s in enumerate(shapes)
            if s is not None and view[i].shape != s
        ]
        if not unaligned:
            with self._stats_lock:
                st.calls += 1
                st.aligned_calls += 1
                st.launches += 1
            out = entry.run(*view, *scalars)
            if lazy_out:
                return LazyBucket(
                    out, m, wl.staged_out_axis, st, self._stats_lock
                )
            return wl.finalize(sel, out, *args)
        # Check a buffer set out of the entry's pool: staging and the
        # launch run with NO entry-wide lock, so concurrent same-bucket
        # dispatches overlap instead of serializing (each set is private
        # to this call until released).
        need = {i: (shapes[i], view[i].dtype) for i in unaligned}
        bufs = entry.pool.acquire(need)
        staged = list(view)
        for i in unaligned:
            buf = _stage_into(bufs[i], view[i])
            bufs[i] = buf
            staged[i] = buf
        with self._stats_lock:
            st.calls += 1
            st.unaligned_calls += 1
            st.stage_copies += len(unaligned)
            st.launches += 1
            # A lazy output defers the unstage slice: it is only paid (and
            # counted, as realize_slices) if a non-engine consumer forces
            # the handle.
            if wl.unstages and not lazy_out:
                st.unstage_copies += 1
        try:
            out = entry.run(*staged, *scalars)
        finally:
            # Settle the staging-pool checkout on the failure path too: a
            # launch that raises (degradation ladder) must not strand the
            # buffer set — the staged buffers stay valid (the launch does
            # not donate them), so they go straight back into rotation.
            entry.pool.release(bufs)
        if lazy_out:
            return LazyBucket(out, m, wl.staged_out_axis, st,
                              self._stats_lock)
        return wl.finalize(sel, out, *args)

    # -- degradation ladder (DESIGN.md §11) ---------------------------------

    @staticmethod
    def _qkey(sel: Selection) -> str:
        """The quarantine identity of a candidate: what failed is the
        (bucket, backend, tiling) triple — the executable the lattice
        produced — not the runtime extent that happened to trigger it."""
        return repr((sel.bucket, sel.backend, sel.strategy.tiles))

    def _select_healthy(self, m: int) -> Selection:
        """The table/argmin selection, skipping quarantined candidates.

        The quarantine set is empty on every healthy host, so the hot path
        pays one falsy check on top of the plain ``select``.
        """
        sel = self.selector.select(m)
        q = self._quarantined
        if q and self._qkey(sel) in q:
            healthy = self.selector.select_excluding(m, q, self._qkey)
            if healthy is not None:
                return healthy
        return sel

    def _quarantine(self, sel: Selection) -> bool:
        """Quarantine ``sel``; True if it was not already quarantined."""
        key = self._qkey(sel)
        if key in self._quarantined:
            return False
        with self._stats_lock:
            self.dispatch_stats.quarantined += 1
        self._quarantined.add(key)
        return True

    def _degrade(
        self, m: int, sel: Selection, args: tuple, lazy: bool,
        exc: Exception,
    ):
        """Walk the ladder after ``sel`` failed: quarantine it, re-select
        the next-best lattice candidate excluding quarantined entries,
        retry up to ``max_retries``, then run the XLA reference rung.

        Quarantine keys are persisted to the denylist only once a LOWER
        rung succeeds — evidence the failure was candidate-specific rather
        than a caller error (bad dtypes, shape mismatch) that every
        candidate would reproduce.  If even the reference rung fails, this
        call's quarantines are rolled back and the original exception
        propagates: nothing was learned about the candidates.
        """
        fresh = [sel] if self._quarantine(sel) else []
        for _ in range(self._max_retries):
            nxt = self.selector.select_excluding(
                m, self._quarantined, self._qkey
            )
            if nxt is None:
                break  # lattice exhausted: straight to the reference rung
            try:
                out = self._dispatch(nxt, m, args, lazy)
            except Exception as e:
                exc = e
                if self._quarantine(nxt):
                    fresh.append(nxt)
                continue
            self._persist_quarantines(fresh)
            return out
        try:
            out = self._fallback_dispatch(m, args)
        except Exception as e:
            with self._stats_lock:
                self.dispatch_stats.quarantined -= len(fresh)
            for t in fresh:
                self._quarantined.discard(self._qkey(t))
            raise e from exc
        self._persist_quarantines(fresh)
        return out

    def _persist_quarantines(self, fresh: list[Selection]) -> None:
        if self._denylist is None:
            return
        for t in fresh:
            self._denylist.add(self._sig_key, self._qkey(t))

    def _fallback_dispatch(self, m: int, args: tuple):
        """The last rung: a plain jitted XLA reference executable for the
        analytical selection's bucket, via the zero-pad reference path.
        No AOT entry, no staging buffers — nothing the failing rungs
        shared — and no fault hooks, so chaos plans cannot reach it."""
        wl = self._wl
        sel = self.selector.select(m)
        key = (
            "__xla_fallback__", sel.bucket, sel.strategy.l1,
            wl.exec_key(*args) if args else (),
        )
        entry = self._exec_cache.get(key)
        if entry is None:
            fn = wl.build_executable(
                sel, impl="xla", interpret=self._interpret
            )
            entry = _CacheEntry(fn=jax.jit(fn), compile_seconds=0.0)
            self._exec_cache[key] = entry
        entry.hits += 1
        with self._stats_lock:
            self.dispatch_stats.calls += 1
            self.dispatch_stats.fallbacks += 1
        return self._call_padded(sel, entry, args)

    def _call_forwarded(self, args: tuple, handles: set, lazy: bool):
        """Bucket-to-bucket dispatch: LazyBucket operands hand their raw
        bucket buffers to the program, the true extents ride in the runtime
        scalars.  Selection happens at the PADDED extent (the buffers' own
        bucket), so a producer and consumer sharing a bucket forward with
        zero copies; a handle whose buffer does not match this selection's
        staged shape restages (counted stage copy) — correct either way,
        because staged tails are garbage by contract and every mask scalar
        is computed from the TRUE shapes.

        ``consumes_staged`` positions are call-arg positions; only
        identity-``stage_view`` workloads declare any, so view index ==
        arg index throughout.
        """
        wl = self._wl
        st = self.dispatch_stats

        def realize_all():
            flat = tuple(
                a.realize() if isinstance(a, LazyBucket) else a for a in args
            )
            return self(*flat, lazy=lazy)

        raw = tuple(
            a.buffer if isinstance(a, LazyBucket) else a for a in args
        )
        true = tuple(
            jax.ShapeDtypeStruct(a.shape, a.dtype)
            if isinstance(a, LazyBucket) else a
            for a in args
        )
        view = wl.stage_view(*raw)
        if any(isinstance(a, jax.core.Tracer) for a in view):
            return realize_all()  # forwarding is eager-only
        try:
            m_disp = wl.dynamic_extent(*raw)
            m_true = wl.dynamic_extent(*true)
        except AssertionError:
            # Mixed handle/plain operands whose padded vs true extents the
            # workload refuses to reconcile (attention's q/kv seq match).
            return realize_all()
        sel = self.selector.select(m_disp)
        entry = self._entry_for(sel, raw)
        scalars = wl.runtime_scalars(sel, *wl.stage_view(*true))
        shapes = wl.staged_shapes(sel, *view)
        unaligned = [
            i for i, s in enumerate(shapes)
            if s is not None and view[i].shape != s
        ]
        lazy_out = lazy and wl.staged_out_axis is not None
        slices_out = (
            wl.unstages and not lazy_out and wl.dynamic_bucket(sel) != m_true
        )
        if not unaligned:
            with self._stats_lock:
                st.calls += 1
                st.aligned_calls += 1
                st.launches += 1
                st.forwarded += len(handles)
                if slices_out:
                    st.unstage_copies += 1
            out = entry.run(*view, *scalars)
        else:
            need = {i: (shapes[i], view[i].dtype) for i in unaligned}
            bufs = entry.pool.acquire(need)
            staged = list(view)
            for i in unaligned:
                # Restaging a handle writes its WHOLE buffer — garbage tail
                # included — into the larger bucket; safe, since the
                # scalars above mask at the true extents.
                buf = _stage_into(bufs[i], view[i])
                bufs[i] = buf
                staged[i] = buf
            with self._stats_lock:
                st.calls += 1
                st.unaligned_calls += 1
                st.stage_copies += len(unaligned)
                st.launches += 1
                st.forwarded += len(handles - set(unaligned))
                if slices_out:
                    st.unstage_copies += 1
            out = entry.run(*staged, *scalars)
            entry.pool.release(bufs)
        if lazy_out:
            return LazyBucket(
                out, m_true, wl.staged_out_axis, st, self._stats_lock
            )
        return wl.finalize(sel, out, *true)

    def _call_padded(self, sel, entry, args, view=None) -> jax.Array:
        """The zero-pad reference path: functionally identical to staging
        (same fused executable, same extent scalars), with fresh padded
        allocations instead of engine-owned buffers.  Used for parity
        testing, tracer-context calls, and staging-disabled kernels."""
        wl = self._wl
        st = self.dispatch_stats
        if view is None:
            view = wl.stage_view(*args)
        scalars = wl.runtime_scalars(sel, *view)
        if not wl.supports_staging:
            # Legacy-contract workloads: prepare is the only bucket mapping
            # (it must be an identity for already-aligned extents).
            with self._stats_lock:
                st.padded_calls += 1
            out = entry.fn(*wl.prepare(sel, *view), *scalars)
            return wl.finalize(sel, out, *args)
        shapes = wl.staged_shapes(sel, *view)
        aligned = all(
            s is None or view[i].shape == s for i, s in enumerate(shapes)
        )
        if aligned:
            out = entry.fn(*view, *scalars)
        else:
            with self._stats_lock:
                st.padded_calls += 1
            out = entry.fn(*wl.prepare(sel, *view), *scalars)
        return wl.finalize(sel, out, *args)

    def call_padded(self, *args) -> jax.Array:
        """Public reference dispatch: the padded path end to end (select,
        zero-pad prepare, fused executable, finalize).  The staged hot path
        must be bit-identical to this — tests/test_staged_dispatch.py."""
        wl = self._wl
        sel = self.selector.select(wl.dynamic_extent(*args))
        entry = self._entry_for(sel, args)
        with self._stats_lock:
            self.dispatch_stats.calls += 1
        return self._call_padded(sel, entry, args)

    @property
    def cache_info(self) -> dict:
        return {
            "entries": len(self._exec_cache),
            "hits": sum(e.hits for e in self._exec_cache.values()),
            "compile_seconds": sum(
                e.compile_seconds for e in self._exec_cache.values()
            ),
        }

    @property
    def select_stats(self) -> dict:
        s = self.selector.stats
        return {
            "selects": s.selects,
            "table_hits": s.table_hits,
            "lru_hits": s.lru_hits,
            "argmin_misses": s.argmin_misses,
            "cache_hits": s.cache_hits,
            "mean_select_us": s.mean_select_us,
            "table_builds": s.table_builds,
            "table_build_seconds": s.table_build_seconds,
            "calibration_seconds": s.calibration_seconds,
            "table_swaps": s.table_swaps,
        }


def __getattr__(name: str):
    # Deprecation shims live with the public API (repro.vortex.compat) but
    # stay importable from their historical home; the import is deferred so
    # repro.core never pulls repro.vortex at module-import time (the vortex
    # package imports this module).
    if name in ("VortexEngine", "VortexGemm"):
        from repro.vortex import compat

        return getattr(compat, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
