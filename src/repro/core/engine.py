"""VortexKernel: the end-to-end sample-free compiler (paper Fig. 6).

Offline stage (no shape samples anywhere):
  1. top-down: describe the workload as an rKernel program (workloads.py
     declares it; rkernel.py holds the layer metadata),
  2. bottom-up: generate the hardware-pruned candidate lattice per backend
     (candidates.py, Algorithm 2),
  3. score it with the hybrid analyzer (analyzer.py).

Runtime stage:
  4. given the actual shape, select strategy + launch geometry + backend
     (selector.py) — a bisect into the offline-materialized selection table
     (selection_table.py) on the hot path, the fused analytical argmin past
     the table,
  5. construct/fetch the executable for the induced bucket and run (skipping
     pad/unpad entirely when the extent is already bucket-aligned).

:class:`VortexKernel` drives ANY registered
:class:`~repro.core.workloads.Workload` through the same lattice → analyzer →
selector → bucketed-executable pipeline.  The multi-workload session layer —
one engine serving every registered kind from one scored-lattice cache and
one dispatch table — lives in :mod:`repro.vortex` (the public API);
``VortexEngine``/``VortexGemm`` remain importable from here as deprecation
shims over that package.

Execution backends:
  * ``xla``    — flat JAX ops on the bucket shape (host-CPU execution in
                 this container; what the benchmarks time),
  * ``pallas`` — the Vortex-tiled Pallas TPU kernels (kernels/) with
                 BlockSpecs taken from the selected strategy; run in
                 interpret mode off-TPU and compile natively on TPU.
"""
from __future__ import annotations

import dataclasses
import os
import time
from concurrent.futures import ThreadPoolExecutor, as_completed
from typing import Callable

import jax

from repro.core.analyzer import HybridAnalyzer, Profiler, ScoredLattice
from repro.core.candidates import generate_lattice
from repro.core.hardware import HardwareSpec
from repro.core.selector import RuntimeSelector, Selection
from repro.core.workloads import Workload

__all__ = [
    "OfflineStats",
    "PrecompileError",
    "VortexKernel",
    "VortexGemm",
    "VortexEngine",
]


@dataclasses.dataclass(frozen=True)
class OfflineStats:
    """Offline-stage accounting (paper §7.4 'Offline Overhead Analysis')."""

    num_candidates: int
    num_measured: int
    build_seconds: float
    backends: tuple[str, ...]


class PrecompileError(RuntimeError):
    """A bucket failed to compile during :meth:`VortexKernel.precompile`.

    Parallel precompiles surface through ``as_completed`` futures, which
    would otherwise raise the bare builder exception with no hint of WHICH
    bucket died; this wrapper names the failing Selection so a fleet-wide
    warmup failure is diagnosable from the message alone.
    """

    def __init__(self, kind: str, sel: Selection, cause: BaseException):
        self.kind = kind
        self.selection = sel
        super().__init__(
            f"precompile failed for workload {kind!r}: bucket={sel.bucket} "
            f"backend={sel.backend} strategy l1={sel.strategy.l1} "
            f"grid={sel.grid}: {type(cause).__name__}: {cause}"
        )


@dataclasses.dataclass
class _CacheEntry:
    fn: Callable
    compile_seconds: float
    hits: int = 0


class VortexKernel:
    """One dynamic-shape workload, compiled sample-free.

    Generic over the Workload protocol: the workload declares its lattice
    footprints, its runtime-dims view and its executable builder; this class
    owns the offline build (lattice + scoring, optionally shared through
    ``scored_cache``), the runtime selector and the bucketed executable
    cache.  This is the unit the paper evaluates (BERT GEMMs with
    M = batch*seq; attention/conv ride the same machinery).

    ``table_m_max``/``table_extend_limit`` size the selector's offline
    selection table (see selector.py); they are what
    :class:`repro.vortex.EngineConfig` threads through.
    """

    def __init__(
        self,
        hw: HardwareSpec,
        wl: Workload,
        profiler: Profiler | None = None,
        empirical_levels: tuple[int, ...] = (0,),
        backends: tuple[str, ...] | None = None,
        num_cores: int = 1,
        impl: str = "xla",
        interpret: bool = True,
        scored_cache: dict | None = None,
        table_m_max: int = 4096,
        table_extend_limit: int = 1 << 17,
    ):
        self._hw = hw
        self._wl = wl
        self._impl = impl
        self._interpret = interpret
        t0 = time.perf_counter()
        backends = backends or tuple(hw.backends)
        scored: dict[str, ScoredLattice] = {}
        n_cands = 0
        n_meas = 0
        for backend in backends:
            cache_key = (wl.lattice_key, hw.name, backend, empirical_levels)
            hit = scored_cache.get(cache_key) if scored_cache is not None \
                else None
            if hit is not None:
                scored[backend] = hit
                continue
            lattice = generate_lattice(hw, wl, backend)
            n_cands += lattice.num_candidates()
            analyzer = HybridAnalyzer(
                hw, wl, profiler=profiler, empirical_levels=empirical_levels
            )
            sl = analyzer.score(lattice)
            n_meas += sl.num_measured
            scored[backend] = sl
            if scored_cache is not None:
                scored_cache[cache_key] = sl
        self.selector = RuntimeSelector(
            hw, wl, scored, num_cores=num_cores,
            table_m_max=table_m_max, table_extend_limit=table_extend_limit,
        )
        self.offline_stats = OfflineStats(
            num_candidates=n_cands,
            num_measured=n_meas,
            build_seconds=time.perf_counter() - t0,
            backends=backends,
        )
        self._exec_cache: dict[tuple, _CacheEntry] = {}

    @property
    def workload(self) -> Workload:
        return self._wl

    # -- executable construction ------------------------------------------

    def _build_executable(self, sel: Selection, args: tuple) -> _CacheEntry:
        fn = self._wl.build_executable(
            sel, impl=self._impl, interpret=self._interpret
        )
        jfn = jax.jit(fn)
        t0 = time.perf_counter()
        warm = self._wl.example_args(sel, *args)
        jax.block_until_ready(jfn(*warm))
        return _CacheEntry(fn=jfn, compile_seconds=time.perf_counter() - t0)

    def _exec_cache_key(self, sel: Selection, args: tuple) -> tuple:
        return (
            sel.bucket, sel.strategy.l1, sel.backend, self._impl,
            self._wl.exec_key(*args) if args else (),
        )

    def _entry_for(self, sel: Selection, args: tuple = ()) -> _CacheEntry:
        key = self._exec_cache_key(sel, args)
        entry = self._exec_cache.get(key)
        if entry is None:
            entry = self._build_executable(sel, args)
            self._exec_cache[key] = entry
        entry.hits += 1
        return entry

    # -- public API ---------------------------------------------------------

    def select(self, m: int) -> Selection:
        return self.selector.select(m)

    def precompile(
        self, m_max: int, *args, max_workers: int | None = None
    ) -> int:
        """Precompile every bucket reachable for M <= m_max (sample-free:
        the bucket set comes from the lattice, not from shape samples).

        Workloads whose executables specialize on outer dims beyond the
        bucket (``exec_key``, e.g. attention's batch/head counts) need
        representative call ``args`` — otherwise the warmed entries sit
        under a key real calls never hit.  Only the args' shapes matter.

        Missing buckets compile on a thread pool (XLA compilation releases
        the GIL); ``max_workers`` caps it, defaulting to min(8, cpu count).
        A failing bucket raises :class:`PrecompileError` naming the failing
        Selection — after every other bucket has drained and registered, so
        a retry after fixing the bad bucket recompiles nothing else.
        """
        sels = self.selector.selections_upto(m_max)
        pending: dict[tuple, Selection] = {}
        for sel in sels:
            key = self._exec_cache_key(sel, args)
            if key not in self._exec_cache and key not in pending:
                pending[key] = sel
        if pending:
            workers = min(
                max_workers or 8, os.cpu_count() or 1, len(pending)
            )
            if workers > 1:
                # Drain ALL futures, registering each success as it
                # completes, and only then raise for the first failure:
                # raising mid-drain would block in the executor's shutdown
                # anyway (no cancel) while discarding every in-flight build
                # that finishes after the failure — a retry would recompile
                # buckets that had already built fine.
                failed: tuple[Selection, Exception] | None = None
                with ThreadPoolExecutor(max_workers=workers) as pool:
                    futures = {
                        pool.submit(self._build_executable, sel, args): key
                        for key, sel in pending.items()
                    }
                    for fut in as_completed(futures):
                        key = futures[fut]
                        try:
                            self._exec_cache[key] = fut.result()
                        except Exception as e:
                            if failed is None:
                                failed = (pending[key], e)
                if failed is not None:
                    sel, e = failed
                    raise PrecompileError(self._wl.kind, sel, e) from e
            else:
                for key, sel in pending.items():
                    try:
                        self._exec_cache[key] = self._build_executable(
                            sel, args
                        )
                    except Exception as e:
                        raise PrecompileError(self._wl.kind, sel, e) from e
        return len(sels)

    def __call__(self, *args) -> jax.Array:
        """Dynamic-shape dispatch: select on the runtime extent, pad to the
        induced bucket, run the cached executable, undo the padding.

        When the extent is already bucket-aligned and the workload's
        prepare is pad-only, prepare/finalize are skipped entirely — the
        steady-state call is table-bisect + dict-lookup + execute.
        """
        wl = self._wl
        m = wl.dynamic_extent(*args)
        sel = self.selector.select(m)
        entry = self._entry_for(sel, args)
        if wl.prepare_is_pad_only and wl.is_bucket_aligned(sel, *args):
            return entry.fn(*args)
        out = entry.fn(*wl.prepare(sel, *args))
        return wl.finalize(sel, out, *args)

    @property
    def cache_info(self) -> dict:
        return {
            "entries": len(self._exec_cache),
            "hits": sum(e.hits for e in self._exec_cache.values()),
            "compile_seconds": sum(
                e.compile_seconds for e in self._exec_cache.values()
            ),
        }

    @property
    def select_stats(self) -> dict:
        s = self.selector.stats
        return {
            "selects": s.selects,
            "table_hits": s.table_hits,
            "lru_hits": s.lru_hits,
            "argmin_misses": s.argmin_misses,
            "cache_hits": s.cache_hits,
            "mean_select_us": s.mean_select_us,
            "table_builds": s.table_builds,
            "table_build_seconds": s.table_build_seconds,
        }


def __getattr__(name: str):
    # Deprecation shims live with the public API (repro.vortex.compat) but
    # stay importable from their historical home; the import is deferred so
    # repro.core never pulls repro.vortex at module-import time (the vortex
    # package imports this module).
    if name in ("VortexEngine", "VortexGemm"):
        from repro.vortex import compat

        return getattr(compat, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
