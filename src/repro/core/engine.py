"""VortexEngine: the end-to-end sample-free compiler (paper Fig. 6).

Offline stage (no shape samples anywhere):
  1. top-down: describe the workload as an rKernel program (rkernel.py),
  2. bottom-up: generate the hardware-pruned candidate lattice per backend
     (candidates.py, Algorithm 2),
  3. score it with the hybrid analyzer (analyzer.py).

Runtime stage:
  4. given the actual shape, select strategy + launch geometry + backend
     (selector.py) via the analytical model only,
  5. construct/fetch the executable for the induced bucket and run.

Execution backends:
  * ``xla``    — lax.dot_general on the bucket shape (host-CPU execution in
                 this container; what the benchmarks time),
  * ``pallas`` — the Vortex-tiled Pallas TPU kernel (kernels/gemm.py) with
                 BlockSpecs taken from the selected strategy; runs in
                 interpret mode off-TPU and compiles natively on TPU.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Mapping

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.analyzer import (
    HybridAnalyzer,
    Profiler,
    ScoredLattice,
    TableProfiler,
    WallClockProfiler,
)
from repro.core.candidates import generate_lattice
from repro.core.hardware import HardwareSpec, get_hardware
from repro.core.rkernel import GemmWorkload, Strategy, make_gemm_program
from repro.core.selector import RuntimeSelector, Selection

__all__ = ["OfflineStats", "VortexGemm", "VortexEngine"]


@dataclasses.dataclass(frozen=True)
class OfflineStats:
    """Offline-stage accounting (paper §7.4 'Offline Overhead Analysis')."""

    num_candidates: int
    num_measured: int
    build_seconds: float
    backends: tuple[str, ...]


@dataclasses.dataclass
class _CacheEntry:
    fn: Callable
    compile_seconds: float
    hits: int = 0


class VortexGemm:
    """One dynamic-shape GEMM workload, compiled sample-free.

    N and K are static (weights side); M is dynamic.  This is the unit the
    paper evaluates (BERT GEMMs with M = batch*seq).
    """

    def __init__(
        self,
        hw: HardwareSpec,
        wl: GemmWorkload,
        profiler: Profiler | None = None,
        empirical_levels: tuple[int, ...] = (0,),
        backends: tuple[str, ...] | None = None,
        num_cores: int = 1,
        impl: str = "xla",
        interpret: bool = True,
    ):
        self._hw = hw
        self._wl = wl
        self._impl = impl
        self._interpret = interpret
        t0 = time.perf_counter()
        backends = backends or tuple(hw.backends)
        scored: dict[str, ScoredLattice] = {}
        n_cands = 0
        n_meas = 0
        for backend in backends:
            lattice = generate_lattice(hw, wl, backend)
            n_cands += lattice.num_candidates()
            analyzer = HybridAnalyzer(
                hw, wl, profiler=profiler, empirical_levels=empirical_levels
            )
            sl = analyzer.score(lattice)
            n_meas += sl.num_measured
            scored[backend] = sl
        self.selector = RuntimeSelector(hw, wl, scored, num_cores=num_cores)
        self.offline_stats = OfflineStats(
            num_candidates=n_cands,
            num_measured=n_meas,
            build_seconds=time.perf_counter() - t0,
            backends=backends,
        )
        self._exec_cache: dict[tuple, _CacheEntry] = {}

    # -- executable construction ------------------------------------------

    def _build_executable(self, sel: Selection) -> _CacheEntry:
        mp = sel.padded_m
        N, K = self._wl.N, self._wl.K
        if self._impl == "pallas":
            from repro.kernels import gemm as gemm_kernel

            m1, n1, k1 = sel.strategy.l1

            def fn(a, b):
                return gemm_kernel.vortex_gemm(
                    a, b, block_m=m1, block_n=min(n1, N), block_k=min(k1, K),
                    interpret=self._interpret,
                )

        else:

            def fn(a, b):
                return jax.lax.dot_general(
                    a, b, (((1,), (0,)), ((), ())),
                    preferred_element_type=jnp.float32,
                ).astype(a.dtype)

        jfn = jax.jit(fn)
        t0 = time.perf_counter()
        a = jnp.zeros((mp, K), jnp.float32)
        b = jnp.zeros((K, N), jnp.float32)
        jfn(a, b).block_until_ready()
        return _CacheEntry(fn=jfn, compile_seconds=time.perf_counter() - t0)

    def _entry_for(self, sel: Selection) -> _CacheEntry:
        key = (sel.padded_m, sel.strategy.l1, sel.backend, self._impl)
        entry = self._exec_cache.get(key)
        if entry is None:
            entry = self._build_executable(sel)
            self._exec_cache[key] = entry
        entry.hits += 1
        return entry

    # -- public API ---------------------------------------------------------

    def select(self, m: int) -> Selection:
        return self.selector.select(m)

    def precompile(self, m_max: int) -> int:
        """Precompile every bucket reachable for M <= m_max (sample-free:
        the bucket set comes from the lattice, not from shape samples)."""
        n = 0
        for m in self.selector.buckets_upto(m_max):
            self._entry_for(self.selector.select(m))
            n += 1
        return n

    def __call__(self, a: jax.Array, b: jax.Array) -> jax.Array:
        """Dynamic-shape matmul: pad M to the selected bucket, run, slice."""
        m = a.shape[0]
        sel = self.select(m)
        entry = self._entry_for(sel)
        if sel.padded_m != m:
            a = jnp.pad(a, ((0, sel.padded_m - m), (0, 0)))
        out = entry.fn(a, b)
        return out[:m] if sel.padded_m != m else out

    @property
    def cache_info(self) -> dict:
        return {
            "entries": len(self._exec_cache),
            "hits": sum(e.hits for e in self._exec_cache.values()),
        }


class VortexEngine:
    """Engine over many workloads: one VortexGemm per (N, K, dtype) signature.

    Model layers request matmuls through :meth:`gemm`; signatures are built
    lazily but *without* any dependence on the dynamic dim — first use of a
    new (N, K) builds its lattice once, after which every runtime M is
    served from the same scored lattice (sample-free across all M).
    """

    def __init__(
        self,
        hardware: str = "host_cpu",
        profiler: Profiler | None = None,
        empirical_levels: tuple[int, ...] | None = None,
        backends: tuple[str, ...] | None = None,
        impl: str = "xla",
        num_cores: int = 1,
    ):
        self._hw = get_hardware(hardware)
        if profiler is None:
            profiler = (
                WallClockProfiler() if hardware == "host_cpu"
                else TableProfiler(self._hw)
            )
        if empirical_levels is None:
            # Paper defaults (Table 7): E:L0 on CPU; E:L0,L1 on GPU-class HW.
            empirical_levels = (0,) if hardware == "host_cpu" else (0, 1)
        self._profiler = profiler
        self._empirical_levels = tuple(empirical_levels)
        self._backends = backends
        self._impl = impl
        self._num_cores = num_cores
        self._gemms: dict[tuple[int, int], VortexGemm] = {}

    def gemm_for(self, n: int, k: int) -> VortexGemm:
        key = (n, k)
        if key not in self._gemms:
            wl = GemmWorkload(M=None, N=n, K=k)
            self._gemms[key] = VortexGemm(
                self._hw,
                wl,
                profiler=self._profiler,
                empirical_levels=self._empirical_levels,
                backends=self._backends,
                num_cores=self._num_cores,
                impl=self._impl,
            )
        return self._gemms[key]

    def gemm(self, a: jax.Array, b: jax.Array) -> jax.Array:
        return self.gemm_for(b.shape[1], b.shape[0])(a, b)

    def offline_stats(self) -> OfflineStats:
        stats = [g.offline_stats for g in self._gemms.values()]
        return OfflineStats(
            num_candidates=sum(s.num_candidates for s in stats),
            num_measured=sum(s.num_measured for s in stats),
            build_seconds=sum(s.build_seconds for s in stats),
            backends=stats[0].backends if stats else (),
        )
