"""VortexKernel: the end-to-end sample-free compiler (paper Fig. 6).

Offline stage (no shape samples anywhere):
  1. top-down: describe the workload as an rKernel program (workloads.py
     declares it; rkernel.py holds the layer metadata),
  2. bottom-up: generate the hardware-pruned candidate lattice per backend
     (candidates.py, Algorithm 2),
  3. score it with the hybrid analyzer (analyzer.py).

Runtime stage:
  4. given the actual shape, select strategy + launch geometry + backend
     (selector.py) — a bisect into the offline-materialized selection table
     (selection_table.py) on the hot path, the fused analytical argmin past
     the table,
  5. construct/fetch the executable for the induced bucket and run (skipping
     pad/unpad entirely when the extent is already bucket-aligned).

:class:`VortexKernel` drives ANY registered
:class:`~repro.core.workloads.Workload` through the same lattice → analyzer →
selector → bucketed-executable pipeline.  The multi-workload session layer —
one engine serving every registered kind from one scored-lattice cache and
one dispatch table — lives in :mod:`repro.vortex` (the public API);
``VortexEngine``/``VortexGemm`` remain importable from here as deprecation
shims over that package.

Execution backends:
  * ``xla``    — flat JAX ops on the bucket shape (host-CPU execution in
                 this container; what the benchmarks time),
  * ``pallas`` — the Vortex-tiled Pallas TPU kernels (kernels/) with
                 BlockSpecs taken from the selected strategy; run in
                 interpret mode off-TPU and compile natively on TPU.
"""
from __future__ import annotations

import dataclasses
import functools
import os
import threading
import time
from concurrent.futures import ThreadPoolExecutor, as_completed
from typing import Any, Callable

import jax

from repro.core.analyzer import HybridAnalyzer, Profiler, ScoredLattice
from repro.core.candidates import generate_lattice
from repro.core.hardware import HardwareSpec
from repro.core.selector import RuntimeSelector, Selection
from repro.core.workloads import Workload

__all__ = [
    "DispatchStats",
    "OfflineStats",
    "PrecompileError",
    "VortexKernel",
    "VortexGemm",
    "VortexEngine",
]


@dataclasses.dataclass(frozen=True)
class OfflineStats:
    """Offline-stage accounting (paper §7.4 'Offline Overhead Analysis')."""

    num_candidates: int
    num_measured: int
    build_seconds: float
    backends: tuple[str, ...]


class PrecompileError(RuntimeError):
    """A bucket failed to compile during :meth:`VortexKernel.precompile`.

    Parallel precompiles surface through ``as_completed`` futures, which
    would otherwise raise the bare builder exception with no hint of WHICH
    bucket died; this wrapper names the failing Selection so a fleet-wide
    warmup failure is diagnosable from the message alone.
    """

    def __init__(self, kind: str, sel: Selection, cause: BaseException):
        self.kind = kind
        self.selection = sel
        super().__init__(
            f"precompile failed for workload {kind!r}: bucket={sel.bucket} "
            f"backend={sel.backend} strategy l1={sel.strategy.l1} "
            f"grid={sel.grid}: {type(cause).__name__}: {cause}"
        )


@dataclasses.dataclass
class DispatchStats:
    """Per-call accounting for the serving hot path (the numbers the
    Fig. 8/Fig. 14 'padding confined to the outermost level' claim is
    checked against).

    ``launches`` counts executions of the ONE fused per-bucket program;
    ``stage_copies``/``unstage_copies`` count the O(true-size) boundary
    copies an unaligned extent pays (dynamic_update_slice into a donated
    engine buffer / the output slice back).  ``padded_calls`` counts falls
    back to the zero-pad reference path (tracer-context calls and
    workloads without staging support); ``traced_calls`` counts calls that
    arrived as tracers inside an enclosing jit (they become part of the
    surrounding program, not runtime launches).
    """

    calls: int = 0
    launches: int = 0
    aligned_calls: int = 0
    unaligned_calls: int = 0
    stage_copies: int = 0
    unstage_copies: int = 0
    padded_calls: int = 0
    traced_calls: int = 0

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


@functools.partial(jax.jit, donate_argnums=0)
def _stage_into(buf, x):
    """Copy ``x`` into the leading corner of the engine-owned bucket buffer
    IN PLACE (``buf`` is donated): only the true extent is written, the pad
    tail keeps whatever stale bytes it held — the masked-tail kernels never
    read them — and no fresh zero-filled allocation is made."""
    return jax.lax.dynamic_update_slice(buf, x, (0,) * buf.ndim)


class _StagingPool:
    """A small pool of engine-owned staging-buffer SETS for one cache entry.

    One set (a dict mapping view-arg index -> bucket-shaped buffer) serves
    one in-flight unaligned dispatch: concurrent same-bucket calls each
    check out their own set, stage and launch WITHOUT any entry-wide lock,
    and return the set afterwards — the per-dtype-singleton design this
    replaces serialized staging AND the launch of every concurrent
    same-bucket call behind one lock (ROADMAP: multi-tenant serialization).

    The pool lock covers only the list pop/append (nanoseconds).  A set's
    buffers keep whatever stale bytes the last staging left past the true
    extent — never re-zeroed; correctness is the kernel's kv_len/m_true
    masking (the poisoned-staging tests assert it).  At most ``cap`` sets
    are retained; a burst beyond the cap allocates transient sets that are
    simply dropped on release.
    """

    __slots__ = ("cap", "_lock", "_free")

    def __init__(self, cap: int = 4):
        self.cap = cap
        self._lock = threading.Lock()
        self._free: list[dict] = []

    def acquire(self, need: dict) -> dict:
        """A buffer set satisfying ``need`` (index -> (shape, dtype)).
        Reuses a pooled set when every needed slot matches; otherwise
        builds fresh zero-initialized buffers (zeros only because a fresh
        buffer must not leak other tenants' bytes through the never-read
        pad — the kernels never rely on it)."""
        with self._lock:
            for i, bufs in enumerate(self._free):
                for idx, (shape, dtype) in need.items():
                    b = bufs.get(idx)
                    if b is None or b.shape != shape or b.dtype != dtype:
                        break
                else:
                    return self._free.pop(i)
        return {
            idx: jax.numpy.zeros(shape, dtype)
            for idx, (shape, dtype) in need.items()
        }

    def release(self, bufs: dict) -> None:
        with self._lock:
            if len(self._free) < self.cap:
                self._free.append(bufs)

    @property
    def retained(self) -> list[dict]:
        """The currently pooled buffer sets (tests poison these)."""
        return self._free


@dataclasses.dataclass
class _CacheEntry:
    """One fused per-bucket program + its engine-owned staging state.

    ``fn`` is the dtype-flexible jitted program (also what tracer-context
    calls inline); ``aot`` is the AOT ``lower().compile()`` artifact for the
    bucket's canonical dtypes — the steady-state serve path, which skips
    jit's dispatch machinery entirely.  ``pool`` holds the engine-owned
    bucket-shaped staging buffer sets (created lazily on the first
    unaligned call; their pad regions are NEVER re-zeroed — correctness
    is the kernel's masking, asserted by the poisoned-staging tests).
    """

    fn: Callable
    compile_seconds: float
    aot: Any = None
    aot_dtypes: tuple = ()
    hits: int = 0
    pool: _StagingPool = dataclasses.field(default_factory=_StagingPool)

    def run(self, *args):
        if self.aot is not None and len(args) == len(self.aot_dtypes):
            for a, d in zip(args, self.aot_dtypes):
                if getattr(a, "dtype", None) != d:
                    break
            else:
                return self.aot(*args)
        return self.fn(*args)


class VortexKernel:
    """One dynamic-shape workload, compiled sample-free.

    Generic over the Workload protocol: the workload declares its lattice
    footprints, its runtime-dims view and its executable builder; this class
    owns the offline build (lattice + scoring, optionally shared through
    ``scored_cache``), the runtime selector and the bucketed executable
    cache.  This is the unit the paper evaluates (BERT GEMMs with
    M = batch*seq; attention/conv ride the same machinery).

    ``table_m_max``/``table_extend_limit`` size the selector's offline
    selection table (see selector.py); they are what
    :class:`repro.vortex.EngineConfig` threads through.
    """

    def __init__(
        self,
        hw: HardwareSpec,
        wl: Workload,
        profiler: Profiler | None = None,
        empirical_levels: tuple[int, ...] = (0,),
        backends: tuple[str, ...] | None = None,
        num_cores: int = 1,
        impl: str = "xla",
        interpret: bool = True,
        scored_cache: dict | None = None,
        table_m_max: int = 4096,
        table_extend_limit: int = 1 << 17,
        staging: bool = True,
    ):
        self._hw = hw
        self._wl = wl
        self._impl = impl
        self._interpret = interpret
        self._staging = staging and wl.supports_staging
        self.dispatch_stats = DispatchStats()
        t0 = time.perf_counter()
        backends = backends or tuple(hw.backends)
        scored: dict[str, ScoredLattice] = {}
        n_cands = 0
        n_meas = 0
        for backend in backends:
            cache_key = (wl.lattice_key, hw.name, backend, empirical_levels)
            hit = scored_cache.get(cache_key) if scored_cache is not None \
                else None
            if hit is not None:
                scored[backend] = hit
                continue
            lattice = generate_lattice(hw, wl, backend)
            n_cands += lattice.num_candidates()
            analyzer = HybridAnalyzer(
                hw, wl, profiler=profiler, empirical_levels=empirical_levels
            )
            sl = analyzer.score(lattice)
            n_meas += sl.num_measured
            scored[backend] = sl
            if scored_cache is not None:
                scored_cache[cache_key] = sl
        self.selector = RuntimeSelector(
            hw, wl, scored, num_cores=num_cores,
            table_m_max=table_m_max, table_extend_limit=table_extend_limit,
        )
        self.offline_stats = OfflineStats(
            num_candidates=n_cands,
            num_measured=n_meas,
            build_seconds=time.perf_counter() - t0,
            backends=backends,
        )
        self._exec_cache: dict[tuple, _CacheEntry] = {}
        # DispatchStats increments are read-modify-writes; concurrent
        # same-bucket dispatch (the staging pool's whole point) would lose
        # counts without this.  Never held across a launch.
        self._stats_lock = threading.Lock()

    @property
    def workload(self) -> Workload:
        return self._wl

    # -- executable construction ------------------------------------------

    def _build_executable(self, sel: Selection, args: tuple) -> _CacheEntry:
        fn = self._wl.build_executable(
            sel, impl=self._impl, interpret=self._interpret
        )
        jfn = jax.jit(fn)
        t0 = time.perf_counter()
        warm = self._wl.example_args(sel, *args)
        # ONE AOT program per bucket (the same lower().compile() pattern the
        # serving driver uses for prefill): staging + masked kernel + no
        # in-program pads means this single artifact IS the whole dispatch.
        aot = jfn.lower(*warm).compile()
        aot_dtypes = tuple(
            jax.numpy.asarray(w).dtype for w in warm
        )
        return _CacheEntry(
            fn=jfn, compile_seconds=time.perf_counter() - t0,
            aot=aot, aot_dtypes=aot_dtypes,
        )

    def _exec_cache_key(self, sel: Selection, args: tuple) -> tuple:
        return (
            sel.bucket, sel.strategy.l1, sel.backend, self._impl,
            self._wl.exec_key(*args) if args else (),
        )

    def _entry_for(self, sel: Selection, args: tuple = ()) -> _CacheEntry:
        key = self._exec_cache_key(sel, args)
        entry = self._exec_cache.get(key)
        if entry is None:
            entry = self._build_executable(sel, args)
            self._exec_cache[key] = entry
        entry.hits += 1
        return entry

    # -- public API ---------------------------------------------------------

    def select(self, m: int) -> Selection:
        return self.selector.select(m)

    def precompile(
        self, m_max: int, *args, max_workers: int | None = None
    ) -> int:
        """Precompile every bucket reachable for M <= m_max (sample-free:
        the bucket set comes from the lattice, not from shape samples).

        Workloads whose executables specialize on outer dims beyond the
        bucket (``exec_key``, e.g. attention's batch/head counts) need
        representative call ``args`` — otherwise the warmed entries sit
        under a key real calls never hit.  Only the args' shapes matter.

        Missing buckets compile on a thread pool (XLA compilation releases
        the GIL); ``max_workers`` caps it, defaulting to min(8, cpu count).
        A failing bucket raises :class:`PrecompileError` naming the failing
        Selection — after every other bucket has drained and registered, so
        a retry after fixing the bad bucket recompiles nothing else.
        """
        sels = self.selector.selections_upto(m_max)
        pending: dict[tuple, Selection] = {}
        for sel in sels:
            key = self._exec_cache_key(sel, args)
            if key not in self._exec_cache and key not in pending:
                pending[key] = sel
        if pending:
            workers = min(
                max_workers or 8, os.cpu_count() or 1, len(pending)
            )
            if workers > 1:
                # Drain ALL futures, registering each success as it
                # completes, and only then raise for the first failure:
                # raising mid-drain would block in the executor's shutdown
                # anyway (no cancel) while discarding every in-flight build
                # that finishes after the failure — a retry would recompile
                # buckets that had already built fine.
                failed: tuple[Selection, Exception] | None = None
                with ThreadPoolExecutor(max_workers=workers) as pool:
                    futures = {
                        pool.submit(self._build_executable, sel, args): key
                        for key, sel in pending.items()
                    }
                    for fut in as_completed(futures):
                        key = futures[fut]
                        try:
                            self._exec_cache[key] = fut.result()
                        except Exception as e:
                            if failed is None:
                                failed = (pending[key], e)
                if failed is not None:
                    sel, e = failed
                    raise PrecompileError(self._wl.kind, sel, e) from e
            else:
                for key, sel in pending.items():
                    try:
                        self._exec_cache[key] = self._build_executable(
                            sel, args
                        )
                    except Exception as e:
                        raise PrecompileError(self._wl.kind, sel, e) from e
        return len(sels)

    def __call__(self, *args) -> jax.Array:
        """Dynamic-shape dispatch through the masked-tail staging contract.

        Select on the runtime extent, then launch the ONE fused per-bucket
        AOT program:

          * bucket-aligned extent — the call args are the program inputs
            directly: zero copies, one launch;
          * unaligned extent — dynamic args are staged into engine-owned,
            donated bucket buffers (O(true-size) writes, no allocation, no
            zero fill; the pad tail keeps stale bytes that the kernel masks
            via the runtime-extent scalar), then one launch, then the
            output slice back to the true extent.

        ``jnp.pad`` never runs on this path.  Calls arriving as tracers
        (inside an enclosing jit, e.g. serve's AOT prefill lowering) take
        the functional zero-pad reference path instead — XLA fuses it into
        the surrounding program, and engine-owned buffers must not be
        captured by a trace.
        """
        wl = self._wl
        m = wl.dynamic_extent(*args)
        sel = self.selector.select(m)
        entry = self._entry_for(sel, args)
        st = self.dispatch_stats
        view = wl.stage_view(*args)
        if not self._staging:
            with self._stats_lock:
                st.calls += 1
            return self._call_padded(sel, entry, args, view)
        if any(isinstance(a, jax.core.Tracer) for a in view):
            with self._stats_lock:
                st.calls += 1
                st.traced_calls += 1
            return self._call_padded(sel, entry, args, view)
        scalars = wl.runtime_scalars(sel, *view)
        shapes = wl.staged_shapes(sel, *view)
        unaligned = [
            i for i, s in enumerate(shapes)
            if s is not None and view[i].shape != s
        ]
        if not unaligned:
            with self._stats_lock:
                st.calls += 1
                st.aligned_calls += 1
                st.launches += 1
            out = entry.run(*view, *scalars)
            return wl.finalize(sel, out, *args)
        # Check a buffer set out of the entry's pool: staging and the
        # launch run with NO entry-wide lock, so concurrent same-bucket
        # dispatches overlap instead of serializing (each set is private
        # to this call until released).
        need = {i: (shapes[i], view[i].dtype) for i in unaligned}
        bufs = entry.pool.acquire(need)
        staged = list(view)
        for i in unaligned:
            buf = _stage_into(bufs[i], view[i])
            bufs[i] = buf
            staged[i] = buf
        with self._stats_lock:
            st.calls += 1
            st.unaligned_calls += 1
            st.stage_copies += len(unaligned)
            st.launches += 1
            if wl.unstages:
                st.unstage_copies += 1
        out = entry.run(*staged, *scalars)
        entry.pool.release(bufs)
        return wl.finalize(sel, out, *args)

    def _call_padded(self, sel, entry, args, view=None) -> jax.Array:
        """The zero-pad reference path: functionally identical to staging
        (same fused executable, same extent scalars), with fresh padded
        allocations instead of engine-owned buffers.  Used for parity
        testing, tracer-context calls, and staging-disabled kernels."""
        wl = self._wl
        st = self.dispatch_stats
        if view is None:
            view = wl.stage_view(*args)
        scalars = wl.runtime_scalars(sel, *view)
        if not wl.supports_staging:
            # Legacy-contract workloads: prepare is the only bucket mapping
            # (it must be an identity for already-aligned extents).
            with self._stats_lock:
                st.padded_calls += 1
            out = entry.fn(*wl.prepare(sel, *view), *scalars)
            return wl.finalize(sel, out, *args)
        shapes = wl.staged_shapes(sel, *view)
        aligned = all(
            s is None or view[i].shape == s for i, s in enumerate(shapes)
        )
        if aligned:
            out = entry.fn(*view, *scalars)
        else:
            with self._stats_lock:
                st.padded_calls += 1
            out = entry.fn(*wl.prepare(sel, *view), *scalars)
        return wl.finalize(sel, out, *args)

    def call_padded(self, *args) -> jax.Array:
        """Public reference dispatch: the padded path end to end (select,
        zero-pad prepare, fused executable, finalize).  The staged hot path
        must be bit-identical to this — tests/test_staged_dispatch.py."""
        wl = self._wl
        sel = self.selector.select(wl.dynamic_extent(*args))
        entry = self._entry_for(sel, args)
        with self._stats_lock:
            self.dispatch_stats.calls += 1
        return self._call_padded(sel, entry, args)

    @property
    def cache_info(self) -> dict:
        return {
            "entries": len(self._exec_cache),
            "hits": sum(e.hits for e in self._exec_cache.values()),
            "compile_seconds": sum(
                e.compile_seconds for e in self._exec_cache.values()
            ),
        }

    @property
    def select_stats(self) -> dict:
        s = self.selector.stats
        return {
            "selects": s.selects,
            "table_hits": s.table_hits,
            "lru_hits": s.lru_hits,
            "argmin_misses": s.argmin_misses,
            "cache_hits": s.cache_hits,
            "mean_select_us": s.mean_select_us,
            "table_builds": s.table_builds,
            "table_build_seconds": s.table_build_seconds,
        }


def __getattr__(name: str):
    # Deprecation shims live with the public API (repro.vortex.compat) but
    # stay importable from their historical home; the import is deferred so
    # repro.core never pulls repro.vortex at module-import time (the vortex
    # package imports this module).
    if name in ("VortexEngine", "VortexGemm"):
        from repro.vortex import compat

        return getattr(compat, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
