"""VortexEngine: the end-to-end sample-free compiler (paper Fig. 6).

Offline stage (no shape samples anywhere):
  1. top-down: describe the workload as an rKernel program (workloads.py
     declares it; rkernel.py holds the layer metadata),
  2. bottom-up: generate the hardware-pruned candidate lattice per backend
     (candidates.py, Algorithm 2),
  3. score it with the hybrid analyzer (analyzer.py).

Runtime stage:
  4. given the actual shape, select strategy + launch geometry + backend
     (selector.py) — a bisect into the offline-materialized selection table
     (selection_table.py) on the hot path, the fused analytical argmin past
     the table,
  5. construct/fetch the executable for the induced bucket and run (skipping
     pad/unpad entirely when the extent is already bucket-aligned).

The engine is workload-generic: :class:`VortexKernel` drives ANY registered
:class:`~repro.core.workloads.Workload` through the same lattice → analyzer →
selector → bucketed-executable pipeline, and :class:`VortexEngine` serves
``gemm``, ``attention`` and ``conv2d`` entry points from one workload
registry, one scored-lattice cache and one bucketed executable cache per
signature.

Execution backends:
  * ``xla``    — flat JAX ops on the bucket shape (host-CPU execution in
                 this container; what the benchmarks time),
  * ``pallas`` — the Vortex-tiled Pallas TPU kernels (kernels/) with
                 BlockSpecs taken from the selected strategy; run in
                 interpret mode off-TPU and compile natively on TPU.
"""
from __future__ import annotations

import dataclasses
import os
import time
from concurrent.futures import ThreadPoolExecutor, as_completed
from typing import Callable

import jax

from repro.core.analyzer import (
    HybridAnalyzer,
    Profiler,
    ScoredLattice,
    TableProfiler,
    WallClockProfiler,
)
from repro.core.candidates import generate_lattice
from repro.core.hardware import HardwareSpec, get_hardware
from repro.core.selector import RuntimeSelector, Selection
from repro.core.workloads import (
    AttentionWorkload,
    Conv2dWorkload,
    GemmWorkload,
    Workload,
)

__all__ = ["OfflineStats", "VortexKernel", "VortexGemm", "VortexEngine"]


@dataclasses.dataclass(frozen=True)
class OfflineStats:
    """Offline-stage accounting (paper §7.4 'Offline Overhead Analysis')."""

    num_candidates: int
    num_measured: int
    build_seconds: float
    backends: tuple[str, ...]


@dataclasses.dataclass
class _CacheEntry:
    fn: Callable
    compile_seconds: float
    hits: int = 0


class VortexKernel:
    """One dynamic-shape workload, compiled sample-free.

    Generic over the Workload protocol: the workload declares its lattice
    footprints, its runtime-dims view and its executable builder; this class
    owns the offline build (lattice + scoring, optionally shared through
    ``scored_cache``), the runtime selector and the bucketed executable
    cache.  This is the unit the paper evaluates (BERT GEMMs with
    M = batch*seq; attention/conv ride the same machinery).
    """

    def __init__(
        self,
        hw: HardwareSpec,
        wl: Workload,
        profiler: Profiler | None = None,
        empirical_levels: tuple[int, ...] = (0,),
        backends: tuple[str, ...] | None = None,
        num_cores: int = 1,
        impl: str = "xla",
        interpret: bool = True,
        scored_cache: dict | None = None,
    ):
        self._hw = hw
        self._wl = wl
        self._impl = impl
        self._interpret = interpret
        t0 = time.perf_counter()
        backends = backends or tuple(hw.backends)
        scored: dict[str, ScoredLattice] = {}
        n_cands = 0
        n_meas = 0
        for backend in backends:
            cache_key = (wl.lattice_key, hw.name, backend, empirical_levels)
            hit = scored_cache.get(cache_key) if scored_cache is not None \
                else None
            if hit is not None:
                scored[backend] = hit
                continue
            lattice = generate_lattice(hw, wl, backend)
            n_cands += lattice.num_candidates()
            analyzer = HybridAnalyzer(
                hw, wl, profiler=profiler, empirical_levels=empirical_levels
            )
            sl = analyzer.score(lattice)
            n_meas += sl.num_measured
            scored[backend] = sl
            if scored_cache is not None:
                scored_cache[cache_key] = sl
        self.selector = RuntimeSelector(hw, wl, scored, num_cores=num_cores)
        self.offline_stats = OfflineStats(
            num_candidates=n_cands,
            num_measured=n_meas,
            build_seconds=time.perf_counter() - t0,
            backends=backends,
        )
        self._exec_cache: dict[tuple, _CacheEntry] = {}

    @property
    def workload(self) -> Workload:
        return self._wl

    # -- executable construction ------------------------------------------

    def _build_executable(self, sel: Selection, args: tuple) -> _CacheEntry:
        fn = self._wl.build_executable(
            sel, impl=self._impl, interpret=self._interpret
        )
        jfn = jax.jit(fn)
        t0 = time.perf_counter()
        warm = self._wl.example_args(sel, *args)
        jax.block_until_ready(jfn(*warm))
        return _CacheEntry(fn=jfn, compile_seconds=time.perf_counter() - t0)

    def _exec_cache_key(self, sel: Selection, args: tuple) -> tuple:
        return (
            sel.bucket, sel.strategy.l1, sel.backend, self._impl,
            self._wl.exec_key(*args) if args else (),
        )

    def _entry_for(self, sel: Selection, args: tuple = ()) -> _CacheEntry:
        key = self._exec_cache_key(sel, args)
        entry = self._exec_cache.get(key)
        if entry is None:
            entry = self._build_executable(sel, args)
            self._exec_cache[key] = entry
        entry.hits += 1
        return entry

    # -- public API ---------------------------------------------------------

    def select(self, m: int) -> Selection:
        return self.selector.select(m)

    def precompile(
        self, m_max: int, *args, max_workers: int | None = None
    ) -> int:
        """Precompile every bucket reachable for M <= m_max (sample-free:
        the bucket set comes from the lattice, not from shape samples).

        Workloads whose executables specialize on outer dims beyond the
        bucket (``exec_key``, e.g. attention's batch/head counts) need
        representative call ``args`` — otherwise the warmed entries sit
        under a key real calls never hit.  Only the args' shapes matter.

        Missing buckets compile on a thread pool (XLA compilation releases
        the GIL); ``max_workers`` caps it, defaulting to min(8, cpu count).
        """
        sels = self.selector.selections_upto(m_max)
        pending: dict[tuple, Selection] = {}
        for sel in sels:
            key = self._exec_cache_key(sel, args)
            if key not in self._exec_cache and key not in pending:
                pending[key] = sel
        if pending:
            workers = min(
                max_workers or 8, os.cpu_count() or 1, len(pending)
            )
            if workers > 1:
                # Register each entry as it completes: one failing compile
                # must not discard the buckets that already built.
                with ThreadPoolExecutor(max_workers=workers) as pool:
                    futures = {
                        pool.submit(self._build_executable, sel, args): key
                        for key, sel in pending.items()
                    }
                    for fut in as_completed(futures):
                        self._exec_cache[futures[fut]] = fut.result()
            else:
                for key, sel in pending.items():
                    self._exec_cache[key] = self._build_executable(sel, args)
        return len(sels)

    def __call__(self, *args) -> jax.Array:
        """Dynamic-shape dispatch: select on the runtime extent, pad to the
        induced bucket, run the cached executable, undo the padding.

        When the extent is already bucket-aligned and the workload's
        prepare is pad-only, prepare/finalize are skipped entirely — the
        steady-state call is table-bisect + dict-lookup + execute.
        """
        wl = self._wl
        m = wl.dynamic_extent(*args)
        sel = self.selector.select(m)
        entry = self._entry_for(sel, args)
        if wl.prepare_is_pad_only and wl.is_bucket_aligned(sel, *args):
            return entry.fn(*args)
        out = entry.fn(*wl.prepare(sel, *args))
        return wl.finalize(sel, out, *args)

    @property
    def cache_info(self) -> dict:
        return {
            "entries": len(self._exec_cache),
            "hits": sum(e.hits for e in self._exec_cache.values()),
            "compile_seconds": sum(
                e.compile_seconds for e in self._exec_cache.values()
            ),
        }

    @property
    def select_stats(self) -> dict:
        s = self.selector.stats
        return {
            "selects": s.selects,
            "table_hits": s.table_hits,
            "lru_hits": s.lru_hits,
            "argmin_misses": s.argmin_misses,
            "cache_hits": s.cache_hits,
            "mean_select_us": s.mean_select_us,
            "table_builds": s.table_builds,
            "table_build_seconds": s.table_build_seconds,
        }


class VortexGemm(VortexKernel):
    """One dynamic-shape GEMM workload, compiled sample-free.

    N and K are static (weights side); M is dynamic.  Kept as a named class
    for the GEMM-only callers (serving, benchmarks); it is exactly
    :class:`VortexKernel` over a :class:`GemmWorkload`.
    """


class VortexEngine:
    """Engine over many workloads: one VortexKernel per workload signature.

    Model layers request ops through :meth:`gemm` / :meth:`attention` /
    :meth:`conv2d`; signatures are built lazily but *without* any dependence
    on the dynamic dim — first use of a new signature builds its lattice
    once, after which every runtime extent is served from the same scored
    lattice (sample-free across all dynamic shapes).  Workloads whose
    lattice inputs coincide (e.g. attention signatures differing only in
    masking flags) share scored lattices through one engine-wide cache.
    """

    def __init__(
        self,
        hardware: str = "host_cpu",
        profiler: Profiler | None = None,
        empirical_levels: tuple[int, ...] | None = None,
        backends: tuple[str, ...] | None = None,
        impl: str = "xla",
        num_cores: int = 1,
        interpret: bool = True,
    ):
        self._hw = get_hardware(hardware)
        if profiler is None:
            profiler = (
                WallClockProfiler() if hardware == "host_cpu"
                else TableProfiler(self._hw)
            )
        if empirical_levels is None:
            # Paper defaults (Table 7): E:L0 on CPU; E:L0,L1 on GPU-class HW.
            empirical_levels = (0,) if hardware == "host_cpu" else (0, 1)
        self._profiler = profiler
        self._empirical_levels = tuple(empirical_levels)
        self._backends = backends
        self._impl = impl
        self._num_cores = num_cores
        self._interpret = interpret
        self._kernels: dict[tuple, VortexKernel] = {}
        self._scored_cache: dict[tuple, ScoredLattice] = {}
        # Zero-rebuild hot path: raw call-site tuples -> compiled kernel.
        # Steady-state gemm/attention/conv2d calls hash a tuple of ints
        # (shapes/flags straight off the arrays) instead of constructing a
        # Workload dataclass and hashing its signature on every call.
        self._dispatch: dict[tuple, VortexKernel] = {}

    # -- workload plumbing --------------------------------------------------

    def kernel_for(self, wl: Workload) -> VortexKernel:
        """The compiled kernel serving ``wl``'s signature (built lazily)."""
        key = wl.signature
        if key not in self._kernels:
            self._kernels[key] = VortexKernel(
                self._hw,
                wl,
                profiler=self._profiler,
                empirical_levels=self._empirical_levels,
                backends=self._backends,
                num_cores=self._num_cores,
                impl=self._impl,
                interpret=self._interpret,
                scored_cache=self._scored_cache,
            )
        return self._kernels[key]

    def gemm_for(self, n: int, k: int) -> VortexKernel:
        return self.kernel_for(GemmWorkload(M=None, N=n, K=k))

    def _kernel_at(self, key: tuple, make_wl) -> VortexKernel:
        """Raw-tuple hot-path lookup: the Workload is only constructed (and
        its dataclass signature only hashed) on the first call per key."""
        kern = self._dispatch.get(key)
        if kern is None:
            kern = self.kernel_for(make_wl())
            self._dispatch[key] = kern
        return kern

    # -- entry points -------------------------------------------------------

    def gemm(self, a: jax.Array, b: jax.Array) -> jax.Array:
        """C[M,N] = A[M,K] @ B[K,N] with dynamic M."""
        return self._kernel_at(
            ("gemm", b.shape[0], b.shape[1]),
            lambda: GemmWorkload(M=None, N=b.shape[1], K=b.shape[0]),
        )(a, b)

    def attention(
        self,
        q: jax.Array,
        k: jax.Array,
        v: jax.Array,
        *,
        causal: bool = True,
        window: int | None = None,
        softcap: float | None = None,
    ) -> jax.Array:
        """Flash attention with dynamic sequence length.

        q: (batch, q_heads, seq, head_dim); k, v: (batch, kv_heads, seq,
        head_dim) with q_heads % kv_heads == 0 (GQA).  Requires causal=True
        (padding correctness comes from the causal mask; see workloads.py).
        """
        return self._kernel_at(
            ("attention", q.shape[-1], causal, window, softcap),
            lambda: AttentionWorkload(
                seq=None, head_dim=q.shape[-1], causal=causal,
                window=window, softcap=softcap,
            ),
        )(q, k, v)

    def conv2d(
        self, x: jax.Array, w: jax.Array, *, stride: int = 1
    ) -> jax.Array:
        """Conv2D (VALID): x (b, h, w, cin); w (kh, kw, cin, cout)."""
        kh, kw, cin, cout = w.shape
        return self._kernel_at(
            ("conv2d", kh, kw, cin, cout, stride),
            lambda: Conv2dWorkload(
                m=None, cin=cin, cout=cout, kh=kh, kw=kw, stride=stride
            ),
        )(x, w)

    # -- introspection ------------------------------------------------------

    def precompile(self, wl: Workload, m_max: int, *args) -> int:
        """Precompile all buckets of ``wl`` reachable up to ``m_max``.
        Pass representative call ``args`` for workloads with outer-dim
        executable specialization (attention: any q/k/v with the serving
        batch/head layout)."""
        return self.kernel_for(wl).precompile(m_max, *args)

    def offline_stats(self) -> OfflineStats:
        stats = [k.offline_stats for k in self._kernels.values()]
        return OfflineStats(
            num_candidates=sum(s.num_candidates for s in stats),
            num_measured=sum(s.num_measured for s in stats),
            build_seconds=sum(s.build_seconds for s in stats),
            backends=stats[0].backends if stats else (),
        )

    def stats(self) -> dict[str, dict]:
        """Per-workload-kind serving stats: selection overhead and executable
        cache behaviour (what benchmarks/bench_workloads.py reports)."""
        out: dict[str, dict] = {}
        for kernel in self._kernels.values():
            kind = kernel.workload.kind
            agg = out.setdefault(
                kind,
                {
                    "signatures": 0, "selects": 0, "select_table_hits": 0,
                    "select_lru_hits": 0, "select_argmin_misses": 0,
                    "select_cache_hits": 0, "select_us_sum": 0.0,
                    "table_entries": 0, "table_build_s": 0.0,
                    "exec_entries": 0, "exec_hits": 0,
                    "compile_seconds": 0.0,
                },
            )
            sstats = kernel.selector.stats
            cinfo = kernel.cache_info
            table = kernel.selector.table_if_built
            agg["signatures"] += 1
            agg["selects"] += sstats.selects
            agg["select_table_hits"] += sstats.table_hits
            agg["select_lru_hits"] += sstats.lru_hits
            agg["select_argmin_misses"] += sstats.argmin_misses
            agg["select_cache_hits"] += sstats.cache_hits
            agg["select_us_sum"] += sstats.select_seconds * 1e6
            agg["table_entries"] += len(table) if table is not None else 0
            agg["table_build_s"] += sstats.table_build_seconds
            agg["exec_entries"] += cinfo["entries"]
            agg["exec_hits"] += cinfo["hits"]
            agg["compile_seconds"] += cinfo["compile_seconds"]
        return out
