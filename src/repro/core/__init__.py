"""Vortex core: hardware-driven, sample-free dynamic-shape tensor-program
optimization (the paper's contribution), adapted to TPU. See DESIGN.md."""
from repro.core.analyzer import (
    AnalyticalProfiler,
    HybridAnalyzer,
    Profiler,
    ScoredLattice,
    StackedLattices,
    TableProfiler,
    WallClockProfiler,
)
from repro.core.baselines import SampleDrivenCompiler, VendorBaseline
from repro.core.calibrate import (
    BucketMeasurement,
    CalibrationPolicy,
    Calibrator,
    calibration_cache_dir,
    fingerprint_key,
    hardware_fingerprint,
    lattice_checksum,
)
from repro.core.candidates import (
    CandidateLattice,
    filter_by_isa,
    filter_by_multiples,
    generate_lattice,
    init_cands,
)
from repro.core.cost_model import (
    CostBreakdown,
    gemm_runtime_costs,
    gemm_strategy_cost,
    l0_analytical_cost,
    runtime_cost_matrix,
    runtime_costs,
    strategy_cost,
)
from repro.core.engine import (
    OfflineStats,
    PrecompileError,
    VortexKernel,
)
from repro.core.hardware import HOST_CPU, TPU_V5E, HardwareSpec, get_hardware
from repro.core.rkernel import (
    AnalyzeType,
    LayerMetaInfo,
    LoopType,
    RKernelProgram,
    Strategy,
    interpret_gemm,
    make_gemm_program,
)
from repro.core.selection_table import (
    SelectionTable,
    build_selection_table,
    merge_breakpoints,
)
from repro.core.selector import RuntimeSelector, Selection, SelectorStats
from repro.core.timing import MinTimings, interleaved_minima, retry_best
from repro.core.workloads import (
    WORKLOADS,
    AttentionWorkload,
    Conv2dWorkload,
    DecodeAttentionWorkload,
    GemmWorkload,
    Workload,
    make_workload,
    register_workload,
)

__all__ = [n for n in dir() if not n.startswith("_")] + [
    "VortexEngine",
    "VortexGemm",
]

_LAZY_SHIMS = ("VortexEngine", "VortexGemm")


def __getattr__(name: str):
    # Deprecation shims resolve lazily (PEP 562) so `import repro.core`
    # never pulls repro.vortex — the vortex package imports core modules,
    # and an eager re-export here would re-create that cycle at import
    # time.  `from repro.core import VortexEngine` still works.
    if name in _LAZY_SHIMS:
        from repro.core import engine

        return getattr(engine, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
