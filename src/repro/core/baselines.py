"""Baselines the paper compares against (§7.1), rebuilt in this framework.

* :class:`SampleDrivenCompiler` — a DietCode/Nimble-style compiler: it tunes
  micro-kernels *per shape sample* by empirical search (real wall-clock here,
  like DietCode's auto-tuning), then at runtime routes any shape to the
  nearest sample's micro-kernel with padding.  Off-sample shapes pay the
  padding/mismatch penalty the paper demonstrates in Fig. 3 / Table 6.
* :class:`VendorBaseline` — the vendor-library stand-in: XLA's native dot at
  the *exact* runtime shape, precompiled (vendor libraries ship shape-generic
  hand kernels; exact-shape XLA is the strongest equivalent available here).
"""
from __future__ import annotations

import dataclasses
import math
import time
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.candidates import generate_lattice
from repro.core.hardware import HardwareSpec
from repro.core.workloads import GemmWorkload

__all__ = ["SampleDrivenCompiler", "VendorBaseline"]


def _xla_matmul(m: int, n: int, k: int):
    fn = jax.jit(
        lambda a, b: jax.lax.dot_general(
            a, b, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        ).astype(a.dtype)
    )
    a = jnp.zeros((m, k), jnp.float32)
    b = jnp.zeros((k, n), jnp.float32)
    fn(a, b).block_until_ready()
    return fn


@dataclasses.dataclass
class _TunedKernel:
    sample_m: int
    tile_m: int  # the micro-kernel's M tile; runtime M pads up to multiples
    best_us: float


class SampleDrivenCompiler:
    """Sample-driven dynamic-shape compilation (DietCode-like).

    Offline: for every M sample, *empirically* search M-tile candidates by
    timing the padded matmul on the actual device — this is the costly
    auto-tuning loop whose hours-scale overhead the paper's §7.4 contrasts
    with Vortex's sample-free seconds.  ``search_budget`` bounds timed
    configs per sample.

    Runtime: a nearest-sample selector (the decision-tree stand-in) picks
    the micro-kernel whose sample M is closest above the runtime M (else the
    largest sample), then pads M to that kernel's tile multiple.
    """

    def __init__(
        self,
        hw: HardwareSpec,
        wl: GemmWorkload,
        samples: Sequence[int],
        search_budget: int = 8,
        repeats: int = 3,
    ):
        if not samples:
            raise ValueError("sample-driven compilation requires samples")
        self._wl = wl
        self._samples = sorted(set(samples))
        t0 = time.perf_counter()
        tile_space = sorted(
            {t[0] for t in generate_lattice(hw, wl, hw.default_backend).l1}
        )[:search_budget]
        self._kernels: list[_TunedKernel] = []
        self._exec: dict[int, object] = {}
        for s in self._samples:
            best = (float("inf"), tile_space[0])
            for tm in tile_space:
                mp = math.ceil(s / tm) * tm
                fn = _xla_matmul(mp, wl.N, wl.K)
                a = jnp.zeros((mp, wl.K), jnp.float32)
                b = jnp.zeros((wl.K, wl.N), jnp.float32)
                t_best = float("inf")
                for _ in range(repeats):
                    t1 = time.perf_counter()
                    fn(a, b).block_until_ready()
                    t_best = min(t_best, time.perf_counter() - t1)
                if t_best < best[0]:
                    best = (t_best, tm)
            self._kernels.append(
                _TunedKernel(sample_m=s, tile_m=best[1], best_us=best[0] * 1e6)
            )
        self.tuning_seconds = time.perf_counter() - t0

    def _route(self, m: int) -> _TunedKernel:
        for kern in self._kernels:  # samples sorted ascending
            if kern.sample_m >= m:
                return kern
        return self._kernels[-1]

    def padded_m(self, m: int) -> int:
        """DietCode semantics: micro-kernels are compiled per *sample*, so a
        runtime M is padded up to the nearest sample's M (the executable's
        static shape).  Beyond the largest sample there is no tuned kernel;
        pad to the largest sample's tile granularity.  This is exactly the
        off-sample penalty of the paper's Fig. 3 / Table 6."""
        kern = self._route(m)
        if m <= kern.sample_m:
            return kern.sample_m
        return math.ceil(m / kern.tile_m) * kern.tile_m

    def __call__(self, a: jax.Array, b: jax.Array) -> jax.Array:
        m = a.shape[0]
        mp = self.padded_m(m)
        if mp not in self._exec:
            self._exec[mp] = _xla_matmul(mp, self._wl.N, self._wl.K)
        if mp != m:
            a = jnp.pad(a, ((0, mp - m), (0, 0)))
        out = self._exec[mp](a, b)
        return out[:m] if mp != m else out


class VendorBaseline:
    """Exact-shape XLA dot per runtime shape (vendor-library stand-in)."""

    def __init__(self, wl: GemmWorkload):
        self._wl = wl
        self._exec: dict[int, object] = {}

    def __call__(self, a: jax.Array, b: jax.Array) -> jax.Array:
        m = a.shape[0]
        if m not in self._exec:
            self._exec[m] = _xla_matmul(m, self._wl.N, self._wl.K)
        return self._exec[m](a, b)
