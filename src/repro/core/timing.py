"""Phase-robust wall-clock timing: interleaved adaptive min-vs-min.

Shared hosts (CI runners, serving machines under co-tenant load) throttle
in long (~0.5-1.5s) phases during which even IDENTICAL computations run 2x
slower, and the phase can anti-correlate with a naive A/B alternation.
Mean or median of either side is therefore phase lottery.  The harness
here — proven by the bench gates in benchmarks/bench_workloads.py and now
shared with the background calibrator (core/calibrate.py) — defends with
three mechanisms:

  * INTERLEAVED short windows: every round times each variant back to
    back, so a throttling phase inflates all variants the same round
    instead of biasing one side;
  * MIN-VS-MIN with adaptive stop: sampling continues until every
    variant's minimum has stopped improving for ``patience`` rounds —
    each variant has then provably sampled the clean phase — and only the
    minima are compared;
  * RETRY KEEPING BEST (:func:`retry_best`): throttling noise is strictly
    one-sided (it can only inflate a window), so re-measuring and keeping
    the best attempt estimates the true cost, while a real regression
    fails every attempt.

All timings are seconds; per-round samples are kept in microseconds so a
flaky gate can be diagnosed from committed JSON (was the distribution
bimodal throttling or a real shift?).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Sequence

import jax

__all__ = ["MinTimings", "interleaved_minima", "retry_best"]


@dataclasses.dataclass(frozen=True)
class MinTimings:
    """Result of one :func:`interleaved_minima` measurement.

    ``best_s[i]`` is variant ``i``'s best per-call seconds across all
    rounds; ``samples_us[i]`` its raw per-round means (microseconds,
    rounded to ns precision) in measurement order — the flake audit
    trail.  ``rounds`` is how many rounds actually ran before the
    adaptive stop.
    """

    best_s: tuple[float, ...]
    samples_us: tuple[tuple[float, ...], ...]
    rounds: int

    def ratio(self, i: int, j: int) -> float:
        """best_s[i] / best_s[j] (guarded against a zero denominator)."""
        return self.best_s[i] / max(self.best_s[j], 1e-12)


def interleaved_minima(
    calls: Sequence[Callable[[], object]],
    *,
    inner: int = 2,
    min_rounds: int = 20,
    max_rounds: int = 80,
    patience: int = 10,
    improvement: float = 0.99,
    warmup: bool = True,
    deadline_s: float | None = None,
) -> MinTimings:
    """Phase-robust minima for N variants, interleaved per round.

    Each round times ``inner`` back-to-back calls of every variant (each
    call synchronized via ``jax.block_until_ready``).  A round that
    improves ANY variant's minimum by more than ``1 - improvement``
    resets the staleness counter; the loop stops once at least
    ``min_rounds`` ran and no minimum improved for ``patience``
    consecutive rounds (or at ``max_rounds``/``deadline_s``, whichever
    first).  ``warmup`` runs one untimed call per variant first so
    compilation and buffer allocation never land inside a timed window.
    """
    if not calls:
        raise ValueError("need at least one variant to time")
    if warmup:
        for fn in calls:
            jax.block_until_ready(fn())
    n = len(calls)
    best = [float("inf")] * n
    samples: list[list[float]] = [[] for _ in range(n)]
    stale = 0
    rounds = 0
    t_start = time.perf_counter()
    for r in range(max_rounds):
        improved = False
        for i, fn in enumerate(calls):
            t0 = time.perf_counter()
            for _ in range(inner):
                jax.block_until_ready(fn())
            t = (time.perf_counter() - t0) / inner
            samples[i].append(round(t * 1e6, 3))
            if t < best[i] * improvement:
                improved = True
            best[i] = min(best[i], t)
        rounds = r + 1
        stale = 0 if improved else stale + 1
        if rounds >= min_rounds and stale >= patience:
            break
        if (
            deadline_s is not None
            and time.perf_counter() - t_start >= deadline_s
            and all(b != float("inf") for b in best)
        ):
            break
    return MinTimings(
        best_s=tuple(best),
        samples_us=tuple(tuple(s) for s in samples),
        rounds=rounds,
    )


def retry_best(
    measure: Callable[[], object],
    *,
    attempts: int = 4,
    accept: Callable[[object], bool],
    key: Callable[[object], float],
    stats: dict | None = None,
):
    """Re-run ``measure`` until ``accept`` holds or ``attempts`` exhaust,
    keeping the attempt with the smallest ``key``.

    The bench wraps its aligned-vs-unaligned ratio measurement with this
    (accept = ratio under the gate, key = the ratio): throttling can only
    inflate a window, so min-across-attempts estimates the true value
    while a genuine regression fails every attempt.

    When ``stats`` is given, it records the gate's retry telemetry for
    committed bench JSON: ``attempts`` (measurements actually run) and
    ``accepted`` (whether the kept attempt satisfied ``accept``).
    """
    best = measure()
    used = 1
    for _ in range(max(attempts, 1) - 1):
        if accept(best):
            break
        cur = measure()
        used += 1
        if key(cur) < key(best):
            best = cur
    if stats is not None:
        stats["attempts"] = used
        stats["accepted"] = bool(accept(best))
    return best
