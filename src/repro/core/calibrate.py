"""Background calibration: measurement-refined selection tables.

Vortex's bet (PAPER.md, Eq. 2-4) is that an analytical, hardware-derived
cost model picks kernels without runtime shape samples.  That keeps cold
start sample-free — but measured search (FTuner/FlexTensor, PAPERS.md)
beats analytical models at steady state.  This module is the best of
both: the serving stack trusts the analytical tables from the first
request, and IDLE cycles on the live hardware refine them — no user
traffic is ever sampled, so the system stays sample-free in the paper's
sense.

The pipeline, per compiled kernel (DESIGN.md §10):

  1. MEASURE — the top-K analytically-ranked candidates of each reachable
     bucket are timed with the phase-robust interleaved min-vs-min
     harness (core/timing.py, shared with the bench gates), each through
     the exact per-bucket AOT executable the serving path would launch;
  2. FIT or RE-RANK — a per-backend multiplicative coefficient is
     least-squares fitted over (predicted, measured) pairs.  A good fit
     (low max relative residual) refines EVERY bucket through
     ``cost_scale``; a bad fit falls back to measurement-only re-ranking.
     Either way, measured buckets are ground truth: whenever the refined
     model still disagrees with the measured-best candidate, that
     bucket's breakpoint interval is PINNED to the measured winner — so a
     calibrated table never picks worse than the measurements on any
     measured bucket (the CI gate);
  3. SWAP — the table is rebuilt OFFLINE through the same breakpoint
     sweep (``build_selection_table``) and atomically published into the
     live ``RuntimeSelector`` (``install_table``): one reference
     assignment, readers see entirely-old or entirely-new, and the
     O(log B) bisect hot path is byte-for-byte untouched;
  4. PERSIST — results are written (atomic tmp + ``os.replace``) to a
     JSON file keyed by a hardware fingerprint (HardwareSpec descriptor +
     backends + impl + jax/device identity), so a restarted engine loads
     the calibrated tables instead of re-measuring.  Truncated/corrupt
     files are rejected and serving falls back to the analytical tables.

The cache directory defaults to ``~/.cache/vortex`` and is overridable
via ``$VORTEX_CACHE_DIR`` or ``CalibrationPolicy.cache_dir`` — never
inside the repo.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import platform
import threading
import time
from typing import Callable, Iterable

import numpy as np

from repro.core.analyzer import StackedLattices
from repro.core.engine import VortexKernel
from repro.core.timing import interleaved_minima
from repro.core.workloads import Workload
from repro.runtime import faults

__all__ = [
    "CalibrationPolicy",
    "Calibrator",
    "BucketMeasurement",
    "calibration_cache_dir",
    "hardware_fingerprint",
    "fingerprint_key",
    "lattice_checksum",
]

_SCHEMA_VERSION = 1


# ---------------------------------------------------------------------------
# Cache location + hardware fingerprint
# ---------------------------------------------------------------------------


def calibration_cache_dir(override: str | None = None) -> str:
    """The calibrated-table cache directory: explicit ``override`` wins,
    then ``$VORTEX_CACHE_DIR``, then ``~/.cache/vortex`` — never a path
    inside the repository."""
    if override:
        return os.path.expanduser(override)
    env = os.environ.get("VORTEX_CACHE_DIR")
    if env:
        return os.path.expanduser(env)
    return os.path.join(os.path.expanduser("~"), ".cache", "vortex")


def hardware_fingerprint(
    hw, backends: tuple[str, ...], impl: str, interpret: bool
) -> dict:
    """A JSON-able descriptor of everything a measured time depends on:
    the HardwareSpec (name + per-backend peaks + native tiles), the
    executable lowering (impl/interpret), and the host identity the
    measurements actually ran on (jax version, device platform/kind,
    machine).  Two processes with equal fingerprints may share calibrated
    tables; anything else must re-measure."""
    import jax

    dev = jax.devices()[0]
    return {
        "hardware": hw.name,
        "backends": {b: float(hw.backends[b]) for b in backends},
        "native_tile": {b: list(hw.native_tile[b]) for b in backends},
        "impl": impl,
        "interpret": bool(interpret),
        "jax": jax.__version__,
        "device": f"{dev.platform}:{getattr(dev, 'device_kind', '')}",
        "machine": platform.machine(),
    }


def fingerprint_key(fp: dict) -> str:
    """Stable 16-hex key of a fingerprint dict (the cache file name)."""
    blob = json.dumps(fp, sort_keys=True).encode()
    return hashlib.sha256(blob).hexdigest()[:16]


def lattice_checksum(stacked: StackedLattices) -> str:
    """Checksum of the stacked candidate space a calibration was fitted
    over.  Candidate indices are only meaningful against the same lattice
    (same tiles, same scored costs, same backend stacking order); a
    persisted entry whose checksum mismatches is stale and rejected."""
    h = hashlib.sha256()
    h.update(np.ascontiguousarray(stacked.l1_tiles, np.int64).tobytes())
    h.update(np.ascontiguousarray(stacked.l1_costs, np.float64).tobytes())
    h.update(repr((stacked.backends, stacked.offsets)).encode())
    return h.hexdigest()[:16]


def _signature_key(wl: Workload) -> str:
    return repr(wl.signature)


# ---------------------------------------------------------------------------
# Policy + per-kernel state
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class CalibrationPolicy:
    """Knobs for the background calibrator (EngineConfig ``calibration*``).

    ``mode`` — "off" (never instantiate), "on-idle" (the continuous
    scheduler donates budgeted slices when its admission queue is empty),
    or "eager-warmup" (calibrate — loading from disk first — as each
    kernel is built).  ``budget_s`` bounds ONE donated slice, not the
    whole calibration; ``m_max``/``max_buckets`` bound the measured
    extent set per kernel; the rounds/patience knobs feed the
    interleaved min-vs-min harness (core/timing.py).
    ``residual_threshold`` is the max relative fit error above which the
    per-backend coefficient fit is distrusted and the calibrator re-ranks
    from measurements only.
    """

    mode: str = "on-idle"
    top_k: int = 3
    budget_s: float = 0.25
    m_max: int = 512
    max_buckets: int = 8
    inner: int = 1
    min_rounds: int = 5
    max_rounds: int = 30
    patience: int = 3
    residual_threshold: float = 0.25
    cache_dir: str | None = None


@dataclasses.dataclass
class BucketMeasurement:
    """Wall-clock evidence for one measured bucket extent.

    ``seconds``/``predicted`` map candidate index -> measured best
    seconds / unscaled analytical seconds for the top-K candidates;
    ``analytical_idx`` is the unscaled-argmin winner over ALL candidates
    (always included in the measured set)."""

    m: int
    analytical_idx: int
    seconds: dict[int, float]
    predicted: dict[int, float]

    @property
    def best_idx(self) -> int:
        return min(self.seconds, key=lambda i: self.seconds[i])


@dataclasses.dataclass
class _KernelState:
    kernel: VortexKernel
    pending: list[int]                     # bucket extents still to measure
    measured: dict[int, BucketMeasurement] = dataclasses.field(
        default_factory=dict
    )
    applied: bool = False                  # calibrated table installed
    loaded: bool = False                   # applied from disk, not measured
    skipped: str | None = None             # reason this kernel is excluded
    mode: str | None = None                # "coefficients" | "rerank"
    residual: float = 0.0
    backend_scale: dict[str, float] = dataclasses.field(default_factory=dict)
    pinned: dict[int, int] = dataclasses.field(default_factory=dict)
    seconds: float = 0.0                   # calibration wall-clock


class Calibrator:
    """Measure, refit, rebuild, atomically swap, persist — per kernel.

    ``kernels`` is a zero-argument callable returning the LIVE kernels to
    calibrate (the vortex Engine passes a snapshot of its kernel table,
    so signatures built after calibration started are picked up by later
    slices).  All mutation runs under one lock: concurrent ``run_slice``
    callers serialize, while serving threads never take the lock — the
    only cross-thread handoff is the selector's atomic table swap.
    """

    def __init__(
        self,
        kernels: Callable[[], Iterable[VortexKernel]],
        policy: CalibrationPolicy | None = None,
    ):
        self._kernels = kernels
        self.policy = policy or CalibrationPolicy()
        self._lock = threading.RLock()
        self._states: dict[str, _KernelState] = {}
        self.counters = {
            "measurements": 0, "measured_buckets": 0, "fits": 0,
            "reranks": 0, "table_swaps": 0, "loads": 0, "saves": 0,
            "load_rejects": 0, "save_errors": 0, "store_rejects": 0,
            "slices": 0, "seconds": 0.0,
        }

    # -- planning -----------------------------------------------------------

    def _calibratable(self, kernel: VortexKernel) -> str | None:
        """None when the kernel can be measured without representative
        call args, else the reason it is skipped."""
        wl = kernel.workload
        if type(wl).exec_key is not Workload.exec_key:
            # Executables specialize on outer dims of real call args
            # (attention batch/heads): example_args alone can't produce
            # the artifact serving would launch.
            return "exec-specialized (needs representative args)"
        if not wl.supports_staging:
            return "legacy workload contract"
        return None

    def _plan_extents(self, kernel: VortexKernel) -> list[int]:
        """The measured-extent set: every distinct dynamic bucket
        reachable up to ``policy.m_max`` (capped at the installed table's
        coverage), evenly subsampled to ``policy.max_buckets``."""
        pol = self.policy
        sel = kernel.selector
        table = sel.table
        m_hi = pol.m_max if table is None else min(pol.m_max, table.m_max)
        buckets = [b for b in sel.buckets_upto(max(m_hi, 1)) if b >= 1]
        if len(buckets) > pol.max_buckets:
            idx = np.unique(np.linspace(
                0, len(buckets) - 1, pol.max_buckets
            ).round().astype(int))
            buckets = [buckets[i] for i in idx]
        return buckets

    def _state_for(self, kernel: VortexKernel) -> _KernelState:
        key = _signature_key(kernel.workload)
        st = self._states.get(key)
        if st is None:
            skipped = self._calibratable(kernel)
            st = _KernelState(
                kernel=kernel,
                pending=[] if skipped else self._plan_extents(kernel),
                skipped=skipped,
            )
            self._states[key] = st
        return st

    def _sync(self) -> None:
        for kernel in list(self._kernels()):
            self._state_for(kernel)

    def pending(self) -> bool:
        """True when any enrolled kernel still has work (measurements or
        an un-applied fit)."""
        with self._lock:
            self._sync()
            return any(
                st.skipped is None and not st.applied
                for st in self._states.values()
            )

    # -- measurement --------------------------------------------------------

    def _measure_bucket(self, st: _KernelState, m: int) -> None:
        """Time the top-K analytically-ranked candidates at extent ``m``
        through per-bucket AOT executables (the same lowering serving
        launches), interleaved min-vs-min."""
        if faults.ACTIVE is not None:
            faults.ACTIVE.check("calib_measure")
        import jax

        pol = self.policy
        kernel, sel = st.kernel, st.kernel.selector
        wl = kernel.workload
        costs = sel.candidate_costs(m)
        analytical_idx = int(np.argmin(costs))
        idxs = sel.rank_candidates(m, pol.top_k)
        if analytical_idx not in idxs:
            idxs.append(analytical_idx)

        calls = []
        for idx in idxs:
            cand = sel.candidate_selection(idx, m)
            fn = wl.build_executable(
                cand, impl=kernel.impl, interpret=kernel.interpret
            )
            warm = wl.example_args(cand)
            aot = jax.jit(fn).lower(*warm).compile()
            calls.append(lambda aot=aot, warm=warm: aot(*warm))
        t = interleaved_minima(
            calls, inner=pol.inner, min_rounds=pol.min_rounds,
            max_rounds=pol.max_rounds, patience=pol.patience,
        )
        st.measured[m] = BucketMeasurement(
            m=m,
            analytical_idx=analytical_idx,
            seconds={i: t.best_s[j] for j, i in enumerate(idxs)},
            predicted={i: float(costs[i]) for i in idxs},
        )
        self.counters["measurements"] += len(idxs)
        self.counters["measured_buckets"] += 1

    # -- fit / re-rank / swap -----------------------------------------------

    def _fit(self, st: _KernelState) -> None:
        """Per-backend least-squares coefficient fit, pin disagreements,
        rebuild the table offline, atomically swap it in."""
        stacked = st.kernel.selector.stacked
        by_backend: dict[str, list[tuple[float, float]]] = {}
        for meas in st.measured.values():
            for idx, sec in meas.seconds.items():
                by_backend.setdefault(stacked.backend_of(idx), []).append(
                    (meas.predicted[idx], sec)
                )
        scale: dict[str, float] = {}
        residual = 0.0
        for backend, pairs in by_backend.items():
            p = np.asarray([x for x, _ in pairs], np.float64)
            y = np.asarray([y for _, y in pairs], np.float64)
            denom = float(np.dot(p, p))
            alpha = float(np.dot(p, y)) / denom if denom > 0 else 1.0
            alpha = max(alpha, 1e-12)
            scale[backend] = alpha
            rel = np.abs(alpha * p - y) / np.maximum(y, 1e-12)
            residual = max(residual, float(np.max(rel)) if len(rel) else 0.0)

        st.residual = residual
        if residual <= self.policy.residual_threshold:
            st.mode = "coefficients"
            st.backend_scale = scale
            self.counters["fits"] += 1
        else:
            # The global fit extrapolates badly; don't let it move any
            # unmeasured bucket — re-rank from measurements only.
            st.mode = "rerank"
            st.backend_scale = {}
            self.counters["reranks"] += 1
        self._apply(st)

    def _scale_vector(self, st: _KernelState) -> np.ndarray | None:
        if not st.backend_scale:
            return None
        stacked = st.kernel.selector.stacked
        return np.asarray([
            st.backend_scale.get(stacked.backend_of(i), 1.0)
            for i in range(stacked.num_candidates)
        ], np.float64)

    def _apply(self, st: _KernelState) -> None:
        """Pin measured buckets where the refined model still disagrees
        with the measured-best candidate, then rebuild + swap.  After the
        swap, the table's pick on EVERY measured bucket is the measured
        winner — never worse than the analytical pick there."""
        sel = st.kernel.selector
        vec = self._scale_vector(st)
        pinned: dict[int, int] = {}
        for m, meas in st.measured.items():
            model_winner = int(np.argmin(sel.candidate_costs(m) * (
                vec if vec is not None else 1.0
            )))
            best = meas.best_idx
            if model_winner != best:
                pinned[m] = best
        st.pinned = pinned
        table = sel.build_calibrated_table(cost_scale=vec, pinned=pinned)
        sel.install_table(
            table, cost_scale=vec, pinned=pinned,
            calibration_seconds=st.seconds,
        )
        st.applied = True
        self.counters["table_swaps"] += 1

    # -- driving ------------------------------------------------------------

    def run_slice(self, budget_s: float | None = None) -> int:
        """One budgeted calibration slice: measure pending buckets until
        the budget is spent, finalizing (fit + swap + persist) any kernel
        whose measurement set completes.  Returns buckets measured.
        Safe to call from an idle serving loop — all work is off the
        dispatch path, and the only serving-visible effect is the atomic
        table swap."""
        budget = self.policy.budget_s if budget_s is None else budget_s
        done = 0
        t0 = time.perf_counter()
        with self._lock:
            self.counters["slices"] += 1
            self._sync()
            for st in self._states.values():
                if st.skipped is not None or st.applied:
                    continue
                while st.pending:
                    m = st.pending[0]
                    tb = time.perf_counter()
                    try:
                        self._measure_bucket(st, m)
                    except Exception:
                        st.skipped = "measurement failed"
                        break
                    finally:
                        dt = time.perf_counter() - tb
                        st.seconds += dt
                        self.counters["seconds"] += dt
                    st.pending.pop(0)
                    done += 1
                    if time.perf_counter() - t0 >= budget:
                        break
                if not st.pending and st.skipped is None and st.measured:
                    tb = time.perf_counter()
                    self._fit(st)
                    st.seconds += time.perf_counter() - tb
                    self._save_quietly()
                if time.perf_counter() - t0 >= budget:
                    break
        return done

    def run(self) -> dict:
        """Calibrate everything currently pending to completion (the
        eager-warmup path and the CLI); returns :meth:`stats`."""
        while self.pending():
            if self.run_slice(budget_s=float("inf")) == 0:
                break
        return self.stats()

    # -- persistence --------------------------------------------------------

    def fingerprint(self) -> dict:
        for kernel in list(self._kernels()):
            hw = kernel.selector._hw
            backends = tuple(sorted(kernel.selector.scored))
            return hardware_fingerprint(
                hw, backends, kernel.impl, kernel.interpret
            )
        raise RuntimeError("no kernels to fingerprint")

    def cache_path(self) -> str:
        d = calibration_cache_dir(self.policy.cache_dir)
        return os.path.join(d, f"{fingerprint_key(self.fingerprint())}.json")

    def _save_quietly(self) -> None:
        try:
            self.save()
        except Exception:
            self.counters["save_errors"] += 1
            self.counters["store_rejects"] += 1

    def save(self, path: str | None = None) -> str:
        """Persist every applied calibration (atomic tmp + os.replace —
        a reader never observes a partial file from a clean writer;
        killed-mid-write leftovers are caught by load's recovery)."""
        if faults.ACTIVE is not None:
            faults.ACTIVE.check("cache_io")
        with self._lock:
            payload = {
                "version": _SCHEMA_VERSION,
                "fingerprint": self.fingerprint(),
                "kernels": {},
            }
            for key, st in self._states.items():
                if not st.applied or st.mode is None:
                    continue
                table = st.kernel.selector.table_if_built
                payload["kernels"][key] = {
                    "lattice": lattice_checksum(st.kernel.selector.stacked),
                    "mode": st.mode,
                    "residual": st.residual,
                    "backend_scale": st.backend_scale,
                    "pinned": {str(m): i for m, i in st.pinned.items()},
                    "m_max": table.m_max if table is not None else 0,
                    "seconds": st.seconds,
                    "measurements": {
                        str(m): {
                            "analytical_idx": meas.analytical_idx,
                            "seconds": {
                                str(i): s for i, s in meas.seconds.items()
                            },
                            "predicted": {
                                str(i): p for i, p in meas.predicted.items()
                            },
                        }
                        for m, meas in st.measured.items()
                    },
                }
            path = path or self.cache_path()
            os.makedirs(os.path.dirname(path), exist_ok=True)
            tmp = path + ".tmp"
            with open(tmp, "w") as f:
                json.dump(payload, f, indent=1, sort_keys=True)
            if faults.ACTIVE is not None:
                faults.ACTIVE.check("cache_io")
            os.replace(tmp, path)
            self.counters["saves"] += 1
            return path

    def load(self, path: str | None = None) -> int:
        """Apply persisted calibrations to the current kernels; returns
        how many kernels were calibrated FROM DISK (zero re-measurements).

        Every reject path is silent-but-counted (``load_rejects``) and
        falls back to the analytical tables: missing file, truncated or
        corrupt JSON, schema/fingerprint mismatch, stale lattice
        checksum, out-of-range candidate indices.
        """
        with self._lock:
            self._sync()
            try:
                path = path or self.cache_path()
            except RuntimeError:
                return 0
            try:
                if faults.ACTIVE is not None:
                    faults.ACTIVE.check("cache_io")
                with open(path) as f:
                    data = json.load(f)
                if data.get("version") != _SCHEMA_VERSION:
                    raise ValueError("schema version mismatch")
                mine = fingerprint_key(self.fingerprint())
                theirs = fingerprint_key(dict(data["fingerprint"]))
                if mine != theirs:
                    raise ValueError("hardware fingerprint mismatch")
                entries = data["kernels"]
                if not isinstance(entries, dict):
                    raise ValueError("malformed kernels section")
            except FileNotFoundError:
                return 0
            except Exception:
                self.counters["load_rejects"] += 1
                return 0

            applied = 0
            for key, st in self._states.items():
                if st.applied or st.skipped is not None:
                    continue
                entry = entries.get(key)
                if entry is None:
                    continue
                try:
                    applied += self._apply_entry(st, entry)
                except Exception:
                    self.counters["load_rejects"] += 1
            if applied:
                self.counters["loads"] += applied
            return applied

    def _apply_entry(self, st: _KernelState, entry: dict) -> int:
        sel = st.kernel.selector
        stacked = sel.stacked
        if entry["lattice"] != lattice_checksum(stacked):
            raise ValueError("stale lattice checksum")
        mode = entry["mode"]
        if mode not in ("coefficients", "rerank"):
            raise ValueError(f"unknown mode {mode!r}")
        scale = {str(b): float(a) for b, a in entry["backend_scale"].items()}
        pinned = {int(m): int(i) for m, i in entry["pinned"].items()}
        n = stacked.num_candidates
        if any(not 0 <= i < n for i in pinned.values()):
            raise ValueError("pinned candidate index out of range")
        st.mode = mode
        st.residual = float(entry.get("residual", 0.0))
        st.backend_scale = scale if mode == "coefficients" else {}
        st.pinned = pinned
        for m_str, meas in entry.get("measurements", {}).items():
            m = int(m_str)
            st.measured[m] = BucketMeasurement(
                m=m,
                analytical_idx=int(meas["analytical_idx"]),
                seconds={int(i): float(s)
                         for i, s in meas["seconds"].items()},
                predicted={int(i): float(p)
                           for i, p in meas["predicted"].items()},
            )
        vec = self._scale_vector(st)
        table = sel.build_calibrated_table(cost_scale=vec, pinned=pinned)
        sel.install_table(table, cost_scale=vec, pinned=pinned)
        st.applied = True
        st.loaded = True
        st.pending = []
        self.counters["table_swaps"] += 1
        return 1

    # -- reporting ----------------------------------------------------------

    def _candidate_index(self, stacked: StackedLattices) -> dict:
        return {
            (stacked.backend_of(i), stacked.strategy_for(i).tiles): i
            for i in range(stacked.num_candidates)
        }

    def report(self) -> dict:
        """Measured-vs-analytical selection quality per kind — what the
        bench emits into BENCH_dispatch.json's ``calibration`` section.

        Per measured bucket: the ANALYTICAL pick's measured seconds, the
        measured-best seconds, and the CALIBRATED table's pick (resolved
        through a live post-swap ``select``) with its measured seconds.
        ``never_worse_on_measured`` is the CI gate.
        """
        with self._lock:
            out: dict[str, dict] = {}
            for st in self._states.values():
                if not st.measured or not st.applied:
                    continue
                sel = st.kernel.selector
                index = self._candidate_index(sel.stacked)
                agree = 0
                regrets: list[float] = []
                worse = 0
                buckets = []
                for m, meas in sorted(st.measured.items()):
                    pick = sel.select(m)
                    pick_idx = index.get((pick.backend, pick.strategy.tiles))
                    best = meas.best_idx
                    t_best = meas.seconds[best]
                    t_analytical = meas.seconds[meas.analytical_idx]
                    t_pick = meas.seconds.get(pick_idx)
                    if meas.analytical_idx == best:
                        agree += 1
                    if t_pick is None:
                        worse += 1  # pick fell outside the measured set
                        regrets.append(float("nan"))
                    else:
                        if t_pick > t_analytical * (1 + 1e-9):
                            worse += 1
                        regrets.append(t_pick / t_best - 1.0)
                    buckets.append({
                        "m": m,
                        "analytical_us": t_analytical * 1e6,
                        "best_us": t_best * 1e6,
                        "calibrated_us": (
                            t_pick * 1e6 if t_pick is not None else None
                        ),
                    })
                kind = st.kernel.workload.kind
                finite = [r for r in regrets if r == r]
                out[kind] = {
                    "mode": st.mode,
                    "residual": st.residual,
                    "backend_scale": st.backend_scale,
                    "measured_buckets": len(st.measured),
                    "pinned_buckets": len(st.pinned),
                    "agreement_rate": agree / max(len(st.measured), 1),
                    "mean_regret_vs_best": (
                        float(np.mean(finite)) if finite else 0.0
                    ),
                    "never_worse_on_measured": worse == 0,
                    "loaded_from_disk": st.loaded,
                    "buckets": buckets,
                }
            return out

    def stats(self) -> dict:
        """Counter snapshot for ``Engine.stats()["calibration"]``."""
        with self._lock:
            states = list(self._states.values())
            return {
                "enabled": True,
                "mode": self.policy.mode,
                "kernels": len(states),
                "applied": sum(st.applied for st in states),
                "loaded_from_disk": sum(st.loaded for st in states),
                "skipped": sum(st.skipped is not None for st in states),
                "pending_buckets": sum(
                    len(st.pending) for st in states if st.skipped is None
                ),
                **dict(self.counters),
            }
