"""Atomic, async, keep-N checkpointing with restore-time resharding.

Layout:  <dir>/step_<N>/{arrays.npz, META.json}   (+ <dir>/step_<N>.tmp.*
while writing).  The atomic ``os.replace`` of the temp directory is what
makes a mid-write node failure safe: a checkpoint either fully exists or
does not exist at all.

``save_async`` snapshots to host memory synchronously (cheap) and writes in
a background thread, overlapping I/O with the next training steps — the
pattern production frameworks use so the step time does not absorb the
write bandwidth.

Restore takes an optional sharding tree: arrays are loaded on host and
``jax.device_put`` with the *target* sharding, which is how elastic
re-meshing (runtime/elastic.py) moves a checkpoint onto a smaller mesh.
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
import threading
from typing import Any

import jax
import numpy as np

__all__ = ["CheckpointManager"]

_SEP = "||"


def _flatten(tree: Any) -> dict[str, np.ndarray]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = _SEP.join(str(p) for p in path)
        out[key] = np.asarray(leaf)
    return out


def _unflatten(template: Any, arrays: dict[str, np.ndarray]) -> Any:
    flat, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for path, tmpl in flat:
        key = _SEP.join(str(p) for p in path)
        if key not in arrays:
            raise KeyError(f"checkpoint missing {key}")
        arr = arrays[key]
        want = tuple(np.shape(tmpl))
        if tuple(arr.shape) != want:
            raise ValueError(f"{key}: shape {arr.shape} != {want}")
        # Scalar python leaves (float/int counters) come back as scalars.
        if not hasattr(tmpl, "shape") and arr.ndim == 0:
            arr = arr.item()
        leaves.append(arr)
    return jax.tree_util.tree_unflatten(treedef, leaves)


class CheckpointManager:
    def __init__(self, directory: str, keep_n: int = 3):
        self.directory = directory
        self.keep_n = keep_n
        os.makedirs(directory, exist_ok=True)
        self._writer: threading.Thread | None = None
        self._write_error: list[BaseException] = []

    # ---- save --------------------------------------------------------

    def _write(self, step: int, arrays: dict, meta: dict) -> str:
        final = os.path.join(self.directory, f"step_{step:08d}")
        tmp = tempfile.mkdtemp(
            prefix=f"step_{step:08d}.tmp.", dir=self.directory
        )
        try:
            np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
            with open(os.path.join(tmp, "META.json"), "w") as f:
                json.dump({"step": step, **meta}, f)
            if os.path.exists(final):
                shutil.rmtree(final)
            os.replace(tmp, final)  # atomic publish
        except BaseException:
            shutil.rmtree(tmp, ignore_errors=True)
            raise
        self._gc()
        return final

    def save(self, step: int, tree: Any, meta: dict | None = None) -> str:
        return self._write(step, _flatten(tree), meta or {})

    def save_async(self, step: int, tree: Any, meta: dict | None = None):
        """Snapshot now, write in the background.  Joins any prior write
        first (at most one outstanding write)."""
        self.wait()
        arrays = _flatten(tree)  # host snapshot happens here, synchronously

        def work():
            try:
                self._write(step, arrays, meta or {})
            except BaseException as e:  # surfaced on next wait()
                self._write_error.append(e)

        self._writer = threading.Thread(target=work, daemon=True)
        self._writer.start()

    def wait(self):
        if self._writer is not None:
            self._writer.join()
            self._writer = None
        if self._write_error:
            raise self._write_error.pop()

    # ---- restore -----------------------------------------------------

    def steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.directory):
            if name.startswith("step_") and ".tmp." not in name:
                if os.path.exists(
                    os.path.join(self.directory, name, "META.json")
                ):
                    out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> int | None:
        s = self.steps()
        return s[-1] if s else None

    def restore(
        self, step: int, template: Any, shardings: Any | None = None
    ) -> Any:
        path = os.path.join(self.directory, f"step_{step:08d}")
        with np.load(os.path.join(path, "arrays.npz")) as z:
            arrays = {k: z[k] for k in z.files}
        tree = _unflatten(template, arrays)

        def cast_one(arr, t):
            if hasattr(t, "dtype") and hasattr(arr, "astype"):
                return arr.astype(t.dtype)
            return type(t)(arr) if not hasattr(t, "dtype") else arr

        cast = jax.tree.map(cast_one, tree, template)
        if shardings is not None:
            return jax.tree.map(jax.device_put, cast, shardings)
        return jax.tree.map(
            lambda x: jax.numpy.asarray(x) if hasattr(x, "shape") else x,
            cast,
        )

    def meta(self, step: int) -> dict:
        path = os.path.join(
            self.directory, f"step_{step:08d}", "META.json"
        )
        with open(path) as f:
            return json.load(f)

    # ---- gc ----------------------------------------------------------

    def _gc(self):
        steps = self.steps()
        for s in steps[: -self.keep_n] if self.keep_n else []:
            shutil.rmtree(
                os.path.join(self.directory, f"step_{s:08d}"),
                ignore_errors=True,
            )
