from repro.optim.adamw import (
    AdamWConfig,
    adamw_init,
    adamw_update,
    opt_state_pspecs,
)
from repro.optim.schedule import cosine_schedule, linear_warmup_cosine
from repro.optim.compression import (
    compress_int8,
    decompress_int8,
    ef_compress_update,
)

__all__ = [n for n in dir() if not n.startswith("_")]
