"""AdamW in pure JAX with ZeRO-style optimizer-state sharding.

Optimizer moments are f32 regardless of param dtype (bf16 training).  With
``fsdp`` the moments inherit the params' FSDP sharding (params are already
sharded over 'data'); without it, :func:`opt_state_pspecs` can still shard
the moments over 'data' on the largest divisible axis (ZeRO-1): gradients
arrive replicated, the update runs on the shard, and XLA all-gathers the
fresh params — exactly the reduce-scatter/all-gather dance of ZeRO, derived
by GSPMD from the output sharding.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

__all__ = [
    "AdamWConfig",
    "adamw_init",
    "adamw_update",
    "opt_state_pspecs",
]


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0


def adamw_init(params: Any) -> dict:
    def zeros_like_f32(p):
        if isinstance(p, jax.ShapeDtypeStruct):
            return jax.ShapeDtypeStruct(p.shape, jnp.float32)
        return jnp.zeros(p.shape, jnp.float32)

    return {
        "mu": jax.tree.map(zeros_like_f32, params),
        "nu": jax.tree.map(zeros_like_f32, params),
        "step": (
            jax.ShapeDtypeStruct((), jnp.int32)
            if any(
                isinstance(l, jax.ShapeDtypeStruct)
                for l in jax.tree.leaves(params)
            )
            else jnp.zeros((), jnp.int32)
        ),
    }


def global_norm(tree: Any) -> jax.Array:
    return jnp.sqrt(
        sum(
            jnp.sum(jnp.square(g.astype(jnp.float32)))
            for g in jax.tree.leaves(tree)
        )
    )


def adamw_update(
    cfg: AdamWConfig,
    params: Any,
    grads: Any,
    opt_state: dict,
    lr: jax.Array,
) -> tuple[Any, dict]:
    """One AdamW step with global-norm clipping.  Returns (params, state)."""
    step = opt_state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))

    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, mu, nu):
        g = g.astype(jnp.float32) * scale
        mu = cfg.b1 * mu + (1.0 - cfg.b1) * g
        nu = cfg.b2 * nu + (1.0 - cfg.b2) * g * g
        mhat = mu / b1c
        nhat = nu / b2c
        delta = mhat / (jnp.sqrt(nhat) + cfg.eps)
        # Decoupled weight decay only on matrices (ndim >= 2).
        if p.ndim >= 2:
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        new_p = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        return new_p, mu, nu

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_mu = jax.tree.leaves(opt_state["mu"])
    flat_nu = jax.tree.leaves(opt_state["nu"])
    out = [upd(p, g, m, n) for p, g, m, n in zip(flat_p, flat_g, flat_mu, flat_nu)]
    new_params = jax.tree.unflatten(tdef, [o[0] for o in out])
    new_mu = jax.tree.unflatten(tdef, [o[1] for o in out])
    new_nu = jax.tree.unflatten(tdef, [o[2] for o in out])
    return new_params, {"mu": new_mu, "nu": new_nu, "step": step}


def _shard_spec_for_moment(spec: P, shape: tuple[int, ...],
                           data_divisor: int) -> P:
    """ZeRO-1: add 'data' to the first unsharded axis divisible by the data
    axis; keep the param's own spec otherwise."""
    parts = list(spec) + [None] * (len(shape) - len(spec))
    if any(p == "data" or (isinstance(p, tuple) and "data" in p)
           for p in parts):
        return spec  # FSDP params: moments inherit
    for i, (p, dim) in enumerate(zip(parts, shape)):
        if p is None and dim % data_divisor == 0 and dim >= data_divisor:
            parts[i] = "data"
            while parts and parts[-1] is None:
                parts.pop()
            return P(*parts)
    return spec


def opt_state_pspecs(
    param_specs: Any, param_shapes: Any, data_axis_size: int, zero1: bool = True
) -> dict:
    """PartitionSpec tree for the optimizer state."""
    if zero1 and data_axis_size > 1:
        moment = jax.tree.map(
            lambda s, p: _shard_spec_for_moment(s, p.shape, data_axis_size),
            param_specs,
            param_shapes,
            is_leaf=lambda x: isinstance(x, P),
        )
    else:
        moment = param_specs
    return {"mu": moment, "nu": moment, "step": P()}
