"""Error-feedback int8 gradient compression for the cross-pod (DCN) hop.

At 512 chips the intra-pod gradient reduce-scatter rides the ICI, but the
pod-to-pod hop crosses the (much slower) data-center network.  Compressing
that hop 4x (f32 -> int8 with a per-tensor scale) with error feedback
(Seide et al.; Karimireddy et al.) keeps convergence intact: the
quantization residual is carried into the next step's gradient.

The train step uses this inside a ``shard_map`` over the 'pod' axis when
``compress_dcn=True``: grads are psum'd over ('data',) normally, quantized,
psum'd over ('pod',), dequantized — see train/step.py.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

__all__ = ["compress_int8", "decompress_int8", "ef_compress_update"]


def compress_int8(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Per-tensor symmetric int8 quantization. Returns (q, scale)."""
    xf = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(xf))
    scale = jnp.maximum(amax / 127.0, 1e-12)
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def decompress_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def ef_compress_update(
    grad: jax.Array, error: jax.Array
) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Error-feedback compression of one gradient tensor.

    Returns (q, scale, new_error, compressed_grad) where
    ``compressed_grad = dequant(q, scale)`` and
    ``new_error = (grad + error) - compressed_grad``.
    """
    target = grad.astype(jnp.float32) + error
    q, scale = compress_int8(target)
    approx = decompress_int8(q, scale)
    new_error = target - approx
    return q, scale, new_error, approx
