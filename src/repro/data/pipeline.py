"""Deterministic synthetic data pipeline with per-host sharding + prefetch.

Every batch is a pure function of (seed, step, host): after a failure the
restarted job replays exactly the same stream from the restored step — the
data side of the fault-tolerance story (runtime/).  A background prefetch
thread keeps ``depth`` batches ahead of the training loop.

The synthetic stream is a Zipf-ish token distribution (more realistic loss
curves than uniform) with next-token structure so the LM has signal to fit:
token[t+1] = (a * token[t] + noise) mod vocab for a per-sequence multiplier.
"""
from __future__ import annotations

import queue
import threading
from typing import Iterator

import numpy as np

__all__ = ["SyntheticLMDataset", "Prefetcher"]


class SyntheticLMDataset:
    """Deterministic, restart-replayable synthetic LM batches."""

    def __init__(
        self,
        vocab: int,
        seq_len: int,
        global_batch: int,
        *,
        seed: int = 0,
        host_id: int = 0,
        num_hosts: int = 1,
    ):
        assert global_batch % num_hosts == 0, (global_batch, num_hosts)
        self.vocab = vocab
        self.seq_len = seq_len
        self.global_batch = global_batch
        self.host_batch = global_batch // num_hosts
        self.seed = seed
        self.host_id = host_id
        self.num_hosts = num_hosts

    def batch_at(self, step: int) -> dict[str, np.ndarray]:
        """The host-local shard of the global batch for ``step``."""
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step, self.host_id])
        )
        b, s, v = self.host_batch, self.seq_len, self.vocab
        # A dataset-global affine bigram process (token[t+1] = a*token[t]+c
        # + small noise mod v): a *learnable* next-token structure so smoke
        # training visibly reduces the loss within tens of steps.
        grng = np.random.default_rng(np.random.SeedSequence([self.seed]))
        a = int(grng.integers(1, 8))
        c = int(grng.integers(0, v))
        start = rng.integers(0, v, size=(b, 1), dtype=np.int64)
        noise = rng.integers(0, 2, size=(b, s), dtype=np.int64)
        toks = np.empty((b, s), np.int64)
        toks[:, 0] = start[:, 0]
        for t in range(1, s):
            toks[:, t] = (a * toks[:, t - 1] + c + noise[:, t]) % v
        tokens = toks.astype(np.int32)
        labels = np.roll(tokens, -1, axis=1)
        labels[:, -1] = tokens[:, 0]
        return {"tokens": tokens, "labels": labels}

    def iter_from(self, step: int) -> Iterator[dict[str, np.ndarray]]:
        while True:
            yield self.batch_at(step)
            step += 1


class Prefetcher:
    """Background-thread prefetch of a batch iterator."""

    _DONE = object()

    def __init__(self, it: Iterator, depth: int = 2):
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = threading.Event()

        def worker():
            try:
                for item in it:
                    if self._stop.is_set():
                        return
                    self._q.put(item)
            finally:
                self._q.put(self._DONE)

        self._t = threading.Thread(target=worker, daemon=True)
        self._t.start()

    def __iter__(self):
        return self

    def __next__(self):
        item = self._q.get()
        if item is self._DONE:
            raise StopIteration
        return item

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
