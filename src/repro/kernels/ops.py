"""Public jit'd wrappers over the Pallas kernels.

``interpret`` defaults to True off-TPU so the same call sites work in this
CPU container and compile natively on TPU.
"""
from __future__ import annotations

import jax

from repro.kernels.attention import flash_attention
from repro.kernels.conv import vortex_conv2d
from repro.kernels.gemm import vortex_gemm

__all__ = ["matmul", "attention", "conv2d", "on_tpu"]


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def matmul(
    a, b, m_true=None, *, block_m=128, block_n=128, block_k=128,
    interpret=None,
):
    interpret = (not on_tpu()) if interpret is None else interpret
    return vortex_gemm(
        a, b, m_true, block_m=block_m, block_n=block_n, block_k=block_k,
        interpret=interpret,
    )


def attention(
    q, k, v, kv_len=None, *, block_q=128, block_k=128, causal=True,
    window=None, softcap=None, interpret=None,
):
    interpret = (not on_tpu()) if interpret is None else interpret
    return flash_attention(
        q, k, v, kv_len, block_q=block_q, block_k=block_k, causal=causal,
        window=window, softcap=softcap, interpret=interpret,
    )


def conv2d(x, w, *, stride=1, block_m=128, block_n=128, block_k=128,
           interpret=None):
    interpret = (not on_tpu()) if interpret is None else interpret
    return vortex_conv2d(
        x, w, stride=stride, block_m=block_m, block_n=block_n,
        block_k=block_k, interpret=interpret,
    )
