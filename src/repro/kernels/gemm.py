"""Vortex-tiled GEMM as a Pallas TPU kernel, with masked tails.

The BlockSpec tiling is *not* hand-picked: the (block_m, block_n, block_k)
triple is the layer-1 tile selected by Vortex's runtime selector from the
hardware-pruned candidate lattice (core/), and the grid is the layer-2
parallel/temporal loop structure of the rKernel program:

    grid = (gm, gn, gk)   — (m, n) are the PARALLEL loops (distributed over
                            TensorCores on real hardware), k is the
                            TEMPORAL-REDUCTION loop (sequential, accumulator
                            resident in VMEM across the k steps).

The selected tile is honored VERBATIM: dims that are not multiples of their
block are handled by in-kernel tail masks (iota row/column masks on load,
out-of-bounds stores dropped by the grid), never by silently clamping the
block to the shape — a clamped tile would diverge from the Selection the
cost model priced.  Correctness therefore does not depend on zero-filled
padding anywhere: the ``m_true`` scalar marks how many leading rows of ``a``
are real, and everything past it (stale bytes in an engine staging buffer,
uninitialized pad, NaNs) is masked to zero before it can reach the MXU.

TARGET: TPU (MXU).  Validated on CPU via ``interpret=True``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams as _CompilerParams

__all__ = ["vortex_gemm", "validate_blocks"]


def validate_blocks(kind: str, **blocks: int) -> None:
    """Reject block sizes the kernel could not honor.

    The masked-tail kernels never clamp a requested tile (that would
    silently deviate from the Selection that was priced); a tile they
    cannot realize at all is therefore an error, not an adjustment.
    """
    for name, blk in blocks.items():
        if not isinstance(blk, (int,)) or isinstance(blk, bool) or blk < 1:
            raise ValueError(
                f"{kind}: {name}={blk!r} cannot be honored — selected tiles "
                "must be positive integers (the kernel masks tails instead "
                "of clamping, so a degenerate block has no meaning)"
            )


def _gemm_kernel(
    m_ref, a_ref, b_ref, o_ref, acc_ref,
    *, gk: int, block_m: int, block_n: int, block_k: int,
    M: int, N: int, K: int, mask_rows: bool, out_dtype,
):
    """One (m, n) block: accumulate A[m,k] @ B[k,n] over the k grid dim.

    ``acc_ref`` is an f32 VMEM scratch accumulator — it survives across the
    sequential k steps because the k grid dimension is innermost and TPU
    grids execute sequentially per core (rKernel level-2 temporal loop).

    ``m_ref`` (SMEM) holds the TRUE row count: rows past it are masked to
    zero on load, so the pad region of a staged input may hold arbitrary
    garbage.  The static K/N tail masks neutralize boundary blocks when a
    block does not divide the dim (out-of-bounds reads are undefined).
    """
    i, j, k = pl.program_id(0), pl.program_id(1), pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    a = a_ref[...]
    if mask_rows or K % block_k:
        rows = i * block_m + jax.lax.broadcasted_iota(
            jnp.int32, (block_m, block_k), 0
        )
        cols = k * block_k + jax.lax.broadcasted_iota(
            jnp.int32, (block_m, block_k), 1
        )
        valid = cols < K
        if mask_rows:
            valid &= rows < m_ref[0]
        a = jnp.where(valid, a, 0)
    if K % block_k or N % block_n:
        brows = k * block_k + jax.lax.broadcasted_iota(
            jnp.int32, (block_k, block_n), 0
        )
        bcols = j * block_n + jax.lax.broadcasted_iota(
            jnp.int32, (block_k, block_n), 1
        )
        b = jnp.where((brows < K) & (bcols < N), b_ref[...], 0)
    else:
        b = b_ref[...]

    acc_ref[...] += jnp.dot(a, b, preferred_element_type=jnp.float32)

    @pl.when(k == gk - 1)
    def _store():
        o_ref[...] = acc_ref[...].astype(out_dtype)


@functools.partial(
    jax.jit,
    static_argnames=("block_m", "block_n", "block_k", "interpret", "out_dtype"),
)
def vortex_gemm(
    a: jax.Array,
    b: jax.Array,
    m_true=None,
    *,
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 128,
    interpret: bool = False,
    out_dtype=None,
) -> jax.Array:
    """C[M,N] = A[M,K] @ B[K,N] with Vortex layer-1 tiles as BlockSpecs.

    Shapes need NOT be multiples of the blocks: the grid rounds up and the
    boundary tiles are masked in-kernel, so the selected tile is executed
    exactly as priced (no silent clamping) and padding never has to be
    zero-filled.

    ``m_true`` (optional int or i32 scalar) is the number of REAL leading
    rows of ``a``; rows past it are masked to zero on load.  The serving
    engine passes the runtime extent here and hands the kernel a
    bucket-shaped staging buffer whose pad tail holds stale bytes.
    """
    M, K = a.shape
    K2, N = b.shape
    assert K == K2, (a.shape, b.shape)
    validate_blocks(
        "vortex_gemm", block_m=block_m, block_n=block_n, block_k=block_k
    )
    gm, gn, gk = pl.cdiv(M, block_m), pl.cdiv(N, block_n), pl.cdiv(K, block_k)
    out_dtype = out_dtype or a.dtype
    # The row mask costs a VPU compare per tile; skip it when every row is
    # statically real (no runtime extent, M divides evenly).
    mask_rows = m_true is not None or M % block_m != 0
    if m_true is None:
        m_true = M
    m_arr = jnp.asarray(m_true, jnp.int32).reshape(1)

    kernel = functools.partial(
        _gemm_kernel,
        gk=gk, block_m=block_m, block_n=block_n, block_k=block_k,
        M=M, N=N, K=K, mask_rows=mask_rows, out_dtype=out_dtype,
    )
    return pl.pallas_call(
        kernel,
        grid=(gm, gn, gk),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((block_m, block_k), lambda i, j, k: (i, k)),
            pl.BlockSpec((block_k, block_n), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), out_dtype),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(m_arr, a, b)
