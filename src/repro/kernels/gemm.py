"""Vortex-tiled GEMM as a Pallas TPU kernel.

The BlockSpec tiling is *not* hand-picked: the (block_m, block_n, block_k)
triple is the layer-1 tile selected by Vortex's runtime selector from the
hardware-pruned candidate lattice (core/), and the grid is the layer-2
parallel/temporal loop structure of the rKernel program:

    grid = (gm, gn, gk)   — (m, n) are the PARALLEL loops (distributed over
                            TensorCores on real hardware), k is the
                            TEMPORAL-REDUCTION loop (sequential, accumulator
                            resident in VMEM across the k steps).

TARGET: TPU (MXU).  Validated on CPU via ``interpret=True``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams as _CompilerParams

__all__ = ["vortex_gemm"]


def _gemm_kernel(a_ref, b_ref, o_ref, acc_ref, *, gk: int, out_dtype):
    """One (m, n) block: accumulate A[m,k] @ B[k,n] over the k grid dim.

    ``acc_ref`` is an f32 VMEM scratch accumulator — it survives across the
    sequential k steps because the k grid dimension is innermost and TPU
    grids execute sequentially per core (rKernel level-2 temporal loop).
    """
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(k == gk - 1)
    def _store():
        o_ref[...] = acc_ref[...].astype(out_dtype)


@functools.partial(
    jax.jit,
    static_argnames=("block_m", "block_n", "block_k", "interpret", "out_dtype"),
)
def vortex_gemm(
    a: jax.Array,
    b: jax.Array,
    *,
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 128,
    interpret: bool = False,
    out_dtype=None,
) -> jax.Array:
    """C[M,N] = A[M,K] @ B[K,N] with Vortex layer-1 tiles as BlockSpecs.

    M, N, K must be multiples of the respective block dims — the engine pads
    the dynamic dim to the lattice bucket *before* dispatch (padding confined
    to the outermost level, paper Fig. 8), and N/K are static weight dims for
    which the lattice only admits divisors-compatible tiles.
    """
    M, K = a.shape
    K2, N = b.shape
    assert K == K2, (a.shape, b.shape)
    block_m = min(block_m, M)
    block_n = min(block_n, N)
    block_k = min(block_k, K)
    if M % block_m or N % block_n or K % block_k:
        raise ValueError(
            f"shape ({M},{N},{K}) not aligned to blocks "
            f"({block_m},{block_n},{block_k}); engine must pre-pad"
        )
    gm, gn, gk = M // block_m, N // block_n, K // block_k
    out_dtype = out_dtype or a.dtype

    kernel = functools.partial(_gemm_kernel, gk=gk, out_dtype=out_dtype)
    return pl.pallas_call(
        kernel,
        grid=(gm, gn, gk),
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, k: (i, k)),
            pl.BlockSpec((block_k, block_n), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), out_dtype),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(a, b)
