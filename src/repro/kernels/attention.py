"""Flash-attention Pallas TPU kernel with Vortex-selected block sizes.

Attention's two contractions (QK^T and PV) are GEMMs whose dynamic dim is the
sequence length — exactly the paper's dynamic-M case.  The (block_q, block_k)
pair is drawn from the Vortex layer-1 lattice (m-tile for queries, k-tile for
keys), so the same sample-free bucketing governs attention and plain GEMMs.

Key-side padding is handled by an EXPLICIT validity mask, not by the causal
structure: ``kv_len`` (a runtime i32 in SMEM — one scalar shared by the
batch, or a per-batch-row vector for mixed-progress decode) marks how many
leading key/value rows are real, scores past it are masked to -inf and the
value rows are zeroed on load.  The pad tail of k/v may therefore hold arbitrary
garbage (stale bytes in an engine staging buffer, NaNs), and non-causal
attention buckets exactly as safely as causal attention.  Requested blocks
are honored verbatim — sequence lengths that are not block multiples get
masked boundary tiles, never a silently clamped block.

Supports causal masking, sliding-window attention (h2o-danube, gemma2 local
layers) and GQA (kv heads shared across query-head groups via the BlockSpec
index map).  TARGET: TPU; validated on CPU with ``interpret=True``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams as _CompilerParams
from repro.kernels.gemm import validate_blocks

__all__ = ["flash_attention"]

_NEG_INF = -1e30


def _attn_kernel(
    kv_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
    *, gkv: int, block_q: int, block_k: int, scale: float,
    causal: bool, window: int | None, softcap: float | None,
    heads: int, rows: int,
):
    """One (head, q-block): stream kv blocks, online softmax in VMEM scratch.

    ``kv_ref`` (SMEM, shape ``(2, rows)``) holds two runtime i32 values per
    batch row: the TRUE key/value length and the absolute position of query
    row 0.  With ``rows == 1`` both are shared by every batch row (the
    scalar contract); with ``rows == b`` each batch row masks at ITS OWN
    extent — one launch serves rows at different kv positions
    (mixed-progress batched decode), a ``kv_len`` of 0 masking a row to
    zero work (all scores -inf, value rows zeroed, output exactly 0).
    Everything past the per-row kv length — bucket pad, stale staging
    bytes, out-of-bounds block tails — is masked out of the scores and
    zeroed out of the PV product, so no zero-filled padding (and no causal
    structure) is needed for correctness.  The query offset re-bases the
    causal/window masks so a single-row decode query (``sq == 1`` at
    absolute position ``kv_len - 1``) masks exactly like the matching row
    of a full-sequence call.
    """
    kv_i = pl.program_id(2)

    @pl.when(kv_i == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0]  # (block_q, d)
    k = k_ref[0]  # (block_k, d)
    v = v_ref[0]
    # Grid axis 0 is flattened (batch, head): the batch row owning this
    # program recovers as pid // heads (0 when the extents are shared).
    row = pl.program_id(0) // heads if rows > 1 else 0
    kv_limit = kv_ref[0, row]
    q_off = kv_ref[1, row]
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
    if softcap is not None:
        s = jnp.tanh(s / softcap) * softcap

    q_pos = q_off + pl.program_id(1) * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 0
    )
    k_pos = kv_i * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 1
    )
    mask = k_pos < kv_limit  # key validity: replaces zero-pad reliance
    if causal:
        mask &= k_pos <= q_pos
    if window is not None:
        mask &= q_pos - k_pos < window
    s = jnp.where(mask, s, _NEG_INF)

    # Invalid value rows must be ZEROED, not merely down-weighted: their
    # softmax weight is an exact 0.0, but 0 * garbage(NaN/Inf) would still
    # poison the accumulator of every REAL query row.
    v_rows = kv_i * block_k + jax.lax.broadcasted_iota(
        jnp.int32, v.shape, 0
    )
    v = jnp.where(v_rows < kv_limit, v, 0)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, None])
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jnp.dot(
        p.astype(v.dtype), v, preferred_element_type=jnp.float32
    )
    m_ref[...] = m_new

    @pl.when(kv_i == gkv - 1)
    def _store():
        denom = jnp.maximum(l_ref[...], 1e-30)[:, None]
        o_ref[0] = (acc_ref[...] / denom).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=(
        "block_q", "block_k", "causal", "window", "softcap", "interpret",
    ),
)
def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    kv_len=None,
    q_offset=None,
    *,
    block_q: int = 128,
    block_k: int = 128,
    causal: bool = True,
    window: int | None = None,
    softcap: float | None = None,
    interpret: bool = False,
) -> jax.Array:
    """Multi-head attention.

    Args:
      q: (batch, q_heads, seq, head_dim)
      k, v: (batch, kv_heads, seq, head_dim); q_heads % kv_heads == 0 (GQA).
      kv_len: optional runtime i32 — the number of REAL key/value rows;
        rows past it (staging-buffer pad, garbage) are masked out.
        Either a scalar shared by the whole batch or a ``(batch,)`` vector
        giving each batch row its OWN extent (mixed-progress batched
        decode; a 0 masks that row to zero work and an all-zero output).
        Defaults to the full (static) key length.
      q_offset: optional runtime i32 scalar or ``(batch,)`` vector — the
        absolute position of query row 0 (decode: ``kv_len - 1`` for the
        single new token).  Re-bases the causal/window masks; defaults to
        0 (self-attention with queries and keys sharing position 0).
      block_q/block_k: Vortex layer-1 tiles for the sequence dims — honored
        verbatim; non-multiple sequence lengths get masked boundary tiles.
        A decode-shaped call (sq == 1) runs block_q == 1 — the q tile is
        pinned by the static query length, not the lattice.
      window: sliding-window size (keys within [q-window+1, q]).
      softcap: gemma2-style logit soft-capping applied to QK^T scores.
    Returns (batch, q_heads, seq, head_dim).
    """
    b, hq, sq, d = q.shape
    _, hkv, skv, _ = k.shape
    assert hq % hkv == 0, (hq, hkv)
    group = hq // hkv
    validate_blocks("flash_attention", block_q=block_q, block_k=block_k)
    gq, gkv = pl.cdiv(sq, block_q), pl.cdiv(skv, block_k)
    scale = d ** -0.5
    if kv_len is None:
        kv_len = skv
    if q_offset is None:
        q_offset = 0
    kv_vec = jnp.asarray(kv_len, jnp.int32)
    off_vec = jnp.asarray(q_offset, jnp.int32)
    for name, vec in (("kv_len", kv_vec), ("q_offset", off_vec)):
        assert vec.ndim <= 1 and (vec.ndim == 0 or vec.shape == (b,)), (
            f"{name} must be a scalar or a (batch,)=({b},) vector, "
            f"got shape {vec.shape}"
        )
    # Per-row extents ride as a (2, rows) SMEM array: one column per batch
    # row when either extent is a vector, one shared column otherwise.
    rows = b if (kv_vec.ndim or off_vec.ndim) else 1
    kv_arr = jnp.stack([
        jnp.broadcast_to(kv_vec.reshape(-1), (rows,)),
        jnp.broadcast_to(off_vec.reshape(-1), (rows,)),
    ])

    qf = q.reshape(b * hq, sq, d)
    kf = k.reshape(b * hkv, skv, d)
    vf = v.reshape(b * hkv, skv, d)

    kernel = functools.partial(
        _attn_kernel,
        gkv=gkv, block_q=block_q, block_k=block_k, scale=scale,
        causal=causal, window=window, softcap=softcap,
        heads=hq, rows=rows,
    )

    def kv_map(h, i, j):
        del i
        return (h // group, j, 0)

    out = pl.pallas_call(
        kernel,
        grid=(b * hq, gq, gkv),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((1, block_q, d), lambda h, i, j: (h, i, 0)),
            pl.BlockSpec((1, block_k, d), kv_map),
            pl.BlockSpec((1, block_k, d), kv_map),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda h, i, j: (h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b * hq, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(kv_arr, qf, kf, vf)
    return out.reshape(b, hq, sq, d)
