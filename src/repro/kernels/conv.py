"""Convolution via im2col + the Vortex GEMM kernel.

The paper benchmarks convolution (Table 4) by lowering it to the same
hierarchized GEMM strategy space: im2col turns Conv2D into a GEMM with
M = b*h'*w' (dynamic: batch/fmap), N = cout, K = kh*kw*cin — after which the
entire Vortex lattice/selector machinery applies unchanged.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.gemm import vortex_gemm

__all__ = ["im2col", "vortex_conv2d"]


def im2col(x: jax.Array, kh: int, kw: int, stride: int = 1) -> jax.Array:
    """(b, h, w, cin) -> (b*h'*w', kh*kw*cin) patches, VALID padding."""
    b, h, w, cin = x.shape
    ho = (h - kh) // stride + 1
    wo = (w - kw) // stride + 1
    patches = jax.lax.conv_general_dilated_patches(
        x,
        filter_shape=(kh, kw),
        window_strides=(stride, stride),
        padding="VALID",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )  # (b, ho, wo, cin*kh*kw), feature dim ordered (cin, kh, kw)
    return patches.reshape(b * ho * wo, cin * kh * kw), (b, ho, wo)


def vortex_conv2d(
    x: jax.Array,
    w: jax.Array,
    *,
    stride: int = 1,
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """Conv2D (VALID) through im2col + Vortex-tiled GEMM.

    Args: x (b, h, w, cin); w (kh, kw, cin, cout).
    """
    kh, kw, cin, cout = w.shape
    cols, (b, ho, wo) = im2col(x, kh, kw, stride)
    # conv_general_dilated_patches orders features as (cin, kh, kw); match it.
    wmat = w.transpose(2, 0, 1, 3).reshape(kh * kw * cin, cout)
    m = cols.shape[0]

    # Pad every dim up to block multiples (the engine normally does this at
    # the bucket level; conv shapes are arbitrary so pad here).
    def pad_to(v: int, blk: int) -> int:
        blk = min(blk, max(v, 1))
        return (v + blk - 1) // blk * blk, blk

    mp, bm = pad_to(m, block_m)
    np_, bn = pad_to(cout, block_n)
    kp, bk = pad_to(cols.shape[1], block_k)
    cols = jnp.pad(cols, ((0, mp - m), (0, kp - cols.shape[1])))
    wmat = jnp.pad(wmat, ((0, kp - wmat.shape[0]), (0, np_ - cout)))
    out = vortex_gemm(
        cols, wmat, block_m=bm, block_n=bn, block_k=bk, interpret=interpret
    )
    return out[:m, :cout].reshape(b, ho, wo, cout)
