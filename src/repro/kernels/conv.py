"""Convolution via im2col + the Vortex GEMM kernel.

The paper benchmarks convolution (Table 4) by lowering it to the same
hierarchized GEMM strategy space: im2col turns Conv2D into a GEMM with
M = b*h'*w' (dynamic: batch/fmap), N = cout, K = kh*kw*cin — after which the
entire Vortex lattice/selector machinery applies unchanged.

The GEMM-view kernel masks its own tails (kernels/gemm.py), so this path is
padding-free end to end: no dim is rounded up, no block is clamped to the
shape, and the blocks the caller selected are the blocks that run.
"""
from __future__ import annotations

import jax

from repro.kernels.gemm import vortex_gemm

__all__ = ["im2col", "vortex_conv2d"]


def im2col(x: jax.Array, kh: int, kw: int, stride: int = 1) -> jax.Array:
    """(b, h, w, cin) -> (b*h'*w', kh*kw*cin) patches, VALID padding."""
    b, h, w, cin = x.shape
    ho = (h - kh) // stride + 1
    wo = (w - kw) // stride + 1
    patches = jax.lax.conv_general_dilated_patches(
        x,
        filter_shape=(kh, kw),
        window_strides=(stride, stride),
        padding="VALID",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )  # (b, ho, wo, cin*kh*kw), feature dim ordered (cin, kh, kw)
    return patches.reshape(b * ho * wo, cin * kh * kw), (b, ho, wo)


def vortex_conv2d(
    x: jax.Array,
    w: jax.Array,
    *,
    stride: int = 1,
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """Conv2D (VALID) through im2col + masked-tail Vortex GEMM.

    Args: x (b, h, w, cin); w (kh, kw, cin, cout).
    """
    kh, kw, cin, cout = w.shape
    cols, (b, ho, wo) = im2col(x, kh, kw, stride)
    # conv_general_dilated_patches orders features as (cin, kh, kw); match it.
    wmat = w.transpose(2, 0, 1, 3).reshape(kh * kw * cin, cout)
    out = vortex_gemm(
        cols, wmat, block_m=block_m, block_n=block_n, block_k=block_k,
        interpret=interpret,
    )
    return out.reshape(b, ho, wo, cout)
