"""jax version-compat shims shared by the Pallas kernels."""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

__all__ = ["CompilerParams"]

# jax renamed TPUCompilerParams -> CompilerParams around 0.5; support both.
CompilerParams = getattr(pltpu, "CompilerParams", None) or getattr(
    pltpu, "TPUCompilerParams"
)
