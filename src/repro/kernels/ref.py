"""Pure-jnp oracles for every Pallas kernel, plus the compile-friendly
chunked attention the model layer uses inside scanned transformer blocks.

These are the semantic ground truth: the test-suite sweeps shapes/dtypes and
asserts the Pallas kernels (interpret mode) match these to tolerance.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

__all__ = [
    "ref_gemm",
    "ref_grouped_gemm",
    "ref_attention",
    "chunked_attention",
    "ref_conv2d",
    "ref_conv1d",
]


def ref_gemm(a: jax.Array, b: jax.Array, out_dtype=None) -> jax.Array:
    out = jax.lax.dot_general(
        a, b, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    return out.astype(out_dtype or a.dtype)


def ref_grouped_gemm(
    x: jax.Array, w: jax.Array, counts=None, out_dtype=None
) -> jax.Array:
    """out[g] = x[g] @ w[g // (G // E)] — ragged grouped GEMM oracle.

    x ``(G, C, K)``, w ``(E, K, N)``; groups are expert-major (``r = G//E``
    consecutive groups share a weight stack entry).  ``counts`` (optional
    ``(G,)`` runtime i32) marks each group's real rows: rows at or past it
    may hold arbitrary garbage (staged-bucket pad) and are masked to zero
    BEFORE the matmul, so the matching output rows are exactly zero.
    """
    G, C, K = x.shape
    E = w.shape[0]
    r = G // E
    xf = x.astype(jnp.float32)
    if counts is not None:
        valid = (
            jnp.arange(C)[None, :]
            < jnp.asarray(counts, jnp.int32).reshape(G, 1)
        )
        xf = jnp.where(valid[..., None], xf, 0)
    out = jnp.einsum(
        "erck,ekn->ercn", xf.reshape(E, r, C, K), w.astype(jnp.float32)
    )
    return out.reshape(G, C, -1).astype(out_dtype or x.dtype)


def _mask(
    sq: int, skv: int, causal: bool, window: int | None, offset: int = 0,
    kv_len=None,
) -> jax.Array:
    """(sq, skv) boolean mask. ``offset`` is the absolute position of query 0
    (decode: offset = cache_len for a single new token).  ``kv_len`` is the
    optional RUNTIME number of valid keys (rows past it are bucket pad)."""
    q_pos = offset + jnp.arange(sq)[:, None]
    k_pos = jnp.arange(skv)[None, :]
    m = jnp.ones((sq, skv), jnp.bool_)
    if kv_len is not None:
        m &= k_pos < kv_len
    if causal:
        m &= k_pos <= q_pos
    if window is not None:
        m &= q_pos - k_pos < window
    return m


def ref_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int | None = None,
    softcap: float | None = None,
    offset: int = 0,
    kv_len=None,
) -> jax.Array:
    """Exact attention with full score materialization (oracle only).

    Shapes as kernels/attention.py: q (b, hq, sq, d); k, v (b, hkv, skv, d).
    ``kv_len`` (optional runtime i32) marks the real key/value rows; rows
    past it may hold arbitrary garbage (staged-bucket pad) and are both
    score-masked and zeroed out of the PV product.  ``kv_len`` and
    ``offset`` are scalars shared by the batch or (b,) vectors giving each
    batch row its own extent/position (mixed-progress batched decode; a
    kv_len of 0 masks that row entirely — its output is exactly 0).
    """
    b, hq, sq, d = q.shape
    _, hkv, skv, _ = k.shape
    group = hq // hkv
    kx = jnp.repeat(k, group, axis=1) if group > 1 else k
    vx = jnp.repeat(v, group, axis=1) if group > 1 else v
    off_vec = jnp.asarray(offset, jnp.int32)
    kv_vec = None if kv_len is None else jnp.asarray(kv_len, jnp.int32)
    per_row = off_vec.ndim == 1 or (kv_vec is not None and kv_vec.ndim == 1)
    if kv_len is not None:
        # Zero invalid value rows: their softmax weight is exactly 0, but
        # 0 * garbage(NaN) would still poison every real query row.
        if per_row:
            valid = jnp.arange(skv)[None, :] < kv_vec.reshape(-1, 1)
            vx = jnp.where(valid[:, None, :, None], vx, 0)
        else:
            valid = (jnp.arange(skv) < kv_len)[None, None, :, None]
            vx = jnp.where(valid, vx, 0)
    s = jnp.einsum(
        "bhqd,bhkd->bhqk", q.astype(jnp.float32), kx.astype(jnp.float32)
    ) * (d ** -0.5)
    if softcap is not None:
        s = jnp.tanh(s / softcap) * softcap
    if per_row:
        # (b, sq, skv) mask: every row masks at ITS OWN offset/extent.
        q_pos = off_vec.reshape(-1, 1, 1) + jnp.arange(sq)[None, :, None]
        k_pos = jnp.arange(skv)[None, None, :]
        m = jnp.ones((1, sq, skv), jnp.bool_)
        if kv_vec is not None:
            m = m & (k_pos < kv_vec.reshape(-1, 1, 1))
        if causal:
            m = m & (k_pos <= q_pos)
        if window is not None:
            m = m & (q_pos - k_pos < window)
        s = jnp.where(m[:, None], s, -1e30)
    else:
        m = _mask(sq, skv, causal, window, offset, kv_len=kv_len)
        s = jnp.where(m[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", p, vx.astype(jnp.float32))
    return out.astype(q.dtype)


def chunked_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int | None = None,
    softcap: float | None = None,
    chunk: int = 1024,
    offset: int = 0,
    kv_len=None,
    rules=None,
) -> jax.Array:
    """Flash-style online-softmax attention in pure JAX (lax.scan over kv
    chunks).  Never materializes the (sq, skv) score matrix, so the compiled
    artifact's memory stays linear in seq — this is what the model layers use
    (the Pallas kernel is the TPU-native version of the same loop).

    ``kv_len`` (optional runtime i32) marks the real key/value rows, exactly
    as in :func:`ref_attention` — required when the kv pad region may hold
    garbage rather than zeros (the engine's staged buckets).  ``kv_len``
    and ``offset`` accept (b,) per-batch-row vectors (mixed-progress
    batched decode), scalar semantics otherwise unchanged.
    """
    b, hq, sq, d = q.shape
    _, hkv, skv, _ = k.shape
    dv = v.shape[-1]
    group = hq // hkv
    if skv <= chunk:
        return ref_attention(
            q, k, v, causal=causal, window=window, softcap=softcap,
            offset=offset, kv_len=kv_len,
        )
    skv_true = skv
    pad = -skv % chunk
    if pad:  # pad keys/values; padded positions are masked out below
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
        skv = skv + pad
    n_chunks = skv // chunk
    scale = d ** -0.5

    # Sharding pins for the scan body.  Without them XLA's propagation can
    # settle on sharding the CONTRACTED head_dim over 'data' (seen under
    # FSDP on deepseek-v2), all-reducing the full f32 score block on every
    # chunk step (§Perf A3: 2x8.2 TB/device/step).
    def pin(t):
        # Only pin when the head count actually divides the TP axis —
        # otherwise "heads_act" resolves to None and the pin would force
        # FULL replication over 'model' (observed: 10x regression on
        # phi4-mini prefill, 24 heads on a 16-wide axis).
        if rules is None or rules.rules.get("heads_act") is None:
            return t
        from repro.models.partitioning import constrain

        return constrain(t, rules, "batch", "heads_act", None, None)

    def pin5(t):
        # Stacked scan xs (n_chunks, b, h, chunk, d): pinning the primal
        # keeps the scan-transposed cotangent heads-sharded too (otherwise
        # the bwd accumulates a full f32 all-gather over heads per step).
        if rules is None or rules.rules.get("kv_heads_act") is None:
            return t
        from repro.models.partitioning import constrain

        return constrain(t, rules, None, "batch", "kv_heads_act", None, None)

    qf = pin(q.astype(jnp.float32))
    dk = k.shape[-1]
    kc = pin5(k.reshape(b, hkv, n_chunks, chunk, dk).transpose(2, 0, 1, 3, 4))
    vc = pin5(v.reshape(b, hkv, n_chunks, chunk, dv).transpose(2, 0, 1, 3, 4))

    off_vec = jnp.asarray(offset, jnp.int32)
    kv_vec = None if kv_len is None else jnp.asarray(kv_len, jnp.int32)
    per_row = off_vec.ndim == 1 or (kv_vec is not None and kv_vec.ndim == 1)
    q_pos = (
        off_vec.reshape(-1, 1) + jnp.arange(sq)[None]  # (b, sq)
        if per_row else offset + jnp.arange(sq)
    )

    def step(carry, xs):
        m_prev, l_prev, acc = carry
        kb, vb, ci = xs
        if group > 1:
            kb = jnp.repeat(kb, group, axis=1)
            vb = jnp.repeat(vb, group, axis=1)
        kb = pin(kb.astype(jnp.float32))
        vb = pin(vb.astype(jnp.float32))
        k_pos = ci * chunk + jnp.arange(chunk)
        limit = (
            skv_true if kv_vec is None else jnp.minimum(kv_vec, skv_true)
        )
        if per_row:
            lim = jnp.broadcast_to(
                jnp.asarray(limit, jnp.int32).reshape(-1), (b,)
            )
            valid = k_pos[None, :] < lim[:, None]  # (b, chunk)
        else:
            valid = k_pos < limit
        if kv_len is not None:
            # Garbage value rows past kv_len must be zeroed, not merely
            # zero-weighted (0 * NaN poisons every real query row).
            vzero = valid[:, None, :, None] if per_row \
                else valid[None, None, :, None]
            vb = jnp.where(vzero, vb, 0)
        s = pin(jnp.einsum("bhqd,bhkd->bhqk", qf, kb) * scale)
        if softcap is not None:
            s = jnp.tanh(s / softcap) * softcap
        if per_row:
            msk = jnp.broadcast_to(valid[:, None, :], (b, sq, chunk))
            if causal:
                msk = msk & (k_pos[None, None, :] <= q_pos[:, :, None])
            if window is not None:
                msk = msk & (q_pos[:, :, None] - k_pos[None, None, :] < window)
            s = jnp.where(msk[:, None], s, -1e30)
        else:
            msk = jnp.broadcast_to(valid[None, :], (sq, chunk))
            if causal:
                msk = msk & (k_pos[None, :] <= q_pos[:, None])
            if window is not None:
                msk = msk & (q_pos[:, None] - k_pos[None, :] < window)
            s = jnp.where(msk[None, None], s, -1e30)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[..., None])
        l_new = l_prev * alpha + jnp.sum(p, axis=-1)
        acc = acc * alpha[..., None] + jnp.einsum("bhqk,bhkd->bhqd", p, vb)
        return (m_new, l_new, pin(acc)), None

    init = (
        jnp.full((b, hq, sq), -1e30, jnp.float32),
        jnp.zeros((b, hq, sq), jnp.float32),
        jnp.zeros((b, hq, sq, dv), jnp.float32),
    )
    (m, l, acc), _ = jax.lax.scan(
        step, init, (kc, vc, jnp.arange(n_chunks))
    )
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.astype(q.dtype)


def ref_conv1d(
    x: jax.Array, w: jax.Array, stride: int = 1, padding: str = "SAME"
) -> jax.Array:
    """(b, t, cin) * (kw, cin, cout) -> (b, t', cout)."""
    return jax.lax.conv_general_dilated(
        x, w, window_strides=(stride,), padding=padding,
        dimension_numbers=("NWC", "WIO", "NWC"),
    )


def ref_conv2d(
    x: jax.Array, w: jax.Array, stride: int = 1, padding: str = "SAME"
) -> jax.Array:
    """(b, h, w, cin) * (kh, kw, cin, cout) -> (b, h', w', cout)."""
    return jax.lax.conv_general_dilated(
        x, w, window_strides=(stride, stride), padding=padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
