"""Ragged grouped GEMM as a masked-tail Pallas TPU kernel.

MoE expert FFNs are G independent GEMMs that share one stacked weight
tensor: group g multiplies its ``(C, K)`` activation slab against expert
``g // groups_per_expert``'s ``(K, N)`` weights.  The slabs are capacity-
shaped (C rows each) but only ``counts[g]`` leading rows are real — the
rest is routing pad whose content is arbitrary (and, for an engine staging
buffer, stale bytes from a previous dispatch).

This is the masked-tail contract of ``vortex_gemm`` lifted from one scalar
``m_true`` to a per-group ``(G,)`` i32 extent vector: the grid flattens
(group, m-tile) into its first dimension, and every program masks A-rows at
ITS OWN group's count before they can reach the MXU.  Rows at or past
``counts[g]`` are exactly zero in the output (zero A-rows -> zero C-rows),
which is what makes staged dispatch bit-identical to the zero-padded
reference path.

One ``pallas_call`` covers all G groups — a single launch per projection
regardless of how routing distributed the tokens.

TARGET: TPU (MXU).  Validated on CPU via ``interpret=True``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams as _CompilerParams
from repro.kernels.gemm import validate_blocks

__all__ = ["vortex_grouped_gemm"]


def _grouped_gemm_kernel(
    counts_ref, x_ref, w_ref, o_ref, acc_ref,
    *, gm: int, gk: int, block_m: int, block_n: int, block_k: int,
    N: int, K: int, out_dtype,
):
    """One (group, m-tile, n-tile) block; k is the sequential reduction dim.

    Grid dim 0 enumerates (group, m-tile) pairs: ``g = i // gm`` selects the
    group, ``mi = i % gm`` the row tile within it.  ``counts_ref`` (SMEM,
    full ``(G,)`` vector) holds every group's true row count; this program
    masks its A-rows at ``counts_ref[g]``, so each group gets its own
    runtime extent from ONE launch.  K/N tail masks as in ``_gemm_kernel``.
    """
    i, j, k = pl.program_id(0), pl.program_id(1), pl.program_id(2)
    g = i // gm
    mi = i % gm

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # Row mask is unconditional: counts[g] is a runtime value, and the rows
    # past it may be NaN (staging-pool garbage) — they must never reach the
    # accumulator, even through a 0-weight.
    rows = mi * block_m + jax.lax.broadcasted_iota(
        jnp.int32, (block_m, block_k), 0
    )
    valid = rows < counts_ref[g]
    if K % block_k:
        cols = k * block_k + jax.lax.broadcasted_iota(
            jnp.int32, (block_m, block_k), 1
        )
        valid &= cols < K
    x = jnp.where(valid, x_ref[0], 0)

    if K % block_k or N % block_n:
        wrows = k * block_k + jax.lax.broadcasted_iota(
            jnp.int32, (block_k, block_n), 0
        )
        wcols = j * block_n + jax.lax.broadcasted_iota(
            jnp.int32, (block_k, block_n), 1
        )
        w = jnp.where((wrows < K) & (wcols < N), w_ref[0], 0)
    else:
        w = w_ref[0]

    acc_ref[...] += jnp.dot(x, w, preferred_element_type=jnp.float32)

    @pl.when(k == gk - 1)
    def _store():
        o_ref[0] = acc_ref[...].astype(out_dtype)


@functools.partial(
    jax.jit,
    static_argnames=("block_m", "block_n", "block_k", "interpret", "out_dtype"),
)
def vortex_grouped_gemm(
    x: jax.Array,
    w: jax.Array,
    counts: jax.Array,
    *,
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 128,
    interpret: bool = False,
    out_dtype=None,
) -> jax.Array:
    """out[g] = x[g] @ w[g // r] with per-group masked-tail row extents.

    Args:
      x: ``(G, C, K)`` capacity-shaped activation slabs, one per group.
      w: ``(E, K, N)`` stacked expert weights; ``r = G // E`` consecutive-
         in-expert-major-order groups share each stack entry (callers lay
         groups out expert-major: group ``e * r + b`` uses expert ``e``).
      counts: ``(G,)`` i32 — group g's TRUE row count.  Rows of ``x[g]`` at
         or past ``counts[g]`` may hold arbitrary garbage; the matching
         output rows are exactly zero.

    One launch covers all groups: grid dim 0 is the flattened
    (group, m-tile) space, so Selection's (block_m, block_n, block_k) tile
    is honored verbatim per group and the per-group extent is a runtime
    SMEM value, not a shape.
    """
    G, C, K = x.shape
    E, K2, N = w.shape
    assert K == K2, (x.shape, w.shape)
    assert G % E == 0, (G, E)
    validate_blocks(
        "vortex_grouped_gemm",
        block_m=block_m, block_n=block_n, block_k=block_k,
    )
    r = G // E
    gm, gn, gk = pl.cdiv(C, block_m), pl.cdiv(N, block_n), pl.cdiv(K, block_k)
    out_dtype = out_dtype or x.dtype
    counts_arr = jnp.asarray(counts, jnp.int32).reshape(G)

    kernel = functools.partial(
        _grouped_gemm_kernel,
        gm=gm, gk=gk, block_m=block_m, block_n=block_n, block_k=block_k,
        N=N, K=K, out_dtype=out_dtype,
    )
    return pl.pallas_call(
        kernel,
        grid=(G * gm, gn, gk),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((1, block_m, block_k), lambda i, j, k: (i // gm, i % gm, k)),
            pl.BlockSpec((1, block_k, block_n), lambda i, j, k: ((i // gm) // r, k, j)),
        ],
        out_specs=pl.BlockSpec((1, block_m, block_n), lambda i, j, k: (i // gm, i % gm, j)),
        out_shape=jax.ShapeDtypeStruct((G, C, N), out_dtype),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(counts_arr, x, w)
