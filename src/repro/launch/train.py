"""Training launcher: ``python -m repro.launch.train --arch <id> [--smoke]``.

On this CPU container it runs reduced (smoke) configs end-to-end with the
full production plumbing: sharded params (host mesh), microbatched train
step, deterministic data pipeline, async checkpointing, supervisor-driven
restart, straggler monitor.  On a TPU pod the same script runs the full
config on ``make_production_mesh()`` (``--mesh prod``).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.data.pipeline import Prefetcher, SyntheticLMDataset
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.models.params import count_params, init_params, param_pspecs
from repro.models.partitioning import make_rules, spec_tree_to_shardings
from repro.models.registry import get_config, get_smoke_config
from repro.optim.adamw import adamw_init, opt_state_pspecs
from repro.runtime.heartbeat import StepMonitor
from repro.runtime.supervisor import Supervisor
from repro.train.step import TrainHParams, make_train_step


def build_trainer(
    cfg, mesh, *, batch: int, seq: int, hp: TrainHParams, seed: int = 0
):
    rules = make_rules(
        mesh, fsdp=cfg.fsdp, n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads
    )
    params = init_params(cfg, jax.random.PRNGKey(seed))
    opt = adamw_init(params)
    p_specs = param_pspecs(cfg, rules)
    o_specs = opt_state_pspecs(
        p_specs, params, dict(mesh.shape).get("data", 1)
    )
    p_sh = spec_tree_to_shardings(mesh, p_specs)
    o_sh = spec_tree_to_shardings(mesh, o_specs)
    params = jax.tree.map(jax.device_put, params, p_sh)
    opt = jax.tree.map(jax.device_put, opt, o_sh)
    step = jax.jit(
        make_train_step(cfg, rules, hp),
        in_shardings=(p_sh, o_sh, None),
        out_shardings=(p_sh, o_sh, None),
        donate_argnums=(0, 1),
    )
    return params, opt, step


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="paper-gpt2-124m")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--mesh", choices=["host", "prod"], default="host")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--microbatches", type=int, default=2)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    mesh = (
        make_production_mesh() if args.mesh == "prod" else make_host_mesh()
    )
    hp = TrainHParams(
        base_lr=args.lr,
        warmup_steps=max(args.steps // 10, 1),
        total_steps=args.steps,
        num_microbatches=args.microbatches,
    )
    print(f"arch={cfg.name} params={count_params(cfg):,} mesh={dict(mesh.shape)}")
    params, opt, step_fn = build_trainer(
        cfg, mesh, batch=args.batch, seq=args.seq, hp=hp
    )

    data = SyntheticLMDataset(cfg.vocab, args.seq, args.batch)
    ckpt = CheckpointManager(args.ckpt_dir, keep_n=2)
    monitor = StepMonitor()
    sup = Supervisor(ckpt, ckpt_every=args.ckpt_every)

    # NOTE: batches are fetched by step index (not an iterator) so restarts
    # replay the exact stream; Prefetcher covers the steady-state throughput
    # path and is exercised by examples/train_lm.py and the tests.
    state = {"params": params, "opt": opt}

    def one_step(state, step):
        t0 = time.perf_counter()
        batch = {
            k: jnp.asarray(v) for k, v in data.batch_at(step).items()
        }
        if cfg.vision_prefix:
            batch["vision_embeds"] = jnp.zeros(
                (args.batch, cfg.vision_prefix, cfg.d_model),
                jnp.dtype(cfg.dtype),
            )
        if cfg.encoder_decoder:
            batch["encoder_frames"] = jnp.zeros(
                (args.batch, cfg.encoder_seq, cfg.d_model),
                jnp.dtype(cfg.dtype),
            )
        params, opt, metrics = step_fn(state["params"], state["opt"], batch)
        loss = float(metrics["loss"])
        monitor.record(0, step, time.perf_counter() - t0)
        if step % args.log_every == 0:
            print(f"step {step:5d}  loss {loss:.4f}  "
                  f"lr {float(metrics['lr']):.2e}  "
                  f"({time.perf_counter() - t0:.2f}s)")
        return {"params": params, "opt": opt}

    t0 = time.perf_counter()
    state = sup.run(state, one_step, num_steps=args.steps)
    ckpt.wait()
    print(
        f"done: {sup.stats.steps_run} steps in {time.perf_counter()-t0:.1f}s;"
        f" failures={sup.stats.failures} restores={sup.stats.restores};"
        f" stragglers={monitor.stragglers()}"
    )


if __name__ == "__main__":
    main()
