"""Dynamic-shape serving driver — where Vortex earns its keep at runtime.

Requests arrive with arbitrary batch sizes and prompt lengths.  XLA needs
static shapes, so every distinct (batch, prompt_len) would recompile.  The
server quantizes both dims through the vortex engine session it owns:

  * the sequence dim is bucketed by the engine's own selection machinery —
    ``CompiledOp.bucket`` over the model's GEMM signature, i.e. the SAME
    lattice breakpoints the runtime selector bisects (there is no second,
    hand-rolled bucketing scheme in the tree);
  * the request batch dim (an auxiliary outer multiplier) is pow2-bucketed
    (``vortex.pow2_bucket``).

Prefill executables are AOT-compiled per bucket through ONE jit function
(``jit(...).lower(...).compile()``), so ``stats["prefill_compiles"]``
counts real XLA compilations — not per-shape Python wrappers around a jit
that retraces anyway.  Lowering runs under ``engine.use()``: prefill AND
decode attention inside the model dispatch through the engine session, so
the compiled programs embed lattice-selected attention blocks.  (The
engine serves those trace-time calls through its zero-pad reference path
— the pads fuse into the program, and at a bucket-aligned cache length
there is nothing to pad — and counts them as ``traced_calls``; eager
dispatch outside a trace takes the masked-tail staging hot path, whose
launch/copy counters ``engine_dispatch_stats`` surfaces.)

Decode is the third padding-free serving scenario (after aligned and
unaligned prefill): the KV cache lives in kv-BUCKET-shaped buffers (the
decode-attention workload's own bucket set — the same kv buckets prefill
streams), each step runs exactly ONE AOT decode program for the current
(batch-bucket, kv-bucket) pair, and the cache grows IN PLACE by
``dynamic_update_slice`` — the new token's K/V row lands in the bucket
buffer, nothing re-stages per token.  Rows past ``pos`` are dead weight
the kv_len mask never reads.  When ``pos`` outgrows the bucket, the cache
is copied once into the next bucket's buffers (amortized-doubling growth,
so the reachable bucket chain stays logarithmic); ``decode_stats`` (a
DispatchStats) counts launches per token, growth copies and pad
fallbacks (always 0) — surfaced by ``engine_dispatch_stats()`` under
``decode_step``.  ``warmup()`` AOT-compiles the per-bucket prefill AND
decode programs (warming the engine's attention executables through the
session) before traffic arrives.

``prefill="chained"`` swaps the AOT prefill program for the lazy-handle
chain (DESIGN.md §8): the whole model runs eagerly through the engine
session with every dispatch output staying a bucket-shaped
:class:`~repro.core.engine.LazyBucket` that the next dispatch consumes
directly — at a chain-aligned sequence bucket (``chain_seq_bucket``) a
prefill performs ZERO interior unstage+restage pairs, and the decode
cache's k/v leaves consume the attention projections' bucket buffers
without a copy.  The eager per-op reference (``prefill_chained(...,
eager=True)``) runs the identical dispatch sequence on plain arrays and
is bit-identical; the AOT path stays the default and the fallback for
unsupported architectures.

``python -m repro.launch.serve --arch paper-gpt2-124m --smoke --requests 16``
"""
from __future__ import annotations

import argparse
import dataclasses
import math
import threading
import time
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import AttentionWorkload, DecodeAttentionWorkload, GemmWorkload
from repro.core.engine import DispatchStats
from repro.launch.mesh import make_host_mesh
from repro.models.model import abstract_cache
from repro.models.params import init_params
from repro.models.partitioning import make_rules
from repro.models.registry import get_config, get_smoke_config
from repro.runtime import faults
from repro.train.step import make_decode_step, make_prefill_step
from repro.vortex import CompiledOp, Engine, EngineConfig, pow2_bucket

__all__ = [
    "VortexServer",
    "Request",
    "KVBucketPool",
    "RequestError",
    "QueueFullError",
    "DeadlineExceeded",
    "CacheOverflowError",
]


class CacheOverflowError(ValueError):
    """The request cannot fit ``max_cache`` even after growth — refused
    up front (before any prefill work) by BOTH admission paths: the
    serial ``generate()`` and the scheduler's ``submit()``.  A
    ``ValueError`` subclass so pre-existing callers matching ValueError
    keep working."""


class QueueFullError(RuntimeError):
    """``submit()`` refused: the scheduler's bounded admission queue
    (``max_queue``) is at capacity — back-pressure, not failure; retry
    after a drain."""


class RequestError(RuntimeError):
    """A typed per-request failure (DESIGN.md §11): the scheduler's
    ``drain()`` RETURNS this (in place of the token array) for a request
    whose admission, cache growth, or decode raised — the step loop
    itself never tears down.  ``stage`` names the failure domain
    (``admit`` / ``grow`` / ``decode`` / ``deadline``)."""

    def __init__(self, request_id: int, stage: str, message: str):
        self.request_id = request_id
        self.stage = stage
        super().__init__(
            f"request {request_id} failed during {stage}: {message}"
        )


class DeadlineExceeded(RequestError):
    """A request's wall-clock ``deadline_s`` expired before completion;
    its rows retire immediately and the slots are reused next step."""

    def __init__(self, request_id: int, deadline_s: float):
        self.deadline_s = deadline_s
        super().__init__(
            request_id, "deadline",
            f"deadline_s={deadline_s} expired before completion",
        )


@dataclasses.dataclass
class Request:
    tokens: np.ndarray  # (batch, prompt_len)
    max_new: int = 8
    # Early-stop token: a row that emits it retires immediately, its
    # remaining output positions filled with the stop token (scheduler
    # path; the serial ``generate()`` path always runs to max_new).
    stop: int | None = None
    # Assigned by the admission queue (launch/scheduler.py) so responses
    # can be matched to submissions; the serial ``generate()`` path never
    # reads it.
    request_id: int | None = None
    # Wall-clock budget from ``submit()`` (scheduler path only): once it
    # expires the request resolves to ``DeadlineExceeded`` instead of
    # occupying slots forever.  None = no deadline.
    deadline_s: float | None = None


class KVBucketPool:
    """Shared pool of kv-bucket cache buffers, leased per request.

    Cache growth used to drop the outgrown bucket's buffers to the GC and
    allocate fresh zero-filled ones; under continuous batching that churn
    happens on every admitted request.  The pool instead PARKS released
    buffers keyed by (shape, dtype) and hands them back on the next lease.
    A reused buffer is returned AS-IS — stale bytes and all — which is
    safe exactly where the masked-tail contract holds: attention k/v
    leaves are only ever read through the kv_len-masked decode workload,
    so rows past each row's extent are never consumed.  Leaves whose
    decode math masks scores but not values (MLA's ckv/k_rope: the
    absorbed PV contraction would hit 0 * garbage) must lease with
    ``zero=True``, which always allocates fresh zeros.

    Every growable cache leaf in flight counts as one active lease
    (``leases_active``; high-water mark ``leases_peak``) whether it came
    from the free list or a fresh allocation — a non-zero ``leases_active``
    at idle is a leak, asserted by the scheduler tests and surfaced via
    ``VortexServer.engine_dispatch_stats()["kv_pool"]``.  Thread-safe: the
    admission queue leases/releases from submitter and scheduler threads.
    """

    # Parked buffers per (shape, dtype) key; beyond this the oldest are
    # dropped to the GC — the pool bounds memory, it is not a cache of
    # every bucket ever seen.
    _MAX_PARKED = 16

    def __init__(self) -> None:
        self._free: dict[tuple, list[jax.Array]] = {}
        self._lock = threading.Lock()
        self.leases_active = 0
        self.leases_peak = 0
        self.lease_hits = 0
        self.lease_allocs = 0
        self.released = 0

    def lease(self, shape, dtype, *, zero: bool = False) -> jax.Array:
        """One bucket-shaped buffer: a parked one when available (stale
        contents — callers must read it through a kv_len mask), else a
        fresh zero-filled allocation.  ``zero=True`` always allocates."""
        if faults.ACTIVE is not None:
            faults.ACTIVE.check("pool_lease")
        key = (tuple(shape), jnp.dtype(dtype).name)
        buf = None
        with self._lock:
            free = self._free.get(key)
            if free and not zero:
                buf = free.pop()
                self.lease_hits += 1
            else:
                self.lease_allocs += 1
            self.leases_active += 1
            self.leases_peak = max(self.leases_peak, self.leases_active)
        if buf is None:
            buf = jnp.zeros(tuple(shape), jnp.dtype(dtype))
        return buf

    def adopt(self, n: int) -> None:
        """Register ``n`` buffers that entered circulation OUTSIDE
        ``lease`` (the prefill step emits the initial cache leaves) so
        their eventual ``release`` balances the books."""
        with self._lock:
            self.leases_active += n
            self.leases_peak = max(self.leases_peak, self.leases_active)

    def release(self, leaf: jax.Array, *, reuse: bool = True) -> None:
        """Return a leased buffer.  ``reuse=False`` retires it to the GC
        (zero-required leaves gain nothing from parking — their next
        lease allocates fresh zeros anyway) but still settles the lease."""
        with self._lock:
            if reuse:
                free = self._free.setdefault(
                    (tuple(leaf.shape), jnp.dtype(leaf.dtype).name), []
                )
                free.append(leaf)
                if len(free) > self._MAX_PARKED:
                    del free[0]
            self.leases_active -= 1
            self.released += 1

    def stats(self) -> dict[str, int]:
        with self._lock:
            return {
                "leases_active": self.leases_active,
                "leases_peak": self.leases_peak,
                "lease_hits": self.lease_hits,
                "lease_allocs": self.lease_allocs,
                "released": self.released,
            }


class VortexServer:
    """Batched LM serving with Vortex-bucketed dynamic shapes.

    The dynamic dims are the request batch size and the prompt length; both
    are padded to buckets before hitting the compiled prefill/decode
    executables.  The server owns (or is handed) an :class:`Engine`
    session; its sequence buckets are the engine's selection buckets.
    """

    def __init__(
        self,
        cfg,
        mesh,
        *,
        max_cache: int = 512,
        seed: int = 0,
        engine: Engine | None = None,
        prefill: str = "aot",
    ):
        if prefill not in ("aot", "chained"):
            raise ValueError(
                f"prefill must be 'aot' or 'chained', got {prefill!r}"
            )
        self.prefill = prefill
        self.cfg = cfg
        self.rules = make_rules(
            mesh, n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads
        )
        self.params = init_params(cfg, jax.random.PRNGKey(seed))
        self.max_cache = max_cache
        if engine is None:
            # The lattice is built for the TARGET hardware (TPU v5e): its
            # native sublane granularity (16) is what quantizes the bucket
            # set — on the CPU host the same buckets are used so
            # executables dedupe the same way they would on the pod.
            engine = Engine(EngineConfig(hardware="tpu_v5e", backends=("mxu",)))
        self.engine = engine
        # The token dim's bucket source: the model's GEMM signature
        # (N/K = d_model); the selector's M-buckets become our seq buckets.
        # Built via kernel_for, not engine.compile: this handle only ever
        # does bucket arithmetic (select/bucket/buckets), so the engine's
        # eager-precompile policy (precompile_m_max) must not fire for it —
        # the executables would never be dispatched.
        self._seq_op = CompiledOp(engine, engine.kernel_for(
            GemmWorkload(M=None, N=cfg.d_model, K=cfg.d_model)
        ))
        # The cache dim's bucket source: the decode-attention workload over
        # the model's head_dim — its kv buckets (== the kv buckets prefill
        # attention streams, see DecodeAttentionWorkload) are the cache
        # lengths the decode programs are compiled at.
        self._decode_op = CompiledOp(engine, engine.kernel_for(
            DecodeAttentionWorkload(seq=None, head_dim=cfg.resolved_head_dim)
        ))
        # ONE jit per program family; buckets are AOT lowered+compiled
        # through it, so each bucket pays exactly one real compilation and
        # the stats count compilations, not wrapper constructions.
        # Prefill jits are keyed by the emitted cache length (= the kv
        # bucket covering the seq bucket), decode jits by the cache length
        # they serve.
        self._prefill_jits: dict[int, Any] = {}
        self._prefill_exec: dict[tuple[int, int], jax.stages.Compiled] = {}
        self._decode_jits: dict[int, Any] = {}
        self._decode_exec: dict[tuple[int, int], jax.stages.Compiled] = {}
        # Mixed-progress programs: same jit family, pos lowered as a (bp,)
        # per-row vector — a DIFFERENT XLA artifact, cached separately so
        # the scalar-pos serial path keeps its own executables.
        self._decode_exec_vec: dict[tuple[int, int], jax.stages.Compiled] = {}
        # Growable cache leaves are leased from (and returned to) a shared
        # bucket pool instead of churning fresh allocations per growth.
        self.kv_pool = KVBucketPool()
        self.stats = {
            "prefill_compiles": 0, "bucket_hits": 0,
            "decode_compiles": 0, "decode_bucket_hits": 0,
            "chained_prefills": 0,
        }
        # Lazy-chain prefill state: per-(bp, sp) alignment verdicts, the
        # unstacked per-layer params in scan order, and the head matrix.
        self._chain_aligned_cache: dict[tuple[int, int], bool] = {}
        self._chain_layer_cache: list | None = None
        self._head_cache: jax.Array | None = None
        # Per-token decode accounting (the padding-free decode contract):
        # one launch per token, zero pad fallbacks, a stage copy only when
        # the cache grows into the next kv bucket.
        self.decode_stats = DispatchStats()

    # -- engine-owned bucketing ---------------------------------------------

    def seq_bucket(self, s: int) -> int:
        """The engine-selected padded size for a prompt length (capped by
        the cache length)."""
        return min(self._seq_op.bucket(s), self.max_cache)

    @staticmethod
    def batch_bucket(b: int) -> int:
        """Pow2 bucket for the request batch dim (see vortex.pow2_bucket:
        an auxiliary multiplier of the token dim, deliberately NOT lattice
        quantized — that would double-pad)."""
        return pow2_bucket(b)

    def seq_buckets(self, m_max: int | None = None) -> list[int]:
        """Every sequence bucket this server can emit — the engine's own
        reachable-bucket set, capped by the cache length."""
        m_max = self.max_cache if m_max is None else min(m_max, self.max_cache)
        return sorted({min(b, self.max_cache)
                       for b in self._seq_op.buckets(m_max)})

    # -- decode kv buckets --------------------------------------------------

    def kv_bucket(self, n: int) -> int:
        """The decode cache length covering ``n`` valid rows: the
        decode-attention workload's own kv bucket, capped by max_cache."""
        return min(self._decode_op.bucket(n), self.max_cache)

    def _grown_kv_bucket(self, kvb: int, needed: int) -> int:
        """The next cache length once ``needed`` rows outgrow ``kvb``:
        amortized doubling quantized to a kv bucket, so a long generation
        pays O(log) growth copies and the reachable bucket chain (what
        warmup must precompile) stays logarithmic — not one decode program
        per lattice breakpoint."""
        return self.kv_bucket(max(needed, 2 * kvb))

    def decode_buckets(
        self, *, m_max: int | None = None, max_new: int = 0
    ) -> list[int]:
        """Every cache length decode can run at for prompts up to
        ``m_max`` generating up to ``max_new`` tokens: the prefill-emitted
        buckets plus their doubling-growth chains."""
        m_max = self.max_cache if m_max is None else min(m_max, self.max_cache)
        out: set[int] = set()
        for sp in self.seq_buckets(m_max):
            kvb = self.kv_bucket(sp)
            out.add(kvb)
            limit = min(sp + max(max_new, 0), self.max_cache)
            while kvb < limit:
                kvb = self._grown_kv_bucket(kvb, kvb + 1)
                out.add(kvb)
        return sorted(out)

    # -- compiled-program cache ---------------------------------------------

    def _make_batch(self, bp: int, sp: int, tokens: np.ndarray | None = None):
        toks = np.zeros((bp, sp), np.int32)
        if tokens is not None:
            b, s = tokens.shape
            toks[:b, :s] = tokens
        batch = {"tokens": jnp.asarray(toks)}
        if self.cfg.vision_prefix:
            batch["vision_embeds"] = jnp.zeros(
                (bp, self.cfg.vision_prefix, self.cfg.d_model),
                jnp.dtype(self.cfg.dtype),
            )
        if self.cfg.encoder_decoder:
            batch["encoder_frames"] = jnp.zeros(
                (bp, self.cfg.encoder_seq, self.cfg.d_model),
                jnp.dtype(self.cfg.dtype),
            )
        return batch

    def _prefill_exec_for(self, bp: int, sp: int, batch) -> "jax.stages.Compiled":
        key = (bp, sp)
        exe = self._prefill_exec.get(key)
        if exe is None:
            # Lower under the engine session: prefill attention inside the
            # model dispatches through the engine
            # (models/layers.attn_forward consults installed_engine()), so
            # the traced program embeds lattice-selected attention blocks
            # and the engine's executable cache is warmed at trace time.
            # The emitted cache is ALREADY kv-bucket shaped: decode starts
            # on the aligned path with zero copies.
            cache_len = self.kv_bucket(sp)
            pj = self._prefill_jits.get(cache_len)
            if pj is None:
                pj = jax.jit(make_prefill_step(self.cfg, self.rules, cache_len))
                self._prefill_jits[cache_len] = pj
            with self.engine.use():
                exe = pj.lower(self.params, batch).compile()
            self._prefill_exec[key] = exe
            self.stats["prefill_compiles"] += 1
        else:
            self.stats["bucket_hits"] += 1
        return exe

    def _decode_exec_for(self, bp: int, kvb: int) -> "jax.stages.Compiled":
        """The ONE AOT decode program for a (batch-bucket, cache-length)
        pair.  Lowering runs under the engine session: the in-model decode
        attention dispatches through the kv_len-masked decode workload at
        the bucket-aligned cache length, so the compiled step embeds the
        lattice-selected kv block and runs pad-free."""
        key = (bp, kvb)
        exe = self._decode_exec.get(key)
        if exe is None:
            dj = self._decode_jits.get(kvb)
            if dj is None:
                dj = jax.jit(
                    make_decode_step(self.cfg, self.rules, cache_len=kvb)
                )
                self._decode_jits[kvb] = dj
            with self.engine.use():
                exe = dj.lower(
                    self.params,
                    abstract_cache(self.cfg, bp, kvb),
                    jax.ShapeDtypeStruct((bp, 1), jnp.int32),
                    jax.ShapeDtypeStruct((), jnp.int32),
                ).compile()
            self._decode_exec[key] = exe
            self.stats["decode_compiles"] += 1
        else:
            self.stats["decode_bucket_hits"] += 1
        return exe

    def _decode_exec_vec_for(self, bp: int, kvb: int) -> "jax.stages.Compiled":
        """The mixed-progress decode program for a (batch-bucket,
        cache-length) pair: identical to ``_decode_exec_for`` except
        ``pos`` lowers as a ``(bp,)`` per-row i32 vector, so ONE launch
        advances rows sitting at DIFFERENT kv positions — the scheduler's
        batched step.  Shares the jit family (and the compile counters)
        with the scalar program; the compiled artifacts are distinct."""
        key = (bp, kvb)
        exe = self._decode_exec_vec.get(key)
        if exe is None:
            dj = self._decode_jits.get(kvb)
            if dj is None:
                dj = jax.jit(
                    make_decode_step(self.cfg, self.rules, cache_len=kvb)
                )
                self._decode_jits[kvb] = dj
            with self.engine.use():
                exe = dj.lower(
                    self.params,
                    abstract_cache(self.cfg, bp, kvb),
                    jax.ShapeDtypeStruct((bp, 1), jnp.int32),
                    jax.ShapeDtypeStruct((bp,), jnp.int32),
                ).compile()
            self._decode_exec_vec[key] = exe
            self.stats["decode_compiles"] += 1
        else:
            self.stats["decode_bucket_hits"] += 1
        return exe

    # Which axis of each cache leaf is the cache-length dim (leaves carry a
    # leading stacked-groups axis); mamba state and encoder_out never grow.
    _CACHE_SEQ_AXIS = {"k": 3, "v": 3, "ckv": 2, "k_rope": 2}
    # Leaves every read of which goes through the kv_len-masked decode
    # workload: stale bytes past the extent are never consumed, so these
    # may lease RECYCLED pool buffers without zeroing.  MLA's ckv/k_rope
    # are absent — its absorbed decode masks scores but not 0*garbage in
    # the PV contraction, so those always lease fresh zeros.
    _POOLED_STALE_OK = ("k", "v")

    def _cache_kv_leaves(self, cache: dict):
        """(entry, name) for every growable (pool-managed) cache leaf."""
        for key, entry in cache.items():
            if key == "encoder_out":
                continue
            for name in entry:
                if name in self._CACHE_SEQ_AXIS:
                    yield entry, name

    def adopt_cache(self, cache: dict) -> None:
        """Register a prefill-emitted cache's growable leaves as active
        pool leases (they entered circulation outside ``lease``)."""
        self.kv_pool.adopt(sum(1 for _ in self._cache_kv_leaves(cache)))

    def release_cache(self, cache: dict) -> None:
        """Return every growable leaf to the pool — request retirement
        (and the ``generate`` exception path) funds future leases."""
        for entry, name in self._cache_kv_leaves(cache):
            self.kv_pool.release(
                entry[name], reuse=name in self._POOLED_STALE_OK
            )

    def _grow_cache(self, cache: dict, new_len: int) -> dict:
        """Copy the cache into ``new_len``-long bucket buffers: ONE
        O(true-size) ``dynamic_update_slice`` per growing leaf, only at
        bucket transitions — never per token.  Buffers are LEASED from the
        kv pool (attention k/v reuse parked buffers as-is — their stale
        tails sit past kv_len and are never read; MLA's ckv/k_rope lease
        fresh zeros, see ``_POOLED_STALE_OK``) and the outgrown leaves are
        released back, so chained growth recycles instead of churning.

        Growth is TWO-PHASE for failure isolation: every new leaf is
        leased and copied first, and the outgrown leaves are released only
        once the whole cache grew.  A mid-grow failure (lease fault, OOM)
        releases the partial new set and re-raises with ``cache``
        untouched — the caller's settling ``finally`` then releases every
        ORIGINAL lease exactly once, never double-releasing a leaf this
        method already returned.
        """
        st = self.decode_stats
        pool = self.kv_pool
        new_leases: list[tuple[jax.Array, bool]] = []
        old_leaves: list[tuple[jax.Array, bool]] = []
        out_cache: dict = {}
        try:
            for key, entry in cache.items():
                if key == "encoder_out":
                    out_cache[key] = entry
                    continue
                out = {}
                for name, leaf in entry.items():
                    ax = self._CACHE_SEQ_AXIS.get(name)
                    if ax is None or leaf.shape[ax] >= new_len:
                        out[name] = leaf
                        continue
                    shape = list(leaf.shape)
                    shape[ax] = new_len
                    stale_ok = name in self._POOLED_STALE_OK
                    buf = pool.lease(
                        tuple(shape), leaf.dtype, zero=not stale_ok
                    )
                    new_leases.append((buf, stale_ok))
                    out[name] = jax.lax.dynamic_update_slice(
                        buf, leaf, (0,) * leaf.ndim
                    )
                    old_leaves.append((leaf, stale_ok))
                out_cache[key] = out
        except BaseException:
            for buf, stale_ok in new_leases:
                pool.release(buf, reuse=stale_ok)
            raise
        for leaf, stale_ok in old_leaves:
            pool.release(leaf, reuse=stale_ok)
        st.stage_copies += len(old_leaves)
        return out_cache

    # -- lazy-handle chained prefill ----------------------------------------

    def _prefill_chained_supported(self) -> bool:
        """True when every layer of the architecture runs through the lazy
        handle chain (plain attn mixer, dense/none MLP, no cross-attention,
        no vision prefix / encoder stack)."""
        cfg = self.cfg
        if cfg.vision_prefix or cfg.encoder_decoder:
            return False
        return all(
            spec.mixer == "attn" and spec.mlp in ("dense", "none")
            and not spec.cross_attn
            for spec in cfg.pattern
        )

    def _chain_gemm_sigs(self) -> list[tuple[int, int]]:
        """Every (K, N) GEMM signature the chained prefill dispatches:
        q/k/v/o projections, the MLP pair, and the LM head."""
        cfg = self.cfg
        d, hd = cfg.d_model, cfg.resolved_head_dim
        sigs = {
            (d, cfg.n_heads * hd),        # wq
            (d, cfg.n_kv_heads * hd),     # wk / wv
            (cfg.n_heads * hd, d),        # wo
            (d, cfg.vocab_padded),        # lm head
        }
        if any(spec.mlp == "dense" for spec in cfg.pattern):
            sigs.add((d, cfg.d_ff))       # w_in / w_gate
            sigs.add((cfg.d_ff, d))       # w_out
        return sorted(sigs)

    def _chain_aligned(self, bp: int, sp: int) -> bool:
        """True when EVERY dispatch of a (bp, sp) chained prefill lands on
        its own bucket: each chain GEMM's selection at m = bp*sp pads to
        exactly bp*sp, the attention bucket at sp is (sp, hd, sp), and the
        kv cache bucket covering sp is sp itself — so handles forward
        bucket-to-bucket with zero boundary copies end to end."""
        key = (bp, sp)
        hit = self._chain_aligned_cache.get(key)
        if hit is None:
            eng, cfg = self.engine, self.cfg
            hd = cfg.resolved_head_dim
            m = bp * sp
            ok = all(
                eng.kernel_for(
                    GemmWorkload(M=None, N=n, K=k)
                ).select(m).padded_m == m
                for k, n in self._chain_gemm_sigs()
            )
            if ok:
                for window in {
                    spec.window for spec in cfg.pattern
                    if spec.mixer == "attn"
                }:
                    kern = eng.kernel_for(AttentionWorkload(
                        seq=None, head_dim=hd, causal=True,
                        window=window, softcap=cfg.attn_softcap,
                    ))
                    if kern.select(sp).bucket != (sp, hd, sp):
                        ok = False
                        break
            hit = ok and self.kv_bucket(sp) == sp
            self._chain_aligned_cache[key] = hit
        return hit

    def chain_seq_bucket(self, s: int, bp: int = 1) -> int:
        """The sequence bucket a chained prefill serves ``s`` at: the first
        engine bucket >= seq_bucket(s) where the whole chain is aligned
        (``_chain_aligned``), falling back to seq_bucket(s) when none is —
        a misaligned chain stays correct, it just pays counted boundary
        copies."""
        base = self.seq_bucket(s)
        for sp in self.seq_buckets():
            if sp >= base and self._chain_aligned(bp, sp):
                return sp
        return base

    def _chain_layers(self) -> list:
        """(spec, params) per layer in scan execution order (group-major),
        unstacked once from the pos-stacked parameter tree."""
        if self._chain_layer_cache is None:
            cfg = self.cfg
            n_pos = len(cfg.pattern)
            layers = []
            for g in range(cfg.n_groups):
                for i in range(n_pos):
                    p = jax.tree_util.tree_map(
                        lambda t: t[g], self.params[f"pos{i}"]
                    )
                    layers.append((cfg.pattern[i], p))
            self._chain_layer_cache = layers
        return self._chain_layer_cache

    def _head(self) -> jax.Array:
        if self._head_cache is None:
            self._head_cache = (
                self.params["embed"].T if self.cfg.tie_embeddings
                else self.params["lm_head"]
            )
        return self._head_cache

    @staticmethod
    def _chain_cache_leaf(t, kvb: int):
        """One kv-cache leaf from a chain k/v projection: a fully-valid
        handle's bucket buffer is consumed DIRECTLY when it already has the
        cache length (zero copy); otherwise one dynamic_update_slice into
        zeros — bitwise what the AOT prefill's jnp.pad emits."""
        from repro.core.engine import LazyBucket

        if isinstance(t, LazyBucket):
            t = t.realize()  # identity for the chain's fully-valid handles
        if t.shape[2] == kvb:
            return t
        buf = jnp.zeros(t.shape[:2] + (kvb,) + t.shape[3:], t.dtype)
        return jax.lax.dynamic_update_slice(buf, t, (0,) * t.ndim)

    def prefill_chained(self, bp: int, sp: int, batch, *, eager: bool = False):
        """Whole-model prefill as a lazy handle chain: embed (plain ops) →
        per-layer ``block_forward_lazy`` → final norm / head / softcap /
        vocab mask via ``lazy_map`` — every engine boundary passes a
        LazyBucket, so at a chain-aligned ``sp`` nothing unstages between
        dispatches.  Returns ``(last_logits, cache)`` exactly like the AOT
        prefill step: last_logits at the padded position sp-1 (the chain's
        handles are fully valid to the bucket width, reproducing the AOT
        padded-position semantics), cache leaves kv-bucket shaped.

        ``eager=True`` runs the IDENTICAL dispatch sequence on plain arrays
        (per-op stage/unstage) — the bit-identity reference the tests and
        the bench gate compare against."""
        from repro.core.engine import LazyBucket, lazy_map
        from repro.models.layers import (
            block_forward_lazy,
            lazy_matmul,
            norm,
        )

        cfg = self.cfg
        eng = self.engine
        lazy = not eager

        # Pre-block embedding pipeline, bitwise the model's forward().
        x = jnp.take(self.params["embed"], batch["tokens"], axis=0)
        if cfg.embed_scale:
            x = (
                x.astype(jnp.float32) * math.sqrt(cfg.d_model)
            ).astype(x.dtype)
        if not cfg.use_rope:
            p_idx = jnp.arange(sp).astype(jnp.float32)
            half = cfg.d_model // 2
            freq = 10000.0 ** (-jnp.arange(half, dtype=jnp.float32) / half)
            ang = p_idx[:, None] * freq
            pe = jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)
            x = x + pe[None].astype(x.dtype)
        positions = jnp.arange(sp)

        if lazy:
            x = LazyBucket(x, sp, 1)
        kvs = []
        for spec, p in self._chain_layers():
            x, kv = block_forward_lazy(
                eng, p, x, cfg, spec, positions=positions, lazy=lazy,
            )
            kvs.append(kv)

        x = lazy_map(lambda t: norm(t, self.params["final_norm"], cfg), x)
        logits = lazy_matmul(eng, x, self._head(), lazy=lazy)
        if cfg.logit_softcap is not None:
            c = cfg.logit_softcap
            logits = lazy_map(
                lambda t: (
                    jnp.tanh(t.astype(jnp.float32) / c) * c
                ).astype(t.dtype),
                logits,
            )
        if cfg.vocab_padded != cfg.vocab:
            logits = lazy_map(
                lambda t: jnp.where(
                    jax.lax.broadcasted_iota(
                        jnp.int32, t.shape, t.ndim - 1
                    ) < cfg.vocab,
                    t, -1e30,
                ),
                logits,
            )
        # The AOT step returns logits[:, -1] at the PADDED position; the
        # chain's handle is fully valid to the bucket width, so its buffer
        # row sp-1 is the same position — read it without forcing a slice.
        if isinstance(logits, LazyBucket):
            last = logits.buffer[:, -1]
        else:
            last = logits[:, -1]

        kvb = self.kv_bucket(sp)
        n_pos = len(cfg.pattern)
        cache: dict[str, Any] = {}
        for i in range(n_pos):
            ks, vs = [], []
            for g in range(cfg.n_groups):
                kv = kvs[g * n_pos + i]
                ks.append(self._chain_cache_leaf(kv["k"], kvb))
                vs.append(self._chain_cache_leaf(kv["v"], kvb))
            cache[f"pos{i}"] = {"k": jnp.stack(ks), "v": jnp.stack(vs)}
        return last, cache

    def warmup(
        self, *, max_batch: int = 1, m_max: int | None = None,
        max_new: int = 8,
    ) -> int:
        """Precompile before traffic: AOT compile the prefill program for
        every (batch-bucket, seq-bucket) pair up to ``max_batch``/``m_max``
        AND the decode program for every cache length those prompts can
        reach within ``max_new`` generated tokens (the doubling-growth
        bucket chains — see ``decode_buckets``).  The bucket sets are the
        engine's own (CompiledOp.buckets), and each AOT compile warms the
        engine's attention executables through the session — ``generate``
        pads every prompt to one of these buckets first, so this covers
        exactly the executables serving will hit.  Returns the number of
        programs compiled (prefill + decode).

        Direct-op serving (no model in between) warms with
        ``CompiledOp.precompile`` instead — see DESIGN.md §6."""
        m_max = self.max_cache if m_max is None else min(m_max, self.max_cache)
        compiled = 0
        bp = 1
        while True:
            for sp in self.seq_buckets(m_max):
                if (bp, sp) not in self._prefill_exec:
                    self._prefill_exec_for(bp, sp, self._make_batch(bp, sp))
                    compiled += 1
            for kvb in self.decode_buckets(m_max=m_max, max_new=max_new):
                if (bp, kvb) not in self._decode_exec:
                    self._decode_exec_for(bp, kvb)
                    compiled += 1
            if bp >= pow2_bucket(max_batch):
                break
            bp *= 2
        return compiled

    def engine_dispatch_stats(self) -> dict[str, dict]:
        """Per-kind hot-path accounting from the engine session — launches,
        staging/unstaging copies, aligned vs unaligned calls, and how many
        calls ran padded (trace-time lowering) — PLUS the server's own
        per-token decode accounting under ``decode_step`` (the decode
        programs run outside the engine's eager dispatch, so their
        launches are counted here: one per token, a stage copy per cache
        growth, padded always 0).  The padding-free serving contract in
        one dict — what ops dashboards should scrape."""
        keep = (
            "calls", "launches", "aligned_calls", "unaligned_calls",
            "stage_copies", "unstage_copies", "padded_calls",
            "traced_calls", "forwarded", "realize_slices",
            "fallbacks", "quarantined",
        )
        estats = self.engine.stats()
        out = {
            kind: {k: s[k] for k in keep}
            for kind, s in estats.items()
            if kind != "calibration"  # engine-level section, not a kind
        }
        d = self.decode_stats.as_dict()
        out["decode_step"] = {k: d[k] for k in keep}
        # The kv-bucket pool's lease ledger (its OWN key set: lease
        # accounting, not dispatch counters) — ``leases_active`` must read
        # 0 at idle or a retirement path leaked buffers.
        out["kv_pool"] = self.kv_pool.stats()
        # Background-calibration counters (core/calibrate.py), engine-level
        # like kv_pool: applied/loaded tables, swaps, measurement time.
        out["calibration"] = estats["calibration"]
        return out

    # -- serving ------------------------------------------------------------

    def generate(self, req: Request) -> np.ndarray:
        b, s = req.tokens.shape
        if s + req.max_new - 1 > self.max_cache:
            # Refuse loudly BEFORE any prefill work: past the cap the
            # cache cannot grow, the in-program dynamic_update_slice would
            # clamp its start and silently stomp the last KV row —
            # corrupted logits with no signal.  Same typed error as the
            # scheduler's admission-time rejection (launch/scheduler.py).
            raise CacheOverflowError(
                f"prompt_len {s} + max_new {req.max_new} needs "
                f"{s + req.max_new - 1} cache rows > max_cache "
                f"{self.max_cache}; raise max_cache or shorten the request"
            )
        bp = self.batch_bucket(b)
        if self.prefill == "chained" and self._prefill_chained_supported():
            sp = self.chain_seq_bucket(s, bp)
            batch = self._make_batch(bp, sp, req.tokens)
            logits, cache = self.prefill_chained(bp, sp, batch)
            self.stats["chained_prefills"] += 1
        else:
            sp = self.seq_bucket(s)
            batch = self._make_batch(bp, sp, req.tokens)
            logits, cache = self._prefill_exec_for(bp, sp, batch)(
                self.params, batch
            )
        out = [np.asarray(jnp.argmax(logits, -1))]
        tok = jnp.asarray(out[-1][:, None])
        pos = s - 1
        kvb = self.kv_bucket(sp)  # the prefill-emitted cache length
        st = self.decode_stats
        # The prefill-emitted leaves are pool leases from here on: the
        # finally arm settles them on retirement AND on any exception
        # mid-decode, so the pool's lease ledger can never leak.
        self.adopt_cache(cache)
        try:
            for i in range(req.max_new - 1):
                pos += 1
                needed = pos + 1  # rows the cache must hold after this step
                st.calls += 1
                if needed > kvb and kvb < self.max_cache:
                    kvb = self._grown_kv_bucket(kvb, needed)
                    cache = self._grow_cache(cache, kvb)
                    st.unaligned_calls += 1
                else:
                    st.aligned_calls += 1
                logits, cache = self._decode_exec_for(bp, kvb)(
                    self.params, cache, tok, jnp.asarray(pos, jnp.int32)
                )
                st.launches += 1
                nxt = jnp.argmax(logits, -1)
                out.append(np.asarray(nxt))
                tok = nxt[:, None]
        finally:
            self.release_cache(cache)
        return np.stack(out, 1)[:b]  # (b, max_new)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="paper-gpt2-124m")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--max-new", type=int, default=4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument(
        "--warmup", action="store_true",
        help="AOT-precompile every bucket before serving",
    )
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    mesh = make_host_mesh()
    server = VortexServer(cfg, mesh, max_cache=256)
    if args.warmup:
        n = server.warmup(max_batch=8, m_max=64, max_new=args.max_new)
        print(f"warmup: {n} prefill+decode buckets AOT-compiled")
    rng = np.random.default_rng(args.seed)

    t0 = time.perf_counter()
    for i in range(args.requests):
        b = int(rng.integers(1, 9))
        s = int(rng.integers(4, 65))
        req = Request(
            tokens=rng.integers(0, cfg.vocab, (b, s)).astype(np.int32),
            max_new=args.max_new,
        )
        out = server.generate(req)
        print(f"req {i:3d}: batch={b:3d} prompt={s:3d} -> {out.shape}")
    dt = time.perf_counter() - t0
    print(
        f"{args.requests} dynamic requests in {dt:.1f}s; "
        f"compiles={server.stats['prefill_compiles']} "
        f"bucket_hits={server.stats['bucket_hits']} "
        f"decode_compiles={server.stats['decode_compiles']} "
        f"decode_bucket_hits={server.stats['decode_bucket_hits']}"
    )
    ds = server.decode_stats
    print(
        f"decode: tokens={ds.calls} launches={ds.launches} "
        f"growth_copies={ds.stage_copies} padded={ds.padded_calls}"
    )
    for kind, d in server.engine_dispatch_stats().items():
        if kind == "kv_pool":  # lease ledger, not dispatch counters
            print(
                f"kv_pool: leases_active={d['leases_active']} "
                f"leases_peak={d['leases_peak']} hits={d['lease_hits']} "
                f"allocs={d['lease_allocs']} released={d['released']}"
            )
            continue
        if kind == "calibration":  # engine-level counters, not a kind
            if d.get("enabled"):
                print(
                    f"calibration: mode={d['mode']} applied={d['applied']} "
                    f"loaded={d['loaded_from_disk']} swaps={d['table_swaps']} "
                    f"seconds={d['seconds']:.3f}"
                )
            continue
        print(
            f"engine/{kind}: launches={d['launches']} "
            f"stage_copies={d['stage_copies']} "
            f"unstage_copies={d['unstage_copies']} "
            f"padded={d['padded_calls']} traced={d['traced_calls']}"
        )


if __name__ == "__main__":
    main()
