"""Dynamic-shape serving driver — where Vortex earns its keep at runtime.

Requests arrive with arbitrary batch sizes and prompt lengths.  XLA needs
static shapes, so every distinct (batch, prompt_len) would recompile.  The
Vortex runtime selector (core/selector.py) instead pads each request up to
the nearest *lattice bucket* — the sample-free bucket set derived offline
from hardware limits — so the executable cache stays small and padding
waste is bounded by the lattice spacing (paper Fig. 8 argument applied at
the serving layer).

``python -m repro.launch.serve --arch paper-gpt2-124m --smoke --requests 16``
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import GemmWorkload, VortexGemm, get_hardware
from repro.launch.mesh import make_host_mesh
from repro.models import model as M
from repro.models.params import init_params
from repro.models.partitioning import make_rules
from repro.models.registry import get_config, get_smoke_config
from repro.train.step import make_decode_step, make_prefill_step

__all__ = ["VortexServer", "Request"]


@dataclasses.dataclass
class Request:
    tokens: np.ndarray  # (batch, prompt_len)
    max_new: int = 8


class VortexServer:
    """Batched LM serving with Vortex-bucketed dynamic shapes.

    The dynamic dims are the request batch size and the prompt length; both
    are padded to Vortex lattice buckets before hitting the compiled
    prefill/decode executables.
    """

    def __init__(self, cfg, mesh, *, max_cache: int = 512, seed: int = 0):
        self.cfg = cfg
        self.rules = make_rules(
            mesh, n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads
        )
        self.params = init_params(cfg, jax.random.PRNGKey(seed))
        self.max_cache = max_cache
        # Vortex engine over the token dim: N/K from the model's GEMM
        # signature; the selector's M-buckets become our batch/seq buckets.
        # The lattice is built for the TARGET hardware (TPU v5e): its native
        # sublane granularity (16) is what quantizes the bucket set — on the
        # CPU host the same buckets are used so executables dedupe the same
        # way they would on the pod.
        hw = get_hardware("tpu_v5e")
        wl = GemmWorkload(M=None, N=cfg.d_model, K=cfg.d_model)
        self._vortex = VortexGemm(hw, wl, backends=("mxu",))
        self._prefill = {}
        self._decode = jax.jit(
            make_decode_step(cfg, self.rules, cache_len=max_cache)
        )
        self.stats = {"prefill_compiles": 0, "bucket_hits": 0}

    def _bucket(self, n: int) -> int:
        """Vortex-selected padded size for the sequence extent."""
        return self._vortex.select(max(n, 1)).padded_m

    @staticmethod
    def _batch_bucket(b: int) -> int:
        """Batch buckets are powers of two: the batch dim multiplies every
        GEMM's M jointly with seq, so quantizing it to the MXU sublane
        granularity would double-pad; pow2 keeps the executable cache small
        with <=2x waste on the batch factor alone."""
        p = 1
        while p < b:
            p *= 2
        return p

    def _prefill_fn(self, b: int, s: int):
        key = (b, s)
        if key not in self._prefill:
            self._prefill[key] = jax.jit(
                make_prefill_step(self.cfg, self.rules, self.max_cache)
            )
            self.stats["prefill_compiles"] += 1
        else:
            self.stats["bucket_hits"] += 1
        return self._prefill[key]

    def generate(self, req: Request) -> np.ndarray:
        b, s = req.tokens.shape
        bp = self._batch_bucket(b)
        sp = min(self._bucket(s), self.max_cache)
        toks = np.zeros((bp, sp), np.int32)
        toks[:b, :s] = req.tokens
        batch = {"tokens": jnp.asarray(toks)}
        if self.cfg.vision_prefix:
            batch["vision_embeds"] = jnp.zeros(
                (bp, self.cfg.vision_prefix, self.cfg.d_model),
                jnp.dtype(self.cfg.dtype),
            )
        if self.cfg.encoder_decoder:
            batch["encoder_frames"] = jnp.zeros(
                (bp, self.cfg.encoder_seq, self.cfg.d_model),
                jnp.dtype(self.cfg.dtype),
            )
        logits, cache = self._prefill_fn(bp, sp)(self.params, batch)
        out = [np.asarray(jnp.argmax(logits, -1))]
        tok = jnp.asarray(out[-1][:, None])
        pos = s - 1
        for i in range(req.max_new - 1):
            pos += 1
            logits, cache = self._decode(
                self.params, cache, tok, jnp.asarray(pos, jnp.int32)
            )
            nxt = jnp.argmax(logits, -1)
            out.append(np.asarray(nxt))
            tok = nxt[:, None]
        return np.stack(out, 1)[:b]  # (b, max_new)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="paper-gpt2-124m")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--max-new", type=int, default=4)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    mesh = make_host_mesh()
    server = VortexServer(cfg, mesh, max_cache=256)
    rng = np.random.default_rng(args.seed)

    t0 = time.perf_counter()
    for i in range(args.requests):
        b = int(rng.integers(1, 9))
        s = int(rng.integers(4, 65))
        req = Request(
            tokens=rng.integers(0, cfg.vocab, (b, s)).astype(np.int32),
            max_new=args.max_new,
        )
        out = server.generate(req)
        print(f"req {i:3d}: batch={b:3d} prompt={s:3d} -> {out.shape}")
    dt = time.perf_counter() - t0
    print(
        f"{args.requests} dynamic requests in {dt:.1f}s; "
        f"compiles={server.stats['prefill_compiles']} "
        f"bucket_hits={server.stats['bucket_hits']}"
    )


if __name__ == "__main__":
    main()
