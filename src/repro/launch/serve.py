"""Dynamic-shape serving driver — where Vortex earns its keep at runtime.

Requests arrive with arbitrary batch sizes and prompt lengths.  XLA needs
static shapes, so every distinct (batch, prompt_len) would recompile.  The
server quantizes both dims through the vortex engine session it owns:

  * the sequence dim is bucketed by the engine's own selection machinery —
    ``CompiledOp.bucket`` over the model's GEMM signature, i.e. the SAME
    lattice breakpoints the runtime selector bisects (there is no second,
    hand-rolled bucketing scheme in the tree);
  * the request batch dim (an auxiliary outer multiplier) is pow2-bucketed
    (``vortex.pow2_bucket``).

Prefill executables are AOT-compiled per bucket through ONE jit function
(``jit(...).lower(...).compile()``), so ``stats["prefill_compiles"]``
counts real XLA compilations — not per-shape Python wrappers around a jit
that retraces anyway.  Lowering runs under ``engine.use()``: causal
prefill attention inside the model dispatches through the engine session,
so the compiled programs embed lattice-selected attention blocks.  (The
engine serves those trace-time calls through its zero-pad reference path
— the pads fuse into the prefill program — and counts them as
``traced_calls``; eager dispatch outside a trace takes the masked-tail
staging hot path, whose launch/copy counters
``engine_dispatch_stats`` surfaces.)  ``warmup()`` AOT-compiles the
per-bucket prefill programs (warming the engine's attention executables
through the session) before traffic arrives.

``python -m repro.launch.serve --arch paper-gpt2-124m --smoke --requests 16``
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import GemmWorkload
from repro.launch.mesh import make_host_mesh
from repro.models.params import init_params
from repro.models.partitioning import make_rules
from repro.models.registry import get_config, get_smoke_config
from repro.train.step import make_decode_step, make_prefill_step
from repro.vortex import CompiledOp, Engine, EngineConfig, pow2_bucket

__all__ = ["VortexServer", "Request"]


@dataclasses.dataclass
class Request:
    tokens: np.ndarray  # (batch, prompt_len)
    max_new: int = 8


class VortexServer:
    """Batched LM serving with Vortex-bucketed dynamic shapes.

    The dynamic dims are the request batch size and the prompt length; both
    are padded to buckets before hitting the compiled prefill/decode
    executables.  The server owns (or is handed) an :class:`Engine`
    session; its sequence buckets are the engine's selection buckets.
    """

    def __init__(
        self,
        cfg,
        mesh,
        *,
        max_cache: int = 512,
        seed: int = 0,
        engine: Engine | None = None,
    ):
        self.cfg = cfg
        self.rules = make_rules(
            mesh, n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads
        )
        self.params = init_params(cfg, jax.random.PRNGKey(seed))
        self.max_cache = max_cache
        if engine is None:
            # The lattice is built for the TARGET hardware (TPU v5e): its
            # native sublane granularity (16) is what quantizes the bucket
            # set — on the CPU host the same buckets are used so
            # executables dedupe the same way they would on the pod.
            engine = Engine(EngineConfig(hardware="tpu_v5e", backends=("mxu",)))
        self.engine = engine
        # The token dim's bucket source: the model's GEMM signature
        # (N/K = d_model); the selector's M-buckets become our seq buckets.
        # Built via kernel_for, not engine.compile: this handle only ever
        # does bucket arithmetic (select/bucket/buckets), so the engine's
        # eager-precompile policy (precompile_m_max) must not fire for it —
        # the executables would never be dispatched.
        self._seq_op = CompiledOp(engine, engine.kernel_for(
            GemmWorkload(M=None, N=cfg.d_model, K=cfg.d_model)
        ))
        # ONE jit for prefill; buckets are AOT lowered+compiled through it,
        # so each bucket pays exactly one real compilation and the stats
        # count compilations, not wrapper constructions.
        self._prefill_jit = jax.jit(
            make_prefill_step(cfg, self.rules, max_cache)
        )
        self._prefill_exec: dict[tuple[int, int], jax.stages.Compiled] = {}
        self._decode = jax.jit(
            make_decode_step(cfg, self.rules, cache_len=max_cache)
        )
        self.stats = {"prefill_compiles": 0, "bucket_hits": 0}

    # -- engine-owned bucketing ---------------------------------------------

    def seq_bucket(self, s: int) -> int:
        """The engine-selected padded size for a prompt length (capped by
        the cache length)."""
        return min(self._seq_op.bucket(s), self.max_cache)

    @staticmethod
    def batch_bucket(b: int) -> int:
        """Pow2 bucket for the request batch dim (see vortex.pow2_bucket:
        an auxiliary multiplier of the token dim, deliberately NOT lattice
        quantized — that would double-pad)."""
        return pow2_bucket(b)

    def seq_buckets(self, m_max: int | None = None) -> list[int]:
        """Every sequence bucket this server can emit — the engine's own
        reachable-bucket set, capped by the cache length."""
        m_max = self.max_cache if m_max is None else min(m_max, self.max_cache)
        return sorted({min(b, self.max_cache)
                       for b in self._seq_op.buckets(m_max)})

    # -- compiled-program cache ---------------------------------------------

    def _make_batch(self, bp: int, sp: int, tokens: np.ndarray | None = None):
        toks = np.zeros((bp, sp), np.int32)
        if tokens is not None:
            b, s = tokens.shape
            toks[:b, :s] = tokens
        batch = {"tokens": jnp.asarray(toks)}
        if self.cfg.vision_prefix:
            batch["vision_embeds"] = jnp.zeros(
                (bp, self.cfg.vision_prefix, self.cfg.d_model),
                jnp.dtype(self.cfg.dtype),
            )
        if self.cfg.encoder_decoder:
            batch["encoder_frames"] = jnp.zeros(
                (bp, self.cfg.encoder_seq, self.cfg.d_model),
                jnp.dtype(self.cfg.dtype),
            )
        return batch

    def _prefill_exec_for(self, bp: int, sp: int, batch) -> "jax.stages.Compiled":
        key = (bp, sp)
        exe = self._prefill_exec.get(key)
        if exe is None:
            # Lower under the engine session: causal prefill attention
            # inside the model dispatches through the engine
            # (models/layers.attn_forward consults installed_engine()), so
            # the traced program embeds lattice-selected attention blocks
            # and the engine's executable cache is warmed at trace time.
            with self.engine.use():
                exe = self._prefill_jit.lower(self.params, batch).compile()
            self._prefill_exec[key] = exe
            self.stats["prefill_compiles"] += 1
        else:
            self.stats["bucket_hits"] += 1
        return exe

    def warmup(self, *, max_batch: int = 1, m_max: int | None = None) -> int:
        """Precompile before traffic: AOT compile the prefill program for
        every (batch-bucket, seq-bucket) pair up to ``max_batch``/``m_max``.
        The bucket set is the engine's own (CompiledOp.buckets), and each
        AOT compile warms the engine's attention executables through the
        session (see _prefill_exec_for) — ``generate`` pads every prompt to
        one of these buckets first, so this covers exactly the executables
        serving will hit.  Returns the number of prefill programs compiled.

        Direct-op serving (no model in between) warms with
        ``CompiledOp.precompile`` instead — see DESIGN.md §6."""
        m_max = self.max_cache if m_max is None else min(m_max, self.max_cache)
        compiled = 0
        bp = 1
        while True:
            for sp in self.seq_buckets(m_max):
                if (bp, sp) not in self._prefill_exec:
                    self._prefill_exec_for(bp, sp, self._make_batch(bp, sp))
                    compiled += 1
            if bp >= pow2_bucket(max_batch):
                break
            bp *= 2
        return compiled

    def engine_dispatch_stats(self) -> dict[str, dict]:
        """Per-kind hot-path accounting from the engine session: launches,
        staging/unstaging copies, aligned vs unaligned calls, and how many
        calls ran padded (trace-time lowering).  The padding-free serving
        contract in one dict — what ops dashboards should scrape."""
        keep = (
            "calls", "launches", "aligned_calls", "unaligned_calls",
            "stage_copies", "unstage_copies", "padded_calls",
            "traced_calls",
        )
        return {
            kind: {k: s[k] for k in keep}
            for kind, s in self.engine.stats().items()
        }

    # -- serving ------------------------------------------------------------

    def generate(self, req: Request) -> np.ndarray:
        b, s = req.tokens.shape
        bp = self.batch_bucket(b)
        sp = self.seq_bucket(s)
        batch = self._make_batch(bp, sp, req.tokens)
        logits, cache = self._prefill_exec_for(bp, sp, batch)(
            self.params, batch
        )
        out = [np.asarray(jnp.argmax(logits, -1))]
        tok = jnp.asarray(out[-1][:, None])
        pos = s - 1
        for i in range(req.max_new - 1):
            pos += 1
            logits, cache = self._decode(
                self.params, cache, tok, jnp.asarray(pos, jnp.int32)
            )
            nxt = jnp.argmax(logits, -1)
            out.append(np.asarray(nxt))
            tok = nxt[:, None]
        return np.stack(out, 1)[:b]  # (b, max_new)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="paper-gpt2-124m")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--max-new", type=int, default=4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument(
        "--warmup", action="store_true",
        help="AOT-precompile every bucket before serving",
    )
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    mesh = make_host_mesh()
    server = VortexServer(cfg, mesh, max_cache=256)
    if args.warmup:
        n = server.warmup(max_batch=8, m_max=64)
        print(f"warmup: {n} prefill buckets AOT-compiled")
    rng = np.random.default_rng(args.seed)

    t0 = time.perf_counter()
    for i in range(args.requests):
        b = int(rng.integers(1, 9))
        s = int(rng.integers(4, 65))
        req = Request(
            tokens=rng.integers(0, cfg.vocab, (b, s)).astype(np.int32),
            max_new=args.max_new,
        )
        out = server.generate(req)
        print(f"req {i:3d}: batch={b:3d} prompt={s:3d} -> {out.shape}")
    dt = time.perf_counter() - t0
    print(
        f"{args.requests} dynamic requests in {dt:.1f}s; "
        f"compiles={server.stats['prefill_compiles']} "
        f"bucket_hits={server.stats['bucket_hits']}"
    )
    for kind, d in server.engine_dispatch_stats().items():
        print(
            f"engine/{kind}: launches={d['launches']} "
            f"stage_copies={d['stage_copies']} "
            f"unstage_copies={d['unstage_copies']} "
            f"padded={d['padded_calls']} traced={d['traced_calls']}"
        )


if __name__ == "__main__":
    main()
