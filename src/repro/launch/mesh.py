"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (not a module constant) so importing
this module never touches jax device state — required because the dry-run
must set XLA_FLAGS before any jax initialization.
"""
from __future__ import annotations

import jax
import numpy as np
from jax.sharding import Mesh

__all__ = ["make_production_mesh", "make_host_mesh"]


def make_production_mesh(*, multi_pod: bool = False):
    """The assignment's production mesh: 16x16 single-pod (256 chips) or
    2x16x16 multi-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1) -> Mesh:
    """A tiny mesh over the locally attached devices (tests / examples)."""
    n = data * model
    devs = np.asarray(jax.devices()[:n]).reshape(data, model)
    return Mesh(devs, ("data", "model"))
