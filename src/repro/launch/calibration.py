"""Serving-side drivers for the background calibrator (core/calibrate.py).

Three entry points, in increasing autonomy:

  * :func:`warm_from_disk` — one-shot: load persisted calibrated tables
    (by hardware fingerprint) into an engine at startup; zero
    measurements, zero effect when nothing matching is on disk;
  * :class:`CalibrationDaemon` — a thread that donates budgeted slices
    whenever the engine has pending calibration work, for serving stacks
    WITHOUT a scheduler loop of their own (the continuous scheduler
    donates idle ``step()`` slices instead — see
    ``ContinuousScheduler._donate_idle_slice`` — and needs no daemon);
  * :func:`main` — the nightly-CI CLI: build an engine over the standard
    bench workloads, run a full (non-budgeted) calibration pass, and
    write the measured-vs-analytical report as JSON.  Exits nonzero if
    any calibrated table picks worse than the analytical selection on a
    measured bucket — the same invariant the bench-smoke gate enforces.
"""
from __future__ import annotations

import argparse
import json
import sys
import threading

__all__ = ["warm_from_disk", "CalibrationDaemon", "run_calibration", "main"]


def warm_from_disk(engine) -> int:
    """Load persisted calibrated tables into ``engine``'s kernels; returns
    how many kernels were calibrated from disk (0 when calibration is off,
    nothing is persisted, or the fingerprint/lattice doesn't match)."""
    cal = engine.calibrator
    return cal.load() if cal is not None else 0


class CalibrationDaemon:
    """Background thread feeding budgeted slices to ``engine.calibrator``.

    ``interval_s`` is the sleep between slices — the coarse "is the
    process idle enough" knob for hosts without a scheduler loop.  The
    thread exits by itself once nothing is pending (new kernels re-arm it
    via :meth:`poke`).  ``stop()`` is prompt: at most one in-flight slice
    (bounded by the engine's ``calibration_budget_s``) completes after it.
    """

    def __init__(self, engine, interval_s: float = 1.0):
        self.engine = engine
        self.interval_s = interval_s
        self._stop = threading.Event()
        self._wake = threading.Event()
        self._thread: threading.Thread | None = None

    def start(self) -> "CalibrationDaemon":
        if self.engine.calibrator is None:
            return self  # calibration off: never spawn the thread
        if self._thread is None or not self._thread.is_alive():
            self._stop.clear()
            self._thread = threading.Thread(
                target=self._loop, name="vortex-calibration", daemon=True
            )
            self._thread.start()
        return self

    def poke(self) -> None:
        """Wake the daemon early (e.g. after compiling a new kernel)."""
        self._wake.set()

    def stop(self) -> None:
        self._stop.set()
        self._wake.set()
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _loop(self) -> None:
        cal = self.engine.calibrator
        cal.load()  # restart path: persisted tables beat re-measuring
        while not self._stop.is_set():
            try:
                if cal.pending():
                    cal.run_slice()
                elif not self._wake.wait(timeout=self.interval_s * 10):
                    continue
            except Exception:
                return  # never let calibration kill a serving process
            self._wake.clear()
            self._stop.wait(timeout=self.interval_s)


def run_calibration(engine, *, load: bool = True) -> dict:
    """One full (non-budgeted) calibration pass over ``engine``'s current
    kernels: optionally load persisted tables first, measure the rest to
    completion, and return the measured-vs-analytical report plus the
    calibrator counters."""
    cal = engine.calibrator
    if cal is None:
        raise ValueError(
            'engine has calibration="off"; construct it with '
            'calibration="on-idle" or "eager-warmup"'
        )
    if load:
        cal.load()
    cal.run()
    return {"report": cal.report(), "stats": cal.stats()}


def main(argv: list[str] | None = None) -> int:
    """Nightly-CI calibration pass (see .github/workflows/ci.yml)."""
    import jax.numpy as jnp
    import numpy as np

    from repro.vortex import Engine

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="write the calibration report as JSON")
    ap.add_argument("--cache-dir", default=None,
                    help="persistence dir (default: $VORTEX_CACHE_DIR "
                         "or ~/.cache/vortex)")
    ap.add_argument("--top-k", type=int, default=3)
    ap.add_argument("--budget-s", type=float, default=0.25)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced bucket set / round counts")
    args = ap.parse_args(argv)

    eng = Engine(
        "host_cpu", empirical_levels=(),
        calibration="on-idle",
        calibration_top_k=args.top_k,
        calibration_budget_s=args.budget_s,
        calibration_cache_dir=args.cache_dir,
    )
    rng = np.random.default_rng(23)
    # The standard bench workload mix: gemm and conv2d calibrate (default
    # exec_key); attention is enrolled to prove the calibrator skips
    # exec-specialized kernels instead of mis-measuring them.
    eng.dispatch(
        "gemm",
        jnp.asarray(rng.normal(size=(33, 256)), jnp.float32),
        jnp.asarray(rng.normal(size=(256, 128)), jnp.float32),
    )
    eng.dispatch(
        "conv2d",
        jnp.asarray(rng.normal(size=(2, 14, 14, 8)), jnp.float32),
        jnp.asarray(rng.normal(size=(3, 3, 8, 16)), jnp.float32),
    )
    q = jnp.asarray(rng.normal(size=(1, 4, 67, 64)), jnp.float32)
    kv = jnp.asarray(rng.normal(size=(1, 2, 67, 64)), jnp.float32)
    eng.dispatch("attention", q, kv, kv)

    if args.smoke:
        import dataclasses

        cal = eng.calibrator
        cal.policy = dataclasses.replace(
            cal.policy, m_max=192, max_buckets=3, min_rounds=3,
            max_rounds=8, patience=2,
        )
    out = run_calibration(eng)
    if args.json:
        with open(args.json, "w") as f:
            json.dump(out, f, indent=2, sort_keys=True)
    ok = True
    for kind, rep in out["report"].items():
        line = (
            f"{kind}: mode={rep['mode']} "
            f"agreement={rep['agreement_rate']:.2f} "
            f"pinned={rep['pinned_buckets']}/{rep['measured_buckets']} "
            f"never_worse={rep['never_worse_on_measured']}"
        )
        print(line)
        ok = ok and rep["never_worse_on_measured"]
    s = out["stats"]
    print(
        f"calibrated {s['applied']}/{s['kernels']} kernels "
        f"({s['skipped']} skipped) in {s['seconds']:.2f}s; "
        f"saved={s['saves']} loaded={s['loaded_from_disk']}"
    )
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
