"""Continuous batching on top of :class:`~repro.launch.serve.VortexServer`.

The serial server runs one request at a time: prefill, then one decode
launch per token with the whole batch at ONE position.  Under concurrent
traffic that leaves the batch-bucket dimension idle — every request pays
its own decode stream.  This module packs concurrent requests into that
dimension instead:

  * an ADMISSION QUEUE (``submit``) accepts requests from any thread,
    assigns ``request_id``s, and rejects requests that could never be
    served (``prompt + max_new - 1 > max_cache``, or more rows than the
    scheduler has slots) with a queue-level error AT SUBMIT TIME — not
    deep inside a decode loop;
  * a STEP SCHEDULER (``step``/``drain``) retires finished rows and
    admits queued prefills between steps, then advances every active row
    with ONE mixed-progress decode launch
    (``VortexServer._decode_exec_vec_for``): ``pos`` is a per-row i32
    vector, so rows sitting at different kv positions — fresh admits next
    to nearly-done generations — share the launch.  Free slots ride along
    at ``pos=0``: the program writes their (finite) k/v row 0 and attends
    over exactly that one masked row, so a retired slot costs one key of
    work and never reads stale pool bytes;
  * the KV state is ONE shared set of kv-bucket buffers LEASED from the
    server's :class:`~repro.launch.serve.KVBucketPool` — each admitted
    row's prefill cache is copied into its slot and the per-request
    buffers released back immediately, and when any row outgrows the
    bucket the shared cache grows through the pool
    (``VortexServer._grow_cache``) exactly like the serial path.

Step-granular contract (asserted by tests/test_scheduler.py and gated in
the bench): one AOT launch per batched decode step, zero padded calls,
and per-request outputs token-identical to serial ``generate()`` on the
same server.

Failure domains (DESIGN.md §11): a fault while admitting, growing, or
decoding resolves to a typed per-request error — ``drain()`` returns
tokens *or* a :class:`~repro.launch.serve.RequestError` per request id —
and never tears down the step loop; every failure path settles its pool
leases.  ``submit()`` adds backpressure: a bounded queue (``max_queue`` →
:class:`~repro.launch.serve.QueueFullError`) and per-request wall-clock
deadlines (``Request.deadline_s`` →
:class:`~repro.launch.serve.DeadlineExceeded`, the slots reused next
step).

Supported architectures are the uniformly-attention decoders (every
mixer ``attn``, no cross-attention / vision prefix / encoder stack): the
shared cache then holds only k/v leaves, whose every read goes through
the kv_len mask — the stale-tail pool contract.  MLA/mamba/encoder
architectures keep the serial path.
"""
from __future__ import annotations

import dataclasses
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.serve import (
    CacheOverflowError,
    DeadlineExceeded,
    QueueFullError,
    Request,
    RequestError,
    VortexServer,
)
from repro.models.model import abstract_cache
from repro.runtime import faults
from repro.vortex import pow2_bucket

__all__ = ["ContinuousScheduler", "batched_decode_supported"]


def batched_decode_supported(cfg) -> bool:
    """True when the mixed-progress batched decode serves this arch: all
    mixers are plain attention (shared cache = k/v leaves only, every
    read kv_len-masked) and there is no cross-attention, vision prefix,
    or encoder stack feeding extra per-request state."""
    if cfg.vision_prefix or cfg.encoder_decoder:
        return False
    return all(
        spec.mixer == "attn" and not spec.cross_attn for spec in cfg.pattern
    )


@dataclasses.dataclass
class _Row:
    """One occupied batch slot: a single sequence of one request."""
    rid: int
    req_row: int        # which row of the request's (b, s) token block
    pos_next: int       # cache position the NEXT decode step writes
    remaining: int      # decode steps left (max_new - tokens emitted)
    last_tok: int       # feeds the next step's token vector
    out: list[int]      # generated tokens so far (prefill argmax first)
    max_new: int
    stop: int | None


class ContinuousScheduler:
    """Admission queue + mixed-progress step scheduler over a server.

    ``submit()`` is thread-safe and returns the assigned request id;
    ``step()``/``drain()`` must run on one scheduler thread.  ``drain()``
    returns ``{request_id: (b, max_new) int64 array | RequestError}`` for
    every request resolved since the previous drain — tokens on success,
    the typed error when the request's admission/growth/decode failed or
    its deadline expired.  ``close()`` releases the shared cache leases
    back to the pool (``leases_active`` returns to 0).

    ``max_queue`` bounds the admission queue (``submit`` raises
    :class:`QueueFullError` at capacity); None = unbounded.
    """

    def __init__(
        self,
        server: VortexServer,
        *,
        batch_rows: int = 8,
        max_queue: int | None = None,
    ):
        if max_queue is not None and max_queue < 1:
            raise ValueError(f"max_queue must be >= 1, got {max_queue}")
        if not batched_decode_supported(server.cfg):
            raise ValueError(
                "continuous batching needs a uniformly-attention decoder "
                "(every mixer 'attn', no cross-attn/vision/encoder); "
                f"arch pattern {[s.mixer for s in server.cfg.pattern]} "
                "is served by the serial generate() path"
            )
        self.server = server
        self.batch_rows = pow2_bucket(batch_rows)
        self.max_queue = max_queue
        self._lock = threading.Lock()
        self._queue: list[Request] = []
        self._next_id = 0
        self._results: dict[int, np.ndarray | RequestError] = {}
        # Per-request assembly: (buffer, rows_outstanding).
        self._partial: dict[int, tuple[np.ndarray, int]] = {}
        # rid -> (absolute monotonic deadline, the request's deadline_s).
        self._deadlines: dict[int, tuple[float, float]] = {}
        self.rows: list[_Row | None] = [None] * self.batch_rows
        self.cache: dict | None = None
        self.kvb = 0
        self.stats = {
            "steps": 0, "launches": 0, "padded_calls": 0,
            "admitted": 0, "retired": 0, "calibration_slices": 0,
            "request_errors": 0, "deadline_expired": 0,
        }
        # Per-step active-row positions (and the bucket they ran at), the
        # evidence the staggering tests read: one entry per launch.
        self.step_positions: list[dict] = []

    # -- admission queue ----------------------------------------------------

    def submit(self, req: Request) -> int:
        """Queue a request, validating it AT ADMISSION: requests that
        could never complete fail here with a clear error instead of
        corrupting a decode loop later.  Thread-safe."""
        b, s = req.tokens.shape
        if b > self.batch_rows:
            raise ValueError(
                f"request has {b} rows but the scheduler batches "
                f"{self.batch_rows}; split the request or raise batch_rows"
            )
        if s + req.max_new - 1 > self.server.max_cache:
            # Same typed error as the serial ``generate()`` pre-prefill
            # check (launch/serve.py) — one overflow contract, two paths.
            raise CacheOverflowError(
                f"admission refused: prompt_len {s} + max_new "
                f"{req.max_new} needs {s + req.max_new - 1} cache rows > "
                f"max_cache {self.server.max_cache}; raise max_cache or "
                "shorten the request"
            )
        with self._lock:
            if (
                self.max_queue is not None
                and len(self._queue) >= self.max_queue
            ):
                raise QueueFullError(
                    f"admission queue is full ({self.max_queue} queued "
                    "requests); drain or retry after capacity frees up"
                )
            rid = self._next_id
            self._next_id += 1
            req = dataclasses.replace(req, request_id=rid)
            self._queue.append(req)
            if req.deadline_s is not None:
                self._deadlines[rid] = (
                    time.monotonic() + req.deadline_s, req.deadline_s
                )
        return rid

    # -- shared kv cache ----------------------------------------------------

    def _ensure_cache(self, kvb: int) -> None:
        """Lease the shared kv-bucket leaves (stale pool contents are fine:
        a slot row is only read after its prefill copy / decode write, and
        always through the kv_len mask)."""
        if self.cache is not None:
            return
        spec = abstract_cache(self.server.cfg, self.batch_rows, kvb)
        pool = self.server.kv_pool
        cache: dict = {}
        leased: list[jax.Array] = []
        # Lease incrementally and settle on failure: a fault partway
        # through (pool_lease injection, OOM) must not strand the leaves
        # already checked out — leases_active stays exact.
        try:
            for key, entry in spec.items():
                got = {}
                for n, leaf in entry.items():
                    buf = pool.lease(leaf.shape, leaf.dtype)
                    leased.append(buf)
                    got[n] = buf
                cache[key] = got
        except BaseException:
            for buf in leased:
                pool.release(buf)
            raise
        self.cache = cache
        self.kvb = kvb

    def _grow(self, new_kvb: int) -> None:
        assert self.cache is not None
        self.cache = self.server._grow_cache(self.cache, new_kvb)
        self.kvb = new_kvb

    def close(self) -> None:
        """Release the shared cache leases; idempotent, and a later
        submit/step re-leases lazily."""
        if self.cache is None:
            return
        self.server.release_cache(self.cache)
        self.cache = None
        self.kvb = 0

    def _copy_row(self, rcache: dict, r: int, slot: int) -> None:
        """One admitted sequence: its prefill-emitted cache row lands in
        the shared cache's slot row (per-leaf dynamic_update_slice; the
        request bucket may be shorter than the shared bucket — the slot
        row's tail past it stays stale, masked by kv_len)."""
        assert self.cache is not None
        for key, entry in self.cache.items():
            src = rcache[key]
            for name in entry:
                row = jax.lax.dynamic_slice_in_dim(src[name], r, 1, axis=1)
                entry[name] = jax.lax.dynamic_update_slice(
                    entry[name], row, (0, slot, 0, 0, 0)
                )

    # -- scheduling ---------------------------------------------------------

    def _free_slots(self) -> list[int]:
        return [i for i, row in enumerate(self.rows) if row is None]

    def _fail_request(
        self, rid: int, stage: str, exc: BaseException
    ) -> None:
        """Resolve EVERY row of one request to a typed error: seated rows
        are cleared (their slots reused next step), the partial output
        buffer dropped, and ``drain()`` returns the
        :class:`~repro.launch.serve.RequestError` instead of tokens.  The
        shared cache is untouched — other requests keep decoding."""
        for slot, row in enumerate(self.rows):
            if row is not None and row.rid == rid:
                self.rows[slot] = None
        self._partial.pop(rid, None)
        self._deadlines.pop(rid, None)
        err = exc if isinstance(exc, RequestError) else RequestError(
            rid, stage, f"{type(exc).__name__}: {exc}"
        )
        with self._lock:
            self._results[rid] = err
        if isinstance(err, DeadlineExceeded):
            self.stats["deadline_expired"] += 1
        else:
            self.stats["request_errors"] += 1

    def _expire_deadlines(self) -> bool:
        """Retire queued and active requests whose wall-clock deadline
        passed; True if anything expired (the tick did work)."""
        if not self._deadlines:
            return False
        now = time.monotonic()
        expired: list[tuple[int, float]] = []
        with self._lock:
            for req in list(self._queue):
                dl = self._deadlines.get(req.request_id)
                if dl is not None and now > dl[0]:
                    self._queue.remove(req)
                    expired.append((req.request_id, dl[1]))
        for rid in {row.rid for row in self.rows if row is not None}:
            dl = self._deadlines.get(rid)
            if dl is not None and now > dl[0]:
                expired.append((rid, dl[1]))
        for rid, deadline_s in expired:
            self._fail_request(
                rid, "deadline", DeadlineExceeded(rid, deadline_s)
            )
        return bool(expired)

    def _admit(self, req: Request) -> None:
        """Prefill ONE queued request through the server's serial prefill
        executables and seat its rows: per-row first token from the
        prefill argmax, cache rows copied into free slots, the transient
        per-request buffers released back to the pool."""
        if faults.ACTIVE is not None:
            faults.ACTIVE.check("scheduler_step")
        srv = self.server
        b, s = req.tokens.shape
        bp = srv.batch_bucket(b)
        sp = srv.seq_bucket(s)
        batch = srv._make_batch(bp, sp, req.tokens)
        logits, rcache = srv._prefill_exec_for(bp, sp, batch)(
            srv.params, batch
        )
        srv.adopt_cache(rcache)
        try:
            first = np.asarray(jnp.argmax(logits, -1))  # (bp,)
            kvb_req = srv.kv_bucket(sp)
            self._ensure_cache(kvb_req)
            if kvb_req > self.kvb:
                self._grow(kvb_req)
            slots = self._free_slots()
            rid = req.request_id
            assert rid is not None
            self._partial[rid] = (
                np.zeros((b, req.max_new), np.int64), b
            )
            for r in range(b):
                slot = slots[r]
                self._copy_row(rcache, r, slot)
                tok = int(first[r])
                self.rows[slot] = _Row(
                    rid=rid, req_row=r, pos_next=s,
                    remaining=req.max_new - 1, last_tok=tok, out=[tok],
                    max_new=req.max_new, stop=req.stop,
                )
                if req.stop is not None and tok == req.stop:
                    self.rows[slot].remaining = 0
        finally:
            srv.release_cache(rcache)
        self.stats["admitted"] += 1

    def _retire(self, slot: int) -> None:
        row = self.rows[slot]
        assert row is not None and row.remaining == 0
        out = row.out
        if len(out) < row.max_new:  # early stop: pad with the stop token
            out = out + [row.stop] * (row.max_new - len(out))
        buf, outstanding = self._partial[row.rid]
        buf[row.req_row] = out
        outstanding -= 1
        if outstanding:
            self._partial[row.rid] = (buf, outstanding)
        else:
            del self._partial[row.rid]
            self._deadlines.pop(row.rid, None)
            with self._lock:
                self._results[row.rid] = buf
        self.rows[slot] = None
        self.stats["retired"] += 1

    def step(self) -> bool:
        """One scheduler tick: retire finished rows, expire deadlines,
        admit every queued request that fits, then advance all active rows
        with EXACTLY ONE mixed-progress decode launch.  Returns False when
        fully idle.

        Failure isolation: an exception while admitting resolves THAT
        request to a ``RequestError``; one while growing fails only the
        rows that needed the larger bucket; one in the decode launch fails
        the rows that shared it.  Nothing propagates out of ``step()`` —
        the loop, the shared cache, and the lease ledger stay serviceable.
        """
        srv = self.server
        worked = False
        for slot, row in enumerate(self.rows):
            if row is not None and row.remaining == 0:
                self._retire(slot)
                worked = True
        worked |= self._expire_deadlines()
        while True:
            with self._lock:
                req = (
                    self._queue.pop(0)
                    if self._queue
                    and self._queue[0].tokens.shape[0]
                    <= len(self._free_slots())
                    else None
                )
            if req is None:
                break
            try:
                self._admit(req)
            except Exception as exc:
                assert req.request_id is not None
                self._fail_request(req.request_id, "admit", exc)
            worked = True
            # A stop token in the prefill argmax retires without a step.
            for slot, row in enumerate(self.rows):
                if row is not None and row.remaining == 0:
                    self._retire(slot)

        active = [
            (slot, row) for slot, row in enumerate(self.rows)
            if row is not None
        ]
        if not active:
            # Fully idle tick: donate one budgeted slice to the engine's
            # background calibrator (config.calibration="on-idle").  The
            # donation deliberately does NOT count as work — drain()'s
            # termination depends only on request progress, so a pending
            # calibration never keeps drain() spinning.
            self._donate_idle_slice()
            return worked
        assert self.cache is not None

        needed = max(row.pos_next + 1 for _, row in active)
        if needed > self.kvb and self.kvb < srv.max_cache:
            try:
                self._grow(srv._grown_kv_bucket(self.kvb, needed))
            except Exception as exc:
                # Two-phase growth left the shared cache (and every lease)
                # untouched — fail exactly the rows that no longer fit the
                # current bucket; everything else decodes next tick.
                stuck = {
                    row.rid for _, row in active
                    if row.pos_next + 1 > self.kvb
                }
                for rid in stuck:
                    self._fail_request(rid, "grow", exc)
                return True

        # Free slots decode at pos 0: their k/v row 0 is freshly written
        # by this very launch (finite), and kv_len = 1 reads only it.
        tok = np.zeros((self.batch_rows, 1), np.int32)
        pos = np.zeros((self.batch_rows,), np.int32)
        for slot, row in active:
            tok[slot, 0] = row.last_tok
            pos[slot] = row.pos_next
        try:
            if faults.ACTIVE is not None:
                faults.ACTIVE.check("scheduler_step")
            exe = srv._decode_exec_vec_for(self.batch_rows, self.kvb)
            logits, self.cache = exe(
                srv.params, self.cache, jnp.asarray(tok), jnp.asarray(pos)
            )
        except Exception as exc:
            # The launch raised before the cache assignment: the shared
            # leaves are exactly the pre-step state.  Every row that
            # shared this launch resolves to a typed error.
            for rid in {row.rid for _, row in active}:
                self._fail_request(rid, "decode", exc)
            return True
        self.stats["steps"] += 1
        self.stats["launches"] += 1  # the ONE launch this step performed
        self.step_positions.append({
            "kvb": self.kvb,
            "pos": np.asarray([row.pos_next for _, row in active]),
            "slots": np.asarray([slot for slot, _ in active]),
        })
        nxt = np.asarray(jnp.argmax(logits, -1))  # (batch_rows,)
        for slot, row in active:
            t = int(nxt[slot])
            row.out.append(t)
            row.last_tok = t
            row.pos_next += 1
            row.remaining -= 1
            if row.stop is not None and t == row.stop:
                row.remaining = 0
        return True

    def _donate_idle_slice(self) -> None:
        """With no queued requests and no active rows, give the engine's
        background calibrator one budgeted measurement slice (bounded by
        ``EngineConfig.calibration_budget_s``).  No-op when calibration is
        off or nothing is pending; never raises into the serving loop."""
        engine = getattr(self.server, "engine", None)
        cal = getattr(engine, "calibrator", None)
        if cal is None:
            return
        with self._lock:
            if self._queue:
                return
        try:
            if cal.pending():
                cal.run_slice()
                self.stats["calibration_slices"] += 1
        except Exception:
            pass

    def drain(self) -> dict[int, np.ndarray | RequestError]:
        """Run steps until queue and slots are empty; return (and clear)
        the results resolved since the last drain — a ``(b, max_new)``
        token array per completed request, or the
        :class:`~repro.launch.serve.RequestError` that resolved it.
        Failed requests free their slots immediately, so drain always
        terminates even when every step faults."""
        while True:
            worked = self.step()
            with self._lock:
                queued = bool(self._queue)
            if not worked and not queued and not any(self.rows):
                break
        with self._lock:
            out = self._results
            self._results = {}
        return out
