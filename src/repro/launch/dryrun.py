import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Multi-pod dry-run: lower + compile every (arch x shape) cell on the
production meshes, and derive the roofline terms.

MUST be invoked as its own process (``python -m repro.launch.dryrun``) so
the XLA_FLAGS line above runs before jax initializes devices.

For each cell:
  * build the abstract inputs (ShapeDtypeStructs — no allocation),
  * jit the appropriate step (train_step / prefill_step / decode_step) with
    explicit in/out shardings from the partitioning rules,
  * ``.lower().compile()`` on the 16x16 mesh and (with --multi-pod) the
    2x16x16 mesh — success proves the sharding config is coherent,
  * record memory_analysis / cost_analysis / trip-corrected HLO costs and
    the three roofline terms into a JSON results file (incremental, so a
    long sweep can resume).
(No ``from __future__ import annotations`` here: the os.environ assignment
must be the first executable statement in the file.)
"""
import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.launch.mesh import make_production_mesh
from repro.models import model as M
from repro.models.config import SHAPES, ModelConfig, ShapeSpec
from repro.models.params import abstract_params, count_params, param_pspecs
from repro.models.partitioning import make_rules, spec_tree_to_shardings
from repro.models.registry import ARCH_IDS, cell_supported, get_config
from repro.optim.adamw import adamw_init, opt_state_pspecs
from repro.roofline.analysis import V5E, roofline_report
from repro.roofline.memory import tree_device_bytes
from repro.train.step import (
    TrainHParams,
    make_decode_step,
    make_prefill_step,
    make_train_step,
    serve_input_specs,
    train_input_specs,
)

DEFAULT_OUT = "results/dryrun.json"


def _microbatches(cfg: ModelConfig, shape: ShapeSpec, dp_extent: int) -> int:
    """Gradient-accumulation factor: keep the per-microbatch logits block
    (mb x seq x vocab) and MoE dispatch buffers inside the HBM budget,
    while the per-microbatch batch still covers every DP shard (a smaller
    microbatch would replicate compute across part of the mesh)."""
    if shape.kind != "train":
        return 1
    mb = 8
    # Very large vocab: accumulate more (the f32 logits block dominates).
    # (Large-expert MoE previously also used 16; §Perf A5 halved it — FSDP
    # weight re-gather traffic scales linearly with the microbatch count
    # and the MoE dispatch buffers fit comfortably at mb=8.)
    if cfg.vocab >= 200000:
        mb = 16
    mb = min(mb, max(shape.global_batch // max(dp_extent, 1), 1))
    while shape.global_batch % mb:
        mb //= 2
    return max(mb, 1)


def _count_active(cfg: ModelConfig) -> int:
    return cfg.active_param_count()


def lower_cell(
    arch: str,
    shape_name: str,
    *,
    multi_pod: bool,
    do_compile: bool = True,
) -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    chips = mesh.devices.size
    rules = make_rules(
        mesh, fsdp=cfg.fsdp, n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads
    )
    axis_sizes = dict(mesh.shape)

    params = abstract_params(cfg)
    p_specs = param_pspecs(cfg, rules)
    p_sh = spec_tree_to_shardings(mesh, p_specs)

    t0 = time.perf_counter()
    extra_bytes = 0.0
    if shape.kind == "train":
        dp_extent = axis_sizes.get("data", 1) * axis_sizes.get("pod", 1)
        hp = TrainHParams(
            num_microbatches=_microbatches(cfg, shape, dp_extent)
        )
        step = make_train_step(cfg, rules, hp, grad_pspecs=p_specs)
        opt = adamw_init(params)
        o_specs = opt_state_pspecs(
            p_specs, params, axis_sizes.get("data", 1), zero1=True
        )
        o_sh = spec_tree_to_shardings(mesh, o_specs)
        batch, b_pspecs = train_input_specs(cfg, shape, rules)
        b_sh = spec_tree_to_shardings(mesh, b_pspecs)
        metrics_sh = NamedSharding(mesh, P())
        jitted = jax.jit(
            step,
            in_shardings=(p_sh, o_sh, b_sh),
            out_shardings=(p_sh, o_sh, None),
        )
        lowered = jitted.lower(params, opt, batch)
        state_bytes = (
            tree_device_bytes(params, p_specs, axis_sizes)
            + tree_device_bytes(opt["mu"], o_specs["mu"], axis_sizes)
            + tree_device_bytes(opt["nu"], o_specs["nu"], axis_sizes)
            + tree_device_bytes(params, p_specs, axis_sizes)  # grads
        )
    elif shape.kind == "prefill":
        step = make_prefill_step(cfg, rules, cache_len=shape.seq_len)
        batch, b_pspecs = serve_input_specs(cfg, shape, rules)
        b_sh = spec_tree_to_shardings(mesh, b_pspecs)
        c_specs = M.cache_pspecs(cfg, rules, shape.global_batch, shape.seq_len)
        c_sh = spec_tree_to_shardings(mesh, c_specs)
        jitted = jax.jit(
            step, in_shardings=(p_sh, b_sh), out_shardings=(None, c_sh)
        )
        lowered = jitted.lower(params, batch)
        cache = M.abstract_cache(cfg, shape.global_batch, shape.seq_len)
        state_bytes = (
            tree_device_bytes(params, p_specs, axis_sizes)
            + tree_device_bytes(cache, c_specs, axis_sizes)
        )
    else:  # decode
        step = make_decode_step(cfg, rules, cache_len=shape.seq_len)
        cache = M.abstract_cache(cfg, shape.global_batch, shape.seq_len)
        c_specs = M.cache_pspecs(cfg, rules, shape.global_batch, shape.seq_len)
        c_sh = spec_tree_to_shardings(mesh, c_specs)
        inputs, i_pspecs = serve_input_specs(cfg, shape, rules)
        tok_sh = spec_tree_to_shardings(mesh, i_pspecs["tokens"])
        pos_sh = NamedSharding(mesh, P())
        jitted = jax.jit(
            step,
            in_shardings=(p_sh, c_sh, tok_sh, pos_sh),
            out_shardings=(None, c_sh),
        )
        lowered = jitted.lower(
            params, cache, inputs["tokens"], inputs["pos"]
        )
        state_bytes = (
            tree_device_bytes(params, p_specs, axis_sizes)
            + tree_device_bytes(cache, c_specs, axis_sizes)
        )

    lower_s = time.perf_counter() - t0
    result: dict = {
        "arch": arch,
        "shape": shape_name,
        "mesh": mesh_name,
        "chips": chips,
        "params": count_params(cfg),
        "active_params": _count_active(cfg),
        "state_bytes_per_device": state_bytes,
        "state_gib_per_device": state_bytes / 2**30,
        "lower_seconds": lower_s,
    }
    if not do_compile:
        return result

    t1 = time.perf_counter()
    compiled = lowered.compile()
    result["compile_seconds"] = time.perf_counter() - t1

    try:
        ma = compiled.memory_analysis()
        result["memory_analysis"] = {
            k: getattr(ma, k)
            for k in dir(ma)
            if not k.startswith("_")
            and isinstance(getattr(ma, k, None), (int, float))
        }
    except Exception as e:  # backend may not support it
        result["memory_analysis"] = {"error": str(e)}

    try:
        ca = compiled.cost_analysis() or {}
    except Exception:
        ca = {}

    hlo = compiled.as_text()
    report = roofline_report(
        arch=arch,
        shape=shape,
        mesh_name=mesh_name,
        chips=chips,
        hlo_text=hlo,
        cost_analysis=ca,
        cfg=cfg,
        params=result["params"],
        active_params=result["active_params"],
        chip=V5E,
    )
    result["roofline"] = report.as_dict()
    result["hlo_bytes"] = len(hlo)
    return result


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument(
        "--mesh", choices=["single", "multi", "both"], default="both"
    )
    ap.add_argument("--out", default=DEFAULT_OUT)
    ap.add_argument("--no-compile", action="store_true")
    ap.add_argument("--force", action="store_true",
                    help="recompute cells already in the results file")
    args = ap.parse_args()

    archs = list(ARCH_IDS) if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[
        args.mesh
    ]

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    results: dict[str, dict] = {}
    if os.path.exists(args.out):
        with open(args.out) as f:
            results = json.load(f)

    n_fail = 0
    for arch in archs:
        for shape_name in shapes:
            ok, reason = cell_supported(arch, shape_name)
            for multi in meshes:
                key = f"{arch}|{shape_name}|{'multi' if multi else 'single'}"
                if key in results and not args.force and (
                    "error" not in results[key]
                ):
                    continue
                if not ok:
                    results[key] = {
                        "arch": arch, "shape": shape_name,
                        "mesh": "pod2x16x16" if multi else "pod16x16",
                        "skipped": reason,
                    }
                    print(f"[skip] {key}: {reason}")
                else:
                    print(f"[cell] {key} ...", flush=True)
                    try:
                        t0 = time.perf_counter()
                        results[key] = lower_cell(
                            arch, shape_name, multi_pod=multi,
                            do_compile=not args.no_compile,
                        )
                        dt = time.perf_counter() - t0
                        r = results[key].get("roofline", {})
                        print(
                            f"       ok in {dt:.1f}s  dominant="
                            f"{r.get('dominant')}  state/dev="
                            f"{results[key]['state_gib_per_device']:.2f}GiB",
                            flush=True,
                        )
                    except Exception as e:
                        n_fail += 1
                        results[key] = {
                            "arch": arch, "shape": shape_name,
                            "mesh": "pod2x16x16" if multi else "pod16x16",
                            "error": f"{type(e).__name__}: {e}",
                            "traceback": traceback.format_exc()[-2000:],
                        }
                        print(f"       FAILED: {e}", flush=True)
                with open(args.out, "w") as f:
                    json.dump(results, f, indent=1)
    print(f"done; {n_fail} failures; results in {args.out}")


if __name__ == "__main__":
    main()
