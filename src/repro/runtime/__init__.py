"""Runtime services: heartbeat, elastic remesh, supervisor, fault plans.

Exports resolve lazily (PEP 562) so that hot-path modules importing the
fault-injection hooks (``from repro.runtime import faults``) never pay for
— or cycle through — the supervisor/checkpoint stack.
"""
import importlib

_EXPORTS = {
    "StepMonitor": "repro.runtime.heartbeat",
    "plan_remesh": "repro.runtime.elastic",
    "RemeshPlan": "repro.runtime.elastic",
    "Supervisor": "repro.runtime.supervisor",
    "SimulatedFailure": "repro.runtime.supervisor",
    "FaultPlan": "repro.runtime.faults",
    "InjectedFault": "repro.runtime.faults",
}

__all__ = ["faults", *_EXPORTS]


def __getattr__(name: str):
    mod = _EXPORTS.get(name)
    if mod is None:
        raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
    value = getattr(importlib.import_module(mod), name)
    globals()[name] = value
    return value


def __dir__():
    return sorted(set(globals()) | set(__all__))
