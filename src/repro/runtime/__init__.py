from repro.runtime.heartbeat import StepMonitor
from repro.runtime.elastic import plan_remesh, RemeshPlan
from repro.runtime.supervisor import Supervisor, SimulatedFailure

__all__ = [
    "StepMonitor",
    "plan_remesh",
    "RemeshPlan",
    "Supervisor",
    "SimulatedFailure",
]
