"""Elastic re-meshing after node loss.

Policy: the TP ('model') extent is an architectural invariant (weight shards
are laid out for it), so on losing hosts we shrink the *data-parallel* axis
to the largest extent the surviving chips support, keep the global batch by
raising per-shard microbatching, and reshard params from the last checkpoint
(checkpoint/manager.py restore with the new mesh's shardings).
"""
from __future__ import annotations

import dataclasses

__all__ = ["RemeshPlan", "plan_remesh"]


@dataclasses.dataclass(frozen=True)
class RemeshPlan:
    mesh_shape: tuple[int, ...]
    mesh_axes: tuple[str, ...]
    chips_used: int
    chips_idle: int
    microbatch_scale: int  # multiply num_microbatches by this to keep GBS

    @property
    def data_extent(self) -> int:
        return self.mesh_shape[self.mesh_axes.index("data")]


def plan_remesh(
    healthy_chips: int,
    model_extent: int,
    *,
    old_data_extent: int,
    pods: int = 1,
) -> RemeshPlan:
    """Largest (pod, data, model) mesh fitting on the surviving chips."""
    if healthy_chips < model_extent:
        raise ValueError(
            f"cannot keep TP={model_extent} with only {healthy_chips} chips"
        )
    per_pod = healthy_chips // max(pods, 1)
    data = per_pod // model_extent
    # data extent must divide the old extent so every new shard's data
    # stream is a union of old streams (deterministic replay, data/pipeline).
    while data > 1 and old_data_extent % data:
        data -= 1
    data = max(data, 1)
    used = pods * data * model_extent
    shape = (pods, data, model_extent) if pods > 1 else (data, model_extent)
    axes = ("pod", "data", "model") if pods > 1 else ("data", "model")
    return RemeshPlan(
        mesh_shape=shape,
        mesh_axes=axes,
        chips_used=used,
        chips_idle=healthy_chips - used,
        microbatch_scale=max(1, old_data_extent // data),
    )
