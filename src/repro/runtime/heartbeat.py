"""Heartbeat / straggler detection.

Each host reports per-step wall-clock durations; the monitor flags
stragglers with a median-absolute-deviation rule (robust to the long tail a
mean/std rule would be pulled by) and flags *dead* hosts that have missed
``dead_after`` heartbeat intervals.  At 1000+ nodes this runs on the
coordinator; here it is exercised by the test-suite and the example driver
with simulated hosts.
"""
from __future__ import annotations

import collections
import dataclasses
import time

import numpy as np

__all__ = ["StepMonitor"]


@dataclasses.dataclass
class HostState:
    last_seen: float
    durations: collections.deque


class StepMonitor:
    def __init__(
        self,
        window: int = 32,
        mad_threshold: float = 5.0,
        dead_after: float = 60.0,
        clock=time.monotonic,
    ):
        self._window = window
        self._mad = mad_threshold
        self._dead_after = dead_after
        self._clock = clock
        self._hosts: dict[int, HostState] = {}

    def record(self, host: int, step: int, seconds: float) -> None:
        st = self._hosts.get(host)
        now = self._clock()
        if st is None:
            st = HostState(now, collections.deque(maxlen=self._window))
            self._hosts[host] = st
        st.last_seen = now
        st.durations.append(float(seconds))

    def _recent(self, host: int) -> float | None:
        st = self._hosts.get(host)
        if not st or not st.durations:
            return None
        return float(np.median(list(st.durations)[-8:]))

    def stragglers(self) -> list[int]:
        """Hosts whose recent step time deviates > threshold * MAD from the
        fleet median."""
        meds = {
            h: m for h in self._hosts
            if (m := self._recent(h)) is not None
        }
        if len(meds) < 3:
            return []
        values = np.array(list(meds.values()))
        fleet_med = np.median(values)
        mad = np.median(np.abs(values - fleet_med)) + 1e-9
        return sorted(
            h for h, m in meds.items()
            if (m - fleet_med) / mad > self._mad
        )

    def dead_hosts(self) -> list[int]:
        now = self._clock()
        return sorted(
            h for h, st in self._hosts.items()
            if now - st.last_seen > self._dead_after
        )

    def healthy_hosts(self) -> list[int]:
        dead = set(self.dead_hosts())
        return sorted(h for h in self._hosts if h not in dead)
