"""Failure-supervised training driver.

The supervisor wraps a step function with checkpoint/restore:

  * every ``ckpt_every`` steps it snapshots (async),
  * on a failure (a real exception, or :class:`SimulatedFailure` injected by
    the tests / chaos hook) it restores the last checkpoint and replays —
    the data pipeline is deterministic in (seed, step), so replay is exact,
  * repeated failures within one step window trip ``max_retries``.

This is the single-process simulation of the multi-host restart protocol;
on a real cluster the same logic runs per-host with the coordinator's
barrier, and the restore path doubles as the *elastic* path by passing a
new mesh's shardings to ``restore``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

from repro.checkpoint.manager import CheckpointManager

__all__ = ["SimulatedFailure", "Supervisor"]


class SimulatedFailure(RuntimeError):
    """Injected node failure (chaos testing)."""


@dataclasses.dataclass
class _RunStats:
    steps_run: int = 0
    failures: int = 0
    restores: int = 0


class Supervisor:
    def __init__(
        self,
        ckpt: CheckpointManager,
        *,
        ckpt_every: int = 50,
        max_retries: int = 3,
    ):
        self.ckpt = ckpt
        self.ckpt_every = ckpt_every
        self.max_retries = max_retries
        self.stats = _RunStats()

    def run(
        self,
        state: Any,
        step_fn: Callable[[Any, int], Any],
        *,
        start_step: int = 0,
        num_steps: int = 100,
        meta: dict | None = None,
        failure_hook: Callable[[int], None] | None = None,
    ) -> Any:
        """Run ``num_steps`` of ``step_fn`` with checkpoint/restart.

        ``step_fn(state, step) -> state``.  ``failure_hook(step)`` may raise
        SimulatedFailure to emulate a node loss at that step boundary.
        """
        step = start_step
        # Resume from the freshest checkpoint if one exists.
        latest = self.ckpt.latest_step()
        if latest is not None and latest > step:
            state = self.ckpt.restore(latest, state)
            step = latest
            self.stats.restores += 1

        retries = 0
        while step < start_step + num_steps:
            try:
                if failure_hook is not None:
                    failure_hook(step)
                state = step_fn(state, step)
                self.stats.steps_run += 1
                step += 1
                retries = 0
                if step % self.ckpt_every == 0:
                    self.ckpt.save_async(step, state, meta)
            except SimulatedFailure:
                self.stats.failures += 1
                retries += 1
                if retries > self.max_retries:
                    raise
                self.ckpt.wait()
                latest = self.ckpt.latest_step()
                if latest is None:
                    # No checkpoint yet: replay from the beginning.
                    step = start_step
                else:
                    state = self.ckpt.restore(latest, state)
                    step = latest
                self.stats.restores += 1
        self.ckpt.wait()
        return state
