"""Deterministic fault injection for chaos tests (DESIGN.md §11).

A :class:`FaultPlan` names *sites* (fixed hook points threaded through the
engine, server, scheduler and calibrator) and the exact 1-based occurrence
indices at which each site must fail.  Hooks are two lines and free when no
plan is installed — a module attribute load plus an ``is None`` check:

    from repro.runtime import faults
    ...
    if faults.ACTIVE is not None:
        faults.ACTIVE.check("pool_lease")

Plans are exact ("fail the 3rd lease"), so a chaos run is reproducible from
its seed alone: the same plan against the same code fails the same calls.
Occurrence counters are per-site and thread-safe; ``fired`` records every
injection in order for post-hoc assertions.  Install scoped via
:func:`installed` so a crashed test never leaks a plan into the next one.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Iterable, Mapping

__all__ = [
    "ACTIVE",
    "SITES",
    "FaultPlan",
    "InjectedFault",
    "installed",
]

# Every named hook point in the codebase.  Keep in sync with DESIGN.md §11.
SITES = (
    "precompile",      # VortexKernel._build_executable (core/engine.py)
    "aot_launch",      # _CacheEntry.run (core/engine.py)
    "pool_lease",      # KVBucketPool.lease (launch/serve.py)
    "cache_io",        # Calibrator save/load, DenylistStore I/O
    "calib_measure",   # Calibrator._measure_bucket (core/calibrate.py)
    "scheduler_step",  # ContinuousScheduler admit + decode launch
)


class InjectedFault(RuntimeError):
    """Raised by a hook when its occurrence index is in the plan."""

    def __init__(self, site: str, occurrence: int):
        self.site = site
        self.occurrence = occurrence
        super().__init__(
            f"injected fault at site {site!r} (occurrence {occurrence})"
        )


class FaultPlan:
    """Site -> set of 1-based occurrence indices that must fail."""

    def __init__(self, spec: Mapping[str, Iterable[int]]):
        for site in spec:
            if site not in SITES:
                raise ValueError(
                    f"unknown fault site {site!r}; known: {SITES}"
                )
        self.spec: dict[str, frozenset[int]] = {
            site: frozenset(int(n) for n in occs)
            for site, occs in spec.items()
        }
        if any(n < 1 for occs in self.spec.values() for n in occs):
            raise ValueError("occurrence indices are 1-based")
        self._lock = threading.Lock()
        self._seen: dict[str, int] = {}
        self.fired: list[tuple[str, int]] = []

    @classmethod
    def random(
        cls,
        seed: int,
        *,
        sites: Iterable[str] = SITES,
        rate: float = 0.05,
        horizon: int = 100,
    ) -> "FaultPlan":
        """Seeded random plan: each of the first ``horizon`` occurrences of
        each site fails independently with probability ``rate``.  If the
        draw selects nothing at all, occurrence 1 of the first site is
        forced so a chaos run always exercises at least one fault."""
        import numpy as np

        rng = np.random.default_rng(seed)
        sites = tuple(sites)
        spec = {
            site: [
                n for n in range(1, horizon + 1) if rng.random() < rate
            ]
            for site in sites
        }
        if not any(spec.values()) and sites:
            spec[sites[0]] = [1]
        return cls(spec)

    def check(self, site: str) -> None:
        """Count one occurrence of ``site``; raise if the plan says so."""
        with self._lock:
            n = self._seen.get(site, 0) + 1
            self._seen[site] = n
            hit = n in self.spec.get(site, ())
            if hit:
                self.fired.append((site, n))
        if hit:
            raise InjectedFault(site, n)

    @property
    def counts(self) -> dict[str, int]:
        """Occurrences observed so far per site (fired or not)."""
        with self._lock:
            return dict(self._seen)


# The installed plan.  Hooks read this exactly once per call; ``None``
# (the default, and the only state production code ever sees) short-
# circuits before any method call.
ACTIVE: FaultPlan | None = None


@contextlib.contextmanager
def installed(plan: FaultPlan):
    """Scope ``plan`` as the active plan, restoring the previous one."""
    global ACTIVE
    prev = ACTIVE
    ACTIVE = plan
    try:
        yield plan
    finally:
        ACTIVE = prev
