"""``vortex.ops``: one callable per registered workload kind — generated
from the ``WORKLOADS`` registry, never hand-listed.

``@register_workload`` alone is what exposes an op here: attribute access
resolves kinds against the live registry (PEP 562 module ``__getattr__``),
so a workload registered at any point — including inside a test — is
immediately callable as ``vortex.ops.<kind>`` with NO edits to any engine
module.  Each op routes through the contextvar session::

    from repro import vortex

    y = vortex.ops.gemm(a, b)                    # process-default engine
    with vortex.use(Engine(cfg)):
        y = vortex.ops.attention(q, k, v)        # scoped engine

Positional arguments are the runtime arrays (what the compiled executable
consumes); keyword arguments are workload parameters (masking flags,
strides) — the split ``Workload.bind`` declares.
"""
from __future__ import annotations

from typing import Any

from repro.core.workloads import WORKLOADS
from repro.vortex.handle import CompiledOp
from repro.vortex.session import current_engine

__all__ = ["op"]


class Op:
    """The generic op front for one workload kind, bound to the ambient
    session at call time (NOT at creation: the same ``vortex.ops.gemm``
    object serves whichever engine is installed where it is called)."""

    __slots__ = ("kind",)

    def __init__(self, kind: str):
        self.kind = kind

    def __call__(self, *args: Any, **kwargs: Any):
        return current_engine().dispatch(self.kind, *args, **kwargs)

    def compile(self, **params: Any) -> CompiledOp:
        """Pin a full workload signature of this kind on the current
        engine: ``vortex.ops.gemm.compile(M=None, N=768, K=2304)``."""
        return current_engine().compile(self.kind, **params)

    def handle_for(self, *args: Any, **kwargs: Any) -> CompiledOp:
        """The CompiledOp a call with these arguments would be served by
        (without executing it)."""
        eng = current_engine()
        return CompiledOp(eng, eng.op_kernel(self.kind, args, kwargs))

    def __repr__(self) -> str:
        return f"vortex.ops.{self.kind}"


_OPS: dict[str, Op] = {}


def op(kind: str) -> Op:
    """The op front for ``kind`` (must be a registered workload)."""
    front = _OPS.get(kind)
    if front is None:
        if kind not in WORKLOADS:
            raise AttributeError(
                f"no workload kind {kind!r} registered; known: "
                f"{sorted(WORKLOADS)}"
            )
        front = _OPS[kind] = Op(kind)
    return front


def __getattr__(name: str) -> Op:
    if name.startswith("_"):
        raise AttributeError(name)
    return op(name)


def __dir__() -> list[str]:
    return sorted(set(globals()) | set(WORKLOADS))
