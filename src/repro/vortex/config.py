"""EngineConfig: the one frozen value that fully describes an Engine.

Everything an :class:`~repro.vortex.Engine` session needs — target
hardware, compute backends, executable implementation, selection-table
sizing, precompile policy — lives here, so engines are reproducible from a
single hashable value and serving harnesses can log/compare them.  The
profiler is the one deliberate exception (a live object measuring the host;
pass it to ``Engine`` directly).
"""
from __future__ import annotations

import dataclasses

__all__ = ["EngineConfig"]


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Frozen description of one engine session.

    * ``hardware`` — a :func:`repro.core.hardware.get_hardware` name; the
      lattice is generated for THIS target even when executing on a host
      (serving uses ``tpu_v5e`` buckets on the CPU so executables dedupe
      the same way they would on the pod).
    * ``backends`` — compute backends to score (None = all the hardware
      declares, e.g. MXU + VPU; the selector picks per shape, Fig. 16).
    * ``impl`` — executable implementation: ``"xla"`` (flat JAX ops) or
      ``"pallas"`` (Vortex-tiled kernels; ``interpret`` runs them off-TPU).
    * ``empirical_levels`` — hierarchy levels the hybrid analyzer measures
      empirically (None = paper defaults, Table 7: level 0 on CPU, levels
      0-1 on accelerator-class hardware; ``()`` = fully analytical).
    * ``table_m_max`` / ``table_extend_limit`` — initial coverage and
      doubling ceiling of the offline-materialized selection table
      (selection_table.py); 0 disables the table (argmin + LRU only).
    * ``precompile_m_max`` — when > 0, compiling an op through this engine
      eagerly warms every executable bucket reachable for extents up to
      this value (only for workloads whose executables are not specialized
      on outer dims — those need representative args, see
      ``CompiledOp.precompile``).
    * ``staging`` — serve unaligned extents through the masked-tail staging
      hot path (engine-owned donated bucket buffers + one fused AOT launch,
      DESIGN.md §4).  False forces every call onto the zero-pad reference
      path — a debugging/parity knob, not a serving configuration.
    * ``staging_pool_cap`` — LRU bound on the staging-buffer sets each
      executable entry retains (``_StagingPool``): a release beyond the cap
      evicts the least-recently-used idle set, so burst concurrency can't
      pin device memory forever.  0 retains nothing (every unaligned call
      allocates transient buffers); in-flight sets are never evicted.
    * ``calibration`` — background measurement-refined tables (DESIGN.md
      §10): ``"off"`` (default; the serving path is bit-identical to an
      uncalibrated engine), ``"on-idle"`` (the continuous scheduler
      donates budgeted slices when its admission queue is empty), or
      ``"eager-warmup"`` (each kernel is calibrated — persisted tables
      loaded from disk first — as it is built).
    * ``calibration_top_k`` / ``calibration_budget_s`` — how many
      analytically-ranked candidates to measure per bucket, and the
      wall-clock bound of ONE donated idle slice.
    * ``calibration_cache_dir`` — where calibrated tables persist, keyed
      by hardware fingerprint (None = ``$VORTEX_CACHE_DIR`` or
      ``~/.cache/vortex``; never inside the repo).
    * ``max_kernel_retries`` — degradation-ladder depth (DESIGN.md §11):
      how many next-best lattice candidates a dispatch re-selects after
      the chosen candidate fails at precompile/launch, before falling
      back to the XLA reference rung.  0 = straight to the reference.
    * ``denylist_persist`` — persist quarantined candidates next to the
      calibration cache (``<fingerprint>.deny.json``) so restarts skip
      known-bad candidates without re-failing them; False keeps the
      quarantine in-memory only (tests, hermetic runs).
    """

    hardware: str = "host_cpu"
    backends: tuple[str, ...] | None = None
    impl: str = "xla"
    interpret: bool = True
    num_cores: int = 1
    empirical_levels: tuple[int, ...] | None = None
    table_m_max: int = 4096
    table_extend_limit: int = 1 << 17
    precompile_m_max: int = 0
    staging: bool = True
    staging_pool_cap: int = 4
    calibration: str = "off"
    calibration_top_k: int = 3
    calibration_budget_s: float = 0.25
    calibration_cache_dir: str | None = None
    max_kernel_retries: int = 2
    denylist_persist: bool = True

    def __post_init__(self) -> None:
        if self.max_kernel_retries < 0:
            raise ValueError(
                f"max_kernel_retries must be >= 0, "
                f"got {self.max_kernel_retries}"
            )
        if self.backends is not None:
            object.__setattr__(self, "backends", tuple(self.backends))
        if self.empirical_levels is not None:
            object.__setattr__(
                self, "empirical_levels", tuple(self.empirical_levels)
            )
        if self.calibration not in ("off", "on-idle", "eager-warmup"):
            raise ValueError(
                f"calibration must be 'off', 'on-idle' or 'eager-warmup', "
                f"got {self.calibration!r}"
            )
