"""Engine: a session over many workloads, served from one cache hierarchy.

One Engine = one :class:`~repro.vortex.config.EngineConfig` + one
scored-lattice cache + one compiled-kernel table + one raw-tuple dispatch
table.  It has NO per-operator entry points: every registered workload kind
(``@register_workload``) is reachable through :meth:`compile` /
:meth:`dispatch` — and therefore through ``vortex.ops.<kind>`` — with zero
engine edits, which is the whole point of the registry-driven API
(DESIGN.md § Public API).

Engines are installed per-context with :func:`repro.vortex.use` (contextvar
scoped: nestable, exception-safe, thread-isolated); model layers and ops
pick up the innermost installed engine.
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Any

from repro.core.analyzer import (
    Profiler,
    ScoredLattice,
    TableProfiler,
    WallClockProfiler,
)
from repro.core.engine import OfflineStats, VortexKernel
from repro.core.hardware import get_hardware
from repro.core.workloads import WORKLOADS, Workload, make_workload
from repro.vortex.config import EngineConfig
from repro.vortex.handle import CompiledOp

__all__ = ["Engine", "pow2_bucket"]


def pow2_bucket(n: int) -> int:
    """Power-of-two bucket for auxiliary outer dims (serving batch size).

    The primary dynamic extent is bucketed by the lattice (CompiledOp.
    bucket); dims that merely multiply it (the request batch) are quantized
    to pow2 so the executable cache stays small with <= 2x waste on that
    factor alone — quantizing them to the sublane granularity too would
    double-pad.
    """
    p = 1
    while p < n:
        p *= 2
    return p


class Engine:
    """A scoped compilation/serving session over the workload registry.

    ``config`` may be an :class:`EngineConfig`, a hardware name string, or
    None (host-CPU defaults); keyword ``overrides`` replace individual
    config fields either way.  Signatures are built lazily but *without*
    any dependence on the dynamic dim — first use of a new signature builds
    its lattice once, after which every runtime extent is served from the
    same scored lattice (sample-free across all dynamic shapes).  Workloads
    whose lattice inputs coincide (e.g. attention signatures differing only
    in masking flags) share scored lattices through one engine-wide cache.
    """

    def __init__(
        self,
        config: EngineConfig | str | None = None,
        *,
        profiler: Profiler | None = None,
        **overrides: Any,
    ):
        if config is None:
            config = EngineConfig(**overrides)
        else:
            if isinstance(config, str):
                config = EngineConfig(hardware=config)
            if overrides:
                config = dataclasses.replace(config, **overrides)
        self.config = config
        self._hw = get_hardware(config.hardware)
        if profiler is None:
            profiler = (
                WallClockProfiler() if config.hardware == "host_cpu"
                else TableProfiler(self._hw)
            )
        self._profiler = profiler
        empirical = config.empirical_levels
        if empirical is None:
            # Paper defaults (Table 7): E:L0 on CPU; E:L0,L1 on GPU-class HW.
            empirical = (0,) if config.hardware == "host_cpu" else (0, 1)
        self._empirical_levels = tuple(empirical)
        self._kernels: dict[tuple, VortexKernel] = {}
        self._scored_cache: dict[tuple, ScoredLattice] = {}
        # Zero-rebuild hot path: raw call-site tuples -> compiled kernel.
        # Steady-state dispatch hashes a tuple of ints (shapes/flags
        # straight off the arrays, Workload.dispatch_key) instead of
        # constructing a Workload dataclass and hashing its dataclass
        # signature on every call.
        self._dispatch: dict[tuple, VortexKernel] = {}
        # Kernel builds are expensive (lattice sweep); serialize them so two
        # threads first touching the same signature don't build it twice.
        self._build_lock = threading.Lock()
        # Background calibrator (core/calibrate.py), created on first use
        # when config.calibration != "off".  Guarded by _build_lock.
        self._calibrator = None
        # Persistent candidate denylist shared by every kernel of this
        # engine (degradation ladder, DESIGN.md §11).  Created lazily under
        # _build_lock; None when persistence is disabled.
        self._denylist = None

    @property
    def calibrator(self):
        """The background :class:`~repro.core.calibrate.Calibrator` for
        this engine's kernels — None when ``config.calibration == "off"``
        (the default), in which case nothing calibration-related is ever
        constructed and serving is bit-identical to an engine predating
        the feature."""
        cfg = self.config
        if cfg.calibration == "off":
            return None
        if self._calibrator is None:
            with self._build_lock:
                if self._calibrator is None:
                    from repro.core.calibrate import (
                        CalibrationPolicy,
                        Calibrator,
                    )

                    self._calibrator = Calibrator(
                        lambda: list(self._kernels.values()),
                        CalibrationPolicy(
                            mode=cfg.calibration,
                            top_k=cfg.calibration_top_k,
                            budget_s=cfg.calibration_budget_s,
                            cache_dir=cfg.calibration_cache_dir,
                        ),
                    )
        return self._calibrator

    @property
    def hardware(self):
        return self._hw

    # -- session scoping ----------------------------------------------------

    def use(self):
        """Install this engine for the current context: shorthand for
        ``vortex.use(engine)`` (nestable, exception-safe, thread-local)."""
        from repro.vortex.session import use

        return use(self)

    # -- workload plumbing --------------------------------------------------

    def kernel_for(self, wl: Workload) -> VortexKernel:
        """The compiled kernel serving ``wl``'s signature (built lazily)."""
        key = wl.signature
        kern = self._kernels.get(key)
        built = False
        if kern is None:
            with self._build_lock:
                kern = self._kernels.get(key)
                if kern is None:
                    built = True
                    cfg = self.config
                    kern = VortexKernel(
                        self._hw,
                        wl,
                        profiler=self._profiler,
                        empirical_levels=self._empirical_levels,
                        backends=cfg.backends,
                        num_cores=cfg.num_cores,
                        impl=cfg.impl,
                        interpret=cfg.interpret,
                        scored_cache=self._scored_cache,
                        table_m_max=cfg.table_m_max,
                        table_extend_limit=cfg.table_extend_limit,
                        staging=cfg.staging,
                        staging_pool_cap=cfg.staging_pool_cap,
                        max_retries=cfg.max_kernel_retries,
                        denylist=self._denylist_store(),
                    )
                    self._kernels[key] = kern
        if built and self.config.calibration == "eager-warmup":
            # Warm synchronously at build time: persisted tables load by
            # hardware fingerprint (zero re-measurements on restart);
            # anything not on disk is measured now, before serving.
            cal = self.calibrator
            cal.load()
            if cal.pending():
                cal.run()
        return kern

    def _denylist_store(self):
        """The engine's persistent quarantine store (or None when
        ``config.denylist_persist`` is off).  Constructed HERE rather than
        inside core/engine.py so core.engine never imports core.denylist
        (which imports core.calibrate, which imports core.engine)."""
        cfg = self.config
        if not cfg.denylist_persist:
            return None
        if self._denylist is None:
            from repro.core.denylist import DenylistStore

            self._denylist = DenylistStore(
                self._hw,
                cfg.backends or tuple(self._hw.backends),
                cfg.impl,
                cfg.interpret,
                cache_dir=cfg.calibration_cache_dir,
            )
        return self._denylist

    def compile(
        self, workload: Workload | str, **params: Any
    ) -> CompiledOp:
        """The CompiledOp handle for a workload signature.

        ``workload`` is either a Workload instance or a registered kind
        name with the workload parameters as keywords::

            op = engine.compile(GemmWorkload(M=None, N=768, K=2304))
            op = engine.compile("gemm", M=None, N=768, K=2304)

        With ``config.precompile_m_max > 0`` the op's executable buckets
        are warmed eagerly (workloads without outer-dim specialization
        only; the rest need representative args, see CompiledOp.precompile).
        """
        if isinstance(workload, str):
            workload = make_workload(workload, **params)
        elif params:
            raise TypeError(
                "workload parameters are only accepted with a kind name, "
                f"not alongside a Workload instance: {sorted(params)}"
            )
        known = self._kernels.get(workload.signature) is not None
        op = CompiledOp(self, self.kernel_for(workload))
        pm = self.config.precompile_m_max
        if pm > 0 and not known and not self._exec_specialized(workload):
            op.precompile(pm)
        return op

    @staticmethod
    def _exec_specialized(wl: Workload) -> bool:
        """True when ``wl``'s executables key on outer dims of the call
        args (overridden ``exec_key``) — eager precompile without
        representative args would warm keys real calls never hit."""
        return type(wl).exec_key is not Workload.exec_key

    # -- registry-driven dispatch -------------------------------------------

    def op_kernel(self, kind: str, args: tuple, kwargs: dict) -> VortexKernel:
        """Resolve a call site to its compiled kernel through the registry:
        raw-tuple lookup on the hot path, Workload.bind on first use."""
        cls = WORKLOADS[kind]
        dkey = cls.dispatch_key(*args, **kwargs)
        if dkey is None:
            return self.kernel_for(cls.bind(*args, **kwargs))
        key = (kind,) + dkey
        kern = self._dispatch.get(key)
        if kern is None:
            kern = self.kernel_for(cls.bind(*args, **kwargs))
            self._dispatch[key] = kern
        return kern

    def dispatch(self, kind: str, *args: Any, lazy: bool = False,
                 **kwargs: Any):
        """Serve one call of a registered workload kind: ``args`` are the
        runtime arrays (or engine :class:`~repro.core.engine.LazyBucket`
        handles), ``kwargs`` the workload parameters (flags/strides).
        ``lazy=True`` asks for the output as a LazyBucket handle —
        best-effort, see ``VortexKernel.__call__``.  This is what
        ``vortex.ops.<kind>(...)`` invokes."""
        return self.op_kernel(kind, args, kwargs)(*args, lazy=lazy)

    # -- introspection ------------------------------------------------------

    def precompile(self, wl: Workload, m_max: int, *args) -> int:
        """Precompile all buckets of ``wl`` reachable up to ``m_max``.
        Pass representative call ``args`` for workloads with outer-dim
        executable specialization (attention: any q/k/v with the serving
        batch/head layout)."""
        return self.kernel_for(wl).precompile(m_max, *args)

    def offline_stats(self) -> OfflineStats:
        # Snapshot: another serving thread's first-touch dispatch may
        # insert a kernel while we aggregate.
        stats = [k.offline_stats for k in list(self._kernels.values())]
        return OfflineStats(
            num_candidates=sum(s.num_candidates for s in stats),
            num_measured=sum(s.num_measured for s in stats),
            build_seconds=sum(s.build_seconds for s in stats),
            backends=stats[0].backends if stats else (),
        )

    def stats(self) -> dict[str, dict]:
        """Per-workload-kind serving stats: selection overhead and executable
        cache behaviour (what benchmarks/bench_workloads.py reports)."""
        out: dict[str, dict] = {}
        for kernel in list(self._kernels.values()):  # snapshot (threads)
            kind = kernel.workload.kind
            agg = out.setdefault(
                kind,
                {
                    "signatures": 0, "selects": 0, "select_table_hits": 0,
                    "select_lru_hits": 0, "select_argmin_misses": 0,
                    "select_cache_hits": 0, "select_us_sum": 0.0,
                    "table_entries": 0, "table_build_s": 0.0,
                    "calibration_seconds": 0.0, "table_swaps": 0,
                    "exec_entries": 0, "exec_hits": 0,
                    "compile_seconds": 0.0,
                    # Hot-path copy/launch accounting (DispatchStats): the
                    # padding-free contract is checkable from here — an
                    # unaligned call is exactly one launch plus its
                    # staging/unstaging boundary copies, never a jnp.pad.
                    "calls": 0, "launches": 0,
                    "aligned_calls": 0, "unaligned_calls": 0,
                    "stage_copies": 0, "unstage_copies": 0,
                    "padded_calls": 0, "traced_calls": 0,
                    "forwarded": 0, "realize_slices": 0,
                    "fallbacks": 0, "quarantined": 0,
                },
            )
            sstats = kernel.selector.stats
            cinfo = kernel.cache_info
            table = kernel.selector.table_if_built
            agg["signatures"] += 1
            agg["selects"] += sstats.selects
            agg["select_table_hits"] += sstats.table_hits
            agg["select_lru_hits"] += sstats.lru_hits
            agg["select_argmin_misses"] += sstats.argmin_misses
            agg["select_cache_hits"] += sstats.cache_hits
            agg["select_us_sum"] += sstats.select_seconds * 1e6
            agg["table_entries"] += len(table) if table is not None else 0
            agg["table_build_s"] += sstats.table_build_seconds
            agg["calibration_seconds"] += sstats.calibration_seconds
            agg["table_swaps"] += sstats.table_swaps
            agg["exec_entries"] += cinfo["entries"]
            agg["exec_hits"] += cinfo["hits"]
            agg["compile_seconds"] += cinfo["compile_seconds"]
            for key, val in kernel.dispatch_stats.as_dict().items():
                agg[key] += val
        # Engine-level calibration section — ALWAYS present, so stats
        # consumers need no feature detection.  NOTE: not a per-kind dict;
        # iterating kinds must skip this key.
        cal = self.calibrator  # lazily constructs when calibration is on
        out["calibration"] = (
            cal.stats() if cal is not None
            else {"enabled": False, "mode": "off"}
        )
        return out

    def __repr__(self) -> str:
        return (
            f"Engine({self.config!r}, kernels={len(self._kernels)}, "
            f"dispatch_keys={len(self._dispatch)})"
        )
