"""Contextvar-scoped engine sessions: ``vortex.use`` / ``current_engine``.

The engine an op or model layer serves from is an ambient *session*, not a
mutable module global: installation is a :class:`contextvars.ContextVar`,
so scopes nest, restore on exception, and are isolated per thread (and per
asyncio task) — two serving threads with different engines cannot observe
each other.  This replaces the old ``layers._ATTN_ENGINE`` global (whose
``set_attention_engine`` setter remains as a deprecation shim delegating
here).

``current_engine()`` falls back to one lazily-created process-default
engine (host-CPU :class:`EngineConfig`), so ``vortex.ops.gemm(a, b)`` works
out of the box; ``installed_engine()`` returns None instead — it is what
opt-in integrations (model layers) consult, so merely importing vortex
never reroutes a model through a default engine nobody asked for.
"""
from __future__ import annotations

import contextlib
import contextvars
import threading
from typing import TYPE_CHECKING, Iterator

if TYPE_CHECKING:  # pragma: no cover - typing only
    from repro.vortex.engine import Engine

__all__ = ["use", "current_engine", "installed_engine", "default_engine"]

_ENGINE: contextvars.ContextVar["Engine | None"] = contextvars.ContextVar(
    "vortex_engine", default=None
)

_default_engine: "Engine | None" = None
_default_lock = threading.Lock()


@contextlib.contextmanager
def use(engine: "Engine") -> Iterator["Engine"]:
    """Install ``engine`` as the session for the enclosed context::

        with vortex.use(Engine(cfg)) as eng:
            vortex.ops.gemm(a, b)          # served by eng

    Nestable (innermost wins), exception-safe (the previous session is
    restored by token on ANY exit), and thread/task-local by construction.
    """
    token = _ENGINE.set(engine)
    try:
        yield engine
    finally:
        _ENGINE.reset(token)


def install(engine: "Engine | None") -> "Engine | None":
    """Imperatively replace the current context's session, returning the
    previous one.  Prefer :func:`use`; this exists for the deprecated
    ``set_attention_engine`` shim and REPL workflows — unlike :func:`use`
    it cannot restore across an exception for you."""
    prev = _ENGINE.get()
    _ENGINE.set(engine)
    return prev


def installed_engine() -> "Engine | None":
    """The innermost explicitly-installed engine, or None.  Opt-in
    integrations (models/layers.attn_forward) use this: no installation,
    no rerouting."""
    return _ENGINE.get()


def default_engine() -> "Engine":
    """The lazily-created process-default engine (host-CPU config)."""
    global _default_engine
    if _default_engine is None:
        with _default_lock:
            if _default_engine is None:
                from repro.vortex.engine import Engine

                _default_engine = Engine()
    return _default_engine


def current_engine() -> "Engine":
    """The engine serving this context: the innermost :func:`use`
    installation, else the process-default."""
    eng = _ENGINE.get()
    return eng if eng is not None else default_engine()
