"""CompiledOp: the one generic handle every workload kind is served by.

``vortex.compile(workload)`` returns a CompiledOp; ``vortex.ops.<kind>``
routes through one per call-site signature.  The handle is a thin, stable
facade over :class:`repro.core.engine.VortexKernel` — callers hold ONE
object with ``__call__`` / ``precompile`` / ``select`` / ``stats`` and
never touch engine internals, so new workload kinds and future multi-device
kernels slot in behind it without API changes.
"""
from __future__ import annotations

from typing import TYPE_CHECKING

from repro.core.engine import VortexKernel
from repro.core.selector import Selection
from repro.core.workloads import Workload

if TYPE_CHECKING:  # pragma: no cover - typing only
    from repro.vortex.engine import Engine

__all__ = ["CompiledOp"]


class CompiledOp:
    """One workload signature, compiled sample-free, bound to an engine.

    * ``op(*args)``             — dynamic-shape dispatch (select → bucket →
                                  cached executable → unpad),
    * ``op.select(m)``          — the Selection the engine would serve at
                                  extent ``m`` (strategy, backend, bucket),
    * ``op.bucket(m)``          — the padded dynamic extent at ``m`` (what
                                  serving layers quantize to),
    * ``op.buckets(m_max)``     — every distinct bucket reachable up to
                                  ``m_max`` (from the lattice breakpoints,
                                  not from shape samples),
    * ``op.precompile(m_max)``  — warm every reachable executable,
    * ``op.stats()``            — selection + executable-cache accounting.
    """

    __slots__ = ("_engine", "_kernel")

    def __init__(self, engine: "Engine", kernel: VortexKernel):
        self._engine = engine
        self._kernel = kernel

    # -- identity -----------------------------------------------------------

    @property
    def engine(self) -> "Engine":
        return self._engine

    @property
    def kernel(self) -> VortexKernel:
        """The underlying compiled kernel (selector + executable cache)."""
        return self._kernel

    @property
    def workload(self) -> Workload:
        return self._kernel.workload

    @property
    def kind(self) -> str:
        return self._kernel.workload.kind

    # -- serving ------------------------------------------------------------

    def __call__(self, *args, lazy: bool = False):
        return self._kernel(*args, lazy=lazy)

    def select(self, m: int) -> Selection:
        return self._kernel.select(m)

    def bucket(self, m: int) -> int:
        """The padded dynamic extent an extent of ``m`` is served at
        (``Workload.dynamic_bucket`` of the Selection: padded_m for
        GEMM-view workloads, the kv bucket for decode attention)."""
        sel = self._kernel.select(max(m, 1))
        return self._kernel.workload.dynamic_bucket(sel)

    def buckets(self, m_max: int) -> list[int]:
        """All distinct padded extents reachable for m in [1, m_max]."""
        return self._kernel.selector.buckets_upto(m_max)

    def precompile(
        self, m_max: int, *args, max_workers: int | None = None
    ) -> int:
        """Warm every executable bucket reachable up to ``m_max``; pass
        representative ``args`` for workloads whose executables specialize
        on outer dims (attention: any q/k/v with the serving batch/head
        layout).  Raises :class:`repro.core.engine.PrecompileError` naming
        the failing Selection if a bucket does not build."""
        return self._kernel.precompile(m_max, *args, max_workers=max_workers)

    # -- introspection ------------------------------------------------------

    def stats(self) -> dict:
        """Selection-path, executable-cache and hot-path copy/launch
        accounting for this op.  ``dispatch`` carries the padding-free
        contract's observables: launches per call, staging/unstaging copies
        for unaligned extents, how many calls fell back to the zero-pad
        reference path (``padded_calls`` — 0 in steady-state serving), and
        the lazy-handle chain counters — ``forwarded`` (LazyBucket operands
        consumed bucket-to-bucket, no boundary copy) and ``realize_slices``
        (deferred output slices forced by non-engine consumers)."""
        k = self._kernel
        return {
            "kind": self.kind,
            "signature": self.workload.signature,
            "select": k.select_stats,
            "exec": k.cache_info,
            "dispatch": k.dispatch_stats.as_dict(),
            "offline": k.offline_stats,
        }

    def __repr__(self) -> str:
        return (
            f"CompiledOp(kind={self.kind!r}, "
            f"signature={self.workload.signature!r})"
        )
