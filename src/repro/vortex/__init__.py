"""repro.vortex — the ONE public API over the sample-free pipeline.

Everything a caller does with Vortex goes through four ideas (DESIGN.md
§ Public API):

* **Handles** — :func:`compile` returns a :class:`CompiledOp`: one generic
  object per workload signature with ``__call__`` / ``precompile`` /
  ``select`` / ``bucket`` / ``stats``.  No per-operator engine methods.
* **Registry-driven ops** — :mod:`vortex.ops` exposes every
  ``@register_workload`` kind as ``vortex.ops.<kind>``; registering a
  workload is the ONLY step to get a served op (no engine edits).
* **Sessions** — an :class:`Engine` (configured by the frozen
  :class:`EngineConfig`) is installed per-context with :func:`use`;
  installation is contextvar-scoped: nestable, exception-safe,
  thread-isolated.  :func:`current_engine` resolves the ambient session
  (falling back to a lazy process default); :func:`installed_engine` is
  the opt-in variant model layers consult.
* **Deprecation shims** — the old surface (``VortexEngine.gemm/...``,
  ``VortexGemm``, ``layers.set_attention_engine``) delegates here and
  warns with :class:`VortexDeprecationWarning` (errors in tier-1 CI).

Quickstart::

    from repro import vortex
    from repro.vortex import Engine, EngineConfig

    y = vortex.ops.gemm(a, b)                       # default session
    with vortex.use(Engine(EngineConfig(hardware="tpu_v5e"))) as eng:
        op = vortex.compile("gemm", M=None, N=768, K=2304)
        op.precompile(4096)                          # warm every bucket
        y = op(a, b)                                 # bisect + cached exec
"""
from __future__ import annotations

import importlib
from typing import TYPE_CHECKING, Any

# Only stdlib-light leaves load eagerly: the session contextvar and the
# deprecation category.  Everything that pulls the core pipeline (Engine,
# handles, ops, the workload registry) resolves lazily via PEP 562 below,
# so broadly-imported modules (models/layers.py consults the session on
# every attention call) can `from repro.vortex import session` without
# dragging jax/numpy-heavy engine machinery into import time.
from repro.vortex._deprecation import VortexDeprecationWarning  # noqa: F401
from repro.vortex.session import (  # noqa: F401
    current_engine,
    default_engine,
    installed_engine,
    use,
)

if TYPE_CHECKING:  # pragma: no cover - typing only
    from repro.vortex.engine import Engine
    from repro.vortex.handle import CompiledOp
    from repro.core.workloads import Workload

__all__ = [
    "CompiledOp",
    "Engine",
    "EngineConfig",
    "LazyBucket",
    "VortexDeprecationWarning",
    "WORKLOADS",
    "Workload",
    "compile",
    "current_engine",
    "default_engine",
    "installed_engine",
    "lazy_map",
    "make_workload",
    "ops",
    "pow2_bucket",
    "register_workload",
    "use",
]

# name -> (module, attr); attr None = the module itself (vortex.ops).
_LAZY: dict[str, tuple[str, str | None]] = {
    "CompiledOp": ("repro.vortex.handle", "CompiledOp"),
    "Engine": ("repro.vortex.engine", "Engine"),
    "EngineConfig": ("repro.vortex.config", "EngineConfig"),
    "LazyBucket": ("repro.core.engine", "LazyBucket"),
    "lazy_map": ("repro.core.engine", "lazy_map"),
    "pow2_bucket": ("repro.vortex.engine", "pow2_bucket"),
    "ops": ("repro.vortex.ops", None),
    "WORKLOADS": ("repro.core.workloads", "WORKLOADS"),
    "Workload": ("repro.core.workloads", "Workload"),
    "make_workload": ("repro.core.workloads", "make_workload"),
    "register_workload": ("repro.core.workloads", "register_workload"),
}


def __getattr__(name: str):
    try:
        mod_name, attr = _LAZY[name]
    except KeyError:
        raise AttributeError(
            f"module {__name__!r} has no attribute {name!r}"
        ) from None
    mod = importlib.import_module(mod_name)
    value = mod if attr is None else getattr(mod, attr)
    globals()[name] = value  # cache: next access skips __getattr__
    return value


def __dir__() -> list[str]:
    return sorted(set(globals()) | set(_LAZY))


def compile(
    workload: "Workload | str",
    *,
    engine: "Engine | None" = None,
    **params: Any,
) -> "CompiledOp":
    """Compile a workload signature on the ambient (or given) session.

    ``workload`` is a Workload instance or a registered kind name with the
    workload parameters as keywords::

        op = vortex.compile(GemmWorkload(M=None, N=768, K=2304))
        op = vortex.compile("attention", seq=None, head_dim=64)

    Sample-free: nothing about the dynamic extent is consulted here — the
    returned handle serves EVERY runtime extent from one scored lattice.
    """
    eng = engine if engine is not None else current_engine()
    return eng.compile(workload, **params)
