"""Repro-owned deprecation machinery.

All shims in this codebase warn with :class:`VortexDeprecationWarning` (a
``DeprecationWarning`` subclass) rather than the stdlib category directly,
so CI can turn *our* deprecations into errors (tier-1 ``filterwarnings``)
without also erroring on unrelated upstream deprecations from jax/numpy.
"""
from __future__ import annotations

import warnings

__all__ = ["VortexDeprecationWarning", "warn_deprecated"]


class VortexDeprecationWarning(DeprecationWarning):
    """A deprecated repro surface was used; migrate to ``repro.vortex``."""


def warn_deprecated(old: str, new: str, *, stacklevel: int = 3) -> None:
    warnings.warn(
        f"{old} is deprecated and will be removed; use {new} instead "
        "(see DESIGN.md § Public API for the migration)",
        VortexDeprecationWarning,
        stacklevel=stacklevel,
    )
