"""Deprecation shims for the pre-`repro.vortex` public surface.

Importable from their historical home (``repro.core.engine`` re-exports
via module ``__getattr__``).  The shims are THIN: they delegate to exactly
the registry-driven machinery the new API uses, so outputs are
bit-identical and the dispatch/executable cache keys are the same — a
caller migrating call-site by call-site never double-compiles.

Deprecation policy (DESIGN.md § Public API): shims warn with
:class:`VortexDeprecationWarning` for one release cycle; tier-1 CI turns
that category into an error so internal callers cannot regress onto them.
"""
from __future__ import annotations

from typing import Any

import jax

from repro.core.analyzer import Profiler
from repro.core.engine import VortexKernel
from repro.core.hardware import HardwareSpec
from repro.core.workloads import GemmWorkload, Workload
from repro.vortex._deprecation import warn_deprecated
from repro.vortex.config import EngineConfig
from repro.vortex.engine import Engine

__all__ = ["VortexEngine", "VortexGemm"]


class VortexEngine(Engine):
    """Deprecated per-operator face of :class:`repro.vortex.Engine`.

    The engine itself lives on; what is deprecated is the hard-coded
    one-method-per-kind surface (``gemm``/``attention``/``conv2d``) — use
    ``vortex.ops.<kind>`` / ``engine.dispatch(kind, ...)``, which serve
    ANY registered workload with no engine edits.
    """

    def __init__(
        self,
        hardware: str = "host_cpu",
        profiler: Profiler | None = None,
        empirical_levels: tuple[int, ...] | None = None,
        backends: tuple[str, ...] | None = None,
        impl: str = "xla",
        num_cores: int = 1,
        interpret: bool = True,
    ):
        super().__init__(
            EngineConfig(
                hardware=hardware,
                backends=backends,
                impl=impl,
                interpret=interpret,
                num_cores=num_cores,
                empirical_levels=empirical_levels,
            ),
            profiler=profiler,
        )

    # -- deprecated per-op entry points ------------------------------------

    def gemm(self, a: jax.Array, b: jax.Array) -> jax.Array:
        """C[M,N] = A[M,K] @ B[K,N] with dynamic M."""
        warn_deprecated("VortexEngine.gemm", "vortex.ops.gemm")
        return self.dispatch("gemm", a, b)

    def attention(
        self,
        q: jax.Array,
        k: jax.Array,
        v: jax.Array,
        *,
        causal: bool = True,
        window: int | None = None,
        softcap: float | None = None,
    ) -> jax.Array:
        """Flash attention with dynamic sequence length (causal only)."""
        warn_deprecated("VortexEngine.attention", "vortex.ops.attention")
        return self.dispatch(
            "attention", q, k, v, causal=causal, window=window,
            softcap=softcap,
        )

    def conv2d(
        self, x: jax.Array, w: jax.Array, *, stride: int = 1
    ) -> jax.Array:
        """Conv2D (VALID): x (b, h, w, cin); w (kh, kw, cin, cout)."""
        warn_deprecated("VortexEngine.conv2d", "vortex.ops.conv2d")
        return self.dispatch("conv2d", x, w, stride=stride)

    def gemm_for(self, n: int, k: int) -> VortexKernel:
        warn_deprecated(
            "VortexEngine.gemm_for", 'engine.compile("gemm", ...).kernel'
        )
        return self.kernel_for(GemmWorkload(M=None, N=n, K=k))


class VortexGemm(VortexKernel):
    """Deprecated name for a GEMM-bound :class:`VortexKernel`.

    Exactly VortexKernel over a GemmWorkload — kept so old GEMM-only
    callers (serving scripts, notebooks) keep importing; new code uses
    ``vortex.compile(GemmWorkload(...))`` or VortexKernel directly.
    """

    def __init__(self, hw: HardwareSpec, wl: Workload, *args: Any, **kw: Any):
        warn_deprecated(
            "VortexGemm", "vortex.compile(GemmWorkload(...)) or VortexKernel"
        )
        super().__init__(hw, wl, *args, **kw)
