"""--arch <id> registry: maps architecture ids to configs + shape skips.

``cell_supported(arch, shape)`` encodes the assignment's skip rules:
  * ``long_500k`` needs sub-quadratic attention (SSM / hybrid / SWA),
  * decode shapes are skipped for encoder-only archs (none assigned here;
    whisper's decoder is autoregressive so it keeps decode).
"""
from __future__ import annotations

import importlib

from repro.models.config import SHAPES, ModelConfig, ShapeSpec

__all__ = [
    "ARCH_IDS",
    "get_config",
    "get_smoke_config",
    "cell_supported",
    "all_cells",
]

_MODULES = {
    "gemma2-9b": "repro.configs.gemma2_9b",
    "phi4-mini-3.8b": "repro.configs.phi4_mini_3_8b",
    "h2o-danube-3-4b": "repro.configs.h2o_danube3_4b",
    "starcoder2-15b": "repro.configs.starcoder2_15b",
    "deepseek-v2-236b": "repro.configs.deepseek_v2_236b",
    "granite-moe-1b-a400m": "repro.configs.granite_moe_1b",
    "internvl2-26b": "repro.configs.internvl2_26b",
    "whisper-small": "repro.configs.whisper_small",
    "jamba-v0.1-52b": "repro.configs.jamba_v01_52b",
    "falcon-mamba-7b": "repro.configs.falcon_mamba_7b",
    "paper-gpt2-124m": "repro.configs.paper_gpt2",
}

ARCH_IDS: tuple[str, ...] = tuple(
    k for k in _MODULES if k != "paper-gpt2-124m"
)


def get_config(arch: str) -> ModelConfig:
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_MODULES)}")
    return importlib.import_module(_MODULES[arch]).CONFIG


def get_smoke_config(arch: str) -> ModelConfig:
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_MODULES)}")
    return importlib.import_module(_MODULES[arch]).SMOKE


def cell_supported(arch: str, shape: str) -> tuple[bool, str]:
    """(supported, reason-if-not) for one (arch x shape) cell."""
    cfg = get_config(arch)
    spec = SHAPES[shape]
    if spec.name == "long_500k" and not cfg.sub_quadratic:
        return False, (
            "long_500k requires sub-quadratic attention; "
            f"{arch} is full-attention (see DESIGN.md §4)"
        )
    return True, ""


def all_cells() -> list[tuple[str, str]]:
    """The 40 assigned (arch, shape) cells, including skipped ones."""
    return [(a, s) for a in ARCH_IDS for s in SHAPES]
