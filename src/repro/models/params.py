"""Parameter schema: a single source of truth for shapes, shardings, init.

Every architecture's parameter tree is *derived* from its
:class:`~repro.models.config.ModelConfig` as a nested dict of
:class:`ParamDef` (shape + dtype + logical axes + init kind).  From the same
schema we materialize:

  * real initialized params (smoke tests / examples),
  * abstract ``ShapeDtypeStruct`` params (the multi-pod dry-run: no bytes
    allocated for the 236B configs),
  * the matching ``PartitionSpec`` tree (pjit in_shardings).

Keeping these three views in one schema is what guarantees the dry-run's
shardings match what training would actually use.
"""
from __future__ import annotations

import dataclasses
import math
import zlib
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import LayerSpec, ModelConfig
from repro.models.partitioning import AxisRules
from jax.sharding import PartitionSpec as P

__all__ = [
    "ParamDef",
    "model_schema",
    "init_params",
    "abstract_params",
    "param_pspecs",
    "count_params",
]


@dataclasses.dataclass(frozen=True)
class ParamDef:
    """Declarative definition of one parameter tensor."""

    shape: tuple[int, ...]
    logical: tuple[str | None, ...]
    init: str = "normal"  # normal | zeros | ones | ssm_a
    dtype: str = "bfloat16"
    scale_axis: int = 0  # fan-in axis for the normal init scale

    def __post_init__(self) -> None:
        assert len(self.shape) == len(self.logical), (self.shape, self.logical)


Schema = dict[str, Any]  # nested dict of ParamDef


def _attn_schema(cfg: ModelConfig, spec: LayerSpec) -> Schema:
    d, hd = cfg.d_model, cfg.resolved_head_dim
    qdim, kvdim = cfg.n_heads * hd, cfg.n_kv_heads * hd
    dt = cfg.dtype
    s: Schema = {
        "wq": ParamDef((d, qdim), ("embed", "q_heads"), dtype=dt),
        "wk": ParamDef((d, kvdim), ("embed", "kv_heads"), dtype=dt),
        "wv": ParamDef((d, kvdim), ("embed", "kv_heads"), dtype=dt),
        "wo": ParamDef((qdim, d), ("q_heads", "embed"), dtype=dt),
    }
    if spec.cross_attn:
        s.update(
            {
                "xq": ParamDef((d, qdim), ("embed", "q_heads"), dtype=dt),
                "xk": ParamDef((d, kvdim), ("embed", "kv_heads"), dtype=dt),
                "xv": ParamDef((d, kvdim), ("embed", "kv_heads"), dtype=dt),
                "xo": ParamDef((qdim, d), ("q_heads", "embed"), dtype=dt),
                "norm_x": ParamDef((d,), (None,), init="ones", dtype=dt),
            }
        )
    return s


def _mla_schema(cfg: ModelConfig) -> Schema:
    m = cfg.mla
    assert m is not None
    d, h = cfg.d_model, cfg.n_heads
    dt = cfg.dtype
    qk = m.qk_nope_dim + m.qk_rope_dim
    return {
        "wdq": ParamDef((d, m.q_lora_rank), ("embed", None), dtype=dt),
        "wuq": ParamDef((m.q_lora_rank, h * qk), (None, "q_heads"), dtype=dt),
        "q_norm": ParamDef((m.q_lora_rank,), (None,), init="ones", dtype=dt),
        "wdkv": ParamDef(
            (d, m.kv_lora_rank + m.qk_rope_dim), ("embed", None), dtype=dt
        ),
        "kv_norm": ParamDef((m.kv_lora_rank,), (None,), init="ones", dtype=dt),
        "wuk": ParamDef(
            (m.kv_lora_rank, h * m.qk_nope_dim), (None, "q_heads"), dtype=dt
        ),
        "wuv": ParamDef(
            (m.kv_lora_rank, h * m.v_head_dim), (None, "q_heads"), dtype=dt
        ),
        "wo": ParamDef((h * m.v_head_dim, d), ("q_heads", "embed"), dtype=dt),
    }


def _mamba_schema(cfg: ModelConfig) -> Schema:
    s = cfg.ssm
    assert s is not None
    d = cfg.d_model
    dtr = s.dt_rank or d // 16
    dt = cfg.dtype
    return {
        "in_proj": ParamDef((d, 2 * s.d_inner), ("embed", "ssm_inner"), dtype=dt),
        "conv_w": ParamDef((s.d_conv, s.d_inner), (None, "ssm_inner"), dtype=dt),
        "conv_b": ParamDef((s.d_inner,), ("ssm_inner",), init="zeros", dtype=dt),
        "x_proj": ParamDef(
            (s.d_inner, dtr + 2 * s.d_state), ("ssm_inner", None), dtype=dt
        ),
        "dt_proj": ParamDef((dtr, s.d_inner), (None, "ssm_inner"), dtype=dt),
        "dt_bias": ParamDef((s.d_inner,), ("ssm_inner",), init="zeros", dtype=dt),
        # A_log/D stay f32: the recurrence decay must not round to 1.0 in bf16.
        "A_log": ParamDef(
            (s.d_inner, s.d_state), ("ssm_inner", None), init="ssm_a",
            dtype="float32",
        ),
        "D": ParamDef((s.d_inner,), ("ssm_inner",), init="ones", dtype="float32"),
        "out_proj": ParamDef((s.d_inner, d), ("ssm_inner", "embed"), dtype=dt),
    }


def _mlp_schema(cfg: ModelConfig) -> Schema:
    d, f = cfg.d_model, cfg.d_ff
    dt = cfg.dtype
    s: Schema = {
        "w_in": ParamDef((d, f), ("embed", "ff"), dtype=dt),
        "w_out": ParamDef((f, d), ("ff", "embed"), dtype=dt),
    }
    if cfg.act in ("swiglu", "geglu"):
        s["w_gate"] = ParamDef((d, f), ("embed", "ff"), dtype=dt)
    return s


def _moe_schema(cfg: ModelConfig) -> Schema:
    m = cfg.moe
    assert m is not None
    d, fe = cfg.d_model, m.d_ff_expert
    dt = cfg.dtype
    s: Schema = {
        # Router in f32: tiny, and routing decisions are precision-sensitive.
        "router": ParamDef((d, m.num_experts), ("embed", None), dtype="float32"),
        "w_in": ParamDef((m.num_experts, d, fe), ("expert", "embed", None), dtype=dt),
        "w_out": ParamDef((m.num_experts, fe, d), ("expert", None, "embed"), dtype=dt),
    }
    if cfg.act in ("swiglu", "geglu"):
        s["w_gate"] = ParamDef(
            (m.num_experts, d, fe), ("expert", "embed", None), dtype=dt
        )
    if m.num_shared:
        f_sh = m.num_shared * fe
        s["shared_in"] = ParamDef((d, f_sh), ("embed", "ff"), dtype=dt)
        s["shared_out"] = ParamDef((f_sh, d), ("ff", "embed"), dtype=dt)
        if cfg.act in ("swiglu", "geglu"):
            s["shared_gate"] = ParamDef((d, f_sh), ("embed", "ff"), dtype=dt)
    return s


def _layer_schema(cfg: ModelConfig, spec: LayerSpec) -> Schema:
    dt = cfg.dtype
    s: Schema = {
        "norm_mixer": ParamDef((cfg.d_model,), (None,), init="ones", dtype=dt),
    }
    if spec.mixer == "attn":
        s["attn"] = _attn_schema(cfg, spec)
    elif spec.mixer == "mla":
        s["mla"] = _mla_schema(cfg)
    elif spec.mixer == "mamba":
        s["mamba"] = _mamba_schema(cfg)
    else:
        raise ValueError(spec.mixer)
    if spec.mlp != "none":
        s["norm_mlp"] = ParamDef(
            (cfg.d_model,), (None,), init="ones", dtype=dt
        )
        s["mlp" if spec.mlp == "dense" else "moe"] = (
            _mlp_schema(cfg) if spec.mlp == "dense" else _moe_schema(cfg)
        )
    return s


def _stack(schema: Schema, n: int) -> Schema:
    """Prepend a stacked 'layers' axis of size n to every ParamDef."""
    out: Schema = {}
    for k, v in schema.items():
        if isinstance(v, ParamDef):
            out[k] = ParamDef(
                shape=(n,) + v.shape,
                logical=("layers",) + v.logical,
                init=v.init,
                dtype=v.dtype,
                scale_axis=v.scale_axis + 1,
            )
        else:
            out[k] = _stack(v, n)
    return out


def model_schema(cfg: ModelConfig) -> Schema:
    """Full parameter schema for one architecture."""
    dt = cfg.dtype
    vp = cfg.vocab_padded
    s: Schema = {
        "embed": ParamDef((vp, cfg.d_model), ("vocab", "embed"), dtype=dt),
        "final_norm": ParamDef((cfg.d_model,), (None,), init="ones", dtype=dt),
    }
    if not cfg.tie_embeddings:
        s["lm_head"] = ParamDef(
            (cfg.d_model, vp), ("embed", "vocab"), dtype=dt
        )
    for p, spec in enumerate(cfg.pattern):
        s[f"pos{p}"] = _stack(_layer_schema(cfg, spec), cfg.n_groups)
    if cfg.encoder_decoder:
        enc_layer = _layer_schema(
            cfg, LayerSpec(mixer="attn", mlp="dense")
        )
        s["encoder"] = {
            "layers": _stack(enc_layer, cfg.n_encoder_layers),
            "final_norm": ParamDef((cfg.d_model,), (None,), init="ones", dtype=dt),
        }
    return s


def _leaves(schema: Schema, prefix: str = "") -> list[tuple[str, ParamDef]]:
    out = []
    for k, v in sorted(schema.items()):
        path = f"{prefix}/{k}" if prefix else k
        if isinstance(v, ParamDef):
            out.append((path, v))
        else:
            out.extend(_leaves(v, path))
    return out


def _map_schema(schema: Schema, fn: Callable[[str, ParamDef], Any],
                prefix: str = "") -> Any:
    out = {}
    for k, v in schema.items():
        path = f"{prefix}/{k}" if prefix else k
        out[k] = fn(path, v) if isinstance(v, ParamDef) else _map_schema(
            v, fn, path
        )
    return out


def _init_one(path: str, d: ParamDef, key: jax.Array) -> jax.Array:
    dtype = jnp.dtype(d.dtype)
    if d.init == "zeros":
        return jnp.zeros(d.shape, dtype)
    if d.init == "ones":
        return jnp.ones(d.shape, dtype)
    if d.init == "ssm_a":
        # Mamba S4D-real init: A = -(1..d_state), broadcast over d_inner.
        n = d.shape[-1]
        a = jnp.broadcast_to(jnp.arange(1, n + 1, dtype=jnp.float32), d.shape)
        return jnp.log(a).astype(dtype)
    fan_in = d.shape[d.scale_axis] if d.scale_axis < len(d.shape) else d.shape[-1]
    # Fold the path into the key so every tensor gets an independent stream.
    # crc32, NOT hash(): str hashing is PYTHONHASHSEED-randomized, which made
    # init_params emit different weights in every process (and bit-identity
    # tests flake on the draws that land on rounding boundaries).
    sub = jax.random.fold_in(key, zlib.crc32(path.encode()) & 0x7FFFFFFF)
    std = 1.0 / math.sqrt(max(fan_in, 1))
    return (jax.random.normal(sub, d.shape, jnp.float32) * std).astype(dtype)


def init_params(cfg: ModelConfig, key: jax.Array) -> Any:
    schema = model_schema(cfg)
    return _map_schema(schema, lambda p, d: _init_one(p, d, key))


def abstract_params(cfg: ModelConfig) -> Any:
    """ShapeDtypeStruct tree — the dry-run's no-allocation parameter stand-in."""
    schema = model_schema(cfg)
    return _map_schema(
        schema,
        lambda p, d: jax.ShapeDtypeStruct(d.shape, jnp.dtype(d.dtype)),
    )


def param_pspecs(cfg: ModelConfig, rules: AxisRules) -> Any:
    schema = model_schema(cfg)
    return _map_schema(
        schema, lambda p, d: rules.spec_for(d.shape, d.logical)
    )


def count_params(cfg: ModelConfig) -> int:
    """Exact parameter count from the schema (vs config.param_count()'s
    closed-form estimate; tests assert they agree to ~1%)."""
    return sum(int(np.prod(d.shape)) for _, d in _leaves(model_schema(cfg)))
