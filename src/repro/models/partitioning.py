"""Logical-axis partitioning rules (MaxText-style) for all architectures.

Parameters and activations carry *logical* axis names; a rules table maps
them onto the physical mesh axes ("pod", "data", "model").  GSPMD handles
non-divisible dims by padding, but the rules below prefer divisible mappings
(e.g. replicating a 12-head axis rather than unevenly splitting it 16 ways).

Parallelism summary (DESIGN.md §5):
  DP   — batch over ("pod", "data")
  TP   — heads / ff / vocab / experts over "model" (Megatron column/row)
  EP   — expert axis over "model"
  FSDP — the non-TP weight axis over "data" for archs with fsdp=True
  SP   — sequence over "model" at layer boundaries (activation constraint)
"""
from __future__ import annotations

import dataclasses
from typing import Any, Mapping, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = [
    "AxisRules",
    "logical_to_pspec",
    "make_rules",
    "spec_tree_to_shardings",
    "constrain",
]

# Logical axis names used by the param schema / activation constraints.
#   batch     activation batch dim
#   seq       activation sequence dim (SP at layer boundaries)
#   embed     d_model axis of weights (FSDP axis when enabled)
#   q_heads   flattened n_heads*head_dim weight axis (TP)
#   kv_heads  flattened n_kv_heads*head_dim weight axis (TP if divisible)
#   heads_act per-head activation axis
#   ff        feed-forward hidden axis (TP)
#   vocab     vocabulary axis (TP)
#   expert    MoE expert axis (EP)
#   layers    stacked-layer leading axis (never sharded)
#   ssm_inner mamba d_inner axis (TP)
#   none      explicitly replicated


@dataclasses.dataclass(frozen=True)
class AxisRules:
    """Mapping from logical axis names to physical mesh axes."""

    rules: Mapping[str, Any]
    mesh_axes: tuple[str, ...]
    axis_sizes: Mapping[str, int] = dataclasses.field(default_factory=dict)
    mesh: Any = None  # concrete Mesh for NamedSharding constraints

    def resolve(self, logical: Sequence[str | None]) -> P:
        out = []
        for name in logical:
            if name is None or name == "none":
                out.append(None)
            else:
                out.append(self.rules.get(name))
        # Trim trailing Nones for a canonical spec.
        while out and out[-1] is None:
            out.pop()
        return P(*out)

    def _extent(self, part: Any) -> int:
        names = part if isinstance(part, tuple) else (part,)
        n = 1
        for name in names:
            n *= self.axis_sizes.get(name, 1)
        return n

    def sanitize(self, spec: P, shape: Sequence[int]) -> P:
        """Drop sharded axes that do not divide the dim evenly — jit input
        shardings must divide; a dropped axis means 'replicate that dim'.
        Also drops repeated uses of one mesh axis (a spec may name each
        axis at most once)."""
        parts = list(spec) + [None] * (len(shape) - len(spec))
        used: set = set()
        for i, part in enumerate(parts):
            if part is None:
                continue
            names = list(part) if isinstance(part, tuple) else [part]
            # Degrade tuple axes gracefully: ('pod','data') on a dim of 16
            # keeps ('data',) rather than dropping sharding entirely (which
            # replicated whole residual streams on the multi-pod mesh).
            while names and (
                shape[i] % self._extent(tuple(names))
                or any(n in used for n in names)
            ):
                names.pop(0)
            if not names:
                parts[i] = None
            else:
                parts[i] = tuple(names) if len(names) > 1 else names[0]
                used.update(names)
        while parts and parts[-1] is None:
            parts.pop()
        return P(*parts)

    def spec_for(self, shape: Sequence[int],
                 logical: Sequence[str | None]) -> P:
        return self.sanitize(self.resolve(logical), shape)


def make_rules(
    mesh: Mesh,
    *,
    fsdp: bool = False,
    n_heads: int = 0,
    n_kv_heads: int = 0,
) -> AxisRules:
    """Build the rules table for one (mesh, architecture) pair.

    ``batch`` spans every data-parallel axis present ("pod" and "data").
    Head *activation* axes shard only when the head count divides the model
    axis; the flattened weight axes always shard (they are large multiples
    of 128).
    """
    axes = mesh.axis_names
    data_axes = tuple(a for a in ("pod", "data") if a in axes)
    batch = data_axes if len(data_axes) > 1 else (
        data_axes[0] if data_axes else None
    )
    model = "model" if "model" in axes else None
    model_size = mesh.shape["model"] if model else 1
    heads_act = model if n_heads and n_heads % max(model_size, 1) == 0 else None
    kv_heads_act = (
        model if n_kv_heads and n_kv_heads % max(model_size, 1) == 0 else None
    )
    rules = {
        "batch": batch,
        "seq": model,           # sequence parallelism at layer boundaries
        "embed": "data" if (fsdp and "data" in axes) else None,
        "q_heads": model,
        "kv_heads": model,
        "heads_act": heads_act,
        "kv_heads_act": kv_heads_act,
        "ff": model,
        "vocab": model,
        "expert": model,
        "ssm_inner": model,
        "layers": None,
    }
    return AxisRules(
        rules=rules,
        mesh_axes=tuple(axes),
        axis_sizes=dict(mesh.shape),
        mesh=mesh if isinstance(mesh, Mesh) else None,
    )


def logical_to_pspec(rules: AxisRules, logical: Sequence[str | None]) -> P:
    return rules.resolve(logical)


def spec_tree_to_shardings(mesh: Mesh, spec_tree: Any) -> Any:
    """PartitionSpec pytree -> NamedSharding pytree."""
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        spec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )


def constrain(x: jax.Array, rules: AxisRules, *logical: str | None) -> jax.Array:
    """with_sharding_constraint via logical names.

    Resolves to a NamedSharding against the rules' concrete mesh — a bare
    PartitionSpec needs an ambient ``with mesh:`` context and silently
    raising/no-op'ing here is how sharding bugs hide.  The spec is sanitized
    against the value's shape (non-divisible dims replicate).
    """
    if rules.mesh is None:
        return x
    spec = rules.sanitize(rules.resolve(logical), x.shape)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(rules.mesh, spec)
    )
