"""Functional layer library covering all 10 assigned architectures.

Every mixer/MLP is a pure function ``(params, x, ...) -> (y, new_cache)``
with three modes:

  * ``train``   — full sequence, no cache,
  * ``prefill`` — full sequence, emits a decode cache of length ``cache_len``,
  * ``decode``  — one new token against an existing cache at ``pos``.

Attention uses the flash-style chunked online-softmax (kernels/ref.py) so the
compiled memory stays linear in sequence length; on real TPU the Pallas
flash kernel (kernels/attention.py) is the drop-in replacement, with block
sizes drawn from the Vortex lattice (core/).

Sharding is expressed through logical-axis constraints (partitioning.py);
layers never mention physical mesh axes.
"""
from __future__ import annotations

import contextlib
import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.kernels.ref import chunked_attention, ref_attention
from repro.models.config import LayerSpec, ModelConfig
from repro.models.partitioning import AxisRules, constrain
from repro.vortex import _deprecation, session

__all__ = [
    "rmsnorm",
    "apply_rope",
    "attn_forward",
    "attn_forward_lazy",
    "block_forward_lazy",
    "lazy_matmul",
    "mla_forward",
    "mamba_forward",
    "mlp_forward",
    "mlp_forward_lazy",
    "moe_forward",
    "set_attention_engine",
    "get_attention_engine",
    "attention_engine",
    "ATTN_CHUNK",
]

# KV-chunk length of the flash-style attention scan; overridable by the
# Vortex autoconfig (core/autoconfig.py picks it from the cost model).
ATTN_CHUNK = 1024

# Optional vortex-engine routing for the serving attention paths: when a
# serving harness installs an Engine session (`with vortex.use(engine):`),
# prefill self-attention (causal or not), non-causal encoder attention and
# single-token decode attention all dispatch through the sample-free
# bucketed pipeline instead of the inline chunked scan / cache mask.  The
# steady-state dispatch is constant time: the engine resolves the call site
# from a raw shape tuple (Workload.dispatch_key) and the selector serves
# unseen sequence lengths from the offline-materialized breakpoint table
# (core/selection_table.py), so a high-cardinality stream of prefill
# lengths costs a bisect per call — no per-call workload construction, no
# argmin.  The installation is contextvar-scoped (repro/vortex/session.py):
# nestable, exception-safe, thread-isolated; no session installed keeps the
# inline path (training, sharded runs, and every existing caller are
# unaffected — the lazily-created *default* engine never reroutes layers).
#
# set_attention_engine / get_attention_engine / attention_engine are the
# deprecated pre-session surface; they delegate to the contextvar.


def set_attention_engine(engine):
    """Deprecated: install (or clear, with None) the engine used by
    :func:`attn_forward` for causal prefill attention; returns the previous
    one.  Use ``vortex.use(engine)`` — scoped, exception-safe, and local to
    the calling thread (this shim shares its semantics: it no longer
    mutates other threads' routing)."""
    _deprecation.warn_deprecated(
        "models.layers.set_attention_engine",
        "repro.vortex.use(engine) — NOTE the shim now writes the "
        "context/thread-LOCAL session (no longer a process-wide global): "
        "multi-threaded harnesses must install per serving thread",
    )
    return session.install(engine)


def get_attention_engine():
    """Deprecated: the engine :func:`attn_forward` currently routes
    through, or None.  Use ``repro.vortex.installed_engine()``."""
    _deprecation.warn_deprecated(
        "models.layers.get_attention_engine",
        "repro.vortex.installed_engine()",
    )
    return session.installed_engine()


@contextlib.contextmanager
def attention_engine(engine):
    """Deprecated: scoped engine install.  Use ``vortex.use(engine)`` —
    identical semantics (this shim delegates to it)."""
    _deprecation.warn_deprecated(
        "models.layers.attention_engine", "repro.vortex.use(engine)"
    )
    with session.use(engine):
        yield engine


def rmsnorm(x: jax.Array, w: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps)).astype(x.dtype) * w


def layernorm(x: jax.Array, w: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    return ((xf - mu) * jax.lax.rsqrt(var + eps)).astype(x.dtype) * w


def norm(x: jax.Array, w: jax.Array, cfg: ModelConfig) -> jax.Array:
    return rmsnorm(x, w) if cfg.norm == "rmsnorm" else layernorm(x, w)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope_tables(
    positions: jax.Array, dim: int, theta: float
) -> tuple[jax.Array, jax.Array]:
    """(..., dim/2) cos/sin tables for integer positions."""
    half = dim // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freq
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(
    x: jax.Array, cos: jax.Array, sin: jax.Array
) -> jax.Array:
    """Rotate pairs (split-half convention). x: (..., seq, dim);
    cos/sin: (seq, dim/2) broadcastable."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    o1 = xf1 * cos - xf2 * sin
    o2 = xf2 * cos + xf1 * sin
    return jnp.concatenate([o1, o2], axis=-1).astype(x.dtype)


# ---------------------------------------------------------------------------
# GQA attention (dense archs, gemma2 local/global, whisper, jamba attn layers)
# ---------------------------------------------------------------------------


def _split_heads(x: jax.Array, n: int) -> jax.Array:
    b, s, _ = x.shape
    return x.reshape(b, s, n, -1).transpose(0, 2, 1, 3)  # (b, h, s, hd)


def _merge_heads(x: jax.Array) -> jax.Array:
    b, h, s, hd = x.shape
    return x.transpose(0, 2, 1, 3).reshape(b, s, h * hd)


def _decode_attend(
    q: jax.Array,       # (b, H, 1, hd)
    k_cache: jax.Array,  # (b, KV, S, hd)
    v_cache: jax.Array,  # (b, KV, S, dv)
    pos: jax.Array,      # i32 index of the new token: scalar or (b,) per-row
    window: int | None,
    softcap: float | None,
    scale: float,
    rules: AxisRules | None = None,
) -> jax.Array:
    b, hq, _, hd = q.shape
    _, hkv, S, _ = k_cache.shape
    group = hq // hkv
    pos = jnp.asarray(pos)
    per_row = pos.ndim == 1  # mixed-progress batched decode

    # §Perf C: sliding-window layers only ever read the last ``window``
    # positions — slice them out (static size) instead of scoring the whole
    # cache with a mask.  At 500k context this is a 128x compute/traffic
    # reduction; correctness is preserved by re-basing the position mask.
    base = 0
    if window is not None and S > 2 * window:
        start = jnp.clip(pos - window + 1, 0, S - window)
        dv = v_cache.shape[-1]
        if per_row:
            # Each row slices ITS OWN window: the slice start is per-row.
            k_cache = jax.vmap(
                lambda c, st: jax.lax.dynamic_slice(
                    c, (0, st, 0), (hkv, window, hd)
                )
            )(k_cache, start)
            v_cache = jax.vmap(
                lambda c, st: jax.lax.dynamic_slice(
                    c, (0, st, 0), (hkv, window, dv)
                )
            )(v_cache, start)
            k_pos = start[:, None] + jnp.arange(window)[None]  # (b, window)
        else:
            k_cache = jax.lax.dynamic_slice(
                k_cache, (0, 0, start, 0), (b, hkv, window, hd)
            )
            v_cache = jax.lax.dynamic_slice(
                v_cache, (0, 0, start, 0), (b, hkv, window, dv)
            )
            k_pos = start + jnp.arange(window)
        base = start
        S = window
    else:
        k_pos = (
            jnp.broadcast_to(jnp.arange(S)[None], (b, S)) if per_row
            else jnp.arange(S)
        )

    # Engine-served decode: with a session installed, the single-token
    # query dispatches through the kv_len-masked decode workload — the
    # cache is consumed at its (bucketed) length S and the number of valid
    # rows rides as a runtime scalar (or a (b,) per-row vector under
    # mixed-progress batched decode: ``pos`` per row, one launch for the
    # whole batch), so cache tails past the last written token may hold
    # ANYTHING (bucket pad, stale bytes) and the selection is static (S),
    # trace-safe.  The inline math below remains the bit-identical
    # fallback for sessionless callers (training harnesses, sharded
    # decode) and for the rare shapes the workload does not cover
    # (MLA-style dv != hd, a non-default scale).
    engine = session.installed_engine()
    if (
        engine is not None
        and v_cache.shape[-1] == hd
        and abs(scale - hd ** -0.5) < 1e-12
    ):
        kv_len = pos - base + 1  # valid rows in (the slice of) the cache
        return engine.dispatch(
            "decode_attention", q, k_cache, v_cache, kv_len,
            window=window, softcap=softcap,
        ).astype(q.dtype)

    # GQA without materializing repeated K/V: fold the group into q's head
    # layout (b, KV, group, 1, hd) and contract against (b, KV, S, hd).
    # NOTE: this inline fallback masks SCORES only — softmax weight 0 at
    # masked rows — so cache tails must be finite here (0 * NaN poisons);
    # the engine path above tolerates garbage tails by zeroing v rows.
    qf = q.astype(jnp.float32).reshape(b, hkv, group, hd)
    kf = k_cache.astype(jnp.float32)
    s = jnp.einsum("bkgd,bksd->bkgs", qf, kf) * scale
    if softcap is not None:
        s = jnp.tanh(s / softcap) * softcap
    if per_row:
        mask = k_pos <= pos[:, None]  # (b, S)
        if window is not None:
            mask &= k_pos > pos[:, None] - window
        s = jnp.where(mask[:, None, None, :], s, -1e30)
    else:
        mask = k_pos <= pos
        if window is not None:
            mask &= k_pos > pos - window
        s = jnp.where(mask[None, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    vf = v_cache.astype(jnp.float32)
    out = jnp.einsum("bkgs,bksd->bkgd", p, vf)
    return out.reshape(b, hq, 1, -1).astype(q.dtype)


def flash_decode_sharded(
    q: jax.Array,        # (b, H, 1, hd)
    k_cache: jax.Array,  # (b, KV, S, hd) — seq-sharded over the TP axis
    v_cache: jax.Array,  # (b, KV, S, dv)
    k_new: jax.Array,    # (b, KV, 1, hd)
    v_new: jax.Array,    # (b, KV, 1, dv)
    pos: jax.Array,
    window: int | None,
    softcap: float | None,
    scale: float,
    rules: AxisRules,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Distributed flash-decode (§Perf B).

    When kv_heads do not divide the TP axis the KV cache must shard on
    sequence; naive attention (and the cache write at a dynamic position)
    then all-gathers the whole cache every layer every token.  Here each
    seq-shard (a) writes the new K/V only if it owns position ``pos``,
    (b) computes a partial online-softmax over its own positions, and
    (c) combines with pmax/psum of (b, KV, group, dv) — bytes per step drop
    from O(cache) to O(heads x head_dim).

    Returns (out, k_cache', v_cache').
    """
    from jax.sharding import PartitionSpec as P
    from jax.experimental.shard_map import shard_map

    mesh = rules.mesh
    seq_ax = rules.rules.get("seq")
    b, hq, _, hd = q.shape
    _, hkv, S, dv = v_cache.shape
    group = hq // hkv
    nshard = rules.axis_sizes[seq_ax]
    s_loc = S // nshard
    batch_ax = rules.rules.get("batch")
    bspec = rules.sanitize(P(batch_ax), (b,))
    b_part = bspec[0] if len(bspec) else None

    cache_spec = P(b_part, None, seq_ax, None)
    flat_spec = P(b_part, None, None, None)

    def body(q_, kc, vc, kn, vn, pos_):
        idx = jax.lax.axis_index(seq_ax)
        base = idx * s_loc
        off = pos_ - base
        owned = (off >= 0) & (off < s_loc)
        safe = jnp.clip(off, 0, s_loc - 1)

        def write(c, new):
            upd = jax.lax.dynamic_update_slice(
                c, new.astype(c.dtype), (0, 0, safe, 0)
            )
            return jnp.where(owned, upd, c)

        kc = write(kc, kn)
        vc = write(vc, vn)

        k_pos = base + jnp.arange(s_loc)
        qf = q_.astype(jnp.float32).reshape(-1, hkv, group, hd)
        sc = jnp.einsum("bkgd,bksd->bkgs", qf, kc.astype(jnp.float32))
        sc = sc * scale
        if softcap is not None:
            sc = jnp.tanh(sc / softcap) * softcap
        mask = k_pos <= pos_
        if window is not None:
            mask &= k_pos > pos_ - window
        sc = jnp.where(mask[None, None, None, :], sc, -1e30)

        m_loc = jnp.max(sc, axis=-1)
        m_glob = jax.lax.pmax(m_loc, seq_ax)
        p = jnp.exp(sc - m_glob[..., None])
        l_glob = jax.lax.psum(jnp.sum(p, axis=-1), seq_ax)
        o_loc = jnp.einsum("bkgs,bksd->bkgd", p, vc.astype(jnp.float32))
        o_glob = jax.lax.psum(o_loc, seq_ax)
        out = o_glob / jnp.maximum(l_glob, 1e-30)[..., None]
        return out.reshape(-1, hq, 1, dv).astype(q_.dtype), kc, vc

    fn = shard_map(
        body,
        mesh=mesh,
        in_specs=(flat_spec, cache_spec, cache_spec, flat_spec, flat_spec,
                  P()),
        out_specs=(flat_spec, cache_spec, cache_spec),
        check_rep=False,
    )
    return fn(q, k_cache, v_cache, k_new, v_new, pos)


def attn_forward(
    p: dict,
    x: jax.Array,
    cfg: ModelConfig,
    spec: LayerSpec,
    rules: AxisRules,
    *,
    mode: str,
    positions: jax.Array | None = None,
    cache: dict | None = None,
    pos: jax.Array | None = None,
    cache_len: int = 0,
    causal: bool = True,
    use_rope: bool = True,
    encoder_out: jax.Array | None = None,
) -> tuple[jax.Array, dict | None]:
    """GQA attention with RoPE, sliding window, logit softcap, cross-attn."""
    b, s, d = x.shape
    hd = cfg.resolved_head_dim
    H, KV = cfg.n_heads, cfg.n_kv_heads
    if mode == "train":
        # Megatron-SP gather point: leave the residual stream seq-sharded,
        # gather the full sequence only for the mixer body.  Train-only:
        # prefill has no bwd remat interactions and XLA's own placement
        # measured cheaper there (§Perf iteration log).
        x = constrain(x, rules, "batch", None, None)
    q = _split_heads(x @ p["wq"], H)
    k = _split_heads(x @ p["wk"], KV)
    v = _split_heads(x @ p["wv"], KV)
    if mode == "train":
        # Train-only: in prefill these pins fight the seq-sharded cache
        # layout (and replicate k over 'model' when kv_heads_act is None).
        q = constrain(q, rules, "batch", "heads_act", None, None)
        k = constrain(k, rules, "batch", "kv_heads_act", None, None)

    if use_rope:
        if mode == "decode":
            assert pos is not None
            if getattr(pos, "ndim", 0):
                # Per-row positions: (b,) -> tables (b, 1, hd/2), lifted to
                # (b, 1, 1, hd/2) so every row rotates at ITS OWN position.
                cos, sin = rope_tables(pos[:, None], hd, cfg.rope_theta)
                cos, sin = cos[:, None], sin[:, None]
            else:
                cos, sin = rope_tables(
                    pos[None], hd, cfg.rope_theta
                )  # (1, hd/2)
                cos, sin = cos[None, None], sin[None, None]
        else:
            assert positions is not None
            cos, sin = rope_tables(positions, hd, cfg.rope_theta)
            cos, sin = cos[None, None], sin[None, None]  # (1,1,s,hd/2)
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)

    scale = hd ** -0.5
    new_cache: dict | None = None
    if mode == "decode":
        assert cache is not None and pos is not None
        S = cache["k"].shape[2]
        model_size = rules.axis_sizes.get("model", 1)
        seq_sharded = (
            rules.mesh is not None
            and rules.rules.get("seq") is not None
            and rules.rules.get("kv_heads_act") is None
            and S % max(model_size, 1) == 0
            and model_size > 1
        )
        if seq_sharded:
            out, k_cache, v_cache = flash_decode_sharded(
                q, cache["k"], cache["v"], k, v, pos,
                spec.window, cfg.attn_softcap, scale, rules,
            )
        else:
            if getattr(pos, "ndim", 0):
                # Mixed-progress rows: each row's new K/V lands at ITS OWN
                # position (vmap over batch — per-row dynamic_update_slice).
                def row_write(c, new, p_):
                    return jax.lax.dynamic_update_slice(
                        c, new, (0, p_, 0)
                    )

                k_cache = jax.vmap(row_write)(
                    cache["k"], k.astype(cache["k"].dtype), pos
                )
                v_cache = jax.vmap(row_write)(
                    cache["v"], v.astype(cache["v"].dtype), pos
                )
            else:
                k_cache = jax.lax.dynamic_update_slice(
                    cache["k"], k.astype(cache["k"].dtype), (0, 0, pos, 0)
                )
                v_cache = jax.lax.dynamic_update_slice(
                    cache["v"], v.astype(cache["v"].dtype), (0, 0, pos, 0)
                )
            out = _decode_attend(
                q, k_cache, v_cache, pos, spec.window, cfg.attn_softcap,
                scale,
            )
        new_cache = {"k": k_cache, "v": v_cache}
    else:
        engine = session.installed_engine()
        if engine is not None and (mode == "prefill" or not causal):
            # Dynamic-seq serving path: the session engine selects
            # (block_q, block_k) from the scored lattice for this runtime
            # seq, pads to the induced bucket, and serves from the bounded
            # executable cache.  Routed calls: ALL prefill self-attention
            # (causal or not) and non-causal encoder self-attention — the
            # whisper/internvl encoders run their bidirectional stacks in
            # "train" mode even while serving, so the non-causal arm is
            # what puts them on the engine.  Causal train-mode attention
            # stays inline (sessions are serving-scoped; training wants
            # the sharding pins of the chunked scan).
            out = engine.dispatch(
                "attention", q, k, v, causal=causal, window=spec.window,
                softcap=cfg.attn_softcap,
            )
        else:
            out = chunked_attention(
                q, k, v,
                causal=causal,
                window=spec.window,
                softcap=cfg.attn_softcap,
                chunk=ATTN_CHUNK,
                rules=rules if mode == "train" else None,
            )
        if mode == "prefill":
            pad = cache_len - s
            k_cache = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
            v_cache = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
            new_cache = {"k": k_cache, "v": v_cache}

    y = _merge_heads(out) @ p["wo"]

    if spec.cross_attn:
        assert encoder_out is not None
        xn = norm(x + y, p["norm_x"], cfg)
        qx = _split_heads(xn @ p["xq"], H)
        kx = _split_heads(encoder_out @ p["xk"], KV)
        vx = _split_heads(encoder_out @ p["xv"], KV)
        ox = chunked_attention(qx, kx, vx, causal=False, chunk=ATTN_CHUNK,
                               rules=rules)
        y = y + _merge_heads(ox) @ p["xo"]

    return y, new_cache


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V2 multi-head latent attention)
# ---------------------------------------------------------------------------


def mla_forward(
    p: dict,
    x: jax.Array,
    cfg: ModelConfig,
    rules: AxisRules,
    *,
    mode: str,
    positions: jax.Array | None = None,
    cache: dict | None = None,
    pos: jax.Array | None = None,
    cache_len: int = 0,
) -> tuple[jax.Array, dict | None]:
    """Multi-head latent attention.

    Train/prefill use the naive (decompressed) form; decode uses the
    *absorbed* form against the compressed ``c_kv``+``k_rope`` cache, which
    is the entire point of MLA (cache bytes ∝ kv_lora_rank, not H*hd).
    """
    m = cfg.mla
    assert m is not None
    b, s, d = x.shape
    H = cfg.n_heads
    nope, rope_d, dv = m.qk_nope_dim, m.qk_rope_dim, m.v_head_dim
    scale = (nope + rope_d) ** -0.5

    cq = rmsnorm(x @ p["wdq"], p["q_norm"])
    q = (cq @ p["wuq"]).reshape(b, s, H, nope + rope_d).transpose(0, 2, 1, 3)
    q_nope, q_rope = q[..., :nope], q[..., nope:]

    ckv_full = x @ p["wdkv"]  # (b, s, kv_lora + rope_d)
    c_kv = rmsnorm(ckv_full[..., : m.kv_lora_rank], p["kv_norm"])
    k_rope = ckv_full[..., m.kv_lora_rank:][:, None]  # (b, 1, s, rope_d)

    if mode == "decode":
        assert cache is not None and pos is not None
        if getattr(pos, "ndim", 0):
            # Per-row positions (mixed-progress batched decode): rotate at
            # and write to each row's OWN position.
            cos, sin = rope_tables(pos[:, None], rope_d, cfg.rope_theta)
            q_rope = apply_rope(q_rope, cos[:, None], sin[:, None])
            k_rope = apply_rope(k_rope, cos[:, None], sin[:, None])
            ckv_c = jax.vmap(
                lambda c, new, p_: jax.lax.dynamic_update_slice(
                    c, new, (p_, 0)
                )
            )(cache["ckv"], c_kv.astype(cache["ckv"].dtype), pos)
            kr_c = jax.vmap(
                lambda c, new, p_: jax.lax.dynamic_update_slice(
                    c, new, (p_, 0)
                )
            )(cache["k_rope"], k_rope[:, 0].astype(cache["k_rope"].dtype),
              pos)
        else:
            cos, sin = rope_tables(pos[None], rope_d, cfg.rope_theta)
            q_rope = apply_rope(q_rope, cos[None, None], sin[None, None])
            k_rope = apply_rope(k_rope, cos[None, None], sin[None, None])
            ckv_c = jax.lax.dynamic_update_slice(
                cache["ckv"], c_kv.astype(cache["ckv"].dtype), (0, pos, 0)
            )
            kr_c = jax.lax.dynamic_update_slice(
                cache["k_rope"], k_rope[:, 0].astype(cache["k_rope"].dtype),
                (0, pos, 0),
            )
        # Absorbed attention: score_h(t) = q_nope_h . (W_uk_h c_t) + q_rope_h . kr_t
        #                               = (W_uk_h^T q_nope_h) . c_t + ...
        wuk = p["wuk"].reshape(m.kv_lora_rank, H, nope)
        q_abs = jnp.einsum("bhqn,chn->bhqc", q_nope.astype(jnp.float32),
                           wuk.astype(jnp.float32))
        s_c = jnp.einsum("bhqc,bkc->bhqk", q_abs,
                         ckv_c.astype(jnp.float32))
        s_r = jnp.einsum("bhqr,bkr->bhqk", q_rope.astype(jnp.float32),
                         kr_c.astype(jnp.float32))
        sc = (s_c + s_r) * scale
        S = ckv_c.shape[1]
        if getattr(pos, "ndim", 0):
            mask = jnp.arange(S)[None] <= pos[:, None]  # (b, S)
            sc = jnp.where(mask[:, None, None], sc, -1e30)
        else:
            mask = jnp.arange(S) <= pos
            sc = jnp.where(mask[None, None, None, :], sc, -1e30)
        pr = jax.nn.softmax(sc, axis=-1)
        out_c = jnp.einsum("bhqk,bkc->bhqc", pr, ckv_c.astype(jnp.float32))
        wuv = p["wuv"].reshape(m.kv_lora_rank, H, dv)
        out = jnp.einsum("bhqc,chv->bhqv", out_c, wuv.astype(jnp.float32))
        out = out.astype(x.dtype)
        new_cache: dict | None = {"ckv": ckv_c, "k_rope": kr_c}
    else:
        assert positions is not None
        cos, sin = rope_tables(positions, rope_d, cfg.rope_theta)
        q_rope = apply_rope(q_rope, cos[None, None], sin[None, None])
        k_rope = apply_rope(k_rope, cos[None, None], sin[None, None])
        k_nope = (c_kv @ p["wuk"]).reshape(b, s, H, nope).transpose(0, 2, 1, 3)
        v = (c_kv @ p["wuv"]).reshape(b, s, H, dv).transpose(0, 2, 1, 3)
        qh = jnp.concatenate([q_nope, q_rope], axis=-1)
        kh = jnp.concatenate(
            [k_nope, jnp.broadcast_to(k_rope, (b, H, s, rope_d))], axis=-1
        )
        qh = constrain(qh, rules, "batch", "heads_act", None, None)
        out = chunked_attention(qh, kh, v, causal=True, chunk=ATTN_CHUNK,
                                rules=rules if mode == "train" else None)
        new_cache = None
        if mode == "prefill":
            pad = cache_len - s
            new_cache = {
                "ckv": jnp.pad(c_kv, ((0, 0), (0, pad), (0, 0))),
                "k_rope": jnp.pad(k_rope[:, 0], ((0, 0), (0, pad), (0, 0))),
            }

    y = _merge_heads(out) @ p["wo"]
    return y, new_cache


# ---------------------------------------------------------------------------
# Mamba-1 selective SSM (falcon-mamba, jamba)
# ---------------------------------------------------------------------------


def _ssm_chunk_scan(
    a: jax.Array, bx: jax.Array, h0: jax.Array
) -> tuple[jax.Array, jax.Array]:
    """Linear recurrence h_t = a_t * h_{t-1} + bx_t over one chunk.

    a, bx: (b, L, di, ds); h0: (b, di, ds).  Returns (h_all, h_last).
    Uses an associative scan (parallel prefix) — O(L log L) work but O(log L)
    depth, the TPU-friendly formulation of the selective scan.
    """

    def combine(lhs, rhs):
        al, bl = lhs
        ar, br = rhs
        return al * ar, bl * ar + br

    a_cum, b_cum = jax.lax.associative_scan(combine, (a, bx), axis=1)
    h_all = a_cum * h0[:, None] + b_cum
    return h_all, h_all[:, -1]


def mamba_forward(
    p: dict,
    x: jax.Array,
    cfg: ModelConfig,
    rules: AxisRules,
    *,
    mode: str,
    cache: dict | None = None,
    pos: jax.Array | None = None,
) -> tuple[jax.Array, dict | None]:
    """Mamba-1: in_proj -> causal depthwise conv -> selective scan -> gate."""
    ssm = cfg.ssm
    assert ssm is not None
    b, s, d = x.shape
    di, ds, dc = ssm.d_inner, ssm.d_state, ssm.d_conv
    dtr = ssm.dt_rank or d // 16

    xz = x @ p["in_proj"]
    x_in, z = xz[..., :di], xz[..., di:]
    x_in = constrain(x_in, rules, "batch", None, "ssm_inner")

    if mode == "decode":
        assert cache is not None
        if s != 1:
            # The conv-window concat below assumes EXACTLY one new token:
            # with s > 1 it builds a (b, dc-1+s, di) window whose [:, 1:]
            # slice silently writes a mis-sized/mis-aligned conv state back
            # into the cache (state corruption, no shape error downstream).
            raise ValueError(
                "mamba_forward(mode='decode') consumes one token per step; "
                f"got s={s}. Feed multi-token input through mode='prefill' "
                "(which rebuilds the conv state from the tail) instead."
            )
        # Conv state: the last (dc-1) pre-conv inputs, (b, dc-1, di).
        conv_st = cache["conv"]
        window = jnp.concatenate([conv_st, x_in], axis=1)  # (b, dc, di)
        xc = jnp.einsum("bkd,kd->bd", window.astype(jnp.float32),
                        p["conv_w"].astype(jnp.float32)) + p["conv_b"]
        xc = jax.nn.silu(xc)[:, None]  # (b, 1, di)
        new_conv = window[:, 1:]
    else:
        pad = jnp.pad(x_in, ((0, 0), (dc - 1, 0), (0, 0)))
        xc = jax.lax.conv_general_dilated(
            pad.astype(jnp.float32),
            p["conv_w"].astype(jnp.float32)[:, None, :],  # (k, 1, di)
            window_strides=(1,),
            padding="VALID",
            dimension_numbers=("NWC", "WIO", "NWC"),
            feature_group_count=di,
        ) + p["conv_b"]
        xc = jax.nn.silu(xc).astype(x.dtype)
        new_conv = None
        if mode == "prefill":
            # Conv state: the last (dc-1) pre-conv inputs.
            new_conv = x_in[:, s - (dc - 1):, :] if s >= dc - 1 else jnp.pad(
                x_in, ((0, 0), (dc - 1 - s, 0), (0, 0))
            )

    proj = xc.astype(x.dtype) @ p["x_proj"]  # (b, s, dtr + 2*ds)
    dt_r = proj[..., :dtr]
    B = proj[..., dtr: dtr + ds].astype(jnp.float32)
    C = proj[..., dtr + ds:].astype(jnp.float32)
    dt = jax.nn.softplus(
        (dt_r @ p["dt_proj"]).astype(jnp.float32) + p["dt_bias"]
    )  # (b, s, di)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))  # (di, ds)
    xcf = xc.astype(jnp.float32)

    if mode == "decode":
        assert cache is not None
        h_prev = cache["ssm"]  # (b, di, ds)
        a = jnp.exp(dt[:, 0, :, None] * A)          # (b, di, ds)
        bx = (dt[:, 0] * xcf[:, 0])[..., None] * B[:, 0][:, None, :]
        h = a * h_prev + bx                          # (b, di, ds)
        y = jnp.einsum("bds,bs->bd", h, C[:, 0]) + p["D"] * xcf[:, 0]
        y = y[:, None]
        new_cache = {"conv": new_conv, "ssm": h}
    else:
        chunk = min(cfg.scan_chunk, s)
        s_pad = -s % chunk  # pad to a chunk multiple (padding contributes 0)
        if s_pad:
            pad2 = lambda t: jnp.pad(t, ((0, 0), (0, s_pad)) + ((0, 0),) * (t.ndim - 2))
            dt, xcf, B, C = pad2(dt), pad2(xcf), pad2(B), pad2(C)
        sp = s + s_pad
        n_chunks = sp // chunk

        def chunk_body(h0, xs):
            dt_c, x_c, B_c, C_c = xs  # (b, L, ...)
            a = jnp.exp(dt_c[..., None] * A)             # (b, L, di, ds)
            bx = (dt_c * x_c)[..., None] * B_c[:, :, None, :]
            h_all, h_last = _ssm_chunk_scan(a, bx, h0)
            y_c = jnp.einsum("blds,bls->bld", h_all, C_c)
            return h_last, y_c

        chunk_body = jax.checkpoint(chunk_body)

        def split(t):  # (b, s, ...) -> (n, b, chunk, ...)
            return t.reshape(b, n_chunks, chunk, *t.shape[2:]).swapaxes(0, 1)

        h0 = jnp.zeros((b, di, ds), jnp.float32)
        h_last, ys = jax.lax.scan(
            chunk_body, h0, (split(dt), split(xcf), split(B), split(C))
        )
        y = ys.swapaxes(0, 1).reshape(b, sp, di)[:, :s] + p["D"] * xcf[:, :s]
        new_cache = None
        if mode == "prefill":
            new_cache = {"conv": new_conv, "ssm": h_last}

    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    out = y @ p["out_proj"]
    return out, new_cache


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------


def _glu_act(cfg: ModelConfig, h: jax.Array, g: jax.Array | None) -> jax.Array:
    if cfg.act == "swiglu":
        return jax.nn.silu(g) * h
    if cfg.act == "geglu":
        return jax.nn.gelu(g) * h
    return jax.nn.gelu(h)


def mlp_forward(
    p: dict, x: jax.Array, cfg: ModelConfig, rules: AxisRules
) -> jax.Array:
    h = x @ p["w_in"]
    g = x @ p["w_gate"] if "w_gate" in p else None
    h = _glu_act(cfg, h, g)
    h = constrain(h, rules, "batch", None, "ff")
    return h @ p["w_out"]


# ---------------------------------------------------------------------------
# Lazy handle chain: whole-block prefill with zero boundary copies
# ---------------------------------------------------------------------------
# Engine-served block forward where every dispatch output stays a bucket-
# shaped LazyBucket and the next dispatch consumes the buffer directly
# (DESIGN.md §8).  The non-engine glue between dispatches (norms, rope,
# residual adds, head splits) runs row-locally on the raw buffers via
# lazy_map/LazyBucket.map, so nothing forces a realize inside a block.
# Single-host serving path (launch/serve.py prefill="chained"): handles are
# eager-only, so there is no lax.scan and no sharding constraint here — the
# eager per-op reference (``lazy=False``) runs the identical dispatch
# sequence on plain arrays and is the bit-identity baseline.  The
# repro.core.engine imports are deferred into the function bodies to keep
# this module import-light (see the module-top import comment).


def lazy_matmul(engine, x, w, *, lazy: bool = True):
    """``x @ w`` through the engine's gemm with ``x`` (b, s, d) either a
    plain array or a fully-valid seq-axis LazyBucket (extent == buffer
    seq).  A handle flattens to a (b*s, d) row handle and forwards
    bucket-to-bucket; the output re-wraps on the seq axis, clamped back to
    the chain width if the gemm bucket outgrew it (one counted slice)."""
    from repro.core.engine import LazyBucket

    if (
        lazy and isinstance(x, LazyBucket) and x.axis == 1
        and x.extent == x.buffer.shape[1]
    ):
        b, s, d = x.buffer.shape
        flat = x.rewrap(x.buffer.reshape(b * s, d), extent=b * s, axis=0)
        out = engine.dispatch("gemm", flat, w, lazy=True)
        if isinstance(out, LazyBucket):
            out = out.clamp(b * s)
            return x.rewrap(out.buffer.reshape(b, s, -1))
        return out.reshape(b, s, -1)  # engine fell back to a plain array
    if isinstance(x, LazyBucket):
        x = x.realize()
    b, s, d = x.shape
    out = engine.dispatch("gemm", x.reshape(b * s, d), w)
    return out.reshape(b, s, -1)


def attn_forward_lazy(
    engine,
    p: dict,
    x,
    cfg: ModelConfig,
    spec: LayerSpec,
    *,
    positions: jax.Array,
    causal: bool = True,
    lazy: bool = True,
):
    """Prefill GQA attention as a handle chain: q/k/v projections,
    attention and the output projection all forward bucket-to-bucket.

    ``positions`` must cover the BUFFER seq width (rope is row-local, so
    pad rows get real rotations applied to garbage — confined).  Returns
    ``(y, {"k": k, "v": v})`` where k/v are the post-rope head-split
    projections — (b, KV, s, hd) handles on the seq axis, which serving
    consumes directly as kv-cache bucket buffers.
    """
    from repro.core.engine import LazyBucket

    hd = cfg.resolved_head_dim
    H, KV = cfg.n_heads, cfg.n_kv_heads

    q = lazy_matmul(engine, x, p["wq"], lazy=lazy)
    k = lazy_matmul(engine, x, p["wk"], lazy=lazy)
    v = lazy_matmul(engine, x, p["wv"], lazy=lazy)

    def split(t, n):
        if isinstance(t, LazyBucket):
            return t.rewrap(_split_heads(t.buffer, n), axis=2)
        return _split_heads(t, n)

    q, k, v = split(q, H), split(k, KV), split(v, KV)

    if cfg.use_rope:
        cos, sin = rope_tables(positions, hd, cfg.rope_theta)
        cos, sin = cos[None, None], sin[None, None]  # (1, 1, s, hd/2)

        def rope(t):
            return apply_rope(t, cos, sin)

        q = q.map(rope) if isinstance(q, LazyBucket) else rope(q)
        k = k.map(rope) if isinstance(k, LazyBucket) else rope(k)

    out = engine.dispatch(
        "attention", q, k, v, causal=causal, window=spec.window,
        softcap=cfg.attn_softcap, lazy=lazy,
    )
    sp = (x.buffer if isinstance(x, LazyBucket) else x).shape[1]
    if isinstance(out, LazyBucket):
        out = out.clamp(sp)
        merged = out.rewrap(_merge_heads(out.buffer), axis=1)
    else:
        merged = _merge_heads(out)
    y = lazy_matmul(engine, merged, p["wo"], lazy=lazy)
    return y, {"k": k, "v": v}


def mlp_forward_lazy(engine, p: dict, x, cfg: ModelConfig, *,
                     lazy: bool = True):
    """Dense MLP as a handle chain (activation via lazy_map, row-local)."""
    from repro.core.engine import lazy_map

    h = lazy_matmul(engine, x, p["w_in"], lazy=lazy)
    if "w_gate" in p:
        g = lazy_matmul(engine, x, p["w_gate"], lazy=lazy)
        h = lazy_map(lambda a, b: _glu_act(cfg, a, b), h, g)
    else:
        h = lazy_map(lambda a: _glu_act(cfg, a, None), h)
    return lazy_matmul(engine, h, p["w_out"], lazy=lazy)


def block_forward_lazy(
    engine,
    p: dict,
    x,
    cfg: ModelConfig,
    spec: LayerSpec,
    *,
    positions: jax.Array,
    causal: bool = True,
    lazy: bool = True,
):
    """One transformer block (attn mixer + dense/none MLP) as a handle
    chain: the attention→projection→MLP sequence passes LazyBuckets across
    every engine boundary; norms and residual adds ride lazy_map.  Returns
    ``(x, kv)`` with kv the layer's k/v handles for the serving cache."""
    from repro.core.engine import lazy_map

    assert spec.mixer == "attn" and spec.mlp in ("dense", "none") \
        and not spec.cross_attn, "lazy chain serves plain attn blocks only"
    h = lazy_map(lambda t: norm(t, p["norm_mixer"], cfg), x)
    y, kv = attn_forward_lazy(
        engine, p["attn"], h, cfg, spec,
        positions=positions, causal=causal, lazy=lazy,
    )
    x = lazy_map(jnp.add, x, y)
    if spec.mlp != "none":
        h = lazy_map(lambda t: norm(t, p["norm_mlp"], cfg), x)
        y = mlp_forward_lazy(engine, p["mlp"], h, cfg, lazy=lazy)
        x = lazy_map(jnp.add, x, y)
    return x, kv


def _expert_ffn(
    p: dict, buf: jax.Array, cfg: ModelConfig, counts: jax.Array | None = None
) -> jax.Array:
    """buf: (g, E, C, d) -> (g, E, C, d) through per-expert FFNs.

    ``counts`` (optional (g, E) i32) is each expert slab's TRUE row count —
    rows past it are routing pad (zero-filled by ``moe_forward``).  When an
    engine session is installed and the call is eager, the three dense
    einsums collapse to three ``grouped_gemm`` dispatches: ONE bucketed
    masked-tail launch each for all g*E expert slabs, with the capacity as
    the dynamic (bucketed) extent and the per-slab counts riding in as the
    runtime extent vector.  The inline einsums below stay the bit-identical
    fallback for sessionless callers and for traced calls inside scanned
    model blocks (where engine-owned staging buffers must not be captured).
    """
    engine = session.installed_engine()
    if (
        engine is not None
        and counts is not None
        and not isinstance(buf, jax.core.Tracer)
    ):
        g, E, C, d = buf.shape
        # Expert-major group layout: (g, E, C, d) -> (E*g, C, d), so the
        # r = g consecutive groups of each expert share one weight-stack
        # entry (the grouped_gemm contract: weight index = group // r).
        xs = jnp.transpose(buf, (1, 0, 2, 3)).reshape(E * g, C, d)
        cnt = jnp.transpose(
            jnp.asarray(counts, jnp.int32), (1, 0)
        ).reshape(E * g)
        h = engine.dispatch("grouped_gemm", xs, p["w_in"], cnt)
        gate = (
            engine.dispatch("grouped_gemm", xs, p["w_gate"], cnt)
            if "w_gate" in p else None
        )
        h = _glu_act(cfg, h, gate)
        out = engine.dispatch("grouped_gemm", h, p["w_out"], cnt)
        return jnp.transpose(out.reshape(E, g, C, d), (1, 0, 2, 3))

    h = jnp.einsum("gecd,edf->gecf", buf, p["w_in"])
    g = (
        jnp.einsum("gecd,edf->gecf", buf, p["w_gate"])
        if "w_gate" in p else None
    )
    h = _glu_act(cfg, h, g)
    return jnp.einsum("gecf,efd->gecd", h, p["w_out"])


def moe_forward(
    p: dict, x: jax.Array, cfg: ModelConfig, rules: AxisRules
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Top-k routed MoE with sort-based, capacity-bounded dispatch.

    The batch dim doubles as the GShard "group": routing, sorting and
    capacity-dropping are per-sequence, so the sort never crosses the
    data-parallel shard boundary.  Expert buffers are sharded over the
    expert (EP) axis.  Returns ``(y, aux_load_balance_loss, dropped_frac)``
    — ``dropped_frac`` is the fraction of (token, choice) assignments the
    capacity bound silently zeroed (a dropped assignment contributes 0 to
    its token's weighted combine, NOT a renormalized mix of the surviving
    experts); it is exactly 0 whenever every expert's load fits its
    capacity, which capacity_factor >= 1.0 guarantees only under perfectly
    uniform routing.
    """
    m = cfg.moe
    assert m is not None
    b, s, d = x.shape
    E, k = m.num_experts, m.top_k
    C = max(1, int(math.ceil(s * k * m.capacity_factor / E)))

    # §Perf A2: routing/sort/dispatch must run on seq-REPLICATED activations
    # (one all-gather here); a seq-sharded input turns the per-group argsort
    # into a distributed bitonic sort (~50 GB/dev/layer of all-to-all).
    # Skip at s==1 (decode): the sort is trivial there, and pinning the
    # batch axis forces XLA to all-gather FSDP weights instead of psum'ing
    # tiny decode activations (observed 20x regression on deepseek decode).
    if s > 1:
        x = constrain(x, rules, "batch", None, None)
    xf = x.astype(jnp.float32)
    logits = jnp.einsum("gtd,de->gte", xf, p["router"])  # (b, s, E)
    probs = jax.nn.softmax(logits, axis=-1)
    topw, topi = jax.lax.top_k(probs, k)  # (b, s, k)
    topw = topw / jnp.sum(topw, axis=-1, keepdims=True)

    # Aux loss (Switch): E * sum_e f_e * P_e over all tokens.
    ids_1h = jax.nn.one_hot(topi[..., 0], E, dtype=jnp.float32)
    f_e = jnp.mean(ids_1h, axis=(0, 1))
    p_e = jnp.mean(probs, axis=(0, 1))
    aux = E * jnp.sum(f_e * p_e)

    # ---- per-group sort-based dispatch, GATHER-ONLY --------------------
    # No scatter anywhere: XLA's SPMD partitioner replicates vmapped
    # scatters ("involuntary full rematerialization"), which cascaded a
    # batch-replication through the whole layer (§Perf A2').  Gathers and
    # per-row sorts partition cleanly over the batch axis.
    S = s * k
    flat_e = topi.reshape(b, S)                        # (g, S)
    order = jnp.argsort(flat_e, axis=-1, stable=True)  # sorted-pos -> flat
    sorted_e = jnp.take_along_axis(flat_e, order, axis=-1)
    # Start offset of each expert's segment in the sorted order.
    first = jax.vmap(
        lambda se: jnp.searchsorted(se, jnp.arange(E), side="left")
    )(sorted_e)                                        # (g, E)

    # Forward map: slot (e, c) <- sorted position first[e] + c.
    p_grid = first[:, :, None] + jnp.arange(C)[None, None, :]  # (g, E, C)
    p_clip = jnp.minimum(p_grid, S - 1)
    e_at_p = jnp.take_along_axis(
        sorted_e, p_clip.reshape(b, E * C), axis=-1
    ).reshape(b, E, C)
    valid = (p_grid < S) & (
        e_at_p == jnp.arange(E)[None, :, None]
    )                                                  # (g, E, C)
    src_flat = jnp.take_along_axis(
        order, p_clip.reshape(b, E * C), axis=-1
    )                                                  # (g, E*C) flat idx
    token_idx = src_flat // k                          # (g, E*C) token idx
    buf = jnp.take_along_axis(x, token_idx[..., None], axis=1)
    buf = jnp.where(valid.reshape(b, E * C, 1), buf, 0).reshape(b, E, C, d)
    # Per-(group, expert) TRUE row counts: ``valid`` marks a contiguous
    # prefix of each slab (the sorted segment, capacity-clipped), so the
    # sum IS the extent the grouped-GEMM kernel masks at.
    counts = jnp.sum(valid.astype(jnp.int32), axis=-1)  # (g, E)
    if s > 1:
        # Prefill: pin the expert buffers to the (batch, expert) sharding
        # so the FFN einsums partition over the EP axis.
        buf = constrain(buf, rules, "batch", "expert", None, None)
    # s == 1 (decode): skip the pin — constraining tiny single-token
    # activations makes XLA all-gather the FSDP-sharded expert weights
    # instead of psum'ing the small activations (same pathology as the
    # routing note above; observed 20x regression on deepseek decode).

    out_buf = _expert_ffn(p, buf, cfg, counts=counts)
    if s > 1:  # prefill: keep the output on the same (batch, expert) pin
        out_buf = constrain(out_buf, rules, "batch", "expert", None, None)
    out_flat = out_buf.reshape(b, E * C, d)

    # Return map: flat position f=(t, j) sits at sorted position inv[f];
    # its slot is (flat_e[f], inv[f] - first[flat_e[f]]).
    inv = jnp.argsort(order, axis=-1)                  # flat -> sorted pos
    first_of = jnp.take_along_axis(first, flat_e, axis=-1)   # (g, S)
    pos_in_e = inv - first_of
    # Capacity bound: assignments past an expert's C-th slot are DROPPED —
    # their contribution to the weighted combine is zero.  Surface the
    # drop rate instead of losing tokens silently.
    kept = pos_in_e < C
    dropped_frac = 1.0 - jnp.mean(kept.astype(jnp.float32))
    out_idx = jnp.minimum(flat_e * C + pos_in_e, E * C - 1)
    y_tok = jnp.take_along_axis(out_flat, out_idx[..., None], axis=1)
    y_tok = jnp.where(kept[..., None], y_tok, 0).astype(jnp.float32)
    y_tok = y_tok * topw.reshape(b, S)[..., None]
    y = jnp.sum(y_tok.reshape(b, s, k, d), axis=2).astype(x.dtype)

    if m.num_shared:
        h = x @ p["shared_in"]
        g = x @ p["shared_gate"] if "shared_gate" in p else None
        h = _glu_act(cfg, h, g)
        y = y + h @ p["shared_out"]
    return y, aux, dropped_frac
