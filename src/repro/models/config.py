"""Model/shape configuration schema for all assigned architectures.

A model is a repeated ``pattern`` of :class:`LayerSpec`s (mixer + mlp kind),
which uniformly expresses dense transformers, MoE, SSM (mamba), hybrids
(jamba's 1:7 attn:mamba interleave) and gemma2's local/global alternation.
Parameters are stacked per pattern position and scanned over pattern
repetitions, keeping the HLO compact for the 512-device dry-run.
"""
from __future__ import annotations

import dataclasses
from typing import Literal, Sequence

__all__ = ["LayerSpec", "MoESpec", "SSMSpec", "MLASpec", "ModelConfig",
           "ShapeSpec", "SHAPES"]


@dataclasses.dataclass(frozen=True)
class MoESpec:
    num_experts: int
    top_k: int
    d_ff_expert: int
    num_shared: int = 0
    capacity_factor: float = 1.25


@dataclasses.dataclass(frozen=True)
class SSMSpec:
    d_inner: int
    d_state: int = 16
    d_conv: int = 4
    dt_rank: int = 0  # 0 -> d_model // 16


@dataclasses.dataclass(frozen=True)
class MLASpec:
    """DeepSeek-V2 multi-head latent attention dims."""

    kv_lora_rank: int = 512
    q_lora_rank: int = 1536
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    """One layer position within the repeating pattern."""

    mixer: Literal["attn", "mla", "mamba"] = "attn"
    mlp: Literal["dense", "moe", "none"] = "dense"
    window: int | None = None  # sliding-window size for this layer's attn
    cross_attn: bool = False   # whisper decoder cross-attention


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    pattern: tuple[LayerSpec, ...] = (LayerSpec(),)
    head_dim: int = 0                # 0 -> d_model // n_heads
    moe: MoESpec | None = None
    ssm: SSMSpec | None = None
    mla: MLASpec | None = None
    norm: Literal["rmsnorm", "layernorm"] = "rmsnorm"
    act: Literal["swiglu", "geglu", "gelu"] = "swiglu"
    use_rope: bool = True            # False -> sinusoidal absolute positions
    rope_theta: float = 10000.0
    attn_softcap: float | None = None
    logit_softcap: float | None = None
    tie_embeddings: bool = True
    embed_scale: bool = False        # gemma-style sqrt(d) embedding scaling
    encoder_decoder: bool = False    # whisper
    n_encoder_layers: int = 0
    encoder_seq: int = 1500          # whisper frame count (frontend stubbed)
    vision_prefix: int = 0           # internvl2: # patch embeddings prepended
    sub_quadratic: bool = False      # eligible for long_500k (SSM/hybrid/SWA)
    dtype: str = "bfloat16"
    fsdp: bool = False               # additionally shard params over 'data'
    scan_chunk: int = 256            # mamba scan remat-chunk length

    def __post_init__(self):
        assert self.n_layers % len(self.pattern) == 0, (
            self.name, self.n_layers, len(self.pattern))

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def vocab_padded(self) -> int:
        """Embedding-table vocab padded to a multiple of 256 so the vocab
        axis always shards evenly over the TP axis (§Perf A1: an unsharded
        vocab replicates the f32 logits through an all-reduce).  Logit
        columns >= vocab are masked to -inf in the forward pass."""
        return -(-self.vocab // 256) * 256

    @property
    def n_groups(self) -> int:
        return self.n_layers // len(self.pattern)

    def param_count(self) -> int:
        """Approximate parameter count N (for MODEL_FLOPS = 6*N*D)."""
        d, v = self.d_model, self.vocab
        total = v * d * (1 if self.tie_embeddings else 2)
        for spec in self.pattern:
            n = self._layer_params(spec)
            total += n * self.n_groups
        total += d  # final norm
        if self.encoder_decoder:
            enc_layer = (4 * d * self.n_heads * self.resolved_head_dim
                         + 3 * d * self.d_ff
                         if self.act in ("swiglu", "geglu")
                         else 4 * d * d + 2 * d * self.d_ff)
            total += self.n_encoder_layers * enc_layer
        return int(total)

    def active_param_count(self) -> int:
        """Active params per token (MoE: only routed top-k + shared)."""
        d, v = self.d_model, self.vocab
        total = v * d * (1 if self.tie_embeddings else 2)
        for spec in self.pattern:
            n = self._layer_params(spec, active=True)
            total += n * self.n_groups
        total += d
        return int(total)

    def _layer_params(self, spec: LayerSpec, active: bool = False) -> int:
        d = self.d_model
        hd = self.resolved_head_dim
        n = 2 * d  # norms
        if spec.mixer == "attn":
            n += d * self.n_heads * hd * 2  # wq, wo
            n += d * self.n_kv_heads * hd * 2  # wk, wv
            if spec.cross_attn:
                n += d * self.n_heads * hd * 2 + d * self.n_kv_heads * hd * 2
        elif spec.mixer == "mla":
            m = self.mla
            qdim = self.n_heads * (m.qk_nope_dim + m.qk_rope_dim)
            n += d * m.q_lora_rank + m.q_lora_rank * qdim
            n += d * (m.kv_lora_rank + m.qk_rope_dim)
            n += m.kv_lora_rank * self.n_heads * (m.qk_nope_dim + m.v_head_dim)
            n += self.n_heads * m.v_head_dim * d
        elif spec.mixer == "mamba":
            s = self.ssm
            dtr = s.dt_rank or d // 16
            n += d * 2 * s.d_inner            # in_proj
            n += s.d_inner * s.d_conv         # depthwise conv
            n += s.d_inner * (dtr + 2 * s.d_state)  # x_proj
            n += dtr * s.d_inner              # dt_proj
            n += s.d_inner * s.d_state        # A_log
            n += s.d_inner * 2                # D, conv bias-ish
            n += s.d_inner * d                # out_proj
        if spec.mlp == "dense":
            mult = 3 if self.act in ("swiglu", "geglu") else 2
            n += mult * d * self.d_ff
        elif spec.mlp == "moe":
            m = self.moe
            mult = 3 if self.act in ("swiglu", "geglu") else 2
            experts = m.top_k if active else m.num_experts
            n += experts * mult * d * m.d_ff_expert
            n += m.num_shared * mult * d * m.d_ff_expert
            n += d * m.num_experts  # router
        return n


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: Literal["train", "prefill", "decode"]


SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}
