"""Unified model: dense / MoE / SSM / hybrid / enc-dec / VLM from one config.

A model is ``n_groups`` repetitions of a layer ``pattern``.  Parameters for
each pattern position are stacked over groups and the forward pass is a
``lax.scan`` over groups (compact HLO — essential for lowering 236B-scale
configs in the dry-run).  Each scanned group body is rematerialized
(``jax.checkpoint``) in training mode.

Entry points:
  * :func:`forward`    — logits for train/prefill/decode,
  * :func:`make_cache` / :func:`abstract_cache` / :func:`cache_pspecs`,
  * :func:`loss_fn`    — next-token cross entropy (+ MoE aux loss).
"""
from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.config import LayerSpec, ModelConfig
from repro.models.layers import (
    attn_forward,
    mamba_forward,
    mla_forward,
    mlp_forward,
    moe_forward,
    norm,
)
from repro.models.partitioning import AxisRules, constrain

__all__ = [
    "forward",
    "loss_fn",
    "make_cache",
    "abstract_cache",
    "cache_pspecs",
]


# ---------------------------------------------------------------------------
# Cache construction
# ---------------------------------------------------------------------------


def _cache_entry_defs(
    cfg: ModelConfig, spec: LayerSpec, batch: int, cache_len: int
) -> dict[str, tuple[tuple[int, ...], Any]]:
    """(shape, dtype) per cache tensor for one pattern position (un-stacked)."""
    hd = cfg.resolved_head_dim
    dt = jnp.dtype(cfg.dtype)
    if spec.mixer == "attn":
        # The cache is allocated full-length even for sliding-window layers
        # (decode indexes with the absolute position); a ring-buffer windowed
        # cache is a recorded perf follow-up in EXPERIMENTS.md §Perf.
        shape = (batch, cfg.n_kv_heads, cache_len, hd)
        return {"k": (shape, dt), "v": (shape, dt)}
    if spec.mixer == "mla":
        m = cfg.mla
        return {
            "ckv": ((batch, cache_len, m.kv_lora_rank), dt),
            "k_rope": ((batch, cache_len, m.qk_rope_dim), dt),
        }
    if spec.mixer == "mamba":
        s = cfg.ssm
        return {
            "conv": ((batch, s.d_conv - 1, s.d_inner), dt),
            "ssm": ((batch, s.d_inner, s.d_state), jnp.float32),
        }
    raise ValueError(spec.mixer)


def make_cache(
    cfg: ModelConfig, batch: int, cache_len: int, abstract: bool = False
) -> dict:
    """Decode cache pytree; leaves have a leading group axis."""
    G = cfg.n_groups

    def mk(shape, dt):
        full = (G,) + shape
        if abstract:
            return jax.ShapeDtypeStruct(full, dt)
        return jnp.zeros(full, dt)

    cache: dict[str, Any] = {}
    for p, spec in enumerate(cfg.pattern):
        defs = _cache_entry_defs(cfg, spec, batch, cache_len)
        cache[f"pos{p}"] = {k: mk(s, d) for k, (s, d) in defs.items()}
    if cfg.encoder_decoder:
        eo = (batch, cfg.encoder_seq, cfg.d_model)
        cache["encoder_out"] = (
            jax.ShapeDtypeStruct(eo, jnp.dtype(cfg.dtype)) if abstract
            else jnp.zeros(eo, jnp.dtype(cfg.dtype))
        )
    return cache


def abstract_cache(cfg: ModelConfig, batch: int, cache_len: int) -> dict:
    return make_cache(cfg, batch, cache_len, abstract=True)


def cache_pspecs(
    cfg: ModelConfig, rules: AxisRules, batch: int, cache_len: int
) -> dict:
    """PartitionSpecs matching make_cache's structure (sanitized against the
    actual shapes, so jit accepts them as in/out shardings).

    KV caches shard on the kv-head axis when it divides the model axis,
    otherwise on the sequence axis (long-context: the cache is the dominant
    HBM consumer and MUST shard on something model-sized).
    """
    batch_ax = rules.rules.get("batch")
    model = rules.rules.get("ff")  # the TP axis name ("model") or None
    kv_ok = rules.rules.get("kv_heads_act") is not None

    out: dict[str, Any] = {}
    for p, spec in enumerate(cfg.pattern):
        defs = _cache_entry_defs(cfg, spec, batch, cache_len)
        if spec.mixer == "attn":
            raw = (
                P(None, batch_ax, model, None, None) if kv_ok
                else P(None, batch_ax, None, model, None)
            )
            entry = {"k": raw, "v": raw}
        elif spec.mixer == "mla":
            entry = {
                "ckv": P(None, batch_ax, model, None),
                "k_rope": P(None, batch_ax, None, None),
            }
        else:  # mamba
            entry = {
                "conv": P(None, batch_ax, None, model),
                "ssm": P(None, batch_ax, model, None),
            }
        out[f"pos{p}"] = {
            k: rules.sanitize(entry[k], (cfg.n_groups,) + defs[k][0])
            for k in entry
        }
    if cfg.encoder_decoder:
        out["encoder_out"] = rules.sanitize(
            P(batch_ax, None, None),
            (batch, cfg.encoder_seq, cfg.d_model),
        )
    return out


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------


def _apply_layer(
    cfg: ModelConfig,
    spec: LayerSpec,
    rules: AxisRules,
    p: dict,
    x: jax.Array,
    *,
    mode: str,
    positions: jax.Array | None,
    cache: dict | None,
    pos: jax.Array | None,
    cache_len: int,
    encoder_out: jax.Array | None,
    causal: bool = True,
    use_rope: bool = True,
) -> tuple[jax.Array, dict | None, jax.Array, jax.Array]:
    aux = jnp.zeros((), jnp.float32)
    dropped = jnp.zeros((), jnp.float32)
    h = norm(x, p["norm_mixer"], cfg)
    if spec.mixer == "attn":
        y, new_cache = attn_forward(
            p["attn"], h, cfg, spec, rules,
            mode=mode, positions=positions, cache=cache, pos=pos,
            cache_len=cache_len,
            causal=causal, use_rope=use_rope, encoder_out=encoder_out,
        )
    elif spec.mixer == "mla":
        y, new_cache = mla_forward(
            p["mla"], h, cfg, rules,
            mode=mode, positions=positions, cache=cache, pos=pos,
            cache_len=cache_len,
        )
    else:
        y, new_cache = mamba_forward(
            p["mamba"], h, cfg, rules, mode=mode, cache=cache, pos=pos,
        )
    x = x + y
    if spec.mlp != "none":
        h = norm(x, p["norm_mlp"], cfg)
        if spec.mlp == "dense":
            y = mlp_forward(p["mlp"], h, cfg, rules)
        else:
            y, aux, dropped = moe_forward(p["moe"], h, cfg, rules)
        x = x + y
    if mode != "decode":
        # Decode streams are tiny (s=1): pinning their batch axis flips
        # XLA from activation-psum to FSDP weight gathers (§Perf log).
        x = constrain(x, rules, "batch", "seq", None)
    return x, new_cache, aux, dropped


def _encode(
    cfg: ModelConfig, rules: AxisRules, params: dict, frames: jax.Array
) -> jax.Array:
    """Whisper-style bidirectional encoder over (stubbed) frame embeddings."""
    enc = params["encoder"]
    b, s, d = frames.shape
    pos = jnp.arange(s)
    half = d // 2
    freq = 10000.0 ** (-jnp.arange(half, dtype=jnp.float32) / half)
    ang = pos[:, None].astype(jnp.float32) * freq
    pe = jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)
    x = frames + pe[None].astype(frames.dtype)
    spec = LayerSpec(mixer="attn", mlp="dense")

    def body(x, p):
        x, _, _, _ = _apply_layer(
            cfg, spec, rules, p, x,
            mode="train", positions=pos, cache=None, pos=None,
            cache_len=0, encoder_out=None, causal=False, use_rope=False,
        )
        return x, None

    x, _ = jax.lax.scan(body, x, enc["layers"])
    return norm(x, enc["final_norm"], cfg)


def forward(
    cfg: ModelConfig,
    rules: AxisRules,
    params: dict,
    tokens: jax.Array,
    *,
    mode: str = "train",
    cache: dict | None = None,
    pos: jax.Array | None = None,
    cache_len: int = 0,
    vision_embeds: jax.Array | None = None,
    encoder_frames: jax.Array | None = None,
    remat: bool = True,
    return_moe_stats: bool = False,
) -> tuple:
    """Run the model.

    Args:
      tokens: (b, s) int32 — s == 1 in decode mode.
      mode: "train" | "prefill" | "decode".
      cache/pos: decode state (cache from make_cache / a prior prefill).
        ``pos`` is a scalar i32 (every batch row at the same position) or
        a (b,) i32 vector giving each row its OWN position — one decode
        step serving rows at mixed progress (continuous batching).
      vision_embeds: (b, vision_prefix, d) precomputed patch embeddings
        (VLM frontend stub) — overwrite the first positions' embeddings.
      encoder_frames: (b, encoder_seq, d) precomputed audio-frame embeddings
        (audio frontend stub) for encoder-decoder configs.
      return_moe_stats: append a routing-stats dict to the return tuple —
        currently ``{"dropped_frac": mean fraction of (token, choice)
        assignments zeroed by the MoE capacity bound, averaged over MoE
        layers}``.  Kept opt-in so the default 3-tuple stays stable.
    Returns:
      (logits, new_cache | None, aux_loss[, moe_stats])
    """
    b, s = tokens.shape
    d = cfg.d_model
    x = jnp.take(params["embed"], tokens, axis=0)
    if cfg.embed_scale:
        x = (x.astype(jnp.float32) * math.sqrt(d)).astype(x.dtype)
    if not cfg.use_rope:
        # Sinusoidal absolute positions (whisper-style backbone).  Decode
        # ``pos`` may be a scalar (whole batch at one position) or a (b,)
        # per-row vector (mixed-progress batched decode): p_idx is kept
        # 2-D (rows, s) with rows in {1, b} so pe broadcasts either way.
        if mode == "decode":
            p = jnp.asarray(pos)
            p_idx = (p.reshape(1, 1) if p.ndim == 0 else p[:, None])
        else:
            p_idx = jnp.arange(s)[None]
        p_idx = p_idx.astype(jnp.float32)
        half = d // 2
        freq = 10000.0 ** (-jnp.arange(half, dtype=jnp.float32) / half)
        ang = p_idx[..., None] * freq
        pe = jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)
        x = x + pe.astype(x.dtype)
    if vision_embeds is not None and mode != "decode":
        nv = vision_embeds.shape[1]
        x = jnp.concatenate([vision_embeds.astype(x.dtype), x[:, nv:]], axis=1)
    if mode != "decode":
        x = constrain(x, rules, "batch", "seq", None)

    encoder_out = None
    if cfg.encoder_decoder:
        if mode == "decode":
            assert cache is not None
            encoder_out = cache["encoder_out"]
        else:
            assert encoder_frames is not None
            encoder_out = _encode(cfg, rules, params, encoder_frames)

    positions = None if mode == "decode" else jnp.arange(s)
    aux_total = jnp.zeros((), jnp.float32)
    dropped_total = jnp.zeros((), jnp.float32)
    new_cache: dict[str, Any] = {}

    n_pos = len(cfg.pattern)

    def group_body(carry, xs):
        x, aux, dropped = carry
        p_slices, c_slices = xs
        new_c = []
        for i in range(n_pos):
            x, nc, aux_i, dropped_i = _apply_layer(
                cfg, cfg.pattern[i], rules, p_slices[i], x,
                mode=mode, positions=positions,
                cache=c_slices[i] if c_slices is not None else None,
                pos=pos, cache_len=cache_len, encoder_out=encoder_out,
                use_rope=cfg.use_rope,
            )
            new_c.append(nc)
            aux = aux + aux_i
            dropped = dropped + dropped_i
        ys = tuple(new_c) if mode != "train" else None
        return (x, aux, dropped), ys

    if remat and mode == "train":
        group_body = jax.checkpoint(
            group_body, policy=jax.checkpoint_policies.nothing_saveable
        )

    p_stacked = tuple(params[f"pos{i}"] for i in range(n_pos))
    c_stacked = (
        tuple(cache[f"pos{i}"] for i in range(n_pos))
        if mode == "decode" else None
    )
    (x, aux_total, dropped_total), ys = jax.lax.scan(
        group_body, (x, aux_total, dropped_total), (p_stacked, c_stacked)
    )
    if ys is not None:
        for i in range(n_pos):
            new_cache[f"pos{i}"] = ys[i]
        if cfg.encoder_decoder:
            new_cache["encoder_out"] = encoder_out

    x = norm(x, params["final_norm"], cfg)
    head = (
        params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    )
    logits = jnp.einsum("bsd,dv->bsv", x, head)
    logits = constrain(logits, rules, "batch", None, "vocab")
    if cfg.logit_softcap is not None:
        lf = logits.astype(jnp.float32)
        logits = (jnp.tanh(lf / cfg.logit_softcap) * cfg.logit_softcap).astype(
            logits.dtype
        )
    if cfg.vocab_padded != cfg.vocab:
        # Mask the padding columns so softmax/argmax never see them.
        col = jax.lax.broadcasted_iota(
            jnp.int32, logits.shape, logits.ndim - 1
        )
        logits = jnp.where(col < cfg.vocab, logits, -1e30)
    ret = (logits, new_cache or None, aux_total / max(cfg.n_layers, 1))
    if return_moe_stats:
        n_moe = cfg.n_groups * sum(
            1 for spec in cfg.pattern if spec.mlp == "moe"
        )
        ret += ({"dropped_frac": dropped_total / max(n_moe, 1)},)
    return ret


def loss_fn(
    cfg: ModelConfig,
    rules: AxisRules,
    params: dict,
    tokens: jax.Array,
    labels: jax.Array,
    *,
    aux_weight: float = 0.01,
    **fwd_kwargs,
) -> tuple[jax.Array, dict]:
    """Mean next-token cross entropy (+ weighted MoE aux loss).

    Metrics carry ``dropped_frac`` next to the aux loss: the capacity bound
    zeroes over-capacity expert assignments SILENTLY in the forward pass,
    so the drop rate must be observable wherever the loss is.
    """
    logits, _, aux, moe_stats = forward(
        cfg, rules, params, tokens, mode="train", return_moe_stats=True,
        **fwd_kwargs
    )
    lf = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(lf, axis=-1)
    ll = jnp.take_along_axis(lf, labels[..., None], axis=-1)[..., 0]
    xent = jnp.mean(lse - ll)
    total = xent + aux_weight * aux
    return total, {
        "xent": xent, "aux": aux,
        "dropped_frac": moe_stats["dropped_frac"],
    }
