"""falcon-mamba-7b [ssm]: attention-free Mamba-1.

64L d_model=4096 d_ff=0 vocab=65024, ssm_state=16, d_inner=8192.
Pure SSM -> decode state is O(1) in context length; long_500k runs.
[arXiv:2410.05355; unverified]
"""
from repro.models.config import LayerSpec, ModelConfig, SSMSpec

CONFIG = ModelConfig(
    name="falcon-mamba-7b",
    family="ssm",
    n_layers=64,
    d_model=4096,
    n_heads=1,       # unused (attention-free)
    n_kv_heads=1,
    d_ff=0,
    vocab=65024,
    pattern=(LayerSpec(mixer="mamba", mlp="none"),),
    ssm=SSMSpec(d_inner=8192, d_state=16, d_conv=4, dt_rank=256),
    norm="rmsnorm",
    act="swiglu",
    tie_embeddings=False,
    sub_quadratic=True,
)

SMOKE = ModelConfig(
    name="falcon-mamba-smoke",
    family="ssm",
    n_layers=4,
    d_model=64,
    n_heads=1,
    n_kv_heads=1,
    d_ff=0,
    vocab=512,
    pattern=(LayerSpec(mixer="mamba", mlp="none"),),
    ssm=SSMSpec(d_inner=128, d_state=8, d_conv=4, dt_rank=8),
    norm="rmsnorm",
    act="swiglu",
    tie_embeddings=False,
    sub_quadratic=True,
    scan_chunk=16,
)
