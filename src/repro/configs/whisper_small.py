"""whisper-small [audio]: enc-dec backbone; conv frontend STUBBED.

12L (decoder) + 12L encoder, d_model=768 12H d_ff=3072 vocab=51865.
``input_specs()`` supplies precomputed frame embeddings (b, 1500, d) for the
encoder per the assignment.  Sinusoidal positions (no RoPE), LayerNorm, GELU.
[arXiv:2212.04356; unverified]
"""
from repro.models.config import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="whisper-small",
    family="audio",
    n_layers=12,
    d_model=768,
    n_heads=12,
    n_kv_heads=12,
    d_ff=3072,
    vocab=51865,
    pattern=(LayerSpec(mixer="attn", mlp="dense", cross_attn=True),),
    norm="layernorm",
    act="gelu",
    use_rope=False,
    tie_embeddings=True,
    encoder_decoder=True,
    n_encoder_layers=12,
    encoder_seq=1500,
    sub_quadratic=False,
)

SMOKE = ModelConfig(
    name="whisper-smoke",
    family="audio",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=128,
    vocab=512,
    pattern=(LayerSpec(mixer="attn", mlp="dense", cross_attn=True),),
    norm="layernorm",
    act="gelu",
    use_rope=False,
    encoder_decoder=True,
    n_encoder_layers=2,
    encoder_seq=32,
    scan_chunk=16,
)
