"""granite-moe-1b-a400m [moe]: 32 experts top-8.

24L d_model=1024 16H (GQA kv=8) d_ff_expert=512 vocab=49155.
[hf:ibm-granite/granite-3.0-1b-a400m-base; hf]
"""
from repro.models.config import LayerSpec, MoESpec, ModelConfig

CONFIG = ModelConfig(
    name="granite-moe-1b-a400m",
    family="moe",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=8,
    d_ff=512,
    vocab=49155,
    pattern=(LayerSpec(mixer="attn", mlp="moe"),),
    moe=MoESpec(num_experts=32, top_k=8, d_ff_expert=512),
    norm="rmsnorm",
    act="swiglu",
    tie_embeddings=True,
    sub_quadratic=False,
)

SMOKE = ModelConfig(
    name="granite-smoke",
    family="moe",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=64,
    vocab=512,
    pattern=(LayerSpec(mixer="attn", mlp="moe"),),
    moe=MoESpec(num_experts=4, top_k=2, d_ff_expert=64),
    norm="rmsnorm",
    act="swiglu",
    scan_chunk=16,
)
