"""jamba-v0.1-52b [hybrid]: Mamba+attention 1:7 interleave + MoE 16e top-2.

32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=65536.  Each 8-layer Jamba
block has one attention layer (index 4) and seven Mamba layers; MoE replaces
the dense MLP on every other layer. [arXiv:2403.19887; hf]
"""
from repro.models.config import LayerSpec, MoESpec, ModelConfig, SSMSpec


def _jamba_pattern() -> tuple[LayerSpec, ...]:
    out = []
    for i in range(8):
        mixer = "attn" if i == 4 else "mamba"
        mlp = "moe" if i % 2 == 1 else "dense"
        out.append(LayerSpec(mixer=mixer, mlp=mlp))
    return tuple(out)


CONFIG = ModelConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab=65536,
    pattern=_jamba_pattern(),
    moe=MoESpec(num_experts=16, top_k=2, d_ff_expert=14336),
    ssm=SSMSpec(d_inner=8192, d_state=16, d_conv=4, dt_rank=256),
    norm="rmsnorm",
    act="swiglu",
    tie_embeddings=False,
    sub_quadratic=True,  # 1:7 attn:mamba -> cache grows only on 4/32 layers
    fsdp=True,           # 52B
)

SMOKE = ModelConfig(
    name="jamba-smoke",
    family="hybrid",
    n_layers=8,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=128,
    vocab=512,
    pattern=_jamba_pattern(),
    moe=MoESpec(num_experts=4, top_k=2, d_ff_expert=128),
    ssm=SSMSpec(d_inner=128, d_state=8, d_conv=4, dt_rank=8),
    norm="rmsnorm",
    act="swiglu",
    tie_embeddings=False,
    sub_quadratic=True,
    scan_chunk=16,
)
