"""The paper's own model-level evaluation target (GPT-2 class, ~124M).

The paper (§7.3) evaluates BERT/BERT-large/GPT-2 under dynamic sequence
lengths.  This config is the GPT-2-small-scale decoder we use for the
end-to-end training example (examples/train_lm.py, ~100M params) and the
dynamic-shape model benchmark (benchmarks/bench_models.py).  RoPE replaces
learned positions (TPU-idiomatic adaptation, noted in DESIGN.md).
"""
from repro.models.config import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="paper-gpt2-124m",
    family="dense",
    n_layers=12,
    d_model=768,
    n_heads=12,
    n_kv_heads=12,
    d_ff=3072,
    vocab=50257,
    pattern=(LayerSpec(mixer="attn", mlp="dense"),),
    norm="layernorm",
    act="gelu",
    tie_embeddings=True,
    sub_quadratic=False,
)

SMOKE = ModelConfig(
    name="paper-gpt2-smoke",
    family="dense",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=128,
    vocab=512,
    pattern=(LayerSpec(mixer="attn", mlp="dense"),),
    norm="layernorm",
    act="gelu",
    scan_chunk=16,
)
