"""Architecture configs — one module per assigned architecture.

Every module exports ``CONFIG`` (the exact assigned config) and ``SMOKE``
(a reduced same-family config for CPU smoke tests).  ``repro.models.registry``
maps ``--arch <id>`` to these.
"""
