"""h2o-danube-3-4b [dense]: llama+mistral mix with sliding-window attention.

24L d_model=3840 32H (GQA kv=8) d_ff=10240 vocab=32000, SWA window 4096.
SWA makes decode memory/compute bounded by the window -> eligible for
long_500k. [arXiv:2401.16818; unverified]
"""
from repro.models.config import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="h2o-danube-3-4b",
    family="dense",
    n_layers=24,
    d_model=3840,
    n_heads=32,
    n_kv_heads=8,
    d_ff=10240,
    vocab=32000,
    pattern=(LayerSpec(mixer="attn", mlp="dense", window=4096),),
    norm="rmsnorm",
    act="swiglu",
    tie_embeddings=False,
    sub_quadratic=True,  # sliding-window attention
)

SMOKE = ModelConfig(
    name="danube-smoke",
    family="dense",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=128,
    vocab=512,
    pattern=(LayerSpec(mixer="attn", mlp="dense", window=16),),
    norm="rmsnorm",
    act="swiglu",
    tie_embeddings=False,
    sub_quadratic=True,
    scan_chunk=16,
)
