"""phi4-mini-3.8b [dense]: RoPE + SwiGLU + GQA.

32L d_model=3072 24H (GQA kv=8) d_ff=8192 vocab=200064.
[arXiv:2412.08905; hf]
"""
from repro.models.config import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="phi4-mini-3.8b",
    family="dense",
    n_layers=32,
    d_model=3072,
    n_heads=24,
    n_kv_heads=8,
    d_ff=8192,
    vocab=200064,
    pattern=(LayerSpec(mixer="attn", mlp="dense"),),
    norm="rmsnorm",
    act="swiglu",
    tie_embeddings=True,
    sub_quadratic=False,
)

SMOKE = ModelConfig(
    name="phi4-smoke",
    family="dense",
    n_layers=2,
    d_model=48,
    n_heads=6,          # 24H -> 6H keeps the non-16-divisible head count
    n_kv_heads=2,
    d_ff=96,
    vocab=512,
    pattern=(LayerSpec(mixer="attn", mlp="dense"),),
    norm="rmsnorm",
    act="swiglu",
    scan_chunk=16,
)
