"""gemma2-9b [dense]: local+global alternating attention, logit softcaps.

42L d_model=3584 16H (GQA kv=8) d_ff=14336 vocab=256000, head_dim 256,
GeGLU, RMSNorm, sqrt(d) embedding scaling, attn softcap 50, final softcap 30,
local layers use a 4096 sliding window. [arXiv:2408.00118; hf]
"""
from repro.models.config import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="gemma2-9b",
    family="dense",
    n_layers=42,
    d_model=3584,
    n_heads=16,
    n_kv_heads=8,
    head_dim=256,
    d_ff=14336,
    vocab=256000,
    pattern=(
        LayerSpec(mixer="attn", mlp="dense", window=4096),  # local
        LayerSpec(mixer="attn", mlp="dense", window=None),  # global
    ),
    norm="rmsnorm",
    act="geglu",
    attn_softcap=50.0,
    logit_softcap=30.0,
    embed_scale=True,
    tie_embeddings=True,
    # Global layers are full-context -> NOT eligible for long_500k.
    sub_quadratic=False,
)

SMOKE = ModelConfig(
    name="gemma2-smoke",
    family="dense",
    n_layers=4,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab=512,
    pattern=(
        LayerSpec(mixer="attn", mlp="dense", window=16),
        LayerSpec(mixer="attn", mlp="dense", window=None),
    ),
    norm="rmsnorm",
    act="geglu",
    attn_softcap=50.0,
    logit_softcap=30.0,
    embed_scale=True,
    scan_chunk=16,
)
