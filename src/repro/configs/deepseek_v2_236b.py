"""deepseek-v2-236b [moe]: MLA (kv_lora=512) + 2 shared + 160 routed top-6.

60L d_model=5120 128H d_ff_expert=1536 vocab=102400.
[arXiv:2405.04434; hf]
"""
from repro.models.config import LayerSpec, MLASpec, MoESpec, ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-236b",
    family="moe",
    n_layers=60,
    d_model=5120,
    n_heads=128,
    n_kv_heads=128,  # MLA: per-head latent KV (cache stores the 512-d latent)
    d_ff=1536,
    vocab=102400,
    pattern=(LayerSpec(mixer="mla", mlp="moe"),),
    moe=MoESpec(num_experts=160, top_k=6, d_ff_expert=1536, num_shared=2),
    mla=MLASpec(
        kv_lora_rank=512, q_lora_rank=1536,
        qk_nope_dim=128, qk_rope_dim=64, v_head_dim=128,
    ),
    norm="rmsnorm",
    act="swiglu",
    tie_embeddings=False,
    sub_quadratic=False,  # MLA compresses the cache; attention is full-context
    fsdp=True,            # 236B: FSDP over 'data' mandatory to fit 16 GB/chip
)

SMOKE = ModelConfig(
    name="deepseek-smoke",
    family="moe",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=64,
    vocab=512,
    pattern=(LayerSpec(mixer="mla", mlp="moe"),),
    moe=MoESpec(num_experts=8, top_k=2, d_ff_expert=64, num_shared=1),
    mla=MLASpec(
        kv_lora_rank=32, q_lora_rank=48,
        qk_nope_dim=16, qk_rope_dim=8, v_head_dim=16,
    ),
    norm="rmsnorm",
    act="swiglu",
    tie_embeddings=False,
    scan_chunk=16,
)
