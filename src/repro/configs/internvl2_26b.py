"""internvl2-26b [vlm]: InternViT frontend (STUB) + InternLM2-20B backbone.

48L d_model=6144 48H (GQA kv=8) d_ff=16384 vocab=92553.  The ViT frontend
is stubbed per the assignment: ``input_specs()`` supplies precomputed patch
embeddings (vision_prefix positions). [arXiv:2404.16821; hf]
"""
from repro.models.config import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="internvl2-26b",
    family="vlm",
    n_layers=48,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=16384,
    vocab=92553,
    pattern=(LayerSpec(mixer="attn", mlp="dense"),),
    norm="rmsnorm",
    act="swiglu",
    tie_embeddings=False,
    vision_prefix=256,  # patch embeddings prepended (frontend stub)
    sub_quadratic=False,
    fsdp=True,  # 26B
)

SMOKE = ModelConfig(
    name="internvl2-smoke",
    family="vlm",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=128,
    vocab=512,
    pattern=(LayerSpec(mixer="attn", mlp="dense"),),
    norm="rmsnorm",
    act="swiglu",
    tie_embeddings=False,
    vision_prefix=8,
    scan_chunk=16,
)
