"""starcoder2-15b [dense]: GQA + RoPE, LayerNorm, plain-GELU MLP.

40L d_model=6144 48H (GQA kv=4) d_ff=24576 vocab=49152.
[arXiv:2402.19173; hf]
"""
from repro.models.config import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-15b",
    family="dense",
    n_layers=40,
    d_model=6144,
    n_heads=48,
    n_kv_heads=4,
    d_ff=24576,
    vocab=49152,
    pattern=(LayerSpec(mixer="attn", mlp="dense"),),
    norm="layernorm",
    act="gelu",
    tie_embeddings=True,
    sub_quadratic=False,
    fsdp=True,  # 15B: shard params+opt over 'data' to keep HBM headroom
)

SMOKE = ModelConfig(
    name="starcoder2-smoke",
    family="dense",
    n_layers=2,
    d_model=64,
    n_heads=8,
    n_kv_heads=2,
    d_ff=256,
    vocab=512,
    pattern=(LayerSpec(mixer="attn", mlp="dense"),),
    norm="layernorm",
    act="gelu",
    scan_chunk=16,
)
