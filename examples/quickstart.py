"""Quickstart: the Vortex sample-free workflow on one dynamic-shape GEMM.

    PYTHONPATH=src python examples/quickstart.py

Walks the paper's pipeline end to end:
  1. offline  — hardware-aware candidate lattice (no shape samples),
  2. offline  — hybrid analyzer scores the lattice,
  3. runtime  — per-shape strategy selection + bucketed execution,
and prints what the paper's figures report: candidate counts, offline
seconds, selection overhead, padding waste.
"""
import time

import jax.numpy as jnp
import numpy as np

from repro.core import (
    GemmWorkload,
    HOST_CPU,
    TPU_V5E,
    VortexGemm,
)
from repro.core.candidates import generate_lattice


def main() -> None:
    # The BERT GEMM of the paper's §2.2 experiment: M dynamic, N/K fixed.
    wl = GemmWorkload(M=None, N=768, K=2304)

    print("== offline: strategy space hierarchization (TPU v5e target) ==")
    lat = generate_lattice(TPU_V5E, wl, "mxu")
    print(f" level-0 (MXU tile) candidates : {len(lat.l0)}")
    print(f" level-1 (VMEM tile) candidates: {len(lat.l1)}")
    print(f" total (paper reports 392 for the tensor-core space): "
          f"{lat.num_candidates()}")

    print("\n== offline: build the full engine on the host CPU ==")
    t0 = time.perf_counter()
    eng = VortexGemm(HOST_CPU, wl)
    print(f" offline stage: {time.perf_counter() - t0:.2f}s "
          f"({eng.offline_stats.num_measured} tiles profiled; "
          f"sample-driven tuning would need hours)")

    print("\n== runtime: dynamic shapes, sample-free ==")
    rng = np.random.default_rng(0)
    b = jnp.asarray(rng.normal(size=(wl.K, wl.N)), jnp.float32)
    for m in (5, 62, 128, 200, 381):
        a = jnp.asarray(rng.normal(size=(m, wl.K)), jnp.float32)
        sel = eng.select(m)
        out = eng(a, b)
        ref = np.asarray(a) @ np.asarray(b)
        err = float(np.max(np.abs(np.asarray(out) - ref)))
        print(
            f" M={m:4d} -> bucket {sel.padded_m:4d} "
            f"(tile {sel.strategy.l1}, backend {sel.backend}, "
            f"select {sel.select_seconds * 1e6:.0f}us, max|err|={err:.1e})"
        )
    print(f"\n executable cache entries: {eng.cache_info['entries']} "
          f"(bounded by the lattice, not by #distinct shapes)")


if __name__ == "__main__":
    main()
