"""Quickstart: the Vortex sample-free workflow through the public API.

    PYTHONPATH=src python examples/quickstart.py

Walks the paper's pipeline end to end:
  1. offline  — hardware-aware candidate lattice (no shape samples),
  2. offline  — hybrid analyzer scores the lattice,
  3. runtime  — per-shape strategy selection + bucketed execution,
and prints what the paper's figures report: candidate counts, offline
seconds, selection overhead, padding waste.  Everything goes through
`repro.vortex` — ONE surface (DESIGN.md § Public API):

  * `vortex.compile(workload)` -> a CompiledOp handle (call / select /
    precompile / stats),
  * `vortex.ops.<kind>` — every `@register_workload` kind, served by the
    ambient engine session,
  * `vortex.use(engine)` — contextvar-scoped session installation.
"""
import time

import jax.numpy as jnp
import numpy as np

from repro.core import AttentionWorkload, GemmWorkload, TPU_V5E
from repro.core.candidates import generate_lattice
from repro.kernels.ref import ref_attention, ref_conv2d
from repro import vortex
from repro.vortex import Engine, EngineConfig


def main() -> None:
    # The BERT GEMM of the paper's §2.2 experiment: M dynamic, N/K fixed.
    wl = GemmWorkload(M=None, N=768, K=2304)

    print("== offline: strategy space hierarchization (TPU v5e target) ==")
    lat = generate_lattice(TPU_V5E, wl, "mxu")
    print(f" level-0 (MXU tile) candidates : {len(lat.l0)}")
    print(f" level-1 (VMEM tile) candidates: {len(lat.l1)}")
    print(f" total (paper reports 392 for the tensor-core space): "
          f"{lat.num_candidates()}")
    alat = generate_lattice(
        TPU_V5E, AttentionWorkload(seq=None, head_dim=64), "mxu"
    )
    print(f" attention (seq-dynamic) lattice: {alat.num_candidates()} "
          f"candidates through the same Algorithm 2")

    print("\n== offline: an engine session on the host CPU ==")
    t0 = time.perf_counter()
    eng = Engine(EngineConfig(hardware="host_cpu"))
    gemm = vortex.compile(wl, engine=eng)
    table = gemm.kernel.selector.table  # materialize the table offline
    print(f" offline stage: {time.perf_counter() - t0:.2f}s "
          f"({gemm.stats()['offline'].num_measured} tiles profiled, "
          f"{len(table)}-entry selection table swept; "
          f"sample-driven tuning would need hours)")

    print("\n== runtime: dynamic GEMM shapes, sample-free ==")
    rng = np.random.default_rng(0)
    b = jnp.asarray(rng.normal(size=(wl.K, wl.N)), jnp.float32)
    with vortex.use(eng):
        for m in (5, 62, 128, 200, 381):
            a = jnp.asarray(rng.normal(size=(m, wl.K)), jnp.float32)
            t_sel = time.perf_counter()
            sel = gemm.select(m)
            sel_us = (time.perf_counter() - t_sel) * 1e6
            path = "table" if sel.select_seconds == 0.0 else "argmin"
            out = vortex.ops.gemm(a, b)
            ref = np.asarray(a) @ np.asarray(b)
            err = float(np.max(np.abs(np.asarray(out) - ref)))
            print(
                f" M={m:4d} -> bucket {sel.padded_m:4d} "
                f"(tile {sel.strategy.l1}, backend {sel.backend}, "
                f"select {sel_us:.1f}us via {path}, max|err|={err:.1e})"
            )

        print("\n== runtime: attention + conv through the same session ==")
        for s in (33, 67, 127):
            q = jnp.asarray(rng.normal(size=(1, 4, s, 64)), jnp.float32)
            k = jnp.asarray(rng.normal(size=(1, 2, s, 64)), jnp.float32)
            v = jnp.asarray(rng.normal(size=(1, 2, s, 64)), jnp.float32)
            out = vortex.ops.attention(q, k, v)
            err = float(np.max(np.abs(
                np.asarray(out)
                - np.asarray(ref_attention(q, k, v, causal=True))
            )))
            print(f" attention seq={s:4d} -> max|err|={err:.1e}")
        for bsz in (1, 3):
            x = jnp.asarray(rng.normal(size=(bsz, 14, 14, 8)), jnp.float32)
            w = jnp.asarray(rng.normal(size=(3, 3, 8, 16)), jnp.float32)
            out = vortex.ops.conv2d(x, w)
            err = float(np.max(np.abs(np.asarray(out) - np.asarray(
                ref_conv2d(x, w, stride=1, padding="VALID")
            ))))
            print(f" conv2d batch={bsz} -> max|err|={err:.1e}")

    print("\n== engine stats (one cache hierarchy across workloads) ==")
    for kind, s in eng.stats().items():
        if kind == "calibration":  # engine-level section, not a workload
            continue
        print(
            f" {kind:9s}: {s['signatures']} signature(s), "
            f"{s['selects']} selects ({s['select_cache_hits']} cached), "
            f"{s['exec_entries']} executables for {s['exec_hits']} calls"
        )


if __name__ == "__main__":
    main()
