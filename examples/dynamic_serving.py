"""Dynamic-shape LM serving with Vortex bucketing.

    PYTHONPATH=src python examples/dynamic_serving.py

A stream of requests with random batch sizes and prompt lengths (the
paper's dynamic-shape serving scenario).  Without bucketing, every distinct
(batch, prompt) shape would force an XLA recompile; the Vortex lattice maps
the stream onto a small bucket set.  The same driver also reports the
off-bucket padding waste, which the lattice bounds by construction.
"""
import sys
import time

sys.path.insert(0, "src")

import numpy as np

from repro.launch.mesh import make_host_mesh
from repro.launch.serve import Request, VortexServer
from repro.models.registry import get_smoke_config


def main() -> None:
    cfg = get_smoke_config("paper-gpt2-124m")
    server = VortexServer(cfg, make_host_mesh(), max_cache=256)
    rng = np.random.default_rng(7)

    n_requests, total_pad = 24, 0.0
    t0 = time.perf_counter()
    for i in range(n_requests):
        b = int(rng.integers(1, 9))
        s = int(rng.integers(4, 120))
        toks = rng.integers(0, cfg.vocab, (b, s)).astype(np.int32)
        out = server.generate(Request(tokens=toks, max_new=4))
        bp = server.batch_bucket(b)
        sp = server.seq_bucket(s)
        total_pad += (bp * sp) / (b * s) - 1.0
        print(f"req {i:2d}: ({b:2d},{s:3d}) -> bucket ({bp:2d},{sp:3d}) "
              f"out {out.shape}")
    dt = time.perf_counter() - t0
    print(
        f"\n{n_requests} dynamic requests in {dt:.1f}s — "
        f"{server.stats['prefill_compiles']} compiled buckets, "
        f"{server.stats['bucket_hits']} bucket hits, "
        f"avg padding overhead {total_pad / n_requests:.1%}"
    )
    print("A sample-driven system tuned for one shape list would pay either "
          "a recompile or an off-sample penalty for most of these.")


if __name__ == "__main__":
    main()
