"""End-to-end driver: train the ~124M-param GPT-2-class model.

    PYTHONPATH=src python examples/train_lm.py --steps 300

Full production plumbing on the host mesh: schema-derived sharded params,
microbatched+remat'd train step, warmup+cosine LR, deterministic data
pipeline with prefetch, async atomic checkpointing, supervisor restart, and
straggler monitoring.  ``--fail-at N`` injects a simulated node failure to
demonstrate recovery.  On a TPU pod, switch ``--mesh prod``.

(~124M params is heavy for one CPU: expect a few seconds per step at the
default batch/seq. Use --smoke for a quick sanity run.)
"""
import argparse
import sys
import time

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp

from repro.checkpoint.manager import CheckpointManager
from repro.data.pipeline import Prefetcher, SyntheticLMDataset
from repro.launch.mesh import make_host_mesh
from repro.launch.train import build_trainer
from repro.models.params import count_params
from repro.models.registry import get_config, get_smoke_config
from repro.runtime.heartbeat import StepMonitor
from repro.runtime.supervisor import SimulatedFailure, Supervisor
from repro.train.step import TrainHParams


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--microbatches", type=int, default=2)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--fail-at", type=int, default=0)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    cfg = (
        get_smoke_config("paper-gpt2-124m") if args.smoke
        else get_config("paper-gpt2-124m")
    )
    mesh = make_host_mesh()
    hp = TrainHParams(
        base_lr=6e-4, warmup_steps=20, total_steps=args.steps,
        num_microbatches=args.microbatches,
    )
    print(f"model={cfg.name} params={count_params(cfg):,}")
    params, opt, step_fn = build_trainer(
        cfg, mesh, batch=args.batch, seq=args.seq, hp=hp
    )
    data = SyntheticLMDataset(cfg.vocab, args.seq, args.batch)
    prefetch = Prefetcher(data.iter_from(0), depth=2)
    ckpt = CheckpointManager(args.ckpt_dir, keep_n=2)
    sup = Supervisor(ckpt, ckpt_every=50)
    mon = StepMonitor()

    def failure_hook(step):
        if args.fail_at and step == args.fail_at:
            args.fail_at = 0  # only once
            raise SimulatedFailure(f"injected node failure at step {step}")

    state = {"params": params, "opt": opt}

    def one_step(state, step):
        t0 = time.perf_counter()
        batch = {k: jnp.asarray(v) for k, v in data.batch_at(step).items()}
        p, o, metrics = step_fn(state["params"], state["opt"], batch)
        dt = time.perf_counter() - t0
        mon.record(0, step, dt)
        if step % 10 == 0:
            print(f"step {step:4d}  loss {float(metrics['loss']):.4f}  "
                  f"lr {float(metrics['lr']):.2e}  {dt:.2f}s/step",
                  flush=True)
        return {"params": p, "opt": o}

    t0 = time.perf_counter()
    state = sup.run(
        state, one_step, num_steps=args.steps, failure_hook=failure_hook
    )
    prefetch.close()
    print(
        f"\ntrained {sup.stats.steps_run} steps in "
        f"{time.perf_counter() - t0:.0f}s  "
        f"(failures={sup.stats.failures}, restores={sup.stats.restores})"
    )


if __name__ == "__main__":
    main()
